"""Kernel-vs-reference correctness: the core numeric signal of the repo.

Hypothesis sweeps shapes / strides / channel counts; every Pallas kernel
output must match the pure-jnp oracle bit-exactly (integer arithmetic — no
tolerance needed or allowed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_aitb as K
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def _rand_int8(rng: np.random.Generator, shape) -> jnp.ndarray:
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int64).astype(np.int8))


def _rand_w(rng: np.random.Generator, shape) -> jnp.ndarray:
    # weight range [-64, 63] like the deployed models (accumulator headroom)
    return jnp.asarray(rng.integers(-64, 64, size=shape, dtype=np.int64).astype(np.int8))


# ---------------------------------------------------------------- conv2d

@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(5, 20),
    w=st.integers(5, 20),
    cin=st.sampled_from([1, 3, 8, 16]),
    cout=st.sampled_from([1, 4, 16, 32]),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    shift=st.sampled_from([0, 4, 7]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(h, w, cin, cout, k, stride, shift, relu, seed):
    pad = k // 2
    rng = np.random.default_rng(seed)
    x = _rand_int8(rng, (h, w, cin))
    wt = _rand_w(rng, (k, k, cin, cout))
    got = K.conv2d(x, wt, stride=stride, pad=pad, shift=shift, relu=relu)
    want = R.requantize(R.conv2d_int32(x, wt, stride, pad), shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv2d_identity_kernel():
    """1x1 conv with the identity matrix reproduces the input (shift=0)."""
    rng = np.random.default_rng(0)
    x = _rand_int8(rng, (6, 6, 4))
    w = jnp.eye(4, dtype=jnp.int8)[None, None]
    got = K.conv2d(x, w, stride=1, pad=0, shift=0, relu=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_conv2d_unpadded_valid():
    rng = np.random.default_rng(1)
    x = _rand_int8(rng, (9, 9, 3))
    w = _rand_w(rng, (3, 3, 3, 8))
    got = K.conv2d(x, w, stride=1, pad=0, shift=5, relu=True)
    want = R.requantize(R.conv2d_int32(x, w, 1, 0), 5, True)
    assert got.shape == (7, 7, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv2d_big_channels_blocked():
    """Channel count larger than the block target exercises the grid."""
    rng = np.random.default_rng(2)
    x = _rand_int8(rng, (8, 8, 32))
    w = _rand_w(rng, (3, 3, 32, 96))
    got = K.conv2d(x, w, stride=1, pad=1, shift=7, relu=True, block_cout=32)
    want = R.requantize(R.conv2d_int32(x, w, 1, 1), 7, True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv2d_stride2_odd_input():
    rng = np.random.default_rng(3)
    x = _rand_int8(rng, (11, 11, 3))
    w = _rand_w(rng, (3, 3, 3, 16))
    got = K.conv2d(x, w, stride=2, pad=1, shift=6, relu=False)
    want = R.requantize(R.conv2d_int32(x, w, 2, 1), 6, False)
    assert got.shape == want.shape == (6, 6, 16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------- depthwise

@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(5, 16),
    w=st.integers(5, 16),
    c=st.sampled_from([1, 4, 16, 32]),
    k=st.sampled_from([3, 5]),
    stride=st.sampled_from([1, 2]),
    shift=st.sampled_from([0, 6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_matches_ref(h, w, c, k, stride, shift, seed):
    pad = k // 2
    rng = np.random.default_rng(seed)
    x = _rand_int8(rng, (h, w, c))
    wt = _rand_w(rng, (k, k, c))
    got = K.depthwise_conv2d(x, wt, stride=stride, pad=pad, shift=shift, relu=True)
    want = R.requantize(R.depthwise_conv2d_int32(x, wt, stride, pad), shift, True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_depthwise_channel_blocking():
    rng = np.random.default_rng(4)
    x = _rand_int8(rng, (10, 10, 48))
    w = _rand_w(rng, (3, 3, 48))
    got = K.depthwise_conv2d(x, w, stride=1, pad=1, shift=5, relu=False, block_c=16)
    want = R.requantize(R.depthwise_conv2d_int32(x, w, 1, 1), 5, False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------------ fc

@settings(max_examples=20, deadline=None)
@given(
    cin=st.sampled_from([8, 64, 130]),
    cout=st.sampled_from([10, 100, 256]),
    shift=st.sampled_from([0, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fc_matches_ref(cin, cout, shift, seed):
    rng = np.random.default_rng(seed)
    x = _rand_int8(rng, (cin,))
    w = _rand_w(rng, (cin, cout))
    got = K.fc(x, w, shift=shift, relu=False)
    want = R.requantize(R.fc_int32(x, w)[None, None], shift, False)[0, 0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------- requantize

@pytest.mark.parametrize(
    "acc,shift,relu,want",
    [
        (1000, 3, False, 125),  # 1000/8 = 125
        (1000, 0, False, 127),  # saturate
        (-1000, 3, False, -125),
        (-1000, 3, True, 0),  # relu clamps negatives
        (20, 3, False, 3),  # 20/8 = 2.5 -> round half away = 3
        (-20, 3, False, -3),
        (12, 3, False, 2),  # 12/8 = 1.5 -> 2 (half away from zero)
        (4, 3, False, 1),  # 4/8 = 0.5 -> 1
        (-4, 3, False, 0),  # -4/8 = -0.5 -> -0 (bias (1<<2)-1=3: (-4+3)>>3 = -1>>3 = -1? )
    ],
)
def test_requantize_cases(acc, shift, relu, want):
    got = int(R.requantize(jnp.asarray([acc], jnp.int32), shift, relu)[0])
    if acc == -4:
        # document the exact hardware rounding: (-4 + 3) >> 3 == -1 (arith
        # shift rounds toward -inf), i.e. half rounds away from zero for
        # negatives as well.
        assert got == -1
    else:
        assert got == want


def test_requantize_range_is_int8():
    accs = jnp.arange(-(2**20), 2**20, 997, dtype=jnp.int32)
    out = np.asarray(R.requantize(accs, 5, False))
    assert out.dtype == np.int8
    assert out.min() >= -128 and out.max() <= 127
