"""L2 model tests: Pallas-path forward equals reference-path forward,
shapes are as declared, and parameters are deterministic."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _img(seed: int, shape=(32, 32, 3)) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int64).astype(np.int8))


def test_cifarnet_pallas_matches_ref():
    pallas_fn = model.cifarnet_fn(seed=0)
    ref_fn = model.cifarnet_ref_fn(seed=0)
    for s in range(3):
        img = _img(s)
        got = np.asarray(pallas_fn(img)[0])
        want = np.asarray(ref_fn(img)[0])
        np.testing.assert_array_equal(got, want)


def test_cifarnet_output_shape_and_dtype():
    out = model.cifarnet_fn()(_img(0))[0]
    assert out.shape == (10,)
    # int32 at the artifact boundary (the xla crate has no i8 literals);
    # values are int8-ranged.
    assert out.dtype == jnp.int32
    assert int(jnp.max(jnp.abs(out))) <= 128


def test_cifarnet_depends_on_input():
    fn = model.cifarnet_fn()
    a = np.asarray(fn(_img(1))[0])
    b = np.asarray(fn(_img(2))[0])
    assert not np.array_equal(a, b)


def test_params_deterministic_per_seed():
    p0 = model.init_params(model.CIFARNET, 3, seed=0)
    p0b = model.init_params(model.CIFARNET, 3, seed=0)
    p1 = model.init_params(model.CIFARNET, 3, seed=1)
    for k in p0:
        np.testing.assert_array_equal(np.asarray(p0[k]), np.asarray(p0b[k]))
    assert any(not np.array_equal(np.asarray(p0[k]), np.asarray(p1[k])) for k in p0)


def test_param_shapes():
    p = model.init_params(model.CIFARNET, 3)
    assert p["conv1"].shape == (3, 3, 3, 32)
    assert p["conv2"].shape == (3, 3, 32, 64)
    assert p["dw3"].shape == (3, 3, 64)
    assert p["conv4"].shape == (3, 3, 64, 128)
    assert p["fc"].shape == (128, 10)


def test_resnet_block_pallas_matches_ref():
    pallas_fn = model.resnet_block_fn(seed=0)
    ref_fn = model.resnet_block_ref_fn(seed=0)
    x = _img(7, shape=(model.RESNET_BLOCK_HW, model.RESNET_BLOCK_HW, model.RESNET_BLOCK_C))
    got = np.asarray(pallas_fn(x)[0])
    want = np.asarray(ref_fn(x)[0])
    np.testing.assert_array_equal(got, want)
    assert got.shape == (56, 56, 64)


def test_resnet_block_residual_identity():
    """Zero weights -> output is relu(clip(x)) == relu(x)."""
    x = _img(9, shape=(model.RESNET_BLOCK_HW, model.RESNET_BLOCK_HW, model.RESNET_BLOCK_C))

    # Build the block by hand with zero weights through the kernels.
    from compile.kernels import conv_aitb as K

    w0 = jnp.zeros((3, 3, 64, 64), jnp.int8)
    y = K.conv2d(x, w0, stride=1, pad=1, shift=7, relu=True)
    y = K.conv2d(y, w0, stride=1, pad=1, shift=7, relu=False)
    out = jnp.maximum(
        jnp.clip(y.astype(jnp.int32) + x.astype(jnp.int32), -128, 127).astype(jnp.int8), 0
    )
    np.testing.assert_array_equal(np.asarray(out), np.maximum(np.asarray(x), 0))
