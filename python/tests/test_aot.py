"""AOT path tests: lowering produces loadable HLO text and the manifest
is consistent. (The rust side re-validates by compiling + executing the
artifacts in its integration suite.)"""

from __future__ import annotations

import json
import pathlib

import jax

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_lower_all_exports_produce_hlo_text():
    for name in model.EXPORTS:
        text, entry = aot.lower_export(name)
        # HLO text module header and an entry computation must be present
        assert text.startswith("HloModule"), f"{name}: {text[:40]!r}"
        assert "ENTRY" in text
        assert entry["bytes"] == len(text)
        assert len(entry["sha256"]) == 64


def test_lowering_is_deterministic():
    a, ea = aot.lower_export("cifarnet")
    b, eb = aot.lower_export("cifarnet")
    assert a == b
    assert ea["sha256"] == eb["sha256"]


def test_exports_declare_int32_boundary():
    for name, (_, (shape, dtype)) in model.EXPORTS.items():
        assert dtype == "int32", f"{name}: runtime literals require int32"
        assert all(d > 0 for d in shape)


def test_manifest_matches_artifacts_if_built():
    art = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    manifest = art / "manifest.json"
    if not manifest.exists():
        return  # artifacts not built in this checkout
    entries = json.loads(manifest.read_text())
    for name, e in entries.items():
        path = art / f"{name}.hlo.txt"
        assert path.exists(), f"{name} listed in manifest but missing"
        assert path.stat().st_size == e["bytes"]
