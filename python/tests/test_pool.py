"""Pallas pooling kernels vs the jnp oracles (bit-exact)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import pool as P
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def _rand_int8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int64).astype(np.int8))


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 16),
    w=st.integers(4, 16),
    c=st.sampled_from([1, 3, 16, 40]),
    k=st.sampled_from([2, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(h, w, c, k, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand_int8(rng, (h, w, c))
    got = P.maxpool2d(x, k, stride)
    want = R.maxpool2d(x, k, stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_maxpool_padded():
    rng = np.random.default_rng(0)
    x = _rand_int8(rng, (7, 7, 8))
    got = P.maxpool2d(x, 3, 2, pad=1)
    want = R.maxpool2d(x, 3, 2, pad=1)
    assert got.shape == (4, 4, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_maxpool_resnet_stem_shape():
    # the ResNet stem pool: 3x3 s2 pad1 on 112x112x64
    rng = np.random.default_rng(1)
    x = _rand_int8(rng, (112, 112, 64))
    got = P.maxpool2d(x, 3, 2, pad=1)
    assert got.shape == (56, 56, 64)
    want = R.maxpool2d(x, 3, 2, pad=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 14),
    w=st.integers(1, 14),
    c=st.sampled_from([4, 64, 100]),
    seed=st.integers(0, 2**31 - 1),
)
def test_global_avgpool_matches_ref(h, w, c, seed):
    rng = np.random.default_rng(seed)
    x = _rand_int8(rng, (h, w, c))
    got = P.global_avgpool(x)
    want = R.requantize(R.global_avgpool_int32(x)[None, None, :], 0, False)[0, 0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_global_avgpool_constant_input():
    x = jnp.full((7, 7, 16), 42, jnp.int8)
    got = P.global_avgpool(x)
    np.testing.assert_array_equal(np.asarray(got), np.full(16, 42, np.int8))
