"""L2: int8 CNN graphs in JAX, built on the L1 Pallas kernels.

Everything here runs at *build time only*. ``aot.py`` lowers the exported
entry points to HLO text; the Rust runtime executes the artifacts and
Python never appears on the request path.

Two graph families are exported:

  * ``cifarnet`` — the end-to-end serving model: a ~0.27M-parameter int8
    CNN over 32x32x3 images producing 10 logits. Small enough that the
    CPU-PJRT interpret-mode artifact executes in milliseconds, yet it
    exercises every kernel flavour (dense conv, depthwise conv, maxpool,
    global-avgpool, FC).
  * ``resnet_block`` — one ResNet basic block at 56x56x64, the shape the
    H2PIPE compiler maps to layer engines; used by the quickstart example
    and the kernel-level §Perf measurements.

Weights are generated deterministically from a seed: the reproduction
validates *numerics against the reference oracle*, not ImageNet accuracy
(DESIGN.md, hardware-substitution table).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv_aitb as K
from .kernels import pool as P
from .kernels import ref as R


def _int8_weights(key: jax.Array, shape: tuple[int, ...]) -> jnp.ndarray:
    """Deterministic int8 weight tensor in [-64, 63] (headroom for acc)."""
    return jax.random.randint(key, shape, -64, 64, dtype=jnp.int32).astype(jnp.int8)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Geometry of one conv layer in a model definition."""

    name: str
    kind: str  # "conv" | "dw" | "pool" | "gap" | "fc"
    k: int = 3
    stride: int = 1
    pad: int = 1
    out_c: int = 0
    shift: int = 7  # requantization shift keeping int8 ranges stable
    relu: bool = True


# CifarNet: conv32 -> conv64/s2 -> dw64 -> conv128/s2 -> gap -> fc10
CIFARNET: tuple[ConvSpec, ...] = (
    ConvSpec("conv1", "conv", k=3, stride=1, pad=1, out_c=32),
    ConvSpec("conv2", "conv", k=3, stride=2, pad=1, out_c=64),
    ConvSpec("dw3", "dw", k=3, stride=1, pad=1),
    ConvSpec("conv4", "conv", k=3, stride=2, pad=1, out_c=128),
    ConvSpec("gap", "gap"),
    ConvSpec("fc", "fc", out_c=10, relu=False),
)


def init_params(specs: tuple[ConvSpec, ...], in_c: int, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Deterministic parameter set for a spec list."""
    params: dict[str, jnp.ndarray] = {}
    key = jax.random.PRNGKey(seed)
    c = in_c
    for s in specs:
        key, sub = jax.random.split(key)
        if s.kind == "conv":
            params[s.name] = _int8_weights(sub, (s.k, s.k, c, s.out_c))
            c = s.out_c
        elif s.kind == "dw":
            params[s.name] = _int8_weights(sub, (s.k, s.k, c))
        elif s.kind == "fc":
            params[s.name] = _int8_weights(sub, (c, s.out_c))
            c = s.out_c
    return params


def _forward(
    specs: tuple[ConvSpec, ...],
    params: dict[str, jnp.ndarray],
    x: jnp.ndarray,
    conv,
    dwconv,
    fc,
    maxpool,
    gap,
) -> jnp.ndarray:
    """Shared forward walker, parameterized over the op implementations so
    the same graph runs through the Pallas kernels or the reference."""
    for s in specs:
        if s.kind == "conv":
            x = conv(x, params[s.name], s.stride, s.pad, s.shift, s.relu)
        elif s.kind == "dw":
            x = dwconv(x, params[s.name], s.stride, s.pad, s.shift, s.relu)
        elif s.kind == "pool":
            x = maxpool(x, s.k, s.stride, s.pad)
        elif s.kind == "gap":
            x = gap(x)
        elif s.kind == "fc":
            x = fc(x, params[s.name], s.shift, s.relu)
        else:
            raise ValueError(f"unknown layer kind {s.kind}")
    return x


def forward_pallas(specs, params, x):
    """Forward pass through the L1 Pallas kernels (what gets AOT-lowered)."""
    return _forward(
        specs,
        params,
        x,
        conv=lambda x, w, s, p, sh, r: K.conv2d(x, w, stride=s, pad=p, shift=sh, relu=r),
        dwconv=lambda x, w, s, p, sh, r: K.depthwise_conv2d(
            x, w, stride=s, pad=p, shift=sh, relu=r
        ),
        fc=lambda x, w, sh, r: K.fc(x, w, shift=sh, relu=r),
        maxpool=P.maxpool2d,
        gap=P.global_avgpool,
    )


def forward_ref(specs, params, x):
    """Same graph through the pure-jnp oracles (pytest ground truth)."""
    return _forward(
        specs,
        params,
        x,
        conv=lambda x, w, s, p, sh, r: R.requantize(R.conv2d_int32(x, w, s, p), sh, r),
        dwconv=lambda x, w, s, p, sh, r: R.requantize(
            R.depthwise_conv2d_int32(x, w, s, p), sh, r
        ),
        fc=lambda x, w, sh, r: R.requantize(R.fc_int32(x, w)[None, None, :], sh, r)[0, 0],
        maxpool=R.maxpool2d,
        gap=lambda x: R.requantize(R.global_avgpool_int32(x)[None, None, :], 0, False)[0, 0],
    )


def cifarnet_fn(seed: int = 0) -> Callable[[jnp.ndarray], tuple[jnp.ndarray]]:
    """The exported serving entry point: (32,32,3) image -> logits (10,).

    Weights are closed over as constants so the Rust hot path passes only
    the image (weights travel to "HBM" through the simulated write path on
    the timing side; the functional side bakes them into the executable).

    Boundary dtype is int32: the ``xla`` crate's literal API has no i8, so
    the artifact casts to the int8 datapath on entry and widens the int8
    logits back to int32 on exit.
    """
    params = init_params(CIFARNET, 3, seed)

    def fn(img: jnp.ndarray) -> tuple[jnp.ndarray]:
        x = jnp.clip(img, -128, 127).astype(jnp.int8)
        return (forward_pallas(CIFARNET, params, x).astype(jnp.int32),)

    return fn


def cifarnet_ref_fn(seed: int = 0) -> Callable[[jnp.ndarray], tuple[jnp.ndarray]]:
    """Reference-path twin of :func:`cifarnet_fn` for artifact validation."""
    params = init_params(CIFARNET, 3, seed)

    def fn(img: jnp.ndarray) -> tuple[jnp.ndarray]:
        x = jnp.clip(img, -128, 127).astype(jnp.int8)
        return (forward_ref(CIFARNET, params, x).astype(jnp.int32),)

    return fn


RESNET_BLOCK_C = 64
RESNET_BLOCK_HW = 56


def resnet_block_fn(seed: int = 0) -> Callable[[jnp.ndarray], tuple[jnp.ndarray]]:
    """One ResNet basic block (two 3x3 convs + residual add) at 56x56x64.

    This is the layer-engine-shaped compute the H2PIPE compiler schedules;
    exported as its own artifact for the quickstart and perf benches.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    c = RESNET_BLOCK_C
    w1 = _int8_weights(k1, (3, 3, c, c))
    w2 = _int8_weights(k2, (3, 3, c, c))

    def fn(x32: jnp.ndarray) -> tuple[jnp.ndarray]:
        x = jnp.clip(x32, -128, 127).astype(jnp.int8)
        y = K.conv2d(x, w1, stride=1, pad=1, shift=7, relu=True)
        y = K.conv2d(y, w2, stride=1, pad=1, shift=7, relu=False)
        out = jnp.clip(y.astype(jnp.int32) + x.astype(jnp.int32), -128, 127).astype(jnp.int8)
        return (jnp.maximum(out, 0).astype(jnp.int32),)

    return fn


def resnet_block_ref_fn(seed: int = 0) -> Callable[[jnp.ndarray], tuple[jnp.ndarray]]:
    """Reference twin of :func:`resnet_block_fn`."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    c = RESNET_BLOCK_C
    w1 = _int8_weights(k1, (3, 3, c, c))
    w2 = _int8_weights(k2, (3, 3, c, c))

    def fn(x32: jnp.ndarray) -> tuple[jnp.ndarray]:
        x = jnp.clip(x32, -128, 127).astype(jnp.int8)
        y = R.requantize(R.conv2d_int32(x, w1, 1, 1), 7, True)
        y = R.requantize(R.conv2d_int32(y, w2, 1, 1), 7, False)
        out = jnp.clip(y.astype(jnp.int32) + x.astype(jnp.int32), -128, 127).astype(jnp.int8)
        return (jnp.maximum(out, 0).astype(jnp.int32),)

    return fn


#: Exported artifacts: name -> (fn factory, example-input shape/dtype).
#: Boundary dtype is int32 (see cifarnet_fn docstring).
EXPORTS: dict[str, tuple[Callable, tuple[tuple[int, ...], str]]] = {
    "cifarnet": (cifarnet_fn, ((32, 32, 3), "int32")),
    "resnet_block": (
        resnet_block_fn,
        ((RESNET_BLOCK_HW, RESNET_BLOCK_HW, RESNET_BLOCK_C), "int32"),
    ),
}
