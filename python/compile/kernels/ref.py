"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are validated against in
``python/tests/``. They intentionally use only stock ``jax.numpy`` /
``jax.lax`` ops, no Pallas, so a bug cannot be shared between kernel and
oracle.

Numeric model (mirrors H2PIPE's 8-bit datapath, paper §VI-A):
  * activations and weights are int8,
  * accumulation is int32 (the AI-TB dot-product accumulator),
  * requantization back to int8 uses a per-tensor power-of-two scale
    (arithmetic shift with round-half-away-from-zero) followed by
    saturation, optionally fused with ReLU.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def conv2d_int32(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 0) -> jnp.ndarray:
    """Dense 2-D convolution with int8 inputs and int32 accumulation.

    Args:
      x: int8 activations, shape (H, W, Cin).
      w: int8 weights, shape (KH, KW, Cin, Cout).
      stride: spatial stride (same in both dims).
      pad: symmetric spatial zero padding.

    Returns:
      int32 accumulator tensor of shape (Ho, Wo, Cout).
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    xf = x.astype(jnp.int32)[None]  # NHWC with N=1
    wf = w.astype(jnp.int32)
    out = lax.conv_general_dilated(
        xf,
        wf,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    return out[0]


def depthwise_conv2d_int32(
    x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, pad: int = 0
) -> jnp.ndarray:
    """Depthwise 2-D convolution, int8 in / int32 accumulate.

    Args:
      x: int8 activations, (H, W, C).
      w: int8 weights, (KH, KW, C).
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    c = x.shape[-1]
    xf = x.astype(jnp.int32)[None]
    wf = w.astype(jnp.int32)[:, :, None, :]  # HWIO with I=1, O=C
    out = lax.conv_general_dilated(
        xf,
        wf,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
        preferred_element_type=jnp.int32,
    )
    return out[0]


def requantize(acc: jnp.ndarray, shift: int, relu: bool = True) -> jnp.ndarray:
    """Requantize an int32 accumulator to int8 by a power-of-two scale.

    Round-half-away-from-zero (a hardware adder + arithmetic shift), then
    saturate to [-128, 127]; optional fused ReLU.
    """
    assert acc.dtype == jnp.int32
    if shift > 0:
        bias = jnp.where(acc >= 0, 1 << (shift - 1), (1 << (shift - 1)) - 1)
        acc = (acc + bias) >> shift
    if relu:
        acc = jnp.maximum(acc, 0)
    return jnp.clip(acc, -128, 127).astype(jnp.int8)


def maxpool2d(x: jnp.ndarray, k: int, stride: int, pad: int = 0) -> jnp.ndarray:
    """Max pooling over (H, W, C) int8 input."""
    assert x.dtype == jnp.int8
    return lax.reduce_window(
        x,
        jnp.array(-128, jnp.int8),
        lax.max,
        window_dimensions=(k, k, 1),
        window_strides=(stride, stride, 1),
        padding=[(pad, pad), (pad, pad), (0, 0)],
    )


def global_avgpool_int32(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pool: int8 (H, W, C) -> int32 (C,), rounded division.

    Mirrors an accumulate-then-divide hardware head.
    """
    assert x.dtype == jnp.int8
    s = jnp.sum(x.astype(jnp.int32), axis=(0, 1))
    n = x.shape[0] * x.shape[1]
    return (s + n // 2) // n


def fc_int32(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Fully-connected layer: int8 (Cin,) x int8 (Cin, Cout) -> int32."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    return jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32)
