"""L1 Pallas pooling kernels.

Max pooling is the other on-chip compute unit HPIPE instantiates between
conv engines; expressed here with the same resident-activation /
line-blocked structure as the conv kernel so the whole network lowers
through Pallas (interpret=True; see conv_aitb.py for the TPU-adaptation
notes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .conv_aitb import INTERPRET, _pick_block


def _maxpool_kernel(x_ref, o_ref, *, bh, wo, k, stride):
    """One output-row-block grid step of max pooling."""
    x = x_ref[...]
    row_off = pl.program_id(0) * bh * stride
    span = (bh - 1) * stride + 1
    acc = jnp.full((bh, wo, x.shape[-1]), -128, jnp.int8)
    for i in range(k):
        for j in range(k):
            xs = lax.dynamic_slice(x, (row_off + i, 0, 0), (span, x.shape[1], x.shape[-1]))
            xs = xs[::stride, j : j + (wo - 1) * stride + 1 : stride, :]
            acc = jnp.maximum(acc, xs)
    o_ref[...] = acc


def maxpool2d(
    x: jnp.ndarray,
    k: int,
    stride: int,
    pad: int = 0,
    block_rows: int = 8,
    block_c: int = 128,
) -> jnp.ndarray:
    """Pallas max pooling over int8 (H, W, C)."""
    assert x.dtype == jnp.int8
    h, w, c = x.shape
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)), constant_values=-128)
    xp = xp[: (ho - 1) * stride + k, : (wo - 1) * stride + k, :]
    bh = _pick_block(ho, block_rows)
    bc = _pick_block(c, block_c)
    kern = functools.partial(_maxpool_kernel, bh=bh, wo=wo, k=k, stride=stride)
    return pl.pallas_call(
        kern,
        grid=(ho // bh, c // bc),
        in_specs=[pl.BlockSpec((xp.shape[0], xp.shape[1], bc), lambda r, ci: (0, 0, ci))],
        out_specs=pl.BlockSpec((bh, wo, bc), lambda r, ci: (r, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c), jnp.int8),
        interpret=INTERPRET,
    )(xp)


def _gap_kernel(x_ref, o_ref, *, n):
    """Global average pool: int8 (H, W, BC) -> int8 (BC,) with rounding."""
    x = x_ref[...].astype(jnp.int32)
    s = jnp.sum(x, axis=(0, 1))
    avg = (s + n // 2) // n
    o_ref[...] = jnp.clip(avg, -128, 127).astype(jnp.int8)


def global_avgpool(x: jnp.ndarray, block_c: int = 256) -> jnp.ndarray:
    """Pallas global average pooling over int8 (H, W, C) -> int8 (C,)."""
    assert x.dtype == jnp.int8
    h, w, c = x.shape
    bc = _pick_block(c, block_c)
    kern = functools.partial(_gap_kernel, n=h * w)
    return pl.pallas_call(
        kern,
        grid=(c // bc,),
        in_specs=[pl.BlockSpec((h, w, bc), lambda ci: (0, 0, ci))],
        out_specs=pl.BlockSpec((bc,), lambda ci: (ci,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.int8),
        interpret=INTERPRET,
    )(x)
