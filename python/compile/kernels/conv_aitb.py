"""L1 Pallas kernels: the H2PIPE compute hot-spot, re-thought for TPU.

Hardware adaptation (DESIGN.md §Hardware-Adaptation):

On the Stratix 10 NX, HPIPE feeds each AI tensor block a broadcast
10-element weight vector per cycle and reuses it across 3 horizontally
adjacent output pixels held in ping-pong registers, so a layer engine needs
only 80 bits of weight per cycle (the number the whole HBM design of the
paper is built around). The TPU analogue implemented here:

  * the *weight tile is the streamed operand*: the weight BlockSpec
    re-fetches the (KH, KW, Cin, BCo) tile for every output-row block,
    mirroring "kernels are reloaded once per output line" — exactly the
    traffic Eq. 2 of the paper counts;
  * the *activation row block stays resident* (the ping-pong registers):
    each grid step computes a (BH x Wo) output tile so one weight vector is
    amortized over the whole output width, as in HPIPE's
    full-width-parallel layer engines;
  * the contraction is expressed as (BH*Wo, Cin) x (Cin, BCo) matmuls per
    kernel-window tap — an MXU-shaped int8 -> int32 systolic contraction
    rather than the FPGA's 10-lane dot products.

All kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute. Correctness is pinned to
``ref.py`` by the pytest suite; TPU performance is *estimated* analytically
(VMEM footprint / MXU utilization) in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Flip to False to debug through the (identical) jax-level semantics of the
# kernels without the Pallas machinery.
INTERPRET = True


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (>= 1)."""
    target = max(1, min(n, target))
    for b in range(target, 0, -1):
        if n % b == 0:
            return b
    return 1


def _requant(acc: jnp.ndarray, shift: int, relu: bool) -> jnp.ndarray:
    """In-kernel requantization: int32 -> int8 (shared with ref semantics)."""
    if shift > 0:
        bias = jnp.where(acc >= 0, 1 << (shift - 1), (1 << (shift - 1)) - 1)
        acc = (acc + bias) >> shift
    if relu:
        acc = jnp.maximum(acc, 0)
    return jnp.clip(acc, -128, 127).astype(jnp.int8)


def _conv_kernel(x_ref, w_ref, o_ref, *, bh, wo, stride, kh, kw, shift, relu):
    """One (output-row-block, output-channel-block) grid step.

    x_ref: (Hp, Wp, Cin) padded activations — resident block.
    w_ref: (KH, KW, Cin, BCo) weight tile — streamed per grid step.
    o_ref: (BH, Wo, BCo) output tile.
    """
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    cin = x.shape[-1]
    bco = w.shape[-1]
    row_off = pl.program_id(0) * bh * stride
    span = (bh - 1) * stride + 1
    acc = jnp.zeros((bh * wo, bco), jnp.int32)
    # Unrolled walk over the kernel window: each tap is one MXU-shaped
    # matmul whose weight slice w[i, j] is broadcast over the whole
    # (BH x Wo) output tile — the AI-TB weight-reuse pattern.
    for i in range(kh):
        for j in range(kw):
            xs = lax.dynamic_slice(x, (row_off + i, 0, 0), (span, x.shape[1], cin))
            xs = xs[::stride, j : j + (wo - 1) * stride + 1 : stride, :]
            acc = acc + jnp.dot(
                xs.reshape(bh * wo, cin), w[i, j], preferred_element_type=jnp.int32
            )
    o_ref[...] = _requant(acc.reshape(bh, wo, bco), shift, relu)


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    stride: int = 1,
    pad: int = 0,
    shift: int = 0,
    relu: bool = True,
    block_rows: int = 8,
    block_cout: int = 64,
) -> jnp.ndarray:
    """Dense int8 conv + requantize via the Pallas AI-TB-style kernel.

    Args:
      x: int8 (H, W, Cin).
      w: int8 (KH, KW, Cin, Cout).
      stride, pad: conv geometry.
      shift: power-of-two requantization shift.
      relu: fuse ReLU before saturation.
      block_rows / block_cout: tile-size targets (rounded to divisors).

    Returns:
      int8 (Ho, Wo, Cout).
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    h, ww_, cin = x.shape
    kh, kw, wcin, cout = w.shape
    assert wcin == cin, f"Cin mismatch {wcin} != {cin}"
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (ww_ + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    # Trim padded input to exactly the receptive field of the output grid
    # so in-kernel dynamic slices are always in bounds.
    hp_need = (ho - 1) * stride + kh
    wp_need = (wo - 1) * stride + kw
    xp = xp[:hp_need, :wp_need, :]

    bh = _pick_block(ho, block_rows)
    bco = _pick_block(cout, block_cout)
    grid = (ho // bh, cout // bco)

    kern = functools.partial(
        _conv_kernel, bh=bh, wo=wo, stride=stride, kh=kh, kw=kw, shift=shift, relu=relu
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # Activations: resident (the "ping-pong registers").
            pl.BlockSpec(xp.shape, lambda r, c: (0, 0, 0)),
            # Weights: streamed tile per (row-block, cout-block) — the HBM
            # -> burst-matching FIFO -> last-stage FIFO schedule.
            pl.BlockSpec((kh, kw, cin, bco), lambda r, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((bh, wo, bco), lambda r, c: (r, 0, c)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, cout), jnp.int8),
        interpret=INTERPRET,
    )(xp, w)


def _dw_kernel(x_ref, w_ref, o_ref, *, bh, wo, stride, kh, kw, shift, relu):
    """Depthwise grid step: x (Hp, Wp, BC), w (KH, KW, BC), o (BH, Wo, BC)."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    row_off = pl.program_id(0) * bh * stride
    span = (bh - 1) * stride + 1
    acc = jnp.zeros((bh, wo, x.shape[-1]), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            xs = lax.dynamic_slice(x, (row_off + i, 0, 0), (span, x.shape[1], x.shape[-1]))
            xs = xs[::stride, j : j + (wo - 1) * stride + 1 : stride, :]
            acc = acc + xs * w[i, j][None, None, :]
    o_ref[...] = _requant(acc, shift, relu)


def depthwise_conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    stride: int = 1,
    pad: int = 0,
    shift: int = 0,
    relu: bool = True,
    block_rows: int = 8,
    block_c: int = 128,
) -> jnp.ndarray:
    """Depthwise int8 conv + requantize (x: (H, W, C), w: (KH, KW, C))."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    h, ww_, c = x.shape
    kh, kw, wc = w.shape
    assert wc == c, f"C mismatch {wc} != {c}"
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (ww_ + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    xp = xp[: (ho - 1) * stride + kh, : (wo - 1) * stride + kw, :]

    bh = _pick_block(ho, block_rows)
    bc = _pick_block(c, block_c)
    grid = (ho // bh, c // bc)
    kern = functools.partial(
        _dw_kernel, bh=bh, wo=wo, stride=stride, kh=kh, kw=kw, shift=shift, relu=relu
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((xp.shape[0], xp.shape[1], bc), lambda r, c_: (0, 0, c_)),
            pl.BlockSpec((kh, kw, bc), lambda r, c_: (0, 0, c_)),
        ],
        out_specs=pl.BlockSpec((bh, wo, bc), lambda r, c_: (r, 0, c_)),
        out_shape=jax.ShapeDtypeStruct((ho, wo, c), jnp.int8),
        interpret=INTERPRET,
    )(xp, w)


def _fc_kernel(x_ref, w_ref, o_ref, *, shift, relu):
    """FC grid step: x (Cin,), w (Cin, BCo), o (BCo,)."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc = jnp.dot(x, w, preferred_element_type=jnp.int32)
    o_ref[...] = _requant(acc, shift, relu)


def fc(
    x: jnp.ndarray,
    w: jnp.ndarray,
    shift: int = 0,
    relu: bool = False,
    block_cout: int = 128,
) -> jnp.ndarray:
    """Fully connected int8 layer + requantize (x: (Cin,), w: (Cin, Cout))."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    cin, cout = w.shape
    assert x.shape == (cin,)
    bco = _pick_block(cout, block_cout)
    kern = functools.partial(_fc_kernel, shift=shift, relu=relu)
    return pl.pallas_call(
        kern,
        grid=(cout // bco,),
        in_specs=[
            pl.BlockSpec((cin,), lambda c: (0,)),
            pl.BlockSpec((cin, bco), lambda c: (0, c)),
        ],
        out_specs=pl.BlockSpec((bco,), lambda c: (c,)),
        out_shape=jax.ShapeDtypeStruct((cout,), jnp.int8),
        interpret=INTERPRET,
    )(x, w)


def vmem_footprint_bytes(
    hp: int, wp: int, cin: int, kh: int, kw: int, bh: int, wo: int, bco: int
) -> int:
    """Analytic VMEM footprint of one conv grid step (bytes).

    Used by the §Perf analysis: resident activations + streamed weight tile
    + output tile + int32 accumulator.
    """
    act = hp * wp * cin  # int8
    wt = kh * kw * cin * bco  # int8
    out = bh * wo * bco  # int8
    acc = bh * wo * bco * 4  # int32
    return act + wt + out + acc
