"""L1 Pallas kernels and their pure-jnp reference oracles."""

from . import conv_aitb, pool, ref  # noqa: F401
