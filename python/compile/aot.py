"""AOT path: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text — not a serialized ``HloModuleProto`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. Pallas kernels are
lowered ``interpret=True`` so the resulting HLO contains plain ops the CPU
PJRT client can execute (real-TPU lowering would emit Mosaic custom-calls).

Usage:  python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_export(name: str) -> tuple[str, dict]:
    """Lower one EXPORTS entry; returns (hlo_text, manifest_entry)."""
    fn_factory, (shape, dtype) = model.EXPORTS[name]
    fn = fn_factory()
    spec = jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    entry = {
        "input_shape": list(shape),
        "input_dtype": dtype,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "bytes": len(text),
    }
    return text, entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--only", default=None, help="lower a single export (default: all of model.EXPORTS)"
    )
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    names = [args.only] if args.only else list(model.EXPORTS)
    manifest: dict[str, dict] = {}
    for name in names:
        text, entry = lower_export(name)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = entry
        print(f"wrote {path} ({entry['bytes']} bytes)")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
