//! The compiled accelerator plan: everything the simulator, the serving
//! runtime, and the report generators need to know about one H2PIPE
//! instance.

use crate::compiler::parallelism::Parallelism;
use crate::compiler::resources::{
    LayerStats, ResourceUsage, ALM_PER_ENGINE, ALM_PER_HBM_LAYER, ALM_PER_TB, M20K_BITS,
    REG_PER_WRITE_PATH_BIT,
};
use crate::config::{CompilerOptions, DeviceConfig, WeightPlacement};
use crate::util::ceil_div;

/// Per-layer slice of the plan.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub stats: LayerStats,
    pub par: Parallelism,
    pub placement: WeightPlacement,
    /// (pseudo-channel, chain slots) feeding this layer (empty when
    /// on-chip). Slots on one PC can be shared between layers.
    pub pcs: Vec<(u32, u32)>,
    /// Eq. 1 score (reporting).
    pub score: f64,
}

impl LayerPlan {
    /// Compute cycles per image, ignoring memory stalls.
    pub fn compute_cycles(&self) -> u64 {
        self.stats.cycles_per_image(self.par.p_i, self.par.p_o)
    }

    /// On-chip M20K cost of this layer's weights at its parallelism:
    /// every duplicated copy stores the kernel capacity AND must feed
    /// `chains x 80` bits per cycle from 40-bit-wide M20K ports, so each
    /// chain adds two banked blocks per duplicate. This growth is what
    /// pushes ResNet-18 to 98% BRAM at full parallelism (Table III) and
    /// forces even a network that fits at minimum parallelism to offload.
    pub fn onchip_weight_m20k(&self) -> u64 {
        if !self.stats.has_weights {
            return 0;
        }
        let cap_blocks = ceil_div(self.stats.weight_bits, M20K_BITS);
        let bank_blocks = 2 * self.par.chains() as u64;
        (cap_blocks + bank_blocks) * self.stats.dup
    }

    /// M20K cost when streamed from HBM (last-stage + burst-matching
    /// FIFOs) at the paper's 512-word last-stage depth.
    pub fn hbm_m20k(&self, burst_len: u32) -> u64 {
        self.hbm_m20k_at(burst_len, 512)
    }

    /// [`Self::hbm_m20k`] at an explicit last-stage FIFO depth — the
    /// accounting path for plans compiled with a tuned
    /// `last_stage_fifo_depth`.
    pub fn hbm_m20k_at(&self, burst_len: u32, fifo_depth: u32) -> u64 {
        if !self.stats.has_weights {
            return 0;
        }
        self.stats.hbm_weight_m20k_at(burst_len, fifo_depth)
    }

    /// Activation-buffer M20K cost.
    pub fn act_m20k(&self) -> u64 {
        ceil_div(self.stats.act_bits, M20K_BITS)
    }
}

/// A fully compiled accelerator.
#[derive(Debug, Clone)]
pub struct AcceleratorPlan {
    pub network: String,
    pub device: DeviceConfig,
    pub options: CompilerOptions,
    pub layers: Vec<LayerPlan>,
    pub burst_len: u32,
    pub usage: ResourceUsage,
    /// Compute-only bottleneck cycles per image.
    pub bottleneck_cycles: u64,
    /// Analytic throughput estimate (im/s) including steady-state HBM
    /// stall factors (the cycle simulator refines this).
    pub est_throughput: f64,
    /// Analytic single-image latency estimate (s).
    pub est_latency: f64,
    /// HBM read efficiency assumed for the estimate.
    pub hbm_read_efficiency: f64,
    /// Unused chain slots after offload.
    pub free_bw_slots: u64,
}

impl AcceleratorPlan {
    /// Layers whose weights stream from HBM.
    pub fn hbm_layers(&self) -> impl Iterator<Item = &LayerPlan> {
        self.layers.iter().filter(|l| l.placement == WeightPlacement::Hbm)
    }

    /// Layers whose weights stay on chip.
    pub fn onchip_layers(&self) -> impl Iterator<Item = &LayerPlan> {
        self.layers
            .iter()
            .filter(|l| l.stats.has_weights && l.placement == WeightPlacement::OnChip)
    }

    /// Total HBM weight bytes (what the boot loader writes, §IV-C).
    pub fn hbm_weight_bytes(&self) -> u64 {
        self.hbm_layers().map(|l| l.stats.weight_bits / 8).sum()
    }

    /// Total weight traffic per image from HBM (Eq. 2 restricted to the
    /// offloaded layers), in bytes.
    pub fn hbm_traffic_per_image(&self) -> u64 {
        self.hbm_layers().map(|l| l.stats.weight_traffic_per_image).sum()
    }

    /// Steady-state stall factor for an offloaded layer: each chain needs
    /// 80 bits/core-cycle; one PC chain-slot supplies
    /// 256/3 bits x (400/300) x efficiency per core cycle.
    pub fn hbm_stall_factor(&self, eff: f64) -> f64 {
        let supply_per_chain = 256.0 / 3.0
            * (self.device.hbm.controller_mhz as f64 / self.device.core_mhz as f64)
            * eff;
        (80.0 / supply_per_chain).max(1.0)
    }

    /// Human-readable plan summary.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "=== H2PIPE plan: {} on {} ===", self.network, self.device.name);
        let _ = writeln!(
            s,
            "burst_len={}  M20K {}/{} ({:.0}%)  AI-TB {}/{} ({:.0}%)  ALM {:.0}%",
            self.burst_len,
            self.usage.m20k,
            self.device.m20k_blocks,
            100.0 * self.usage.m20k_frac(&self.device),
            self.usage.tensor_blocks,
            self.device.tensor_blocks,
            100.0 * self.usage.tb_frac(&self.device),
            100.0 * self.usage.alm_frac(&self.device),
        );
        let _ = writeln!(
            s,
            "est throughput {:.0} im/s   est latency {:.2} ms   bottleneck {} cycles",
            self.est_throughput,
            self.est_latency * 1e3,
            self.bottleneck_cycles
        );
        let n_hbm = self.hbm_layers().count();
        let n_chip = self.onchip_layers().count();
        let _ = writeln!(
            s,
            "{n_hbm} layers on HBM ({} MiB, {} free chain slots), {n_chip} on chip",
            self.hbm_weight_bytes() >> 20,
            self.free_bw_slots
        );
        for l in &self.layers {
            if !l.stats.has_weights {
                continue;
            }
            let place = match l.placement {
                WeightPlacement::Hbm => format!("HBM{:?}", l.pcs),
                WeightPlacement::OnChip => "chip".to_string(),
            };
            let _ = writeln!(
                s,
                "  {:24} p=({},{}) chains={:3} cycles={:9} score={:8.2} {}",
                l.stats.name,
                l.par.p_i,
                l.par.p_o,
                l.par.chains(),
                l.compute_cycles(),
                l.score,
                place
            );
        }
        s
    }

    /// Total chain slots the device exposes (usable PCs x slots per PC).
    pub fn bw_slot_capacity(&self) -> u64 {
        self.device.usable_pcs() as u64 * self.device.chains_per_pc() as u64
    }

    /// Recompute the compute-only bottleneck from the layer plans. The
    /// compiler stores this value and `h2pipe check` (rule H2P051)
    /// re-derives it through this same function, so the two can only
    /// disagree when the stored scalar was tampered with.
    pub fn recompute_bottleneck_cycles(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| l.stats.has_weights)
            .map(LayerPlan::compute_cycles)
            .max()
            .unwrap_or(1)
    }

    /// Recompute the free chain slots from the layer plans (H2P052).
    pub fn recompute_free_bw_slots(&self) -> u64 {
        let used: u64 = self.hbm_layers().map(|l| l.par.chains() as u64).sum();
        self.bw_slot_capacity().saturating_sub(used)
    }

    /// Analytic `(est_throughput, est_latency)` recomputed from the layer
    /// plans: the effective bottleneck applies the steady-state HBM stall
    /// factor to offloaded layers, and latency adds the pipeline fill
    /// (each layer's receptive window). [`crate::compiler::compile`]
    /// stores exactly these values, and the verifier (H2P050) recomputes
    /// them through this same function. The efficiency is looked up from
    /// the embedded table — not taken from the stored
    /// `hbm_read_efficiency` scalar — so a tampered scalar trips only its
    /// own rule (H2P053).
    pub fn analytic_estimates(&self) -> (f64, f64) {
        let eff = self.options.efficiency.lookup(self.burst_len);
        let stall = self.hbm_stall_factor(eff);
        let eff_bottleneck = self
            .layers
            .iter()
            .filter(|l| l.stats.has_weights)
            .map(|l| {
                let c = l.compute_cycles() as f64;
                if l.placement == WeightPlacement::Hbm {
                    c * stall
                } else {
                    c
                }
            })
            .fold(0.0f64, f64::max)
            .max(1.0);
        let hz = self.device.core_mhz as f64 * 1e6;
        let fill: f64 = self
            .layers
            .iter()
            .filter(|l| l.stats.has_weights)
            .map(|l| {
                let per_line = l.compute_cycles() as f64 / l.stats.out_h.max(1) as f64;
                per_line * (l.stats.kh as f64 + 1.0)
            })
            .sum();
        (hz / eff_bottleneck, (fill + eff_bottleneck) / hz)
    }

    /// Total resource usage recomputation (sanity checks / tests).
    pub fn recompute_usage(&self) -> ResourceUsage {
        let mut m20k = 0u64;
        let mut tbs = 0u64;
        let mut alms = 0u64;
        for l in &self.layers {
            if l.stats.has_weights {
                alms += ALM_PER_ENGINE;
                tbs += l.stats.tensor_blocks(l.par.p_i, l.par.p_o);
                match l.placement {
                    WeightPlacement::OnChip => m20k += l.onchip_weight_m20k(),
                    WeightPlacement::Hbm => {
                        m20k += l.hbm_m20k_at(self.burst_len, self.options.last_stage_fifo_depth);
                        alms += ALM_PER_HBM_LAYER;
                    }
                }
            }
            m20k += l.act_m20k();
        }
        alms += tbs * ALM_PER_TB;
        // §IV-C write path: registers scale with configured width.
        alms += (self.options.write_path_bits as u64 * REG_PER_WRITE_PATH_BIT) / 2;
        ResourceUsage { m20k, tensor_blocks: tbs, alms }
    }
}
