//! Layer offload selection (Eq. 1 + Algorithm 1) and pseudo-channel
//! assignment (§V-B).

use crate::compiler::parallelism::Parallelism;
use crate::compiler::resources::{LayerStats, CHAIN_WEIGHT_BITS, M20K_BITS};
use crate::config::DeviceConfig;
use crate::util::ceil_div;

/// Eq. 1: desirability of moving layer `l`'s weights to HBM.
///
/// score_l = (ceil(kh*kw*ci*co*8 / 20480) - 2) * ceil(out_w / 18)
///           -----------------------------------------------------
///                             p_i * p_o * 80
///
/// Numerator: M20Ks saved by replacing every duplicated weight memory
/// with a 2-M20K last-stage FIFO. Denominator: HBM weight bandwidth the
/// layer will consume (bits per core cycle).
pub fn score(s: &LayerStats, p: Parallelism) -> f64 {
    score_sparse(s, p, 0.0)
}

/// Eq. 1 with an HPIPE-style sparsity discount: a sparsity-aware build
/// skips zero weights, so the on-chip memory an offload would reclaim
/// shrinks by `1 - sparsity`. Only the score numerator changes — storage
/// and bandwidth accounting stay dense. `sparsity == 0.0` takes the exact
/// integer path of [`score`], so default-compiled plans are byte-stable.
pub fn score_sparse(s: &LayerStats, p: Parallelism, sparsity: f64) -> f64 {
    if !s.has_weights {
        return f64::NEG_INFINITY;
    }
    let m20k_per_dup = if sparsity > 0.0 {
        (s.weight_bits as f64 * (1.0 - sparsity) / M20K_BITS as f64).ceil() as i64 - 2
    } else {
        ceil_div(s.weight_bits, M20K_BITS) as i64 - 2
    };
    let saved = m20k_per_dup * s.dup as i64;
    let bw = (p.chains() as u64 * CHAIN_WEIGHT_BITS) as f64;
    saved as f64 / bw
}

/// Result of Algorithm 1.
#[derive(Debug, Clone)]
pub struct OffloadPlan {
    /// Index-aligned with the stats slice: offload to HBM?
    pub offload: Vec<bool>,
    /// Chain-slots of HBM bandwidth left unallocated.
    pub free_bw: u64,
    /// Eq. 1 scores (for reporting).
    pub scores: Vec<f64>,
}

/// Algorithm 1 (verbatim): offload the best-scoring layers until the
/// pseudo-channel bandwidth (`n_pc * 3` chain slots) is exhausted.
///
/// `force_all` is the paper's all-HBM configuration; otherwise the greedy
/// stops early once the remaining on-chip layers fit the device
/// (`fits_on_chip` callback), matching "using as many on-chip weight
/// buffers as possible" (§VI-A).
pub fn algorithm1(
    stats: &[LayerStats],
    par: &[Parallelism],
    n_pc: u64,
    chains_per_pc: u64,
    force_all: bool,
    fits_on_chip: impl FnMut(&[bool]) -> bool,
) -> OffloadPlan {
    algorithm1_sparse(stats, par, n_pc, chains_per_pc, force_all, 0.0, fits_on_chip)
}

/// [`algorithm1`] ranking layers by [`score_sparse`] instead of [`score`]:
/// the greedy is unchanged, only the offload ordering shifts when a
/// sparsity fraction discounts the Eq. 1 numerator.
pub fn algorithm1_sparse(
    stats: &[LayerStats],
    par: &[Parallelism],
    n_pc: u64,
    chains_per_pc: u64,
    force_all: bool,
    sparsity: f64,
    mut fits_on_chip: impl FnMut(&[bool]) -> bool,
) -> OffloadPlan {
    let l_count = stats.len();
    let scores: Vec<f64> =
        stats.iter().zip(par.iter()).map(|(s, &p)| score_sparse(s, p, sparsity)).collect();
    let mut offload = vec![false; l_count];

    // order: layer indices sorted by score, best first
    let mut order: Vec<usize> =
        (0..l_count).filter(|&i| stats[i].has_weights).collect();
    order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));

    let mut free_bw = n_pc * chains_per_pc;
    let mut idx = 0;
    while free_bw != 0 && idx < order.len() {
        if !force_all && fits_on_chip(&offload) {
            break; // on-chip memory already fits: stop offloading
        }
        let l = order[idx];
        let need = par[l].chains() as u64;
        if need <= free_bw {
            offload[l] = true;
            free_bw -= need;
        }
        idx += 1;
    }
    OffloadPlan { offload, free_bw, scores }
}

/// §V-B pseudo-channel assignment: offloaded layers ordered from network
/// input to output are assigned clockwise — PCs 0..=15 (bottom stack),
/// then 31 down to 16 (top stack) — skipping excluded PCs. A layer
/// needing more than `chains_per_pc` chains takes consecutive PCs, and a
/// layer may take a *partial* slot count on a PC another layer already
/// occupies, so assignments carry explicit (pc, chains) pairs.
#[derive(Debug, Clone)]
pub struct PcAssignment {
    /// For each layer index: (pseudo-channel, chain slots taken on it).
    /// Empty when the layer stays on chip.
    pub pcs: Vec<Vec<(u32, u32)>>,
    /// Free chain slots per PC id after assignment.
    pub free_slots: Vec<u32>,
}

pub fn assign_pcs(
    stats: &[LayerStats],
    par: &[Parallelism],
    offload: &[bool],
    device: &DeviceConfig,
) -> anyhow::Result<PcAssignment> {
    let total = device.hbm.total_pcs();
    let per_pc = device.chains_per_pc();
    // clockwise order: 0..=15, then 31..=16, extended for unlimited-HBM
    // devices with more than 2 stacks.
    let mut order: Vec<u32> = Vec::new();
    let half = total / 2;
    order.extend(0..half);
    order.extend((half..total).rev());
    order.retain(|pc| !device.excluded_pcs.contains(pc));

    let mut free: Vec<u32> = vec![per_pc; total as usize];
    for &e in &device.excluded_pcs {
        free[e as usize] = 0;
    }
    let mut cursor = 0usize;
    let mut pcs: Vec<Vec<(u32, u32)>> = vec![Vec::new(); stats.len()];
    for (i, s) in stats.iter().enumerate() {
        if !offload[i] || !s.has_weights {
            continue;
        }
        let mut need = par[i].chains();
        while need > 0 {
            anyhow::ensure!(
                cursor < order.len(),
                "out of pseudo-channels assigning layer {} ({} chains left)",
                s.name,
                need
            );
            let pc = order[cursor];
            let take = need.min(free[pc as usize]);
            if take == 0 {
                cursor += 1;
                continue;
            }
            free[pc as usize] -= take;
            need -= take;
            pcs[i].push((pc, take));
            if free[pc as usize] == 0 {
                cursor += 1;
            }
        }
    }
    Ok(PcAssignment { pcs, free_slots: free })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompilerOptions;
    use crate::nn::zoo;

    fn stats_and_par(net: &crate::nn::Network) -> (Vec<LayerStats>, Vec<Parallelism>) {
        let o = CompilerOptions::default();
        let stats: Vec<LayerStats> =
            net.layers().iter().map(|l| LayerStats::from_layer(l, &o)).collect();
        let par = vec![Parallelism { p_i: 1, p_o: 1 }; stats.len()];
        (stats, par)
    }

    #[test]
    fn score_prefers_big_low_bandwidth_layers() {
        let net = zoo::vgg16();
        let (stats, par) = stats_and_par(&net);
        let fc6 = net.layers().iter().position(|l| l.name == "fc6").unwrap();
        let conv1_1 = net.layers().iter().position(|l| l.name == "conv1_1").unwrap();
        assert!(
            score(&stats[fc6], par[fc6]) > score(&stats[conv1_1], par[conv1_1]),
            "fc6 (huge, 1 line) must outscore conv1_1 (tiny, 224 lines)"
        );
    }

    #[test]
    fn sparse_score_discounts_onchip_cost_only() {
        let net = zoo::vgg16();
        let (stats, par) = stats_and_par(&net);
        let fc6 = net.layers().iter().position(|l| l.name == "fc6").unwrap();
        // sparsity 0.0 is bit-identical to the dense Eq. 1 path
        assert_eq!(score_sparse(&stats[fc6], par[fc6], 0.0), score(&stats[fc6], par[fc6]));
        // a sparse build reclaims fewer M20Ks, so offloading looks worse
        let dense = score(&stats[fc6], par[fc6]);
        let half = score_sparse(&stats[fc6], par[fc6], 0.5);
        assert!(half < dense, "sparsity must shrink the score: {half} vs {dense}");
        assert!(half > 0.0, "fc6 still saves memory at 50% sparsity");
        // weightless layers stay -inf at any sparsity
        let pool = net.layers().iter().position(|l| l.name == "pool5").unwrap();
        assert_eq!(score_sparse(&stats[pool], par[pool], 0.5), f64::NEG_INFINITY);
    }

    #[test]
    fn sparse_ranking_can_reorder_algorithm1() {
        let net = zoo::resnet50();
        let (stats, par) = stats_and_par(&net);
        let dense = algorithm1(&stats, &par, 31, 3, true, |_| false);
        let sparse = algorithm1_sparse(&stats, &par, 31, 3, true, 0.5, |_| false);
        // same greedy, same bandwidth cap — only the ordering input moves
        assert_eq!(dense.offload.len(), sparse.offload.len());
        for (i, s) in stats.iter().enumerate() {
            if !s.has_weights {
                assert!(!sparse.offload[i]);
                assert_eq!(sparse.scores[i], f64::NEG_INFINITY);
            } else {
                assert!(sparse.scores[i] <= dense.scores[i], "{}", s.name);
            }
        }
    }

    #[test]
    fn score_negative_for_tiny_layers() {
        // A layer with <= 2 M20Ks of weights saves nothing by offloading.
        let net = zoo::mobilenet_v2();
        let (stats, par) = stats_and_par(&net);
        let tiny = stats
            .iter()
            .position(|s| s.has_weights && ceil_div(s.weight_bits, M20K_BITS) <= 2)
            .expect("v2 has tiny pointwise layers");
        assert!(score(&stats[tiny], par[tiny]) <= 0.0);
    }

    #[test]
    fn algorithm1_respects_bandwidth() {
        let net = zoo::resnet50();
        let (stats, par) = stats_and_par(&net);
        let plan = algorithm1(&stats, &par, 31, 3, true, |_| false);
        let used: u64 = stats
            .iter()
            .zip(plan.offload.iter())
            .zip(par.iter())
            .filter(|((_, &off), _)| off)
            .map(|((_, _), p)| p.chains() as u64)
            .sum();
        assert!(used <= 93);
        assert_eq!(plan.free_bw, 93 - used);
    }

    #[test]
    fn algorithm1_stops_when_memory_fits() {
        let net = zoo::resnet50();
        let (stats, par) = stats_and_par(&net);
        // pretend memory fits after 3 offloads
        let mut calls = 0;
        let plan = algorithm1(&stats, &par, 31, 3, false, |off| {
            calls += 1;
            off.iter().filter(|&&b| b).count() >= 3
        });
        assert_eq!(plan.offload.iter().filter(|&&b| b).count(), 3);
        assert!(calls > 0);
    }

    #[test]
    fn algorithm1_offloads_best_scores_first() {
        let net = zoo::vgg16();
        let (stats, par) = stats_and_par(&net);
        let plan = algorithm1(&stats, &par, 31, 3, false, |off| {
            off.iter().filter(|&&b| b).count() >= 2
        });
        // the two offloaded layers must be the two best-scoring ones
        let mut ranked: Vec<usize> =
            (0..stats.len()).filter(|&i| stats[i].has_weights).collect();
        ranked.sort_by(|&a, &b| plan.scores[b].total_cmp(&plan.scores[a]));
        assert!(plan.offload[ranked[0]]);
        assert!(plan.offload[ranked[1]]);
    }

    /// Synthetic weight layer for precise Algorithm 1 edge-case control.
    fn synth_layer(name: &str, weight_bits: u64, dup: u64) -> LayerStats {
        LayerStats {
            layer: 0,
            name: name.to_string(),
            weight_bits,
            weight_m20k: if weight_bits > 0 { ceil_div(weight_bits, M20K_BITS) * dup } else { 0 },
            dup,
            act_bits: 1 << 14,
            weight_traffic_per_image: weight_bits / 8,
            macs: 1_000,
            out_h: 16,
            out_w: 16,
            kh: 3,
            kw: 3,
            ci: 16,
            co: 16,
            has_weights: weight_bits > 0,
            depthwise: false,
        }
    }

    #[test]
    fn algorithm1_all_weightless_network_offloads_nothing() {
        // pools/adds only: there is nothing Algorithm 1 can move, and the
        // full pseudo-channel bandwidth must remain free.
        let stats = vec![synth_layer("pool1", 0, 1), synth_layer("pool2", 0, 1)];
        let par = vec![Parallelism { p_i: 1, p_o: 1 }; 2];
        for force_all in [false, true] {
            let plan = algorithm1(&stats, &par, 31, 3, force_all, |_| false);
            assert!(plan.offload.iter().all(|&b| !b));
            assert_eq!(plan.free_bw, 93, "bandwidth untouched");
            assert!(plan.scores.iter().all(|s| *s == f64::NEG_INFINITY));
        }
    }

    #[test]
    fn algorithm1_bandwidth_exhausted_before_first_offload() {
        // The best-scoring layer needs more chain slots than the whole
        // HBM subsystem offers: it must be skipped without panicking, the
        // remaining bandwidth intact for smaller layers behind it.
        let wide = synth_layer("wide", 200 * M20K_BITS, 4);
        let narrow = synth_layer("narrow", 50 * M20K_BITS, 1);
        let stats = vec![wide, narrow];
        let par = vec![
            Parallelism { p_i: 7, p_o: 1 }, // 7 chains > 2 PCs x 3
            Parallelism { p_i: 1, p_o: 1 },
        ];
        let plan = algorithm1(&stats, &par, 2, 3, true, |_| false);
        assert!(!plan.offload[0], "over-wide layer cannot offload");
        assert!(plan.offload[1], "bandwidth must flow to the next candidate");
        assert_eq!(plan.free_bw, 5);

        // zero usable pseudo-channels: nothing offloads at all
        let plan = algorithm1(&stats, &par, 0, 3, true, |_| false);
        assert!(plan.offload.iter().all(|&b| !b));
        assert_eq!(plan.free_bw, 0);
    }

    #[test]
    fn algorithm1_tie_break_on_equal_scores_is_deterministic() {
        // Two identical layers have identical Eq. 1 scores; the stable
        // sort must keep index order, so with bandwidth for only one of
        // them the earlier layer wins — on every run.
        let stats = vec![
            synth_layer("twin_a", 100 * M20K_BITS, 2),
            synth_layer("twin_b", 100 * M20K_BITS, 2),
        ];
        let par = vec![Parallelism { p_i: 1, p_o: 1 }; 2];
        assert_eq!(score(&stats[0], par[0]), score(&stats[1], par[1]));
        for _ in 0..3 {
            let plan = algorithm1(&stats, &par, 1, 1, true, |_| false);
            assert!(plan.offload[0], "first twin must win the tie");
            assert!(!plan.offload[1]);
            assert_eq!(plan.free_bw, 0);
        }
    }

    #[test]
    fn pc_assignment_is_clockwise_and_skips_pc16() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet50();
        let (stats, par) = stats_and_par(&net);
        let plan = algorithm1(&stats, &par, 31, 3, true, |_| false);
        let asg = assign_pcs(&stats, &par, &plan.offload, &d).unwrap();
        // no layer lands on the excluded PC16
        for pcs in &asg.pcs {
            assert!(pcs.iter().all(|&(pc, _)| pc != 16));
        }
        assert_eq!(asg.free_slots[16], 0, "PC16 must hold zero slots");
        // earliest offloaded layer sits on the lowest-numbered PCs
        let first = asg.pcs.iter().find(|p| !p.is_empty()).unwrap();
        assert!(first.iter().all(|&(pc, _)| pc < 16), "first layers use bottom stack: {first:?}");
        // capacity respected
        for (pc, &f) in asg.free_slots.iter().enumerate() {
            assert!(f <= 3, "PC{pc} free {f}");
        }
    }

    #[test]
    fn pc_assignment_spans_multiple_pcs_for_wide_layers() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let o = CompilerOptions::default();
        let stats: Vec<LayerStats> =
            net.layers().iter().map(|l| LayerStats::from_layer(l, &o)).collect();
        let mut par = vec![Parallelism { p_i: 1, p_o: 1 }; stats.len()];
        // give one layer 7 chains -> needs ceil(7/3) = 3 PCs
        let li = stats.iter().position(|s| s.has_weights).unwrap();
        par[li] = Parallelism { p_i: 7, p_o: 1 };
        let mut offload = vec![false; stats.len()];
        offload[li] = true;
        let asg = assign_pcs(&stats, &par, &offload, &d).unwrap();
        assert_eq!(asg.pcs[li].len(), 3, "{:?}", asg.pcs[li]);
    }
}
