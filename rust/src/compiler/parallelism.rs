//! Per-layer parallelism allocation.
//!
//! HPIPE "chooses the number of input and output channels processed in
//! parallel, p_i and p_o for each layer, to increase the throughput of
//! layers that would otherwise bottleneck the computation" (§II-B). This
//! is a classic balanced-pipeline allocation: repeatedly give the
//! bottleneck layer the cheapest useful parallelism increase until the
//! device (ALMs / AI-TBs / optional chain budget) is exhausted.

use crate::compiler::resources::{LayerStats, ALM_PER_ENGINE, ALM_PER_TB};
use crate::config::{CompilerOptions, DeviceConfig};

/// Chosen parallelism for one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Input-channel parallelism in units of 10 channels (AI-TB lanes).
    pub p_i: u32,
    /// Output channels in parallel.
    pub p_o: u32,
}

impl Parallelism {
    pub fn chains(&self) -> u32 {
        self.p_i * self.p_o
    }
}

/// Smallest p' > p that strictly reduces `ceil(groups / p')`, or None.
fn next_useful_p(groups: u64, p: u32) -> Option<u32> {
    let cur = groups.div_ceil(p as u64);
    if cur <= 1 {
        return None;
    }
    // smallest p' with ceil(groups/p') == cur-1 ... but any reduction works;
    // take p' = ceil(groups / (cur - 1)) which reduces by exactly one group.
    let p2 = groups.div_ceil(cur - 1) as u32;
    (p2 > p).then_some(p2)
}

/// Allocation result.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// Index-aligned with the `stats` slice passed in.
    pub par: Vec<Parallelism>,
    /// Bottleneck compute cycles per image after allocation.
    pub bottleneck_cycles: u64,
    pub total_tbs: u64,
    pub total_alms: u64,
}

/// Budget the allocator works against.
#[derive(Debug, Clone)]
pub struct Budget {
    pub max_tbs: u64,
    pub max_alms: u64,
    /// Optional cap on total tensor chains (all-HBM mode: 3 per usable
    /// pseudo-channel).
    pub max_chains: Option<u64>,
    /// Per-layer chain cap (weight-memory fanout / Fmax limit — see
    /// `CompilerOptions::max_chains_per_layer`).
    pub max_chains_per_layer: u32,
}

impl Budget {
    pub fn from_device(d: &DeviceConfig, opts: &CompilerOptions, all_hbm: bool) -> Self {
        Self {
            max_tbs: (d.tensor_blocks as f64 * opts.max_utilization) as u64,
            max_alms: (d.alms as f64 * opts.max_utilization) as u64,
            max_chains: all_hbm.then(|| d.usable_pcs() as u64 * d.chains_per_pc() as u64),
            max_chains_per_layer: opts.max_chains_per_layer,
        }
    }
}

/// Allocate parallelism for all weight layers.
pub fn allocate(stats: &[LayerStats], budget: &Budget) -> Allocation {
    let n = stats.len();
    let mut par = vec![Parallelism { p_i: 1, p_o: 1 }; n];

    let tbs = |par: &[Parallelism]| -> u64 {
        stats
            .iter()
            .zip(par)
            .filter(|(s, _)| s.has_weights)
            .map(|(s, p)| s.tensor_blocks(p.p_i, p.p_o))
            .sum()
    };
    let chains = |par: &[Parallelism]| -> u64 {
        stats
            .iter()
            .zip(par)
            .filter(|(s, _)| s.has_weights)
            .map(|(_, p)| p.chains() as u64)
            .sum()
    };
    let alms = |t: u64| -> u64 {
        let engines = stats.iter().filter(|s| s.has_weights).count() as u64;
        engines * ALM_PER_ENGINE + t * ALM_PER_TB
    };

    loop {
        // Find the bottleneck layer.
        let (bi, bcycles) = match stats
            .iter()
            .zip(par.iter())
            .enumerate()
            .filter(|(_, (s, _))| s.has_weights)
            .map(|(i, (s, p))| (i, s.cycles_per_image(p.p_i, p.p_o)))
            .max_by_key(|&(_, c)| c)
        {
            Some(x) => x,
            None => break,
        };
        if bcycles <= 1 {
            break;
        }
        let s = &stats[bi];
        let p = par[bi];

        // Candidate moves: bump p_i or p_o to the next useful value.
        let ci_groups = (s.ci as u64).div_ceil(10).max(1);
        let co_groups = s.co.max(1) as u64;
        let mut cands: Vec<Parallelism> = Vec::new();
        if !s.depthwise {
            if let Some(pi2) = next_useful_p(ci_groups, p.p_i) {
                cands.push(Parallelism { p_i: pi2, p_o: p.p_o });
            }
        }
        if let Some(po2) = next_useful_p(co_groups, p.p_o) {
            cands.push(Parallelism { p_i: p.p_i, p_o: po2 });
        }
        cands.retain(|c| c.chains() <= budget.max_chains_per_layer);
        // Pick the move with the best cycles-saved per extra tensor block.
        let cur_cycles = s.cycles_per_image(p.p_i, p.p_o);
        let cur_tb = s.tensor_blocks(p.p_i, p.p_o);
        let best = cands
            .into_iter()
            .filter_map(|c| {
                let dc = cur_cycles.saturating_sub(s.cycles_per_image(c.p_i, c.p_o));
                let dt = s.tensor_blocks(c.p_i, c.p_o).saturating_sub(cur_tb).max(1);
                (dc > 0).then(|| (c, dc as f64 / dt as f64))
            })
            .max_by(|a, b| a.1.total_cmp(&b.1));
        let Some((cand, _)) = best else {
            break; // bottleneck is at max parallelism
        };

        // Apply tentatively and check budgets.
        let old = par[bi];
        par[bi] = cand;
        let t = tbs(&par);
        let within = t <= budget.max_tbs
            && alms(t) <= budget.max_alms
            && budget.max_chains.map_or(true, |m| chains(&par) <= m);
        if !within {
            par[bi] = old;
            break; // the bottleneck cannot grow further: we're done
        }
    }

    let t = tbs(&par);
    let bottleneck_cycles = stats
        .iter()
        .zip(par.iter())
        .filter(|(s, _)| s.has_weights)
        .map(|(s, p)| s.cycles_per_image(p.p_i, p.p_o))
        .max()
        .unwrap_or(1);
    Allocation { total_tbs: t, total_alms: alms(t), par, bottleneck_cycles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompilerOptions;
    use crate::nn::zoo;

    fn stats_for(net: &crate::nn::Network) -> Vec<LayerStats> {
        let o = CompilerOptions::default();
        net.layers().iter().map(|l| LayerStats::from_layer(l, &o)).collect()
    }

    fn device_budget() -> Budget {
        let d = DeviceConfig::stratix10_nx2100();
        Budget::from_device(&d, &CompilerOptions::default(), false)
    }

    #[test]
    fn next_useful_p_reduces_groups() {
        // 7 groups: p=1 -> 7; next useful p=2 -> ceil(7/2)=4 ... each step
        // strictly reduces.
        let mut p = 1;
        let mut seen = vec![7u64.div_ceil(1)];
        while let Some(p2) = next_useful_p(7, p) {
            let g = 7u64.div_ceil(p2 as u64);
            assert!(g < *seen.last().unwrap());
            seen.push(g);
            p = p2;
        }
        assert_eq!(*seen.last().unwrap(), 1);
    }

    #[test]
    fn allocation_respects_budgets() {
        let stats = stats_for(&zoo::resnet18());
        let b = device_budget();
        let a = allocate(&stats, &b);
        assert!(a.total_tbs <= b.max_tbs, "{} TBs", a.total_tbs);
        assert!(a.total_alms <= b.max_alms);
    }

    #[test]
    fn allocation_improves_over_minimum() {
        let stats = stats_for(&zoo::resnet18());
        let min_bottleneck = stats
            .iter()
            .filter(|s| s.has_weights)
            .map(|s| s.cycles_per_image(1, 1))
            .max()
            .unwrap();
        let a = allocate(&stats, &device_budget());
        assert!(
            a.bottleneck_cycles * 4 < min_bottleneck,
            "allocated {} vs min-parallelism {min_bottleneck}",
            a.bottleneck_cycles
        );
    }

    #[test]
    fn pipeline_roughly_balanced() {
        // After allocation, no layer should be drastically faster than the
        // bottleneck while still holding lots of parallelism (that would
        // be wasted resources). Check: median layer cycles within 100x of
        // bottleneck and bottleneck not improvable was reached.
        let stats = stats_for(&zoo::resnet50());
        let a = allocate(&stats, &device_budget());
        let mut cycles: Vec<u64> = stats
            .iter()
            .zip(a.par.iter())
            .filter(|(s, _)| s.has_weights)
            .map(|(s, p)| s.cycles_per_image(p.p_i, p.p_o))
            .collect();
        cycles.sort_unstable();
        let bottleneck = *cycles.last().unwrap();
        assert_eq!(bottleneck, a.bottleneck_cycles);
        assert!(bottleneck > 0);
    }

    #[test]
    fn chain_cap_binds_in_all_hbm_mode() {
        let d = DeviceConfig::stratix10_nx2100();
        let o = CompilerOptions::default();
        let stats = stats_for(&zoo::resnet50());
        let unlimited = allocate(&stats, &Budget::from_device(&d, &o, false));
        let capped = allocate(&stats, &Budget::from_device(&d, &o, true));
        let chains = |a: &Allocation| -> u64 {
            stats
                .iter()
                .zip(a.par.iter())
                .filter(|(s, _)| s.has_weights)
                .map(|(_, p)| p.chains() as u64)
                .sum()
        };
        assert!(chains(&capped) <= 93);
        assert!(
            capped.bottleneck_cycles >= unlimited.bottleneck_cycles,
            "chain cap must not speed things up"
        );
    }

    #[test]
    fn depthwise_only_scales_po() {
        let stats = stats_for(&zoo::mobilenet_v1());
        let a = allocate(&stats, &device_budget());
        for (s, p) in stats.iter().zip(a.par.iter()) {
            if s.depthwise {
                assert_eq!(p.p_i, 1, "{}: depthwise p_i fixed", s.name);
            }
        }
    }
}
