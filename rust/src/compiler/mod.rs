//! The H2PIPE compiler.
//!
//! Pipeline: IR network -> per-layer [`resources::LayerStats`] ->
//! balanced-pipeline parallelism allocation ([`parallelism`]) ->
//! Eq. 1 / Algorithm 1 offload selection + clockwise PC assignment
//! ([`offload`]) -> burst-length policy -> [`plan::AcceleratorPlan`].

pub mod offload;
pub mod parallelism;
pub mod plan;
pub mod resources;

pub use offload::{algorithm1, algorithm1_sparse, assign_pcs, score, score_sparse};
pub use parallelism::{allocate, Allocation, Budget, Parallelism};
pub use plan::{AcceleratorPlan, LayerPlan};
pub use resources::{memory_breakdown, LayerStats, MemoryBreakdown, ResourceUsage};

use crate::config::{BurstLengthPolicy, CompilerOptions, DeviceConfig, WeightPlacement};
use crate::nn::Network;
use anyhow::{ensure, Context, Result};

/// Measured HBM random-read efficiency by burst length (calibrated from
/// the §III-A traffic experiment; regenerate with
/// `cargo bench --bench fig3a_hbm_efficiency`).
///
/// **Deprecated:** this free function always answers from the default
/// calibration. Prefer [`crate::config::EfficiencyTable`] — the compiler
/// reads `CompilerOptions::efficiency`, so a recalibrated table travels
/// with the options and with every saved plan artifact.
pub fn hbm_read_efficiency(burst_len: u32) -> f64 {
    crate::config::EfficiencyTable::calibrated().lookup(burst_len)
}

/// Compile a network for a device.
///
/// This is the compilation engine; most callers should go through the
/// staged [`crate::session`] API (`Session::builder() -> CompiledModel`),
/// which adds provenance and a persistable JSON artifact around the plan
/// this function returns.
pub fn compile(
    net: &Network,
    device: &DeviceConfig,
    opts: &CompilerOptions,
) -> Result<AcceleratorPlan> {
    opts.validate()?;
    net.validate().context("network validation")?;

    let stats: Vec<LayerStats> =
        net.layers().iter().map(|l| LayerStats::from_layer(l, opts)).collect();

    let m20k_budget = device.m20k_blocks as u64; // BRAM may fill to ~98%
    let trial_burst = match opts.burst_length {
        BurstLengthPolicy::Fixed(b) => b,
        BurstLengthPolicy::Auto => 8,
    };
    // Price the whole memory system for a candidate placement: banked
    // on-chip weight memories + activation buffers + FIFO costs for
    // offloaded layers.
    let m20k_for = |offload: &[bool], par: &[Parallelism]| -> u64 {
        let mut total = 0u64;
        for (i, s) in stats.iter().enumerate() {
            total += ceil_div_m20k(s.act_bits);
            if !s.has_weights {
                continue;
            }
            if offload[i] {
                total += s.hbm_weight_m20k_at(trial_burst, opts.last_stage_fifo_depth);
            } else {
                let cap = crate::util::ceil_div(s.weight_bits, resources::M20K_BITS);
                let bank = 2 * par[i].chains() as u64;
                total += (cap + bank) * s.dup;
            }
        }
        total
    };

    // 1+2. Co-iterate parallelism scale with memory fit: compute-budget
    // parallelism is allocated first; if Algorithm 1 cannot make the
    // memory system fit (too many chains -> too little offloadable
    // bandwidth, too much weight-memory banking), the compute budget is
    // scaled down and the allocation repeated — memory-bound networks
    // like ResNet-50 trade parallelism for offload capacity exactly as
    // the paper's resource columns show (R50: 98% BRAM, only 33% DSP).
    let mut scale = opts.max_utilization;
    let (alloc, off_plan) = loop {
        let mut budget = Budget::from_device(device, opts, opts.all_hbm);
        budget.max_tbs = (device.tensor_blocks as f64 * scale) as u64;
        budget.max_alms = (device.alms as f64 * scale.min(opts.max_utilization)) as u64;
        let alloc = allocate(&stats, &budget);
        let mut off_plan = offload::algorithm1_sparse(
            &stats,
            &alloc.par,
            device.usable_pcs() as u64,
            device.chains_per_pc() as u64,
            opts.all_hbm,
            opts.sparsity_fraction,
            |offload| {
                // the greedy's fit check sees the forced placements too,
                // so it stops (or keeps going) against the real memory
                // system the overrides will produce
                let mut trial = offload.to_vec();
                for &(idx, to_hbm) in &opts.offload_overrides {
                    if idx < trial.len() {
                        trial[idx] = to_hbm;
                    }
                }
                m20k_for(&trial, &alloc.par) <= (m20k_budget as f64 * 0.98) as u64
            },
        );
        apply_offload_overrides(&stats, &alloc.par, opts, device, &mut off_plan)?;
        if m20k_for(&off_plan.offload, &alloc.par) <= m20k_budget {
            break (alloc, off_plan);
        }
        scale *= 0.75;
        ensure!(
            scale >= 0.005,
            "{}: memory system does not fit even with maximal HBM offload and \
             minimal parallelism ({} of {m20k_budget} M20Ks)",
            net.name,
            m20k_for(&off_plan.offload, &alloc.par)
        );
    };

    // 3. Pseudo-channel assignment (§V-B clockwise).
    let asg = assign_pcs(&stats, &alloc.par, &off_plan.offload, device)?;

    // 4. Burst-length policy (§VI-A): 8 when the bottleneck layer is on
    //    chip, 32 when it streams from HBM.
    let bottleneck_idx = stats
        .iter()
        .enumerate()
        .filter(|(_, s)| s.has_weights)
        .max_by_key(|(i, s)| s.cycles_per_image(alloc.par[*i].p_i, alloc.par[*i].p_o))
        .map(|(i, _)| i)
        .context("no weight layers")?;
    let burst_len = match opts.burst_length {
        BurstLengthPolicy::Fixed(b) => b,
        BurstLengthPolicy::Auto => {
            if off_plan.offload[bottleneck_idx] {
                32
            } else {
                8
            }
        }
    };
    let eff = opts.efficiency.lookup(burst_len);

    // 5. Assemble the plan + analytic estimates.
    let layers: Vec<LayerPlan> = stats
        .iter()
        .enumerate()
        .map(|(i, s)| LayerPlan {
            stats: s.clone(),
            par: alloc.par[i],
            placement: if off_plan.offload[i] {
                WeightPlacement::Hbm
            } else {
                WeightPlacement::OnChip
            },
            pcs: asg.pcs[i].clone(),
            score: off_plan.scores[i],
        })
        .collect();

    let mut plan = AcceleratorPlan {
        network: net.name.clone(),
        device: device.clone(),
        options: opts.clone(),
        layers,
        burst_len,
        usage: ResourceUsage::default(),
        bottleneck_cycles: alloc.bottleneck_cycles,
        est_throughput: 0.0,
        est_latency: 0.0,
        hbm_read_efficiency: eff,
        free_bw_slots: off_plan.free_bw,
    };
    plan.usage = plan.recompute_usage();

    // Analytic estimates: shared with the static verifier so a fresh
    // compile always recomputes clean under `h2pipe check`.
    let (est_throughput, est_latency) = plan.analytic_estimates();
    plan.est_throughput = est_throughput;
    plan.est_latency = est_latency;
    debug_assert_eq!(plan.bottleneck_cycles, plan.recompute_bottleneck_cycles());
    debug_assert_eq!(plan.free_bw_slots, plan.recompute_free_bw_slots());
    Ok(plan)
}

fn ceil_div_m20k(bits: u64) -> u64 {
    crate::util::ceil_div(bits, resources::M20K_BITS)
}

/// Apply `CompilerOptions::offload_overrides` on top of an Algorithm 1
/// result and re-derive the free-bandwidth count. Overrides share the
/// pseudo-channel budget with the greedy's own picks, so a set of flips
/// that oversubscribes the HBM chain slots (or names a layer that cannot
/// hold weights) fails compilation here — the autotuner records such
/// candidates as infeasible instead of ever scoring them.
fn apply_offload_overrides(
    stats: &[LayerStats],
    par: &[Parallelism],
    opts: &CompilerOptions,
    device: &DeviceConfig,
    off: &mut offload::OffloadPlan,
) -> Result<()> {
    if opts.offload_overrides.is_empty() {
        return Ok(());
    }
    for &(idx, to_hbm) in &opts.offload_overrides {
        ensure!(
            idx < stats.len(),
            "offload override targets layer {idx} but the network has {} layers",
            stats.len()
        );
        ensure!(
            stats[idx].has_weights,
            "offload override targets weightless layer {idx} ({})",
            stats[idx].name
        );
        off.offload[idx] = to_hbm;
    }
    let cap = device.usable_pcs() as u64 * device.chains_per_pc() as u64;
    let used: u64 = stats
        .iter()
        .enumerate()
        .filter(|&(i, _)| off.offload[i])
        .map(|(i, _)| par[i].chains() as u64)
        .sum();
    ensure!(
        used <= cap,
        "offload overrides oversubscribe HBM bandwidth: {used} chain slots > {cap}"
    );
    off.free_bw = cap - used;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn device() -> DeviceConfig {
        DeviceConfig::stratix10_nx2100()
    }

    #[test]
    fn compile_all_table1_models() {
        let d = device();
        let o = CompilerOptions::default();
        for net in zoo::table1_models() {
            let plan = compile(&net, &d, &o).unwrap_or_else(|e| panic!("{}: {e}", net.name));
            assert!(plan.est_throughput > 0.0);
            assert!(plan.usage.m20k <= d.m20k_blocks as u64, "{}", net.name);
            assert!(plan.usage.tensor_blocks <= d.tensor_blocks as u64);
        }
    }

    #[test]
    fn mobilenets_stay_fully_on_chip() {
        // They fit in BRAM (Table I), so the hybrid compiler offloads
        // nothing.
        let d = device();
        let o = CompilerOptions::default();
        for net in [zoo::mobilenet_v1(), zoo::mobilenet_v2()] {
            let plan = compile(&net, &d, &o).unwrap();
            assert_eq!(plan.hbm_layers().count(), 0, "{}", net.name);
        }
    }

    #[test]
    fn resnet50_and_vgg_must_offload() {
        let d = device();
        let o = CompilerOptions::default();
        for net in [zoo::resnet50(), zoo::vgg16()] {
            let plan = compile(&net, &d, &o).unwrap();
            assert!(plan.hbm_layers().count() > 0, "{}", net.name);
        }
    }

    #[test]
    fn all_hbm_mode_offloads_everything_it_can() {
        let d = device();
        let mut o = CompilerOptions::default();
        o.all_hbm = true;
        let plan = compile(&zoo::resnet18(), &d, &o).unwrap();
        let on_chip = plan.onchip_layers().count();
        // bandwidth-limited: not necessarily zero, but the big layers go
        let hbm = plan.hbm_layers().count();
        assert!(hbm >= on_chip, "hbm {hbm} vs on-chip {on_chip}");
    }

    #[test]
    fn hybrid_beats_all_hbm() {
        // Fig. 6's core message: the hybrid memory system outperforms
        // all-HBM for every network.
        let d = device();
        for net in zoo::eval_models() {
            let hybrid = compile(&net, &d, &CompilerOptions::default()).unwrap();
            let mut o = CompilerOptions::default();
            o.all_hbm = true;
            let all_hbm = compile(&net, &d, &o).unwrap();
            assert!(
                hybrid.est_throughput > all_hbm.est_throughput,
                "{}: hybrid {:.0} vs all-HBM {:.0}",
                net.name,
                hybrid.est_throughput,
                all_hbm.est_throughput
            );
        }
    }

    #[test]
    fn auto_burst_length_follows_bottleneck_placement() {
        let d = device();
        let o = CompilerOptions::default();
        // ResNet-18's bottleneck stays on chip -> BL8 (§VI-A conclusion)
        let r18 = compile(&zoo::resnet18(), &d, &o).unwrap();
        assert_eq!(r18.burst_len, 8, "R18 expected BL8");
    }

    #[test]
    fn bandwidth_slots_never_oversubscribed() {
        let d = device();
        let mut o = CompilerOptions::default();
        o.all_hbm = true;
        for net in zoo::eval_models() {
            let plan = compile(&net, &d, &o).unwrap();
            let used: u64 = plan.hbm_layers().map(|l| l.par.chains() as u64).sum();
            let cap = d.usable_pcs() as u64 * d.chains_per_pc() as u64;
            assert!(used + plan.free_bw_slots == cap, "{}: {used}+{}", net.name, plan.free_bw_slots);
        }
    }

    #[test]
    fn pc_slots_respected_per_layer() {
        let d = device();
        let o = CompilerOptions::default();
        let plan = compile(&zoo::vgg16(), &d, &o).unwrap();
        for l in plan.hbm_layers() {
            assert!(!l.pcs.is_empty(), "{} offloaded but no PCs", l.stats.name);
            // a layer's PC slots exactly cover its chain demand
            let slots: u32 = l.pcs.iter().map(|&(_, c)| c).sum();
            assert_eq!(slots, l.par.chains(), "{}: {:?}", l.stats.name, l.pcs);
        }
    }

    #[test]
    fn throughput_in_plausible_range() {
        // Analytic estimates should land within ~2.5x of the paper's
        // hybrid hardware numbers (the cycle simulator does better).
        let d = device();
        let o = CompilerOptions::default();
        let targets = [("ResNet-18", 4174.0), ("ResNet-50", 1004.0), ("VGG-16", 545.0)];
        for (name, t) in targets {
            let net = zoo::by_name(name).unwrap();
            let plan = compile(&net, &d, &o).unwrap();
            let r = plan.est_throughput / t;
            assert!(
                (0.4..2.5).contains(&r),
                "{name}: est {:.0} vs paper {t} (ratio {r:.2})",
                plan.est_throughput
            );
        }
    }

    #[test]
    fn recalibrated_efficiency_table_overrides_stall_model() {
        let d = device();
        let mut o = CompilerOptions::default();
        o.burst_length = BurstLengthPolicy::Fixed(8);
        let base = compile(&zoo::resnet50(), &d, &o).unwrap();
        assert_eq!(base.hbm_read_efficiency, o.efficiency.lookup(8));
        // a (hypothetical) recalibration halving BL8 efficiency must flow
        // into the plan without any source edit
        let mut recal = o.clone();
        for e in recal.efficiency.entries.iter_mut() {
            if e.0 == 8 {
                e.1 = 0.413;
            }
        }
        let slow = compile(&zoo::resnet50(), &d, &recal).unwrap();
        assert_eq!(slow.hbm_read_efficiency, 0.413);
        assert!(
            slow.est_throughput <= base.est_throughput,
            "halved HBM efficiency cannot raise throughput: {:.0} vs {:.0}",
            slow.est_throughput,
            base.est_throughput
        );
    }

    #[test]
    fn legacy_efficiency_wrapper_matches_table() {
        for bl in crate::config::BurstLengthPolicy::LEGAL {
            assert_eq!(
                hbm_read_efficiency(bl),
                crate::config::EfficiencyTable::calibrated().lookup(bl)
            );
        }
    }

    #[test]
    fn offload_overrides_flip_placements_and_rebalance_bandwidth() {
        let d = device();
        let base = compile(&zoo::resnet18(), &d, &CompilerOptions::default()).unwrap();
        // force the two largest on-chip weight layers to HBM
        let mut targets: Vec<usize> = base
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.stats.has_weights && l.placement == WeightPlacement::OnChip)
            .map(|(i, _)| i)
            .collect();
        targets.sort_by_key(|&i| std::cmp::Reverse(base.layers[i].stats.weight_m20k));
        targets.truncate(2);
        targets.sort_unstable();
        let mut o = CompilerOptions::default();
        o.offload_overrides = targets.iter().map(|&i| (i, true)).collect();
        let plan = compile(&zoo::resnet18(), &d, &o).unwrap();
        for &i in &targets {
            assert_eq!(plan.layers[i].placement, WeightPlacement::Hbm, "layer {i} must flip");
            assert!(!plan.layers[i].pcs.is_empty(), "flipped layer {i} needs PC slots");
        }
        let cap = d.usable_pcs() as u64 * d.chains_per_pc() as u64;
        let used: u64 = plan.hbm_layers().map(|l| l.par.chains() as u64).sum();
        assert_eq!(used + plan.free_bw_slots, cap, "free bandwidth must be re-derived");
    }

    #[test]
    fn bad_offload_overrides_fail_compilation() {
        let d = device();
        let net = zoo::resnet18();
        let mut o = CompilerOptions::default();
        o.offload_overrides = vec![(10_000, true)];
        assert!(compile(&net, &d, &o).is_err(), "out-of-range layer index");
        let weightless = net
            .layers()
            .iter()
            .position(|l| l.weight_params() == 0 && l.id > 0)
            .expect("resnet18 has pools/adds");
        let mut o = CompilerOptions::default();
        o.offload_overrides = vec![(weightless, true)];
        assert!(compile(&net, &d, &o).is_err(), "weightless layer cannot offload");
    }

    #[test]
    fn deterministic_compilation() {
        let d = device();
        let o = CompilerOptions::default();
        let a = compile(&zoo::resnet50(), &d, &o).unwrap();
        let b = compile(&zoo::resnet50(), &d, &o).unwrap();
        assert_eq!(a.burst_len, b.burst_len);
        assert_eq!(a.usage.m20k, b.usage.m20k);
        for (x, y) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(x.par, y.par);
            assert_eq!(x.placement, y.placement);
            assert_eq!(x.pcs, y.pcs);
        }
    }
}
