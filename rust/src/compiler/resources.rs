//! Per-layer resource accounting — the arithmetic behind Table I, the
//! Eq. 1 score numerator, and the logic-utilization columns of
//! Tables II/III.
//!
//! Conventions (HPIPE NX, §II-B / §III-B):
//!   * `p_i` counts input-channel parallelism in units of **10 channels**
//!     (one AI-TB dot-product lane group = 80 bits of weights/cycle);
//!     `p_o` counts output channels computed in parallel.
//!   * one *tensor chain* = the daisy chain of `ceil(out_w/3)` AI-TBs that
//!     covers the full activation width for one (p_i, p_o) combination;
//!     a layer uses `p_i * p_o` chains and each chain consumes 80 bits of
//!     weight data per core cycle.
//!   * weight memories (and last-stage FIFOs) are duplicated once per
//!     group of 6 AI-TBs = 18 output pixels (§IV-A), i.e.
//!     `dup = ceil(out_w / 18)`.

use crate::config::{CompilerOptions, DeviceConfig};
use crate::nn::{ConvKind, Layer, OpKind};
use crate::util::ceil_div;

/// Bits per M20K block (20 Kb).
pub const M20K_BITS: u64 = 20480;
/// Output pixels covered by one duplicated weight-memory / FIFO group
/// (6 AI-TBs x 3 pixels).
pub const DUP_GROUP_PIXELS: u64 = 18;
/// Weight bits one tensor chain consumes per core cycle.
pub const CHAIN_WEIGHT_BITS: u64 = 80;
/// Output pixels one AI-TB computes per cycle.
pub const TB_PIXELS: u64 = 3;
/// Input channels one AI-TB lane group covers.
pub const TB_LANES: u64 = 10;

/// ALM cost model, fitted to the Table III utilization columns.
pub const ALM_PER_ENGINE: u64 = 5_000;
pub const ALM_PER_TB: u64 = 170;
/// Prefetch/distribution logic per HBM-offloaded layer (§IV-A).
pub const ALM_PER_HBM_LAYER: u64 = 1_800;
/// Registers per bit of boot-time write-path width (§IV-C: narrowing from
/// 256 to 30 bits saves >3000 registers ~= 12.8 regs/bit; 2 ALMs ~= 4 regs).
pub const REG_PER_WRITE_PATH_BIT: u64 = 13;

/// Static per-layer accounting, independent of parallelism.
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// IR layer id.
    pub layer: usize,
    pub name: String,
    /// Raw weight bits (params x weight precision).
    pub weight_bits: u64,
    /// On-chip weight storage in M20K blocks *including* the
    /// `ceil(out_w/18)` duplication (Table I accounting).
    pub weight_m20k: u64,
    /// Weight-memory duplication factor.
    pub dup: u64,
    /// Activation buffering in bits (line buffers, pooling windows, the
    /// full-tensor skip buffers of residual adds, x2 Fmax duplication).
    pub act_bits: u64,
    /// Weight elements re-read per image: kh*kw*ci*co*out_h (Eq. 2 term —
    /// HPIPE reloads the kernel once per output line).
    pub weight_traffic_per_image: u64,
    /// MACs per image.
    pub macs: u64,
    /// Output geometry.
    pub out_h: u32,
    pub out_w: u32,
    /// Per-(p_i=1,p_o=1) cycle count factors: cycles/image =
    /// out_h * kh * kw * ceil(ci/10/p_i) * ceil(co/p_o).
    pub kh: u32,
    pub kw: u32,
    pub ci: u32,
    pub co: u32,
    /// True for layers that hold weights (engines the compiler manages).
    pub has_weights: bool,
    /// Depthwise engines have no channel-parallel weight reuse.
    pub depthwise: bool,
}

impl LayerStats {
    /// Build stats for one IR layer under the given options.
    pub fn from_layer(l: &Layer, opts: &CompilerOptions) -> Self {
        let wb = opts.weight_bits as u64;
        let (kh, kw, ci, co, depthwise) = match &l.op {
            OpKind::Conv { kind, kh, kw, out_c, .. } => {
                (*kh, *kw, l.in_shape().c, *out_c, *kind == ConvKind::Depthwise)
            }
            OpKind::Fc { out_features } => (1, 1, l.in_elems() as u32, *out_features, false),
            OpKind::SqueezeExcite { squeeze_c } => (1, 1, l.out.c.max(1), 2 * *squeeze_c, false),
            _ => (0, 0, l.in_shape().c, l.out.c, false),
        };
        let weight_bits = l.weight_params() * wb;
        let dup = ceil_div(l.out.w as u64, DUP_GROUP_PIXELS).max(1);
        let weight_m20k =
            if weight_bits > 0 { ceil_div(weight_bits, M20K_BITS) * dup } else { 0 };
        let act_bits = Self::act_bits_for(l, wb);
        let weight_traffic_per_image = l.weight_params() * l.out.h as u64;
        Self {
            layer: l.id,
            name: l.name.clone(),
            weight_bits,
            weight_m20k,
            dup,
            act_bits,
            weight_traffic_per_image,
            macs: l.macs(),
            out_h: l.out.h,
            out_w: l.out.w,
            kh,
            kw,
            ci,
            co,
            has_weights: weight_bits > 0,
            depthwise,
        }
    }

    /// Activation buffering model (validated against Table I):
    ///   * convs / pools hold a sliding window of `k+1` input lines,
    ///     double-buffered for Fmax (x2) — §II-B;
    ///   * residual adds buffer the full skip tensor (the dominant term
    ///     for the ResNets: ~44 of ResNet-50's 57 Mb);
    ///   * FC layers hold their input vector, double-buffered.
    fn act_bits_for(l: &Layer, wb: u64) -> u64 {
        let in_s = l.in_shape();
        match &l.op {
            OpKind::Conv { kh, .. } => {
                u64::from(*kh + 1) * in_s.w as u64 * in_s.c as u64 * wb * 2
            }
            OpKind::MaxPool { k, .. } => {
                u64::from(*k + 1) * in_s.w as u64 * in_s.c as u64 * wb * 2
            }
            OpKind::Add => in_s.elems() * wb,
            OpKind::Fc { .. } => l.in_elems() * wb * 2,
            OpKind::GlobalAvgPool => in_s.w as u64 * in_s.c as u64 * wb * 2,
            OpKind::SqueezeExcite { .. } => l.out.c as u64 * 32 * 2,
            OpKind::Input { .. } => 0,
        }
    }

    /// Tensor chains used at parallelism (p_i, p_o).
    pub fn chains(&self, p_i: u32, p_o: u32) -> u32 {
        p_i * p_o
    }

    /// AI tensor blocks used at (p_i, p_o).
    pub fn tensor_blocks(&self, p_i: u32, p_o: u32) -> u64 {
        self.chains(p_i, p_o) as u64 * ceil_div(self.out_w as u64, TB_PIXELS)
    }

    /// Compute cycles per image at (p_i, p_o), ignoring memory stalls.
    pub fn cycles_per_image(&self, p_i: u32, p_o: u32) -> u64 {
        if !self.has_weights {
            return 0;
        }
        let ci_groups = ceil_div(self.ci as u64, TB_LANES * p_i as u64).max(1);
        let co_groups = ceil_div(self.co as u64, p_o as u64).max(1);
        let per_line = self.kh as u64 * self.kw as u64 * ci_groups * co_groups;
        (self.out_h as u64 * per_line).max(1)
    }

    /// Maximum useful parallelism (beyond this, extra lanes idle).
    pub fn max_p_i(&self) -> u32 {
        if self.depthwise {
            1 // depthwise engines broadcast no channel groups
        } else {
            ceil_div(self.ci as u64, TB_LANES) as u32
        }
    }

    pub fn max_p_o(&self) -> u32 {
        self.co.max(1)
    }

    /// HBM weight-stream demand in bits per core cycle at (p_i, p_o).
    pub fn weight_bw_bits_per_cycle(&self, p_i: u32, p_o: u32) -> u64 {
        self.chains(p_i, p_o) as u64 * CHAIN_WEIGHT_BITS
    }

    /// On-chip M20K cost if this layer's weights stay on chip.
    pub fn onchip_weight_m20k(&self) -> u64 {
        self.weight_m20k
    }

    /// M20K cost if offloaded: 2 M20Ks (512x40 last-stage FIFO) per
    /// duplicate (Eq. 1's "-2" term) plus the burst-matching FIFO.
    pub fn hbm_weight_m20k(&self, burst_len: u32) -> u64 {
        self.hbm_weight_m20k_at(burst_len, 512)
    }

    /// [`Self::hbm_weight_m20k`] at an explicit last-stage FIFO depth.
    /// The paper's 512-word sizing is where the Eq. 1 "-2" comes from;
    /// the autotuner explores shallower/deeper FIFOs, whose M20K cost
    /// scales with depth (never below one block per duplicate).
    pub fn hbm_weight_m20k_at(&self, burst_len: u32, fifo_depth: u32) -> u64 {
        let last_stage = last_stage_fifo_m20k(fifo_depth) * self.dup;
        // burst-matching FIFO: sized to hold 4 bursts of 256-bit words
        let bm_bits = 4 * burst_len as u64 * 256;
        last_stage + ceil_div(bm_bits, M20K_BITS)
    }

    /// M20K savings from offloading (the Eq. 1 numerator).
    pub fn m20k_saved(&self, burst_len: u32) -> i64 {
        self.onchip_weight_m20k() as i64 - self.hbm_weight_m20k(burst_len) as i64
    }
}

/// M20K blocks of one duplicated last-stage weight FIFO at `depth` 80-bit
/// words: 2 blocks at the paper's 512 words (§IV-A), scaling linearly
/// with depth and never dropping below one physical block.
pub fn last_stage_fifo_m20k(depth: u32) -> u64 {
    ceil_div(2 * depth as u64, 512).max(1)
}

/// Whole-accelerator resource totals.
#[derive(Debug, Clone, Default)]
pub struct ResourceUsage {
    pub m20k: u64,
    pub tensor_blocks: u64,
    pub alms: u64,
}

impl ResourceUsage {
    /// Utilization fractions against a device.
    pub fn m20k_frac(&self, d: &DeviceConfig) -> f64 {
        self.m20k as f64 / d.m20k_blocks as f64
    }

    pub fn tb_frac(&self, d: &DeviceConfig) -> f64 {
        self.tensor_blocks as f64 / d.tensor_blocks as f64
    }

    pub fn alm_frac(&self, d: &DeviceConfig) -> f64 {
        self.alms as f64 / d.alms as f64
    }

    pub fn fits(&self, d: &DeviceConfig, max_util: f64) -> bool {
        self.m20k_frac(d) <= max_util.max(0.98).min(1.0)
            && self.tb_frac(d) <= max_util
            && self.alm_frac(d) <= max_util
    }
}

/// Table I row: memory required by a network at minimum parallelism.
#[derive(Debug, Clone)]
pub struct MemoryBreakdown {
    pub model: String,
    pub weight_bits: u64,
    pub act_bits: u64,
}

impl MemoryBreakdown {
    pub fn act_fraction(&self) -> f64 {
        self.act_bits as f64 / (self.weight_bits + self.act_bits) as f64
    }

    /// Does the total exceed the device BRAM (the shaded cells of
    /// Table I)?
    pub fn exceeds(&self, d: &DeviceConfig) -> bool {
        self.weight_bits + self.act_bits > d.bram_bits()
    }
}

/// Compute the Table I accounting for a network: weight memory uses the
/// duplicated-M20K model, activations the line-buffer/skip model.
pub fn memory_breakdown(net: &crate::nn::Network, opts: &CompilerOptions) -> MemoryBreakdown {
    let mut weight_bits = 0u64;
    let mut act_bits = 0u64;
    for l in net.layers() {
        let s = LayerStats::from_layer(l, opts);
        weight_bits += s.weight_m20k * M20K_BITS;
        act_bits += s.act_bits;
    }
    MemoryBreakdown { model: net.name.clone(), weight_bits, act_bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompilerOptions;
    use crate::nn::zoo;

    fn opts() -> CompilerOptions {
        CompilerOptions::default()
    }

    #[test]
    fn table1_weight_memory_magnitudes() {
        // paper Table I (Mb): V1 35, V2 29, V3 32, R18 102, R50 219,
        // VGG 1204. Allow our model +-35% (the paper's numbers embed
        // unpublished HPIPE implementation details; MobileNetV3 deviates
        // most — the published V3-Large checkpoint is 5.4M params = 43 Mb
        // raw, already above the paper's 32 Mb row, suggesting they used
        // a slimmer variant. See EXPERIMENTS.md §Table I.)
        let targets = [
            ("MobileNetV1", 35.0),
            ("MobileNetV2", 29.0),
            ("MobileNetV3", 32.0),
            ("ResNet-18", 102.0),
            ("ResNet-50", 219.0),
            ("VGG-16", 1204.0),
        ];
        for (net, (name, mb)) in zoo::table1_models().iter().zip(targets) {
            assert_eq!(net.name, name);
            let b = memory_breakdown(net, &opts());
            let got = b.weight_bits as f64 / 1e6;
            assert!(
                (0.65 * mb..1.45 * mb).contains(&got),
                "{name}: weight mem {got:.0} Mb vs paper {mb} Mb"
            );
        }
    }

    #[test]
    fn table1_activation_fraction_below_35_percent() {
        // paper: "In all compared networks, the activations represent less
        // than 35% of the memory requirements"
        for net in zoo::table1_models() {
            let b = memory_breakdown(&net, &opts());
            assert!(b.act_fraction() < 0.35, "{}: {:.2}", net.name, b.act_fraction());
        }
    }

    #[test]
    fn table1_vgg_activations_tiny() {
        // paper: VGG-16 activations < 2% of memory
        let b = memory_breakdown(&zoo::vgg16(), &opts());
        assert!(b.act_fraction() < 0.02, "{:.3}", b.act_fraction());
    }

    #[test]
    fn table1_shading_resnet50_and_vgg_exceed_device() {
        let d = DeviceConfig::stratix10_nx2100();
        let fits = |n: &crate::nn::Network| !memory_breakdown(n, &opts()).exceeds(&d);
        assert!(fits(&zoo::mobilenet_v1()));
        assert!(fits(&zoo::mobilenet_v2()));
        assert!(fits(&zoo::mobilenet_v3_large()));
        assert!(!fits(&zoo::resnet50()), "ResNet-50 must exceed 140 Mb");
        assert!(!fits(&zoo::vgg16()), "VGG-16 must exceed 140 Mb");
    }

    #[test]
    fn resnet50_activations_dominated_by_skip_buffers() {
        let net = zoo::resnet50();
        let o = opts();
        let mut add_bits = 0u64;
        let mut other = 0u64;
        for l in net.layers() {
            let s = LayerStats::from_layer(l, &o);
            if matches!(l.op, crate::nn::OpKind::Add) {
                add_bits += s.act_bits;
            } else {
                other += s.act_bits;
            }
        }
        assert!(add_bits > other, "skip buffers {add_bits} vs line buffers {other}");
    }

    #[test]
    fn chains_and_tensor_blocks() {
        let net = zoo::resnet18();
        let l = net.layers().iter().find(|l| l.name == "layer1.0.conv1").unwrap();
        let s = LayerStats::from_layer(l, &opts());
        // 56-wide output: 19 AI-TBs per chain
        assert_eq!(s.tensor_blocks(1, 1), 19);
        assert_eq!(s.tensor_blocks(2, 3), 19 * 6);
        assert_eq!(s.chains(2, 3), 6);
        // dup = ceil(56/18) = 4
        assert_eq!(s.dup, 4);
    }

    #[test]
    fn cycles_scale_inversely_with_parallelism() {
        let net = zoo::resnet18();
        let l = net.layers().iter().find(|l| l.name == "layer1.0.conv1").unwrap();
        let s = LayerStats::from_layer(l, &opts());
        let c11 = s.cycles_per_image(1, 1);
        let c12 = s.cycles_per_image(1, 2);
        let c72 = s.cycles_per_image(7, 64);
        assert!(c12 < c11);
        assert!(c72 < c12);
        // at max useful parallelism one line costs kh*kw cycles
        assert_eq!(c72, 56 * 9);
    }

    #[test]
    fn offload_savings_positive_for_big_layers() {
        let net = zoo::vgg16();
        let l = net.layers().iter().find(|l| l.name == "fc6").unwrap();
        let s = LayerStats::from_layer(l, &opts());
        assert!(s.m20k_saved(8) > 4000, "fc6 must save thousands of M20Ks");
        // savings shrink as burst length grows (bigger burst-matching FIFOs)
        assert!(s.m20k_saved(32) < s.m20k_saved(8));
    }

    #[test]
    fn fifo_depth_scales_last_stage_cost() {
        // 512 words is the paper's 2-M20K sizing; the depth-aware cost
        // must agree with it exactly so default plans are unchanged.
        assert_eq!(last_stage_fifo_m20k(512), 2);
        assert_eq!(last_stage_fifo_m20k(256), 1);
        assert_eq!(last_stage_fifo_m20k(128), 1, "floor of one physical block");
        assert_eq!(last_stage_fifo_m20k(1024), 4);
        let net = zoo::vgg16();
        let l = net.layers().iter().find(|l| l.name == "fc6").unwrap();
        let s = LayerStats::from_layer(l, &opts());
        assert_eq!(s.hbm_weight_m20k(8), s.hbm_weight_m20k_at(8, 512));
        assert!(s.hbm_weight_m20k_at(8, 256) < s.hbm_weight_m20k(8));
        assert!(s.hbm_weight_m20k_at(8, 1024) > s.hbm_weight_m20k(8));
    }

    #[test]
    fn eq2_weight_traffic_counts_per_line_reload() {
        let net = zoo::resnet18();
        let l = net.layers().iter().find(|l| l.name == "conv1").unwrap();
        let s = LayerStats::from_layer(l, &opts());
        // conv1: 7x7x3x64 weights, 112 output lines
        assert_eq!(s.weight_traffic_per_image, 7 * 7 * 3 * 64 * 112);
    }
}
