//! Multi-FPGA clustering: sharding, replication, and fleet serving.
//!
//! The single-device pipeline stops scaling when a network's memory
//! system outgrows one FPGA's M20K and pseudo-channel budget. This
//! module scales it out in three layers:
//!
//! * [`partition`] — cuts a network into pipeline-parallel shards at
//!   layer boundaries where a single activation stream crosses, balances
//!   per-shard M20K/DSP and HBM demand, and compiles each shard as a
//!   standalone accelerator (Eq. 1 / Algorithm 1 offload decisions are
//!   re-run per shard against a full device);
//! * [`fleet`] — cycle-level co-simulation of all shards, one
//!   [`crate::sim::pipeline::PipelineSim`] per device, with inter-device
//!   links modelled as credit-based FIFOs so shard-to-shard back-pressure
//!   and the §IV-B freeze semantics compose across devices;
//! * [`router`] — fleet-level serving: least-outstanding-requests routing
//!   across N replicas with per-replica bounded queues, failover, and
//!   merged metrics.
//!
//! Entry points: `h2pipe serve --replicas N --shards M` and the
//! `cluster_serve` example — both routed through
//! [`crate::session::DeploymentTarget::Fleet`] /
//! [`crate::session::DeploymentTarget::Serve`]; the types here are the
//! engines those deployments drive.

pub mod fleet;
pub mod partition;
pub mod router;

pub use fleet::{FleetConfig, FleetReport, FleetSim, LinkStats, ShardStats};
pub use partition::{
    partition, partition_at, valid_cuts, PartitionOptions, PartitionPlan, ShardPlan,
};
pub use router::{FleetRouter, FleetServeReport};
