//! Fleet-level request router: least-outstanding-requests over N replica
//! servers.
//!
//! Each replica is a full [`InferenceServer`] (own worker thread, own
//! bounded queue, own batcher), standing in for one sharded accelerator
//! fleet. The router keeps an outstanding-request count per replica,
//! sends every request to the least-loaded replica (ties rotate
//! round-robin so idle fleets still share work), and fails over to the
//! next-least-loaded replica when a bounded queue rejects. Latency and
//! rejection accounting happens at the router in a merged
//! [`Metrics`], so the fleet report reflects what clients observed —
//! including failover time — next to the per-replica breakdowns.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{InferenceServer, Metrics, MetricsSnapshot, ServerConfig, ServerReport};
use crate::obs::RequestSpan;
use crate::util::Json;

#[derive(Debug)]
struct Replica {
    server: InferenceServer,
    outstanding: AtomicUsize,
}

/// Router over N identical replicas.
#[derive(Debug)]
pub struct FleetRouter {
    replicas: Vec<Replica>,
    /// Round-robin tie-break cursor.
    rr: AtomicUsize,
    metrics: Mutex<Metrics>,
    /// Router boot time — the origin for request-span timestamps.
    started: Instant,
    /// Per-request spans for `serve --trace`; `None` = tracing off (the
    /// default: no per-request allocation on the serving path).
    spans: Option<Mutex<Vec<RequestSpan>>>,
}

/// Fleet serving summary: merged client-side metrics plus the per-replica
/// server reports.
#[derive(Debug, Clone)]
pub struct FleetServeReport {
    pub replicas: usize,
    pub completed: u64,
    /// Requests no replica could absorb.
    pub rejected: u64,
    pub wall_throughput: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Summed modelled FPGA rate across replicas.
    pub modelled_throughput: f64,
    /// Merged router-level [`Metrics::to_json`] snapshot — the single
    /// source for the scalar metric keys in the JSON form.
    pub metrics: Json,
    pub per_replica: Vec<ServerReport>,
    /// Wall-clock request spans (empty unless the router was started with
    /// tracing enabled) — the input to `obs::trace::chrome_serve_trace`.
    pub request_spans: Vec<RequestSpan>,
}

impl FleetServeReport {
    /// Machine-scrapable form (the serve CLI emits this). Scalar metric
    /// keys live in the embedded `metrics` object so the field list is
    /// defined once, in [`Metrics::to_json`].
    pub fn to_json(&self) -> Json {
        let mut reps = Json::Arr(Vec::new());
        for r in &self.per_replica {
            reps.push(r.to_json());
        }
        let mut o = Json::obj();
        o.set("replicas", self.replicas)
            .set("metrics", self.metrics.clone())
            .set("modelled_throughput_rps", self.modelled_throughput)
            .set("per_replica", reps);
        o
    }
}

impl FleetRouter {
    /// Boot `replicas` identical servers from one config.
    pub fn start(cfg: ServerConfig, replicas: usize) -> Result<Self> {
        Self::start_with_tracing(cfg, replicas, false)
    }

    /// [`Self::start`], optionally recording one [`RequestSpan`] per
    /// completed request for `serve --trace`.
    pub fn start_with_tracing(cfg: ServerConfig, replicas: usize, trace: bool) -> Result<Self> {
        anyhow::ensure!(replicas >= 1, "need at least one replica");
        let replicas = (0..replicas)
            .map(|i| {
                Ok(Replica {
                    server: InferenceServer::start(cfg.clone())
                        .with_context(|| format!("starting replica {i}"))?,
                    outstanding: AtomicUsize::new(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            replicas,
            rr: AtomicUsize::new(0),
            metrics: Mutex::new(Metrics::new()),
            started: Instant::now(),
            spans: trace.then(|| Mutex::new(Vec::new())),
        })
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Router metrics guard, tolerating lock poisoning: the metrics are
    /// plain counters with no cross-field invariant, so a panic in
    /// another client thread must not cascade into every later request.
    fn metrics(&self) -> MutexGuard<'_, Metrics> {
        self.metrics.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Route one request to the replica with the fewest outstanding
    /// requests; on rejection, fail over through the remaining replicas
    /// in load order before giving up.
    pub fn infer(&self, image: Vec<i32>) -> Result<Vec<i32>> {
        let n = self.replicas.len();
        let start = Instant::now();
        let rot = self.rr.fetch_add(1, Ordering::Relaxed);
        let mut order: Vec<usize> = (0..n).map(|k| (rot + k) % n).collect();
        // stable sort: equal loads keep the rotated order
        order.sort_by_key(|&i| self.replicas[i].outstanding.load(Ordering::SeqCst));
        let mut last_err = None;
        for &i in &order {
            let r = &self.replicas[i];
            r.outstanding.fetch_add(1, Ordering::SeqCst);
            let res = r.server.infer(image.clone());
            r.outstanding.fetch_sub(1, Ordering::SeqCst);
            match res {
                Ok(out) => {
                    self.metrics().record(start.elapsed().as_secs_f64());
                    if let Some(spans) = &self.spans {
                        let span = RequestSpan {
                            start_us: (start - self.started).as_secs_f64() * 1e6,
                            dur_us: start.elapsed().as_secs_f64() * 1e6,
                            replica: i,
                        };
                        spans.lock().unwrap_or_else(PoisonError::into_inner).push(span);
                    }
                    return Ok(out);
                }
                Err(e) => last_err = Some(e),
            }
        }
        self.metrics().rejected += 1;
        // `start` guarantees replicas >= 1, so the loop ran at least once.
        Err(last_err.expect("FleetRouter::start enforces replicas >= 1"))
            .context("all replicas rejected the request")
    }

    /// Labelled live snapshots — the router's merged client-side view
    /// first, then one per replica — in the shape
    /// [`crate::obs::prometheus_text`] renders.
    pub fn metrics_snapshots(&self) -> Vec<(String, MetricsSnapshot)> {
        let mut out = vec![("router".to_string(), self.metrics().snapshot())];
        for (i, r) in self.replicas.iter().enumerate() {
            out.push((format!("replica{i}"), r.server.metrics_snapshot()));
        }
        out
    }

    /// Current Prometheus text exposition (what `serve --metrics-port`
    /// serves per scrape).
    pub fn prometheus(&self) -> String {
        crate::obs::prometheus_text(&self.metrics_snapshots())
    }

    /// Stop every replica and produce the merged fleet report.
    pub fn shutdown(self) -> FleetServeReport {
        let per_replica: Vec<ServerReport> =
            self.replicas.into_iter().map(|r| r.server.shutdown()).collect();
        let m = self.metrics.into_inner().unwrap_or_else(PoisonError::into_inner);
        let request_spans = self
            .spans
            .map(|s| s.into_inner().unwrap_or_else(PoisonError::into_inner))
            .unwrap_or_default();
        FleetServeReport {
            replicas: per_replica.len(),
            completed: m.completed,
            rejected: m.rejected,
            wall_throughput: m.throughput(),
            mean_latency_ms: m.mean_latency_ms(),
            p50_ms: m.latency_ms(50.0),
            p99_ms: m.latency_ms(99.0),
            modelled_throughput: per_replica.iter().map(|r| r.modelled_throughput).sum(),
            metrics: m.to_json(),
            per_replica,
            request_spans,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn round_robin_tie_break_spreads_idle_load() {
        let cfg = ServerConfig::cifarnet(&artifact_dir());
        let router = FleetRouter::start(cfg, 2).unwrap();
        let img = vec![1i32; 32 * 32 * 3];
        // strictly sequential traffic: every replica is idle at dispatch
        // time, so the rotation alone must alternate them
        for _ in 0..6 {
            router.infer(img.clone()).unwrap();
        }
        let rep = router.shutdown();
        assert_eq!(rep.completed, 6);
        assert_eq!(rep.rejected, 0);
        for (i, r) in rep.per_replica.iter().enumerate() {
            assert_eq!(r.completed, 3, "replica {i} served {}", r.completed);
        }
    }

    #[test]
    fn failover_absorbs_a_full_replica_queue() {
        // queue_depth 1 + batch 1: easy to overflow one replica; the
        // router must fail over rather than reject while another replica
        // has room.
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.queue_depth = 1;
        cfg.batch_size = 1;
        let router = std::sync::Arc::new(FleetRouter::start(cfg, 3).unwrap());
        let mut handles = Vec::new();
        for t in 0..6u64 {
            let r = router.clone();
            handles.push(std::thread::spawn(move || {
                let img = vec![t as i32; 32 * 32 * 3];
                let mut ok = 0u64;
                for _ in 0..8 {
                    if r.infer(img.clone()).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let rep = std::sync::Arc::into_inner(router).unwrap().shutdown();
        assert_eq!(rep.completed, total);
        assert_eq!(rep.completed + rep.rejected, 48, "every request accounted for");
    }

    #[test]
    fn tracing_records_spans_and_prometheus_renders() {
        let cfg = ServerConfig::cifarnet(&artifact_dir());
        let router = FleetRouter::start_with_tracing(cfg, 2, true).unwrap();
        let img = vec![1i32; 32 * 32 * 3];
        for _ in 0..4 {
            router.infer(img.clone()).unwrap();
        }
        let text = router.prometheus();
        assert!(
            text.contains("h2pipe_requests_completed_total{scope=\"router\"} 4"),
            "{text}"
        );
        assert!(text.contains("scope=\"replica1\""), "{text}");
        let rep = router.shutdown();
        assert_eq!(rep.request_spans.len(), 4, "one span per completed request");
        assert!(rep.request_spans.iter().all(|s| s.dur_us >= 0.0 && s.replica < 2));
    }

    #[test]
    fn merged_report_sums_modelled_rate() {
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.modelled_image_s = 1.0 / 1000.0;
        let router = FleetRouter::start(cfg, 4).unwrap();
        let rep = router.shutdown();
        assert_eq!(rep.replicas, 4);
        assert!((rep.modelled_throughput - 4000.0).abs() < 1.0);
        let j = rep.to_json().to_string();
        assert!(j.contains("\"replicas\":4"), "{j}");
    }
}
