//! Fleet-level request router: least-outstanding-requests over N replica
//! servers, with bounded failure recovery.
//!
//! Each replica is a full [`InferenceServer`] (own worker thread, own
//! bounded queue, own batcher), standing in for one sharded accelerator
//! fleet. The router keeps an outstanding-request count per replica,
//! sends every request to the least-loaded replica (ties rotate
//! round-robin so idle fleets still share work), and on failure retries
//! through the remaining replicas in load order — capped by the recovery
//! policy's attempt budget, with exponential backoff between sweeps, all
//! inside the per-request deadline. A watchdog thread health-checks the
//! workers and reboots crashed replicas from their boot config, so a
//! `--faults` crash heals instead of shrinking the fleet forever.
//! Latency, rejection, retry, failover, and reboot accounting happens at
//! the router in a merged [`Metrics`], so the fleet report reflects what
//! clients observed — including failover time — next to the per-replica
//! breakdowns.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{
    InferenceServer, Metrics, MetricsSnapshot, ServeError, ServerConfig, ServerReport,
};
use crate::faults::{FaultPlan, RecoveryPolicy};
use crate::obs::RequestSpan;
use crate::util::Json;

#[derive(Debug)]
struct Replica {
    /// `RwLock` so `infer` holds a shared read while the watchdog swaps a
    /// freshly booted server in under a write lock.
    server: RwLock<InferenceServer>,
    outstanding: AtomicUsize,
}

impl Replica {
    fn infer(&self, image: Vec<i32>) -> Result<Vec<i32>, ServeError> {
        self.server.read().unwrap_or_else(PoisonError::into_inner).infer(image)
    }
}

/// Router over N identical replicas.
#[derive(Debug)]
pub struct FleetRouter {
    replicas: Arc<Vec<Replica>>,
    /// Round-robin tie-break cursor.
    rr: AtomicUsize,
    metrics: Arc<Mutex<Metrics>>,
    /// Router boot time — the origin for request-span timestamps.
    started: Instant,
    /// Per-request spans for `serve --trace`; `None` = tracing off (the
    /// default: no per-request allocation on the serving path).
    spans: Option<Mutex<Vec<RequestSpan>>>,
    /// Retry / deadline / admission knobs (defaults without a fault plan).
    policy: RecoveryPolicy,
    /// Whether a fault plan armed this router (gates the report's
    /// `faults` block, keeping healthy-run reports byte-shaped as before).
    faults_armed: bool,
    /// Health-check watchdog (spawned only under a fault plan).
    watchdog: Option<(Arc<AtomicBool>, JoinHandle<()>)>,
}

/// Fleet serving summary: merged client-side metrics plus the per-replica
/// server reports.
#[derive(Debug, Clone)]
pub struct FleetServeReport {
    pub replicas: usize,
    pub completed: u64,
    /// Requests no replica could absorb.
    pub rejected: u64,
    pub wall_throughput: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Summed modelled FPGA rate across replicas.
    pub modelled_throughput: f64,
    /// Merged router-level [`Metrics::to_json`] snapshot — the single
    /// source for the scalar metric keys in the JSON form.
    pub metrics: Json,
    pub per_replica: Vec<ServerReport>,
    /// Wall-clock request spans (empty unless the router was started with
    /// tracing enabled) — the input to `obs::trace::chrome_serve_trace`.
    pub request_spans: Vec<RequestSpan>,
    /// Serve-side fault/recovery ledger — `Some` only on `--faults` runs.
    /// `lost` is offered minus (completed + rejected): every request must
    /// leave through exactly one of those doors, so it is 0 unless the
    /// router itself leaks a request.
    pub faults: Option<Json>,
}

impl FleetServeReport {
    /// Machine-scrapable form (the serve CLI emits this). Scalar metric
    /// keys live in the embedded `metrics` object so the field list is
    /// defined once, in [`Metrics::to_json`].
    pub fn to_json(&self) -> Json {
        let mut reps = Json::Arr(Vec::new());
        for r in &self.per_replica {
            reps.push(r.to_json());
        }
        let mut o = Json::obj();
        o.set("replicas", self.replicas)
            .set("metrics", self.metrics.clone())
            .set("modelled_throughput_rps", self.modelled_throughput)
            .set("per_replica", reps);
        if let Some(f) = &self.faults {
            o.set("faults", f.clone());
        }
        o
    }
}

impl FleetRouter {
    /// Boot `replicas` identical servers from one config.
    pub fn start(cfg: ServerConfig, replicas: usize) -> Result<Self> {
        Self::start_full(cfg, replicas, false, None)
    }

    /// [`Self::start`], optionally recording one [`RequestSpan`] per
    /// completed request for `serve --trace`.
    pub fn start_with_tracing(cfg: ServerConfig, replicas: usize, trace: bool) -> Result<Self> {
        Self::start_full(cfg, replicas, trace, None)
    }

    /// [`Self::start`] under a fault plan: per-replica serve faults are
    /// armed from `plan.serve`, the recovery policy comes from
    /// `plan.recovery`, and a watchdog thread reboots crashed replicas.
    pub fn start_with_faults(
        cfg: ServerConfig,
        replicas: usize,
        trace: bool,
        plan: &FaultPlan,
    ) -> Result<Self> {
        plan.validate()?;
        Self::start_full(cfg, replicas, trace, Some(plan))
    }

    fn start_full(
        cfg: ServerConfig,
        replicas: usize,
        trace: bool,
        plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        anyhow::ensure!(replicas >= 1, "need at least one replica");
        let policy = plan.map_or_else(RecoveryPolicy::default, |p| p.recovery.clone());
        // The healthy boot config: what the watchdog reboots from. The
        // per-replica faults are one-shot — a rebooted replica comes back
        // clean, as a re-provisioned machine would.
        let mut boot_cfg = cfg;
        boot_cfg.fault = None;
        if plan.is_some() {
            boot_cfg.request_deadline = Duration::from_millis(policy.request_deadline_ms);
        }
        let replicas = (0..replicas)
            .map(|i| {
                let mut rcfg = boot_cfg.clone();
                if let Some(p) = plan {
                    rcfg.fault = p.serve.iter().find(|s| s.replica == i).map(|s| s.kind);
                }
                Ok(Replica {
                    server: RwLock::new(
                        InferenceServer::start(rcfg)
                            .with_context(|| format!("starting replica {i}"))?,
                    ),
                    outstanding: AtomicUsize::new(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let replicas = Arc::new(replicas);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let watchdog = plan.map(|_| {
            Self::spawn_watchdog(replicas.clone(), metrics.clone(), boot_cfg, policy.watchdog_ms)
        });
        Ok(Self {
            replicas,
            rr: AtomicUsize::new(0),
            metrics,
            started: Instant::now(),
            spans: trace.then(|| Mutex::new(Vec::new())),
            faults_armed: plan.is_some(),
            policy,
            watchdog,
        })
    }

    /// The health-check loop: every `watchdog_ms`, any replica whose
    /// worker thread has exited is rebooted from the healthy boot config.
    /// Detection-to-serving time feeds the MTTR metric.
    fn spawn_watchdog(
        replicas: Arc<Vec<Replica>>,
        metrics: Arc<Mutex<Metrics>>,
        boot_cfg: ServerConfig,
        watchdog_ms: u64,
    ) -> (Arc<AtomicBool>, JoinHandle<()>) {
        let stop = Arc::new(AtomicBool::new(false));
        let s2 = stop.clone();
        let handle = std::thread::spawn(move || {
            while !s2.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(watchdog_ms.max(1)));
                for r in replicas.iter() {
                    let healthy =
                        r.server.read().unwrap_or_else(PoisonError::into_inner).is_healthy();
                    if healthy {
                        continue;
                    }
                    let t0 = Instant::now();
                    match InferenceServer::start(boot_cfg.clone()) {
                        Ok(fresh) => {
                            *r.server.write().unwrap_or_else(PoisonError::into_inner) = fresh;
                            let mut m =
                                metrics.lock().unwrap_or_else(PoisonError::into_inner);
                            m.reboots += 1;
                            m.mttr_sum_ms += t0.elapsed().as_secs_f64() * 1e3;
                        }
                        Err(_) => {
                            // Boot failed (transient resource issue):
                            // leave the replica down and retry next tick.
                        }
                    }
                }
            }
        });
        (stop, handle)
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Router metrics guard, tolerating lock poisoning: the metrics are
    /// plain counters with no cross-field invariant, so a panic in
    /// another client thread must not cascade into every later request.
    fn metrics(&self) -> MutexGuard<'_, Metrics> {
        self.metrics.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Route one request to the replica with the fewest outstanding
    /// requests; on failure, retry through the remaining replicas in load
    /// order, then back off exponentially and sweep again — all bounded
    /// by the policy's attempt budget and the per-request deadline.
    pub fn infer(&self, image: Vec<i32>) -> Result<Vec<i32>, ServeError> {
        let n = self.replicas.len();
        let start = Instant::now();
        let deadline = Duration::from_millis(self.policy.request_deadline_ms);
        {
            let mut m = self.metrics();
            m.offered += 1;
            if self.policy.admission_max_outstanding > 0 {
                let in_flight: usize =
                    self.replicas.iter().map(|r| r.outstanding.load(Ordering::SeqCst)).sum();
                if in_flight >= self.policy.admission_max_outstanding {
                    m.rejected += 1;
                    m.shed += 1;
                    return Err(ServeError::Overloaded);
                }
            }
        }
        let mut tries: u32 = 0;
        let mut last = ServeError::ReplicaDown;
        'sweeps: loop {
            let rot = self.rr.fetch_add(1, Ordering::Relaxed);
            let mut order: Vec<usize> = (0..n).map(|k| (rot + k) % n).collect();
            // stable sort: equal loads keep the rotated order
            order.sort_by_key(|&i| self.replicas[i].outstanding.load(Ordering::SeqCst));
            for &i in &order {
                if tries >= self.policy.max_attempts {
                    break 'sweeps;
                }
                if start.elapsed() >= deadline {
                    last = ServeError::Timeout;
                    break 'sweeps;
                }
                tries += 1;
                if tries > 1 {
                    self.metrics().retries += 1;
                }
                let r = &self.replicas[i];
                r.outstanding.fetch_add(1, Ordering::SeqCst);
                let res = r.infer(image.clone());
                r.outstanding.fetch_sub(1, Ordering::SeqCst);
                match res {
                    Ok(out) => {
                        let mut m = self.metrics();
                        m.record(start.elapsed().as_secs_f64());
                        if tries > 1 {
                            m.failovers += 1;
                        }
                        drop(m);
                        if let Some(spans) = &self.spans {
                            let span = RequestSpan {
                                start_us: (start - self.started).as_secs_f64() * 1e6,
                                dur_us: start.elapsed().as_secs_f64() * 1e6,
                                replica: i,
                            };
                            spans.lock().unwrap_or_else(PoisonError::into_inner).push(span);
                        }
                        return Ok(out);
                    }
                    Err(e) => last = e,
                }
            }
            if tries >= self.policy.max_attempts {
                break;
            }
            // Exponential backoff before the next sweep, capped by the
            // remaining deadline budget (a zero budget ends the request).
            let remaining = deadline.saturating_sub(start.elapsed());
            if remaining.is_zero() {
                last = ServeError::Timeout;
                break;
            }
            let backoff =
                Duration::from_millis(self.policy.backoff_ms.saturating_mul(1 << tries.min(10)));
            std::thread::sleep(backoff.min(remaining));
        }
        self.metrics().rejected += 1;
        Err(last)
    }

    /// Labelled live snapshots — the router's merged client-side view
    /// first, then one per replica — in the shape
    /// [`crate::obs::prometheus_text`] renders.
    pub fn metrics_snapshots(&self) -> Vec<(String, MetricsSnapshot)> {
        let mut out = vec![("router".to_string(), self.metrics().snapshot())];
        for (i, r) in self.replicas.iter().enumerate() {
            let snap =
                r.server.read().unwrap_or_else(PoisonError::into_inner).metrics_snapshot();
            out.push((format!("replica{i}"), snap));
        }
        out
    }

    /// Current Prometheus text exposition (what `serve --metrics-port`
    /// serves per scrape).
    pub fn prometheus(&self) -> String {
        crate::obs::prometheus_text(&self.metrics_snapshots())
    }

    /// Stop the watchdog and every replica and produce the merged fleet
    /// report.
    pub fn shutdown(mut self) -> FleetServeReport {
        if let Some((stop, handle)) = self.watchdog.take() {
            stop.store(true, Ordering::SeqCst);
            let _ = handle.join();
        }
        let replicas = Arc::try_unwrap(self.replicas)
            .expect("watchdog joined; no other replica handles remain");
        let per_replica: Vec<ServerReport> = replicas
            .into_iter()
            .map(|r| r.server.into_inner().unwrap_or_else(PoisonError::into_inner).shutdown())
            .collect();
        let m = Arc::try_unwrap(self.metrics)
            .expect("watchdog joined; no other metrics handles remain")
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner);
        let request_spans = self
            .spans
            .take()
            .map(|s| s.into_inner().unwrap_or_else(PoisonError::into_inner))
            .unwrap_or_default();
        let faults = self.faults_armed.then(|| {
            let mut f = Json::obj();
            f.set("injected", m.reboots)
                .set("retried", m.retries)
                .set("failed_over", m.failovers)
                .set("dropped", m.rejected)
                .set("recovered", m.failovers + m.reboots)
                .set("lost", m.offered.saturating_sub(m.completed + m.rejected))
                .set("timeouts", m.timeouts)
                .set("shed", m.shed)
                .set("reboots", m.reboots)
                .set("mttr_ms", m.mttr_ms());
            f
        });
        FleetServeReport {
            replicas: per_replica.len(),
            completed: m.completed,
            rejected: m.rejected,
            wall_throughput: m.throughput(),
            mean_latency_ms: m.mean_latency_ms(),
            p50_ms: m.latency_ms(50.0),
            p99_ms: m.latency_ms(99.0),
            modelled_throughput: per_replica.iter().map(|r| r.modelled_throughput).sum(),
            metrics: m.to_json(),
            per_replica,
            request_spans,
            faults,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{ServeFault, ServeFaultKind};

    fn artifact_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn round_robin_tie_break_spreads_idle_load() {
        let cfg = ServerConfig::cifarnet(&artifact_dir());
        let router = FleetRouter::start(cfg, 2).unwrap();
        let img = vec![1i32; 32 * 32 * 3];
        // strictly sequential traffic: every replica is idle at dispatch
        // time, so the rotation alone must alternate them
        for _ in 0..6 {
            router.infer(img.clone()).unwrap();
        }
        let rep = router.shutdown();
        assert_eq!(rep.completed, 6);
        assert_eq!(rep.rejected, 0);
        assert!(rep.faults.is_none(), "no fault plan, no faults block");
        for (i, r) in rep.per_replica.iter().enumerate() {
            assert_eq!(r.completed, 3, "replica {i} served {}", r.completed);
        }
    }

    #[test]
    fn failover_absorbs_a_full_replica_queue() {
        // queue_depth 1 + batch 1: easy to overflow one replica; the
        // router must fail over rather than reject while another replica
        // has room.
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.queue_depth = 1;
        cfg.batch_size = 1;
        let router = std::sync::Arc::new(FleetRouter::start(cfg, 3).unwrap());
        let mut handles = Vec::new();
        for t in 0..6u64 {
            let r = router.clone();
            handles.push(std::thread::spawn(move || {
                let img = vec![t as i32; 32 * 32 * 3];
                let mut ok = 0u64;
                for _ in 0..8 {
                    if r.infer(img.clone()).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let rep = std::sync::Arc::into_inner(router).unwrap().shutdown();
        assert_eq!(rep.completed, total);
        assert_eq!(rep.completed + rep.rejected, 48, "every request accounted for");
    }

    #[test]
    fn tracing_records_spans_and_prometheus_renders() {
        let cfg = ServerConfig::cifarnet(&artifact_dir());
        let router = FleetRouter::start_with_tracing(cfg, 2, true).unwrap();
        let img = vec![1i32; 32 * 32 * 3];
        for _ in 0..4 {
            router.infer(img.clone()).unwrap();
        }
        let text = router.prometheus();
        assert!(
            text.contains("h2pipe_requests_completed_total{scope=\"router\"} 4"),
            "{text}"
        );
        assert!(text.contains("scope=\"replica1\""), "{text}");
        let rep = router.shutdown();
        assert_eq!(rep.request_spans.len(), 4, "one span per completed request");
        assert!(rep.request_spans.iter().all(|s| s.dur_us >= 0.0 && s.replica < 2));
    }

    #[test]
    fn merged_report_sums_modelled_rate() {
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.modelled_image_s = 1.0 / 1000.0;
        let router = FleetRouter::start(cfg, 4).unwrap();
        let rep = router.shutdown();
        assert_eq!(rep.replicas, 4);
        assert!((rep.modelled_throughput - 4000.0).abs() < 1.0);
        let j = rep.to_json().to_string();
        assert!(j.contains("\"replicas\":4"), "{j}");
    }

    #[test]
    fn watchdog_reboots_a_crashed_replica_and_nothing_is_lost() {
        let cfg = ServerConfig::cifarnet(&artifact_dir());
        let mut plan = FaultPlan::new(3);
        plan.serve =
            vec![ServeFault { replica: 0, kind: ServeFaultKind::Crash { after_requests: 2 } }];
        plan.recovery.watchdog_ms = 5;
        plan.recovery.backoff_ms = 1;
        let router = FleetRouter::start_with_faults(cfg, 2, false, &plan).unwrap();
        let img = vec![1i32; 32 * 32 * 3];
        // Enough sequential traffic to trip the crash and ride through
        // the reboot; with failover every request must succeed.
        for k in 0..16 {
            router.infer(img.clone()).unwrap_or_else(|e| panic!("request {k}: {e}"));
        }
        // Wait for the watchdog to record the reboot.
        let t0 = Instant::now();
        while router.metrics().reboots == 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(5));
        }
        let rep = router.shutdown();
        assert_eq!(rep.completed, 16);
        assert_eq!(rep.rejected, 0);
        let f = rep.faults.expect("fault plan arms the ledger");
        let s = f.to_string();
        assert!(s.contains("\"lost\":0"), "{s}");
        let recovered = f.get("recovered").and_then(Json::as_u64).unwrap();
        assert!(recovered > 0, "crash must surface as failover and/or reboot: {s}");
        let reboots = f.get("reboots").and_then(Json::as_u64).unwrap();
        assert!(reboots >= 1, "watchdog must have rebooted replica 0: {s}");
        let j = rep.to_json().to_string();
        assert!(j.contains("\"mttr_ms\":"), "{j}");
    }

    #[test]
    fn admission_control_sheds_rather_than_queues_unboundedly() {
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.batch_size = 1;
        let mut plan = FaultPlan::new(4);
        plan.serve = vec![ServeFault { replica: 0, kind: ServeFaultKind::Slow { extra_ms: 30 } }];
        plan.recovery.admission_max_outstanding = 1;
        plan.recovery.max_attempts = 1;
        let router = std::sync::Arc::new(
            FleetRouter::start_with_faults(cfg, 1, false, &plan).unwrap(),
        );
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = router.clone();
            handles.push(std::thread::spawn(move || {
                let img = vec![2i32; 32 * 32 * 3];
                for _ in 0..4 {
                    let _ = r.infer(img.clone());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let rep = std::sync::Arc::into_inner(router).unwrap().shutdown();
        assert_eq!(rep.completed + rep.rejected, 32, "conservation");
        let f = rep.faults.expect("fault plan arms the ledger");
        assert!(f.to_string().contains("\"lost\":0"), "{f}");
        let shed = f.get("shed").and_then(Json::as_u64).unwrap();
        assert!(shed > 0, "8 clients against a 1-in-flight bound must shed: {f}");
    }
}
