//! Fleet-level cycle simulation: one [`PipelineSim`] per shard, composed
//! through credit-based inter-device links.
//!
//! Each shard runs on its own simulated FPGA (own HBM stacks, own weight
//! distribution network, own §IV-B freeze semantics). The boundary
//! activation stream between consecutive shards crosses a credit-based
//! link modelled exactly like the §V-A weight fabric: the downstream
//! device exposes its receive FIFO as a credit window (in boundary-tensor
//! lines), the upstream sink may only run `capacity` lines ahead of the
//! downstream head, and at the bound it blocks — back-pressure propagates
//! through the upstream shard instead of dropping data. All shards step
//! from the same 1200 MHz base tick, so the core/HBM clock-domain
//! relationship of the single-device simulator composes unchanged.

use anyhow::{ensure, Result};

use crate::cluster::partition::PartitionPlan;
use crate::fabric::CreditCounter;
use crate::hbm::controller::PcStats;
use crate::obs::Probe;
use crate::sim::engine::EngineStats;
use crate::sim::pipeline::PipelineSim;
use crate::util::Json;

/// Fleet simulation parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Images pushed through every replica pipeline.
    pub images: u64,
    /// Leading images excluded from the throughput measurement.
    pub warmup_images: u64,
    /// Safety valve on base ticks (per replica).
    pub max_base_ticks: u64,
    /// Inter-device link capacity in boundary-tensor lines — the receive
    /// FIFO a downstream device advertises as credits.
    pub link_capacity_lines: u32,
    /// Identical replicas of the whole sharded pipeline.
    pub replicas: u32,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            images: 6,
            warmup_images: 2,
            max_base_ticks: 40_000_000_000,
            link_capacity_lines: 4,
            replicas: 1,
        }
    }
}

/// Per-link measurement (shard `i` -> shard `i + 1`).
#[derive(Debug, Clone)]
pub struct LinkStats {
    /// Boundary lines transferred over the link.
    pub lines: u64,
    /// Peak link occupancy in lines (never exceeds the capacity).
    pub peak_occupancy: u64,
    /// Core cycles the upstream sink spent blocked on link credit.
    pub upstream_blocked: u64,
}

/// Per-shard measurement within one replica.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub name: String,
    /// Busiest weight engine of the shard and its active cycles.
    pub bottleneck_engine: String,
    pub bottleneck_active: u64,
}

/// Aggregate fleet simulation results.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub network: String,
    pub shards: usize,
    pub replicas: u32,
    /// Mean steady-state throughput of one replica (im/s).
    pub per_replica_throughput: f64,
    /// Summed throughput across replicas (im/s).
    pub aggregate_throughput: f64,
    /// First-image latency through the whole shard pipeline (s).
    pub latency: f64,
    /// Index of the slowest shard (the fleet bottleneck).
    pub bottleneck_shard: usize,
    /// Busiest engine within the bottleneck shard.
    pub bottleneck_engine: String,
    pub shard_stats: Vec<ShardStats>,
    pub links: Vec<LinkStats>,
    /// Core cycles one replica ran for.
    pub core_cycles: u64,
}

impl FleetReport {
    /// Machine-scrapable form (see `Metrics::to_json` for the serving
    /// counterpart).
    pub fn to_json(&self) -> Json {
        let mut links = Json::Arr(Vec::new());
        for l in &self.links {
            let mut o = Json::obj();
            o.set("lines", l.lines)
                .set("peak_occupancy", l.peak_occupancy)
                .set("upstream_blocked", l.upstream_blocked);
            links.push(o);
        }
        let mut shards = Json::Arr(Vec::new());
        for s in &self.shard_stats {
            let mut o = Json::obj();
            o.set("name", s.name.as_str())
                .set("bottleneck_engine", s.bottleneck_engine.as_str())
                .set("bottleneck_active", s.bottleneck_active);
            shards.push(o);
        }
        let mut o = Json::obj();
        o.set("network", self.network.as_str())
            .set("shards", self.shards)
            .set("replicas", self.replicas)
            .set("per_replica_throughput", self.per_replica_throughput)
            .set("aggregate_throughput", self.aggregate_throughput)
            .set("latency_s", self.latency)
            .set("bottleneck_shard", self.bottleneck_shard)
            .set("bottleneck_engine", self.bottleneck_engine.as_str())
            .set("shard_stats", shards)
            .set("links", links)
            .set("core_cycles", self.core_cycles);
        o
    }
}

/// Re-bases one shard's sample stream into fleet-global track ids so a
/// single [`Probe`] can record the whole replica: engine/FIFO indices are
/// offset by the layers of the preceding shards, PC ids by their device's
/// pseudo-channel count, and names gain an `s{shard}/` prefix.
struct ShardProbe<'a> {
    inner: &'a mut dyn Probe,
    shard: usize,
    engine_base: usize,
    pc_base: u32,
}

impl Probe for ShardProbe<'_> {
    fn window(&self) -> u64 {
        self.inner.window()
    }

    fn engine_sample(&mut self, now: u64, idx: usize, name: &str, cum: &EngineStats) {
        let name = format!("s{}/{name}", self.shard);
        self.inner.engine_sample(now, self.engine_base + idx, &name, cum);
    }

    fn pc_sample(&mut self, now: u64, pc: u32, cum: &PcStats) {
        self.inner.pc_sample(now, self.pc_base + pc, cum);
    }

    fn fifo_sample(&mut self, now: u64, layer: usize, name: &str, occ: u64, cap: u64, peak: u64) {
        let name = format!("s{}/{name}", self.shard);
        self.inner.fifo_sample(now, self.engine_base + layer, &name, occ, cap, peak);
    }

    fn link_sample(&mut self, now: u64, link: usize, occupancy: u64, lines: u64, blocked: u64) {
        self.inner.link_sample(now, link, occupancy, lines, blocked);
    }

    fn hbm_burst(&mut self, pc: u32, accept_cycle: u64, done_cycle: u64, beats: u32) {
        self.inner.hbm_burst(self.pc_base + pc, accept_cycle, done_cycle, beats);
    }
}

/// Result of one replica run.
struct ReplicaRun {
    throughput: f64,
    latency: f64,
    bottleneck_shard: usize,
    bottleneck_engine: String,
    shard_stats: Vec<ShardStats>,
    links: Vec<LinkStats>,
    core_cycles: u64,
}

/// The fleet: N replicas of an M-shard pipeline.
#[derive(Debug)]
pub struct FleetSim {
    pp: PartitionPlan,
}

impl FleetSim {
    /// Build from a partition plan; validates the boundary tensors.
    pub fn new(pp: &PartitionPlan) -> Result<Self> {
        ensure!(!pp.shards.is_empty(), "partition has no shards");
        for w in pp.shards.windows(2) {
            let up = w[0].net.layers().last().expect("non-empty shard").out;
            let down = w[1].net.input_shape();
            ensure!(up == down, "boundary shape mismatch: {up} -> {down}");
        }
        Ok(Self { pp: pp.clone() })
    }

    /// Run the fleet. One replica's shard pipeline is co-simulated
    /// cycle-accurately; replicas share no simulated hardware and the
    /// simulation is fully deterministic, so N identical replicas are an
    /// exact N-fold scale-out of that run rather than N redundant
    /// simulations.
    pub fn run(&self, cfg: &FleetConfig) -> Result<FleetReport> {
        self.run_with(cfg, None)
    }

    /// [`Self::run`] with a flight-recorder probe attached. Track ids are
    /// fleet-global (see [`ShardProbe`]); inter-device links are sampled
    /// on the sink shard's window boundary.
    pub fn run_probed(&self, cfg: &FleetConfig, probe: &mut dyn Probe) -> Result<FleetReport> {
        self.run_with(cfg, Some(probe))
    }

    fn run_with(&self, cfg: &FleetConfig, probe: Option<&mut dyn Probe>) -> Result<FleetReport> {
        ensure!(cfg.replicas >= 1, "need at least one replica");
        ensure!(cfg.link_capacity_lines >= 1, "link capacity must be >= 1 line");
        let run = self.run_replica(cfg, probe)?;
        Ok(FleetReport {
            network: self.pp.network.clone(),
            shards: self.pp.shards.len(),
            replicas: cfg.replicas,
            per_replica_throughput: run.throughput,
            aggregate_throughput: run.throughput * cfg.replicas as f64,
            latency: run.latency,
            bottleneck_shard: run.bottleneck_shard,
            bottleneck_engine: run.bottleneck_engine,
            shard_stats: run.shard_stats,
            links: run.links,
            core_cycles: run.core_cycles,
        })
    }

    /// Cycle-accurate co-simulation of one replica's shard pipeline.
    fn run_replica(
        &self,
        cfg: &FleetConfig,
        mut probe: Option<&mut dyn Probe>,
    ) -> Result<ReplicaRun> {
        let images = cfg.images.max(cfg.warmup_images + 1);
        let shards = &self.pp.shards;
        let mut sims = shards
            .iter()
            .map(|s| PipelineSim::new(&s.net, &s.plan))
            .collect::<Result<Vec<_>>>()?;
        let n = sims.len();
        let cap = cfg.link_capacity_lines as u64;

        // Fleet-global track-id bases for the probe (engines/FIFOs by
        // preceding layer counts, PCs by preceding devices' PC counts).
        let mut engine_bases = Vec::with_capacity(n);
        let mut pc_bases = Vec::with_capacity(n);
        let (mut eb, mut pb) = (0usize, 0u32);
        for s in shards {
            engine_bases.push(eb);
            pc_bases.push(pb);
            eb += s.plan.layers.len();
            pb += s.plan.device.hbm.total_pcs();
        }
        let window = probe.as_deref().map_or(0, |p| p.window().max(1));
        let mut next_link_sample = window;
        let mut credits: Vec<CreditCounter> =
            (1..n).map(|_| CreditCounter::new(cfg.link_capacity_lines)).collect();
        let mut peak = vec![0u64; n.saturating_sub(1)];

        // Initial bounds: nothing has arrived downstream yet; every
        // upstream sink may run one credit window ahead.
        for i in 0..n.saturating_sub(1) {
            sims[i].set_sink_limit(cap);
            sims[i + 1].set_input_limit(0);
        }

        let mut warmup_done_at: Option<u64> = None;
        loop {
            ensure!(
                sims[n - 1].base_ticks() < cfg.max_base_ticks,
                "fleet simulation exceeded max_base_ticks — pipeline wedged?"
            );
            for (i, s) in sims.iter_mut().enumerate() {
                match probe.as_deref_mut() {
                    None => s.step_base_tick(images),
                    Some(p) => {
                        let mut sp = ShardProbe {
                            inner: p,
                            shard: i,
                            engine_base: engine_bases[i],
                            pc_base: pc_bases[i],
                        };
                        s.step_base_tick_probed(images, Some(&mut sp));
                    }
                }
            }
            // Exchange link state: occupancy is lines offered upstream
            // minus lines retired downstream; the hardware-style counter
            // must never be overdrawn (that would mean dropped data).
            for i in 0..n - 1 {
                let produced = sims[i].sink_lines_produced();
                let consumed = sims[i + 1].head_lines_consumed();
                let occupancy = produced - consumed;
                let held = credits[i].outstanding() as u64;
                if occupancy > held {
                    ensure!(
                        credits[i].acquire((occupancy - held) as u32),
                        "link {i} overran its credit window"
                    );
                } else if held > occupancy {
                    credits[i].release((held - occupancy) as u32);
                }
                peak[i] = peak[i].max(occupancy);
                sims[i].set_sink_limit(consumed + cap);
                sims[i + 1].set_input_limit(produced);
            }
            // Link windows sample on the sink shard's core-cycle window
            // boundary: cumulative lines/blocked plus the instantaneous
            // in-flight occupancy.
            if let Some(p) = probe.as_deref_mut() {
                let now = sims[n - 1].core_cycles();
                if now >= next_link_sample {
                    for i in 0..n - 1 {
                        let produced = sims[i].sink_lines_produced();
                        let consumed = sims[i + 1].head_lines_consumed();
                        p.link_sample(
                            now,
                            i,
                            produced - consumed,
                            produced,
                            sims[i].sink_output_blocked(),
                        );
                    }
                    next_link_sample = now + window;
                }
            }
            if warmup_done_at.is_none() && sims[n - 1].sink_images_done() >= cfg.warmup_images {
                warmup_done_at = Some(sims[n - 1].core_cycles());
            }
            if sims.iter().all(|s| s.all_done(images)) {
                break;
            }
        }

        // Final flush: record the trailing partial window of every shard
        // and link so window sums equal end-of-run aggregates.
        if probe.is_some() {
            for i in 0..n {
                let p = probe.as_deref_mut().expect("probe present");
                let mut sp = ShardProbe {
                    inner: p,
                    shard: i,
                    engine_base: engine_bases[i],
                    pc_base: pc_bases[i],
                };
                sims[i].sample_probe(&mut sp);
            }
            let p = probe.as_deref_mut().expect("probe present");
            let now = sims[n - 1].core_cycles();
            for i in 0..n.saturating_sub(1) {
                let produced = sims[i].sink_lines_produced();
                let consumed = sims[i + 1].head_lines_consumed();
                p.link_sample(now, i, produced - consumed, produced, sims[i].sink_output_blocked());
            }
        }

        let hz = shards[0].plan.device.core_mhz as f64 * 1e6;
        let last = &sims[n - 1];
        let span = last.core_cycles() - warmup_done_at.unwrap_or(0);
        let throughput = (images - cfg.warmup_images) as f64 * hz / span.max(1) as f64;
        let latency = last.first_image_done_cycle().map(|c| c as f64 / hz).unwrap_or(f64::NAN);

        let shard_stats: Vec<ShardStats> = sims
            .iter()
            .zip(shards.iter())
            .map(|(sim, sh)| {
                let (engine, active) = sim.busiest_engine();
                ShardStats {
                    name: sh.plan.network.clone(),
                    bottleneck_engine: engine,
                    bottleneck_active: active,
                }
            })
            .collect();
        let bottleneck_shard = shard_stats
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.bottleneck_active)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let bottleneck_engine = shard_stats[bottleneck_shard].bottleneck_engine.clone();
        let links = (0..n - 1)
            .map(|i| LinkStats {
                lines: sims[i].sink_lines_produced(),
                peak_occupancy: peak[i],
                upstream_blocked: sims[i].sink_output_blocked(),
            })
            .collect();
        Ok(ReplicaRun {
            throughput,
            latency,
            bottleneck_shard,
            bottleneck_engine,
            shard_stats,
            links,
            core_cycles: last.core_cycles(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{partition, PartitionOptions};
    use crate::config::{CompilerOptions, DeviceConfig};
    use crate::nn::zoo;

    fn quick() -> FleetConfig {
        FleetConfig { images: 3, warmup_images: 1, ..Default::default() }
    }

    #[test]
    fn single_shard_fleet_matches_plain_sim() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let o = CompilerOptions::default();
        let pp = partition(&net, &d, &o, &PartitionOptions::default()).unwrap();
        assert_eq!(pp.num_shards(), 1);
        let fleet = FleetSim::new(&pp).unwrap();
        let rep = fleet.run(&quick()).unwrap();
        let plain = crate::sim::pipeline::simulate(
            &net,
            &crate::compiler::compile(&net, &d, &o).unwrap(),
            &crate::sim::pipeline::SimConfig {
                images: 3,
                warmup_images: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let ratio = rep.aggregate_throughput / plain.throughput;
        assert!(
            (0.95..1.05).contains(&ratio),
            "1-shard fleet {:.0} vs plain sim {:.0}",
            rep.aggregate_throughput,
            plain.throughput
        );
        assert!(rep.links.is_empty());
    }

    #[test]
    fn two_shard_fleet_conserves_lines() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let o = CompilerOptions::default();
        let pp = partition(&net, &d, &o, &PartitionOptions { shards: Some(2), max_shards: 2 })
            .unwrap();
        let fleet = FleetSim::new(&pp).unwrap();
        let cfg = quick();
        let rep = fleet.run(&cfg).unwrap();
        assert!(rep.aggregate_throughput > 0.0);
        let boundary_h = pp.shards[0].net.layers().last().unwrap().out.h as u64;
        assert_eq!(rep.links[0].lines, cfg.images * boundary_h, "no line lost or duplicated");
        assert!(rep.links[0].peak_occupancy <= cfg.link_capacity_lines as u64);
    }
}
