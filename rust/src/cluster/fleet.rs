//! Fleet-level cycle simulation: one [`PipelineSim`] per shard, composed
//! through credit-based inter-device links.
//!
//! Each shard runs on its own simulated FPGA (own HBM stacks, own weight
//! distribution network, own §IV-B freeze semantics). The boundary
//! activation stream between consecutive shards crosses a credit-based
//! link modelled exactly like the §V-A weight fabric: the downstream
//! device exposes its receive FIFO as a credit window (in boundary-tensor
//! lines), the upstream sink may only run `capacity` lines ahead of the
//! downstream head, and at the bound it blocks — back-pressure propagates
//! through the upstream shard instead of dropping data. All shards step
//! from the same 1200 MHz base tick, so the core/HBM clock-domain
//! relationship of the single-device simulator composes unchanged.

use anyhow::{bail, ensure, Result};

use crate::cluster::partition::PartitionPlan;
use crate::fabric::CreditCounter;
use crate::faults::{site_seed, FaultPlan, FaultTotals, LinkFaultKind};
use crate::hbm::controller::PcStats;
use crate::obs::Probe;
use crate::sim::engine::EngineStats;
use crate::sim::pipeline::PipelineSim;
use crate::util::Json;

/// Fleet simulation parameters.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Images pushed through every replica pipeline.
    pub images: u64,
    /// Leading images excluded from the throughput measurement.
    pub warmup_images: u64,
    /// Safety valve on base ticks (per replica).
    pub max_base_ticks: u64,
    /// Inter-device link capacity in boundary-tensor lines — the receive
    /// FIFO a downstream device advertises as credits.
    pub link_capacity_lines: u32,
    /// Identical replicas of the whole sharded pipeline.
    pub replicas: u32,
    /// Step every base tick of every shard (the reference interpreter)
    /// instead of the event-driven scheduler. Both paths produce
    /// identical reports, artifacts and probe streams; see
    /// [`crate::sim::pipeline::SimConfig::exact_stepping`].
    pub exact_stepping: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            images: 6,
            warmup_images: 2,
            max_base_ticks: 40_000_000_000,
            link_capacity_lines: 4,
            replicas: 1,
            exact_stepping: crate::sim::pipeline::slow_sim_from_env(),
        }
    }
}

/// Merge `[start, end)` windows into sorted, disjoint, non-adjacent
/// intervals (the shape the closed-form tick accounting needs).
fn merge_windows(mut iv: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    iv.retain(|&(s, e)| e > s);
    iv.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::new();
    for (s, e) in iv {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Wall base tick at which the `local`-th *executed* tick runs — replica
/// outages freeze the shards but not the wall clock, so executed ticks
/// skip over the merged outage intervals.
fn to_wall(local: u64, outages: &[(u64, u64)]) -> u64 {
    let mut w = local;
    for &(s, e) in outages {
        if w >= s {
            w += e - s;
        } else {
            break;
        }
    }
    w
}

/// Number of executed ticks strictly before wall tick `wall` (the local
/// clock an executed wall tick runs at).
fn executed_before(wall: u64, outages: &[(u64, u64)]) -> u64 {
    let mut dead = 0;
    for &(s, e) in outages {
        if s >= wall {
            break;
        }
        dead += e.min(wall) - s;
    }
    wall - dead
}

/// First executed wall tick at or after `wall`.
fn first_executed(wall: u64, outages: &[(u64, u64)]) -> u64 {
    let mut w = wall;
    for &(s, e) in outages {
        if s <= w && w < e {
            w = e;
        }
    }
    w
}

/// `|[a.0, a.1) ∩ [b.0, b.1)|`
fn overlap(a: (u64, u64), b: (u64, u64)) -> u64 {
    a.1.min(b.1).saturating_sub(a.0.max(b.0))
}

/// Per-link measurement (shard `i` -> shard `i + 1`).
#[derive(Debug, Clone)]
pub struct LinkStats {
    /// Boundary lines transferred over the link.
    pub lines: u64,
    /// Peak link occupancy in lines (never exceeds the capacity).
    pub peak_occupancy: u64,
    /// Core cycles the upstream sink spent blocked on link credit.
    pub upstream_blocked: u64,
    /// Base ticks a fault plan held this link stalled (0 without faults).
    pub stalled_ticks: u64,
}

/// Per-shard measurement within one replica.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub name: String,
    /// Busiest weight engine of the shard and its active cycles.
    pub bottleneck_engine: String,
    pub bottleneck_active: u64,
}

/// Aggregate fleet simulation results.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub network: String,
    pub shards: usize,
    pub replicas: u32,
    /// Mean steady-state throughput of one replica (im/s).
    pub per_replica_throughput: f64,
    /// Summed throughput across replicas (im/s).
    pub aggregate_throughput: f64,
    /// First-image latency through the whole shard pipeline (s).
    pub latency: f64,
    /// Index of the slowest shard (the fleet bottleneck).
    pub bottleneck_shard: usize,
    /// Busiest engine within the bottleneck shard.
    pub bottleneck_engine: String,
    pub shard_stats: Vec<ShardStats>,
    pub links: Vec<LinkStats>,
    /// Core cycles one replica ran for.
    pub core_cycles: u64,
    /// Fault-injection ledger summed over all replicas — `Some` only
    /// when a fault plan was armed, so healthy-run reports keep their
    /// pre-fault shape.
    pub faults: Option<FaultTotals>,
}

impl FleetReport {
    /// Machine-scrapable form (see `Metrics::to_json` for the serving
    /// counterpart).
    pub fn to_json(&self) -> Json {
        let mut links = Json::Arr(Vec::new());
        for l in &self.links {
            let mut o = Json::obj();
            o.set("lines", l.lines)
                .set("peak_occupancy", l.peak_occupancy)
                .set("upstream_blocked", l.upstream_blocked);
            if self.faults.is_some() {
                o.set("stalled_ticks", l.stalled_ticks);
            }
            links.push(o);
        }
        let mut shards = Json::Arr(Vec::new());
        for s in &self.shard_stats {
            let mut o = Json::obj();
            o.set("name", s.name.as_str())
                .set("bottleneck_engine", s.bottleneck_engine.as_str())
                .set("bottleneck_active", s.bottleneck_active);
            shards.push(o);
        }
        let mut o = Json::obj();
        o.set("network", self.network.as_str())
            .set("shards", self.shards)
            .set("replicas", self.replicas)
            .set("per_replica_throughput", self.per_replica_throughput)
            .set("aggregate_throughput", self.aggregate_throughput)
            .set("latency_s", self.latency)
            .set("bottleneck_shard", self.bottleneck_shard)
            .set("bottleneck_engine", self.bottleneck_engine.as_str())
            .set("shard_stats", shards)
            .set("links", links)
            .set("core_cycles", self.core_cycles);
        if let Some(f) = &self.faults {
            o.set("faults", f.to_json());
        }
        o
    }
}

/// Re-bases one shard's sample stream into fleet-global track ids so a
/// single [`Probe`] can record the whole replica: engine/FIFO indices are
/// offset by the layers of the preceding shards, PC ids by their device's
/// pseudo-channel count, and names gain an `s{shard}/` prefix.
struct ShardProbe<'a> {
    inner: &'a mut dyn Probe,
    shard: usize,
    engine_base: usize,
    pc_base: u32,
}

impl Probe for ShardProbe<'_> {
    fn window(&self) -> u64 {
        self.inner.window()
    }

    fn engine_sample(&mut self, now: u64, idx: usize, name: &str, cum: &EngineStats) {
        let name = format!("s{}/{name}", self.shard);
        self.inner.engine_sample(now, self.engine_base + idx, &name, cum);
    }

    fn pc_sample(&mut self, now: u64, pc: u32, cum: &PcStats) {
        self.inner.pc_sample(now, self.pc_base + pc, cum);
    }

    fn fifo_sample(&mut self, now: u64, layer: usize, name: &str, occ: u64, cap: u64, peak: u64) {
        let name = format!("s{}/{name}", self.shard);
        self.inner.fifo_sample(now, self.engine_base + layer, &name, occ, cap, peak);
    }

    fn link_sample(&mut self, now: u64, link: usize, occupancy: u64, lines: u64, blocked: u64) {
        self.inner.link_sample(now, link, occupancy, lines, blocked);
    }

    fn hbm_burst(&mut self, pc: u32, accept_cycle: u64, done_cycle: u64, beats: u32) {
        self.inner.hbm_burst(self.pc_base + pc, accept_cycle, done_cycle, beats);
    }

    fn fault_event(&mut self, site: u32, now: u64, kind: &str, detail: u64) {
        // Only HBM sites live in a per-shard namespace; link and replica
        // sites are already fleet-global.
        let site = if kind.starts_with("hbm_") { self.pc_base + site } else { site };
        self.inner.fault_event(site, now, kind, detail);
    }
}

/// Result of one replica run.
struct ReplicaRun {
    throughput: f64,
    latency: f64,
    bottleneck_shard: usize,
    bottleneck_engine: String,
    shard_stats: Vec<ShardStats>,
    links: Vec<LinkStats>,
    core_cycles: u64,
    faults: FaultTotals,
}

/// The fleet: N replicas of an M-shard pipeline.
#[derive(Debug)]
pub struct FleetSim {
    pp: PartitionPlan,
    faults: Option<FaultPlan>,
}

impl FleetSim {
    /// Build from a partition plan; validates the boundary tensors.
    pub fn new(pp: &PartitionPlan) -> Result<Self> {
        ensure!(!pp.shards.is_empty(), "partition has no shards");
        for w in pp.shards.windows(2) {
            let up = w[0].net.layers().last().expect("non-empty shard").out;
            let down = w[1].net.input_shape();
            ensure!(up == down, "boundary shape mismatch: {up} -> {down}");
        }
        Ok(Self { pp: pp.clone(), faults: None })
    }

    /// Arm a fault plan for subsequent runs. HBM error/throttle specs are
    /// forwarded into each shard's weight subsystem (throttle windows use
    /// fleet-global PC ids and are re-based per shard), link windows act
    /// on the inter-device exchange, and replica outages switch the run
    /// from the N-fold scale-out shortcut to simulating every replica.
    pub fn apply_faults(&mut self, fp: &FaultPlan) -> Result<()> {
        fp.validate()?;
        self.faults = Some(fp.clone());
        Ok(())
    }

    /// Run the fleet. One replica's shard pipeline is co-simulated
    /// cycle-accurately; replicas share no simulated hardware and the
    /// simulation is fully deterministic, so N identical replicas are an
    /// exact N-fold scale-out of that run rather than N redundant
    /// simulations.
    pub fn run(&self, cfg: &FleetConfig) -> Result<FleetReport> {
        self.run_with(cfg, None)
    }

    /// [`Self::run`] with a flight-recorder probe attached. Track ids are
    /// fleet-global (see [`ShardProbe`]); inter-device links are sampled
    /// on the sink shard's window boundary.
    pub fn run_probed(&self, cfg: &FleetConfig, probe: &mut dyn Probe) -> Result<FleetReport> {
        self.run_with(cfg, Some(probe))
    }

    fn run_with(&self, cfg: &FleetConfig, mut probe: Option<&mut dyn Probe>) -> Result<FleetReport> {
        ensure!(cfg.replicas >= 1, "need at least one replica");
        ensure!(cfg.link_capacity_lines >= 1, "link capacity must be >= 1 line");
        if self.faults.is_none() {
            // Healthy replicas share no simulated hardware and the run is
            // deterministic, so N replicas are an exact N-fold scale-out.
            let run = self.run_replica(cfg, probe, 0)?;
            return Ok(FleetReport {
                network: self.pp.network.clone(),
                shards: self.pp.shards.len(),
                replicas: cfg.replicas,
                per_replica_throughput: run.throughput,
                aggregate_throughput: run.throughput * cfg.replicas as f64,
                latency: run.latency,
                bottleneck_shard: run.bottleneck_shard,
                bottleneck_engine: run.bottleneck_engine,
                shard_stats: run.shard_stats,
                links: run.links,
                core_cycles: run.core_cycles,
                faults: None,
            });
        }
        // Faults break replica symmetry (outages name a replica index, and
        // every site seed folds the replica in), so simulate each replica
        // and sum. The probe watches replica 0.
        let mut totals = FaultTotals::default();
        let mut aggregate = 0.0;
        let mut first: Option<ReplicaRun> = None;
        for r in 0..cfg.replicas as usize {
            let p = if r == 0 { probe.as_deref_mut() } else { None };
            let run = self.run_replica(cfg, p, r)?;
            totals.absorb(&run.faults);
            aggregate += run.throughput;
            if first.is_none() {
                first = Some(run);
            }
        }
        let run = first.expect("at least one replica ran");
        Ok(FleetReport {
            network: self.pp.network.clone(),
            shards: self.pp.shards.len(),
            replicas: cfg.replicas,
            per_replica_throughput: aggregate / cfg.replicas as f64,
            aggregate_throughput: aggregate,
            latency: run.latency,
            bottleneck_shard: run.bottleneck_shard,
            bottleneck_engine: run.bottleneck_engine,
            shard_stats: run.shard_stats,
            links: run.links,
            core_cycles: run.core_cycles,
            faults: Some(totals),
        })
    }

    /// Cycle-accurate co-simulation of one replica's shard pipeline.
    fn run_replica(
        &self,
        cfg: &FleetConfig,
        mut probe: Option<&mut dyn Probe>,
        rep_idx: usize,
    ) -> Result<ReplicaRun> {
        let images = cfg.images.max(cfg.warmup_images + 1);
        let shards = &self.pp.shards;
        let mut sims = shards
            .iter()
            .map(|s| PipelineSim::new(&s.net, &s.plan))
            .collect::<Result<Vec<_>>>()?;
        let n = sims.len();
        let cap = cfg.link_capacity_lines as u64;

        // Fleet-global track-id bases for the probe (engines/FIFOs by
        // preceding layer counts, PCs by preceding devices' PC counts).
        let mut engine_bases = Vec::with_capacity(n);
        let mut pc_bases = Vec::with_capacity(n);
        let (mut eb, mut pb) = (0usize, 0u32);
        for s in shards {
            engine_bases.push(eb);
            pc_bases.push(pb);
            eb += s.plan.layers.len();
            pb += s.plan.device.hbm.total_pcs();
        }

        // Arm per-shard HBM faults. Throttle windows address fleet-global
        // PC ids, so each shard sees only the windows that fall inside its
        // PC range, re-based to its local numbering; the site seed folds
        // in (replica, shard) so no two devices share an error stream.
        if let Some(fp) = &self.faults {
            for i in 0..n {
                let base = pc_bases[i] as usize;
                let limit = base + shards[i].plan.device.hbm.total_pcs() as usize;
                let mut local = fp.clone();
                local.seed = site_seed(fp.seed, 0x0F1E_E700 + (rep_idx * n + i) as u64);
                local.throttle = fp
                    .throttle
                    .iter()
                    .filter(|t| t.pc >= base && t.pc < limit)
                    .map(|t| {
                        let mut t = t.clone();
                        t.pc -= base;
                        t
                    })
                    .collect();
                if local.hbm.is_some() || !local.throttle.is_empty() {
                    sims[i].apply_faults(&local);
                }
            }
        }
        // Link and outage windows for this replica, on the base-tick clock.
        let link_faults: Vec<&crate::faults::LinkFault> =
            self.faults.as_ref().map_or_else(Vec::new, |fp| fp.links.iter().collect());
        let outages: Vec<&crate::faults::ReplicaOutage> = self
            .faults
            .as_ref()
            .map_or_else(Vec::new, |fp| {
                fp.replicas.iter().filter(|o| o.replica == rep_idx).collect()
            });
        let mut ftotals = FaultTotals::default();
        let mut link_stalled = vec![0u64; n.saturating_sub(1)];
        let mut stall_prev = vec![false; n.saturating_sub(1)];
        let mut down_prev = false;

        let window = probe.as_deref().map_or(0, |p| p.window().max(1));
        let mut next_link_sample = window;
        let mut credits: Vec<CreditCounter> =
            (1..n).map(|_| CreditCounter::new(cfg.link_capacity_lines)).collect();
        let mut peak = vec![0u64; n.saturating_sub(1)];

        // Initial bounds: nothing has arrived downstream yet; every
        // upstream sink may run one credit window ahead.
        for i in 0..n.saturating_sub(1) {
            sims[i].set_sink_limit(cap);
            sims[i + 1].set_input_limit(0);
        }

        let mut warmup_done_at: Option<u64> = None;
        if cfg.exact_stepping {
            // Wall base-tick clock. Equals the sims' own base ticks on a
            // healthy run; during an outage the sims freeze but the wall
            // clock (and the fault windows defined on it) keeps advancing.
            let mut t: u64 = 0;
            loop {
                if t >= cfg.max_base_ticks {
                    let mut msg = String::new();
                    for (i, s) in sims.iter().enumerate() {
                        msg.push_str(&format!("shard {i}: {}\n", s.wedge_breakdown()));
                    }
                    bail!(
                        "fleet simulation exceeded max_base_ticks — pipeline wedged?\n{}",
                        msg.trim_end()
                    );
                }
                // Replica outage: the whole device pipeline freezes for the
                // window (crash plus reboot are modelled as dead ticks — the
                // wall-clock serving stack is where real reboot-from-artifact
                // recovery lives). Queued work is delayed, never lost.
                let down = outages.iter().any(|o| t >= o.start && t < o.end);
                if down != down_prev {
                    let kind = if down { "replica_down" } else { "replica_up" };
                    if down {
                        ftotals.injected += 1;
                        ftotals.failed_over += 1;
                    }
                    if let Some(p) = probe.as_deref_mut() {
                        p.fault_event(rep_idx as u32, t, kind, 0);
                    }
                    down_prev = down;
                }
                if down {
                    ftotals.outage_ticks += 1;
                    t += 1;
                    continue;
                }
                for (i, s) in sims.iter_mut().enumerate() {
                    match probe.as_deref_mut() {
                        None => s.step_base_tick(images),
                        Some(p) => {
                            let mut sp = ShardProbe {
                                inner: p,
                                shard: i,
                                engine_base: engine_bases[i],
                                pc_base: pc_bases[i],
                            };
                            s.step_base_tick_probed(images, Some(&mut sp));
                        }
                    }
                }
                // Exchange link state: occupancy is lines offered upstream
                // minus lines retired downstream; the hardware-style counter
                // must never be overdrawn (that would mean dropped data).
                for i in 0..n - 1 {
                    // A stalled link moves nothing and returns no credits:
                    // both sides keep their last granted bounds, so upstream
                    // backpressure absorbs the window and no line is lost.
                    let stalled = link_faults.iter().any(|f| {
                        f.link == i && f.kind == LinkFaultKind::Stall && t >= f.start && t < f.end
                    });
                    if stalled != stall_prev[i] {
                        if stalled {
                            ftotals.injected += 1;
                            ftotals.retried += 1;
                            if let Some(p) = probe.as_deref_mut() {
                                p.fault_event(i as u32, t, "link_stall", 0);
                            }
                        }
                        stall_prev[i] = stalled;
                    }
                    if stalled {
                        link_stalled[i] += 1;
                        ftotals.link_stall_ticks += 1;
                        continue;
                    }
                    // Credit loss shrinks the window upstream may run ahead
                    // (floor 1 so the link still trickles); in-flight lines
                    // above the shrunken cap drain normally.
                    let lost: u32 = link_faults
                        .iter()
                        .filter(|f| f.link == i && t >= f.start && t < f.end)
                        .filter_map(|f| match f.kind {
                            LinkFaultKind::CreditLoss(l) => Some(l),
                            LinkFaultKind::Stall => None,
                        })
                        .sum();
                    let eff_cap = cap.saturating_sub(u64::from(lost)).max(1);
                    let produced = sims[i].sink_lines_produced();
                    let consumed = sims[i + 1].head_lines_consumed();
                    let occupancy = produced - consumed;
                    let held = credits[i].outstanding() as u64;
                    if occupancy > held {
                        ensure!(
                            credits[i].acquire((occupancy - held) as u32),
                            "link {i} overran its credit window"
                        );
                    } else if held > occupancy {
                        credits[i].release((held - occupancy) as u32);
                    }
                    peak[i] = peak[i].max(occupancy);
                    sims[i].set_sink_limit(consumed + eff_cap);
                    sims[i + 1].set_input_limit(produced);
                }
                // Link windows sample on the sink shard's core-cycle window
                // boundary: cumulative lines/blocked plus the instantaneous
                // in-flight occupancy.
                if let Some(p) = probe.as_deref_mut() {
                    let now = sims[n - 1].core_cycles();
                    if now >= next_link_sample {
                        for i in 0..n - 1 {
                            let produced = sims[i].sink_lines_produced();
                            let consumed = sims[i + 1].head_lines_consumed();
                            p.link_sample(
                                now,
                                i,
                                produced - consumed,
                                produced,
                                sims[i].sink_output_blocked(),
                            );
                        }
                        next_link_sample = now + window;
                    }
                }
                if warmup_done_at.is_none() && sims[n - 1].sink_images_done() >= cfg.warmup_images {
                    warmup_done_at = Some(sims[n - 1].core_cycles());
                }
                if sims.iter().all(|s| s.all_done(images)) {
                    break;
                }
                t += 1;
            }
        } else {
            // Event-driven co-simulation: one skip-ahead scheduler per
            // shard on a shared *local* (executed-tick) clock, plus fleet
            // events for fault-window boundaries and link samples. The
            // exchange is idempotent — limits are pure functions of
            // produced/consumed/eff_cap, which only change at processed
            // ticks — so running it at event ticks only is exact.
            use crate::sim::events::FastCore;
            let merged = merge_windows(outages.iter().map(|o| (o.start, o.end)).collect());
            let mut boundaries: Vec<u64> = link_faults
                .iter()
                .flat_map(|f| [first_executed(f.start, &merged), first_executed(f.end, &merged)])
                .collect();
            boundaries.sort_unstable();
            boundaries.dedup();
            let (mut oi, mut bi) = (0usize, 0usize);
            let mut cores: Vec<FastCore> =
                sims.iter().map(|s| FastCore::new(s, images, window)).collect();
            let mut prev_sink = vec![cap; n.saturating_sub(1)];
            let mut prev_input = vec![0u64; n.saturating_sub(1)];
            let local_done;
            loop {
                let local_next = cores.iter().filter_map(|c| c.next_tick()).min();
                let mut t = local_next.map_or(u64::MAX, |l| to_wall(l, &merged));
                if bi < boundaries.len() {
                    t = t.min(boundaries[bi]);
                }
                if window > 0 && n > 1 {
                    t = t.min(to_wall(4 * (next_link_sample - 1), &merged));
                }
                // Replica outages wholly before the next executed tick:
                // fire both edges and close over the dead ticks. The run
                // never ends mid-outage (executed ticks skip the windows),
                // so an interval is either fully behind the next event or
                // fully ahead of the end of the run.
                while oi < merged.len() && merged[oi].0 < t.min(cfg.max_base_ticks) {
                    let (s, e) = merged[oi];
                    ftotals.injected += 1;
                    ftotals.failed_over += 1;
                    ftotals.outage_ticks += e.min(cfg.max_base_ticks) - s;
                    if let Some(p) = probe.as_deref_mut() {
                        p.fault_event(rep_idx as u32, s, "replica_down", 0);
                        if e < cfg.max_base_ticks {
                            p.fault_event(rep_idx as u32, e, "replica_up", 0);
                        }
                    }
                    oi += 1;
                }
                if t >= cfg.max_base_ticks {
                    let local_cap = executed_before(cfg.max_base_ticks, &merged);
                    let mut msg = String::new();
                    for (i, core) in cores.iter_mut().enumerate() {
                        core.settle_for_wedge(&mut sims[i], local_cap);
                        msg.push_str(&format!("shard {i}: {}\n", sims[i].wedge_breakdown()));
                    }
                    bail!(
                        "fleet simulation exceeded max_base_ticks — pipeline wedged?\n{}",
                        msg.trim_end()
                    );
                }
                let tau = executed_before(t, &merged);
                for i in 0..n {
                    match probe.as_deref_mut() {
                        None => cores[i].process_tick(&mut sims[i], tau, None),
                        Some(p) => {
                            let mut sp = ShardProbe {
                                inner: p,
                                shard: i,
                                engine_base: engine_bases[i],
                                pc_base: pc_bases[i],
                            };
                            cores[i].process_tick(&mut sims[i], tau, Some(&mut sp));
                        }
                    }
                }
                // The slow path's exchange, run at event ticks only; the
                // per-tick stall counters are closed over after the loop.
                for i in 0..n - 1 {
                    let stalled = link_faults.iter().any(|f| {
                        f.link == i && f.kind == LinkFaultKind::Stall && t >= f.start && t < f.end
                    });
                    if stalled != stall_prev[i] {
                        if stalled {
                            ftotals.injected += 1;
                            ftotals.retried += 1;
                            if let Some(p) = probe.as_deref_mut() {
                                p.fault_event(i as u32, t, "link_stall", 0);
                            }
                        }
                        stall_prev[i] = stalled;
                    }
                    if stalled {
                        continue;
                    }
                    let lost: u32 = link_faults
                        .iter()
                        .filter(|f| f.link == i && t >= f.start && t < f.end)
                        .filter_map(|f| match f.kind {
                            LinkFaultKind::CreditLoss(l) => Some(l),
                            LinkFaultKind::Stall => None,
                        })
                        .sum();
                    let eff_cap = cap.saturating_sub(u64::from(lost)).max(1);
                    let produced = sims[i].sink_lines_produced();
                    let consumed = sims[i + 1].head_lines_consumed();
                    let occupancy = produced - consumed;
                    let held = credits[i].outstanding() as u64;
                    if occupancy > held {
                        ensure!(
                            credits[i].acquire((occupancy - held) as u32),
                            "link {i} overran its credit window"
                        );
                    } else if held > occupancy {
                        credits[i].release((held - occupancy) as u32);
                    }
                    peak[i] = peak[i].max(occupancy);
                    // A bound granted at tick tau is first consulted by a
                    // core evaluation at cycle tau/4 + 2 (the next core
                    // tick); changed bounds wake the affected engines.
                    let vis = tau / 4 + 2;
                    let new_sink = consumed + eff_cap;
                    if new_sink != prev_sink[i] {
                        let shrunk = new_sink < prev_sink[i];
                        sims[i].set_sink_limit(new_sink);
                        cores[i].note_sink_limit_changed(&mut sims[i], vis, shrunk);
                        prev_sink[i] = new_sink;
                    }
                    if produced != prev_input[i] {
                        sims[i + 1].set_input_limit(produced);
                        cores[i + 1].note_input_limit_raised(vis);
                        prev_input[i] = produced;
                    }
                }
                // Link samples on the sink shard's window boundary — the
                // sink shard's core tick for that cycle is always in the
                // processed set, so `now` matches the slow path exactly.
                if window > 0 && n > 1 && tau == 4 * (next_link_sample - 1) {
                    let now = next_link_sample;
                    if let Some(p) = probe.as_deref_mut() {
                        for i in 0..n - 1 {
                            let sink_idx = sims[i].engines.len() - 1;
                            cores[i].materialize_engine_stats(&mut sims[i], sink_idx, now);
                            let produced = sims[i].sink_lines_produced();
                            let consumed = sims[i + 1].head_lines_consumed();
                            p.link_sample(
                                now,
                                i,
                                produced - consumed,
                                produced,
                                sims[i].sink_output_blocked(),
                            );
                        }
                    }
                    next_link_sample = now + window;
                }
                if warmup_done_at.is_none() && sims[n - 1].sink_images_done() >= cfg.warmup_images {
                    warmup_done_at = Some(sims[n - 1].core_cycles());
                }
                if cores.iter().all(|c| c.finished()) {
                    local_done = tau;
                    break;
                }
                while bi < boundaries.len() && boundaries[bi] <= t {
                    bi += 1;
                }
            }
            // Closed form for the per-tick stall counters the skipped
            // exchanges would have bumped: executed ticks under a stall
            // window, clipped to the run, minus outage overlap.
            let wall_done = to_wall(local_done, &merged);
            for i in 0..n.saturating_sub(1) {
                let stalls = merge_windows(
                    link_faults
                        .iter()
                        .filter(|f| f.link == i && f.kind == LinkFaultKind::Stall)
                        .map(|f| (f.start, f.end.min(wall_done + 1)))
                        .collect(),
                );
                let mut ticks = 0u64;
                for &w in &stalls {
                    ticks += w.1 - w.0;
                    for &o in &merged {
                        ticks -= overlap(w, o);
                    }
                }
                link_stalled[i] = ticks;
                ftotals.link_stall_ticks += ticks;
            }
            // The run breaks at the final core event of the last shard to
            // finish, so the break tick is a core tick.
            debug_assert_eq!(local_done % 4, 0, "fleet break off a core tick");
            let c_done = local_done / 4 + 1;
            for i in 0..n {
                cores[i].finalize(&mut sims[i], c_done);
            }
        }

        // Final flush: record the trailing partial window of every shard
        // and link so window sums equal end-of-run aggregates.
        if probe.is_some() {
            for i in 0..n {
                let p = probe.as_deref_mut().expect("probe present");
                let mut sp = ShardProbe {
                    inner: p,
                    shard: i,
                    engine_base: engine_bases[i],
                    pc_base: pc_bases[i],
                };
                sims[i].sample_probe(&mut sp);
            }
            let p = probe.as_deref_mut().expect("probe present");
            let now = sims[n - 1].core_cycles();
            for i in 0..n.saturating_sub(1) {
                let produced = sims[i].sink_lines_produced();
                let consumed = sims[i + 1].head_lines_consumed();
                p.link_sample(now, i, produced - consumed, produced, sims[i].sink_output_blocked());
            }
        }

        let hz = shards[0].plan.device.core_mhz as f64 * 1e6;
        let last = &sims[n - 1];
        let span = last.core_cycles() - warmup_done_at.unwrap_or(0);
        let throughput = (images - cfg.warmup_images) as f64 * hz / span.max(1) as f64;
        let latency = last.first_image_done_cycle().map(|c| c as f64 / hz).unwrap_or(f64::NAN);

        let shard_stats: Vec<ShardStats> = sims
            .iter()
            .zip(shards.iter())
            .map(|(sim, sh)| {
                let (engine, active) = sim.busiest_engine();
                ShardStats {
                    name: sh.plan.network.clone(),
                    bottleneck_engine: engine,
                    bottleneck_active: active,
                }
            })
            .collect();
        let bottleneck_shard = shard_stats
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.bottleneck_active)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let bottleneck_engine = shard_stats[bottleneck_shard].bottleneck_engine.clone();
        let links = (0..n - 1)
            .map(|i| LinkStats {
                lines: sims[i].sink_lines_produced(),
                peak_occupancy: peak[i],
                upstream_blocked: sims[i].sink_output_blocked(),
                stalled_ticks: link_stalled[i],
            })
            .collect();
        for s in &sims {
            ftotals.absorb(&s.fault_totals());
        }
        Ok(ReplicaRun {
            throughput,
            latency,
            bottleneck_shard,
            bottleneck_engine,
            shard_stats,
            links,
            core_cycles: last.core_cycles(),
            faults: ftotals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::{partition, PartitionOptions};
    use crate::config::{CompilerOptions, DeviceConfig};
    use crate::nn::zoo;

    fn quick() -> FleetConfig {
        FleetConfig { images: 3, warmup_images: 1, ..Default::default() }
    }

    #[test]
    fn single_shard_fleet_matches_plain_sim() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let o = CompilerOptions::default();
        let pp = partition(&net, &d, &o, &PartitionOptions::default()).unwrap();
        assert_eq!(pp.num_shards(), 1);
        let fleet = FleetSim::new(&pp).unwrap();
        let rep = fleet.run(&quick()).unwrap();
        let plain = crate::sim::pipeline::simulate(
            &net,
            &crate::compiler::compile(&net, &d, &o).unwrap(),
            &crate::sim::pipeline::SimConfig {
                images: 3,
                warmup_images: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let ratio = rep.aggregate_throughput / plain.throughput;
        assert!(
            (0.95..1.05).contains(&ratio),
            "1-shard fleet {:.0} vs plain sim {:.0}",
            rep.aggregate_throughput,
            plain.throughput
        );
        assert!(rep.links.is_empty());
    }

    #[test]
    fn link_stall_delays_but_conserves_lines() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let o = CompilerOptions::default();
        let pp = partition(&net, &d, &o, &PartitionOptions { shards: Some(2), max_shards: 2 })
            .unwrap();
        let mut fleet = FleetSim::new(&pp).unwrap();
        let mut fp = crate::faults::FaultPlan::new(11);
        fp.links.push(crate::faults::LinkFault {
            link: 0,
            start: 5_000,
            end: 60_000,
            kind: LinkFaultKind::Stall,
        });
        fleet.apply_faults(&fp).unwrap();
        let cfg = quick();
        let rep = fleet.run(&cfg).unwrap();
        let f = rep.faults.expect("fault plan armed");
        assert_eq!(f.lost(), 0, "stall must delay, not drop");
        assert!(f.injected >= 1 && f.link_stall_ticks > 0, "{f:?}");
        assert!(rep.links[0].stalled_ticks > 0);
        let boundary_h = pp.shards[0].net.layers().last().unwrap().out.h as u64;
        assert_eq!(rep.links[0].lines, cfg.images * boundary_h, "no line lost or duplicated");
        assert!(rep.links[0].peak_occupancy <= cfg.link_capacity_lines as u64);

        let healthy = FleetSim::new(&pp).unwrap().run(&cfg).unwrap();
        assert!(
            rep.core_cycles >= healthy.core_cycles,
            "a stalled link cannot finish earlier ({} < {})",
            rep.core_cycles,
            healthy.core_cycles
        );
        assert!(healthy.faults.is_none(), "healthy report keeps its pre-fault shape");
    }

    #[test]
    fn replica_outage_is_absorbed_and_deterministic() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let o = CompilerOptions::default();
        let pp = partition(&net, &d, &o, &PartitionOptions::default()).unwrap();
        let mut fleet = FleetSim::new(&pp).unwrap();
        let mut fp = crate::faults::FaultPlan::new(5);
        fp.hbm = Some(crate::faults::HbmFaultSpec {
            start: 0,
            end: 100_000,
            prob: 0.02,
            max_replays: 3,
        });
        fp.replicas.push(crate::faults::ReplicaOutage { replica: 1, start: 10_000, end: 90_000 });
        fleet.apply_faults(&fp).unwrap();
        let cfg = FleetConfig { replicas: 2, ..quick() };
        let rep = fleet.run(&cfg).unwrap();
        let f = rep.faults.expect("fault plan armed");
        assert_eq!(f.lost(), 0, "{f:?}");
        assert!(f.outage_ticks > 0, "outage window must have been hit: {f:?}");
        assert!(f.injected > 0 && f.injected == f.retried + f.failed_over + f.dropped, "{f:?}");
        assert!(rep.aggregate_throughput > 0.0);

        let again = fleet.run(&cfg).unwrap();
        assert_eq!(
            rep.to_json().to_string(),
            again.to_json().to_string(),
            "same seed, same scenario, same bytes"
        );
    }

    #[test]
    fn two_shard_fleet_conserves_lines() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let o = CompilerOptions::default();
        let pp = partition(&net, &d, &o, &PartitionOptions { shards: Some(2), max_shards: 2 })
            .unwrap();
        let fleet = FleetSim::new(&pp).unwrap();
        let cfg = quick();
        let rep = fleet.run(&cfg).unwrap();
        assert!(rep.aggregate_throughput > 0.0);
        let boundary_h = pp.shards[0].net.layers().last().unwrap().out.h as u64;
        assert_eq!(rep.links[0].lines, cfg.images * boundary_h, "no line lost or duplicated");
        assert!(rep.links[0].peak_occupancy <= cfg.link_capacity_lines as u64);
    }
}
