//! Pipeline-parallel partitioning of one CNN across multiple FPGAs.
//!
//! H2PIPE's layer-pipelined dataflow trades chip area for throughput, so
//! the largest networks saturate a single device's M20K and
//! pseudo-channel budget. The partition planner cuts a network into
//! contiguous layer ranges ("shards") at boundaries where exactly one
//! activation stream crosses — a residual skip spanning a cut would need
//! a second inter-device link — and compiles every shard as a standalone
//! accelerator against the *same* per-device budget. Compiling per shard
//! re-runs the whole single-device pipeline (parallelism allocation, the
//! Eq. 1 score, Algorithm 1 offload, §V-B PC assignment), so each device
//! gets its own hybrid memory system sized to the layers it actually
//! hosts.
//!
//! Balancing uses the per-layer M20K floor (activation buffers plus the
//! cheaper of on-chip weight storage at minimum parallelism or the HBM
//! FIFO cost): memory fit is the binding constraint that forces
//! multi-device plans in the first place, and the compiler's own
//! memory-fit co-iteration then settles compute within each shard.

use anyhow::{ensure, Context, Result};

use crate::compiler::{self, resources::M20K_BITS, AcceleratorPlan, LayerStats};
use crate::config::{CompilerOptions, DeviceConfig};
use crate::nn::Network;
use crate::util::ceil_div;

/// Options controlling the partition search.
#[derive(Debug, Clone)]
pub struct PartitionOptions {
    /// Exact shard count, or `None` for the smallest count whose shards
    /// all fit the device.
    pub shards: Option<usize>,
    /// Upper bound on the auto search.
    pub max_shards: usize,
}

impl Default for PartitionOptions {
    fn default() -> Self {
        Self { shards: None, max_shards: 8 }
    }
}

/// One shard: a contiguous run of the original network compiled as a
/// standalone accelerator.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// First original-network layer id in the shard (layer 0, the input
    /// placeholder, belongs to no shard).
    pub first_layer: usize,
    /// Last original-network layer id in the shard (inclusive).
    pub last_layer: usize,
    /// The shard as a standalone network: a synthetic input carrying the
    /// boundary tensor, then the original layers.
    pub net: Network,
    /// The shard's compiled plan — offload decisions re-run per shard.
    pub plan: AcceleratorPlan,
}

/// A network partitioned into pipeline-parallel shards.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub network: String,
    pub shards: Vec<ShardPlan>,
}

impl PartitionPlan {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Analytic fleet throughput bound: the slowest shard paces the
    /// pipeline.
    pub fn est_throughput(&self) -> f64 {
        self.shards.iter().map(|s| s.plan.est_throughput).fold(f64::INFINITY, f64::min)
    }

    /// Index of the analytically slowest shard.
    pub fn bottleneck_shard(&self) -> usize {
        self.shards
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                // total_cmp: never panics, even on a NaN estimate from a
                // corrupt plan
                a.plan.est_throughput.total_cmp(&b.plan.est_throughput)
            })
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Human-readable partition summary.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "=== partition: {} into {} shard(s), est {:.0} im/s ===",
            self.network,
            self.shards.len(),
            self.est_throughput()
        );
        for (i, sh) in self.shards.iter().enumerate() {
            let _ = writeln!(
                s,
                "  shard{i}: layers {:3}..={:3}  M20K {:5}/{} ({:.0}%)  AI-TB {:.0}%  \
                 {} on HBM  est {:.0} im/s",
                sh.first_layer,
                sh.last_layer,
                sh.plan.usage.m20k,
                sh.plan.device.m20k_blocks,
                100.0 * sh.plan.usage.m20k_frac(&sh.plan.device),
                100.0 * sh.plan.usage.tb_frac(&sh.plan.device),
                sh.plan.hbm_layers().count(),
                sh.plan.est_throughput,
            );
        }
        s
    }
}

/// Cut validity per position: `valid[p]` means a shard boundary *before*
/// original layer `p` is legal — the only edge crossing the cut is the
/// boundary activation stream out of layer `p - 1`. Any other crossing
/// edge (a residual skip spanning the cut) would need a second
/// inter-device stream, which the single-link fleet fabric does not
/// provide. Public so the static verifier (`h2pipe check`, rule H2P060)
/// re-derives cut legality from the same definition the planner uses.
pub fn valid_cuts(net: &Network) -> Vec<bool> {
    let n = net.len();
    let mut ok = vec![true; n + 1];
    for l in net.layers() {
        for &u in &l.inputs {
            // edge u -> l.id crosses every cut p in (u+1, l.id]; only
            // p == u + 1 keeps the producer on the boundary.
            for v in &mut ok[(u + 2)..=l.id] {
                *v = false;
            }
        }
    }
    ok
}

/// Per-layer M20K floor used for balancing: activation buffers plus the
/// cheaper weight home (on-chip at minimum parallelism vs. HBM FIFOs at
/// BL8) — the quantity the per-device budget binds on.
fn layer_cost(s: &LayerStats) -> u64 {
    let act = ceil_div(s.act_bits, M20K_BITS);
    let weights = if s.has_weights {
        // on-chip at p=(1,1): capacity + one chain's 2-block banking, per
        // duplicate (matches LayerPlan::onchip_weight_m20k)
        (s.weight_m20k + 2 * s.dup).min(s.hbm_weight_m20k(8))
    } else {
        0
    };
    act + weights
}

/// Choose `m - 1` cut positions from the valid set minimizing the maximum
/// shard cost; every shard must hold at least one weight layer. Returns
/// `None` when the valid cuts cannot support `m` shards.
fn balanced_cuts(stats: &[LayerStats], valid: &[bool], m: usize) -> Option<Vec<usize>> {
    let n = stats.len();
    // prefix sums over real layers 1..n
    let mut cost = vec![0u64; n + 1];
    let mut weighted = vec![0u64; n + 1];
    for i in 1..n {
        cost[i + 1] = cost[i] + layer_cost(&stats[i]);
        weighted[i + 1] = weighted[i] + u64::from(stats[i].has_weights);
    }
    let seg_cost = |a: usize, b: usize| cost[b] - cost[a];
    let seg_weights = |a: usize, b: usize| weighted[b] - weighted[a];

    // dp[k][p]: minimal max-shard-cost splitting layers 1..p into k shards
    // with a boundary at p; prev[k][p] reconstructs the cuts.
    const INF: u64 = u64::MAX;
    let mut dp = vec![vec![INF; n + 1]; m + 1];
    let mut prev = vec![vec![0usize; n + 1]; m + 1];
    dp[0][1] = 0;
    for k in 1..=m {
        for p in 2..=n {
            if p != n && !valid[p] {
                continue;
            }
            let mut best = INF;
            let mut arg = 0usize;
            for q in 1..p {
                if dp[k - 1][q] == INF || seg_weights(q, p) == 0 {
                    continue;
                }
                let c = dp[k - 1][q].max(seg_cost(q, p));
                if c < best {
                    best = c;
                    arg = q;
                }
            }
            dp[k][p] = best;
            prev[k][p] = arg;
        }
    }
    if dp[m][n] == INF {
        return None;
    }
    let mut cuts = Vec::with_capacity(m - 1);
    let mut p = n;
    for k in (2..=m).rev() {
        p = prev[k][p];
        cuts.push(p);
    }
    cuts.reverse();
    Some(cuts)
}

/// Materialize original layers `[first, end)` as a standalone network
/// whose input carries the boundary producer's output tensor.
fn build_shard_net(net: &Network, first: usize, end: usize, shard_idx: usize) -> Result<Network> {
    let boundary = first - 1;
    let name = format!("{}.shard{shard_idx}", net.name);
    let mut sub = Network::new(&name, net.layer(boundary).out);
    let mut map = vec![usize::MAX; net.len()];
    map[boundary] = 0;
    for id in first..end {
        let l = net.layer(id);
        let inputs = l
            .inputs
            .iter()
            .map(|&u| {
                ensure!(
                    map[u] != usize::MAX,
                    "layer {} consumes layer {u} from outside shard {shard_idx}",
                    l.name
                );
                Ok(map[u])
            })
            .collect::<Result<Vec<_>>>()?;
        map[id] = sub.add(&l.name, l.op.clone(), &inputs)?;
    }
    sub.validate().with_context(|| format!("shard {shard_idx} of {}", net.name))?;
    Ok(sub)
}

/// Partition at explicit cut positions (`cuts[i]` is the first original
/// layer id of shard `i + 1`), compiling every shard against `device`.
pub fn partition_at(
    net: &Network,
    device: &DeviceConfig,
    opts: &CompilerOptions,
    cuts: &[usize],
) -> Result<PartitionPlan> {
    net.validate()?;
    let valid = valid_cuts(net);
    let n = net.len();
    let mut bounds = Vec::with_capacity(cuts.len() + 2);
    bounds.push(1usize);
    for &c in cuts {
        ensure!((2..n).contains(&c), "cut position {c} out of range 2..{n}");
        ensure!(
            valid[c],
            "cut before layer {c} ({}) is crossed by a residual edge",
            net.layer(c).name
        );
        ensure!(*bounds.last().unwrap() < c, "cut positions must be strictly increasing");
        bounds.push(c);
    }
    bounds.push(n);

    let mut shards = Vec::with_capacity(bounds.len() - 1);
    for (i, w) in bounds.windows(2).enumerate() {
        let sub = build_shard_net(net, w[0], w[1], i)?;
        ensure!(
            sub.weight_layers().next().is_some(),
            "shard {i} (layers {}..={}) holds no weight layer",
            w[0],
            w[1] - 1
        );
        let plan = compiler::compile(&sub, device, opts)
            .with_context(|| format!("compiling shard {i} (layers {}..={})", w[0], w[1] - 1))?;
        shards.push(ShardPlan { first_layer: w[0], last_layer: w[1] - 1, net: sub, plan });
    }
    Ok(PartitionPlan { network: net.name.clone(), shards })
}

/// Partition a network across identical devices: the smallest shard count
/// (or the exact count in [`PartitionOptions::shards`]) whose
/// cost-balanced shards all compile within the per-device budget.
pub fn partition(
    net: &Network,
    device: &DeviceConfig,
    opts: &CompilerOptions,
    popts: &PartitionOptions,
) -> Result<PartitionPlan> {
    net.validate()?;
    let stats: Vec<LayerStats> =
        net.layers().iter().map(|l| LayerStats::from_layer(l, opts)).collect();
    let valid = valid_cuts(net);
    let (lo, hi) = match popts.shards {
        Some(m) => {
            ensure!(m >= 1, "shard count must be >= 1");
            (m, m)
        }
        None => (1, popts.max_shards.max(1)),
    };
    let mut last_err: Option<anyhow::Error> = None;
    for m in lo..=hi {
        let cuts = if m == 1 { Some(Vec::new()) } else { balanced_cuts(&stats, &valid, m) };
        let Some(cuts) = cuts else { continue };
        match partition_at(net, device, opts, &cuts) {
            Ok(plan) => return Ok(plan),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| {
        anyhow::anyhow!("no legal cut set yields the requested shard count")
    }))
    .with_context(|| {
        format!("partitioning {} into {lo}..={hi} shard(s) on {}", net.name, device.name)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn device() -> DeviceConfig {
        DeviceConfig::stratix10_nx2100()
    }

    #[test]
    fn residual_spans_invalidate_cuts() {
        let net = zoo::resnet18();
        let valid = valid_cuts(&net);
        // layers: 0 input, 1 conv1, 2 maxpool, 3 layer1.0.conv1,
        // 4 layer1.0.conv2, 5 layer1.0.add (skip 2 -> 5).
        assert!(valid[3], "cut before the first residual block is legal");
        assert!(!valid[4], "cut inside a residual block crosses the skip");
        assert!(!valid[5]);
        assert!(valid[6], "cut between blocks is legal");
    }

    #[test]
    fn plain_chains_cut_anywhere() {
        let net = zoo::vgg16();
        let valid = valid_cuts(&net);
        for p in 2..net.len() {
            assert!(valid[p], "VGG-16 has no skips; cut {p} must be legal");
        }
    }

    #[test]
    fn explicit_two_way_partition_covers_the_network() {
        let net = zoo::resnet18();
        let pp = partition_at(&net, &device(), &CompilerOptions::default(), &[6]).unwrap();
        assert_eq!(pp.num_shards(), 2);
        assert_eq!(pp.shards[0].first_layer, 1);
        assert_eq!(pp.shards[1].last_layer, net.len() - 1);
        assert_eq!(pp.shards[1].first_layer, pp.shards[0].last_layer + 1);
        // boundary tensors line up
        assert_eq!(
            pp.shards[1].net.input_shape(),
            pp.shards[0].net.layers().last().unwrap().out
        );
        // every shard fits the device on its own
        for sh in &pp.shards {
            assert!(sh.plan.usage.m20k <= device().m20k_blocks as u64);
        }
    }

    #[test]
    fn auto_partition_uses_one_shard_when_it_fits() {
        let net = zoo::mobilenet_v2();
        let pp = partition(
            &net,
            &device(),
            &CompilerOptions::default(),
            &PartitionOptions::default(),
        )
        .unwrap();
        assert_eq!(pp.num_shards(), 1);
    }

    #[test]
    fn forced_shard_count_balances_cost() {
        let net = zoo::vgg16();
        let o = CompilerOptions::default();
        let pp = partition(
            &net,
            &device(),
            &o,
            &PartitionOptions { shards: Some(3), max_shards: 3 },
        )
        .unwrap();
        assert_eq!(pp.num_shards(), 3);
        // balanced: no shard may carry (nearly) the whole cost
        let stats: Vec<LayerStats> =
            net.layers().iter().map(|l| LayerStats::from_layer(l, &o)).collect();
        let total: u64 = stats[1..].iter().map(layer_cost).sum();
        for sh in &pp.shards {
            let c: u64 =
                (sh.first_layer..=sh.last_layer).map(|i| layer_cost(&stats[i])).sum();
            assert!(
                c < total * 3 / 4,
                "shard {}..{} holds {c}/{total}",
                sh.first_layer,
                sh.last_layer
            );
        }
    }

    #[test]
    fn weightless_shard_is_rejected() {
        // cuts [2, 3] isolate the stem maxpool alone in the middle shard
        let net = zoo::resnet18();
        let err =
            partition_at(&net, &device(), &CompilerOptions::default(), &[2, 3]).unwrap_err();
        assert!(format!("{err:#}").contains("no weight layer"), "{err:#}");
    }

    #[test]
    fn invalid_cut_is_rejected() {
        let net = zoo::resnet18();
        let err = partition_at(&net, &device(), &CompilerOptions::default(), &[4]).unwrap_err();
        assert!(format!("{err:#}").contains("residual"), "{err:#}");
    }

    #[test]
    fn shard_offload_decisions_are_local() {
        // Each shard re-runs Algorithm 1 against a full device. Either
        // half of VGG-16 still exceeds the 140 Mb BRAM on its own, so
        // every shard must offload to its *own* HBM — and stay within its
        // own pseudo-channel bandwidth.
        let net = zoo::vgg16();
        let o = CompilerOptions::default();
        let d = device();
        let pp =
            partition(&net, &d, &o, &PartitionOptions { shards: Some(2), max_shards: 2 })
                .unwrap();
        let cap = d.usable_pcs() as u64 * d.chains_per_pc() as u64;
        for (i, sh) in pp.shards.iter().enumerate() {
            let offloaded = sh.plan.hbm_layers().count();
            assert!(offloaded > 0, "shard {i} must offload to its own HBM");
            let slots: u64 = sh.plan.hbm_layers().map(|l| l.par.chains() as u64).sum();
            assert!(slots + sh.plan.free_bw_slots == cap, "shard {i} oversubscribed: {slots}");
        }
    }
}
