//! The `h2pipe.tune/v1` report artifact: every candidate the search
//! evaluated, the Pareto front, the winner, and a human-readable diff of
//! the winning plan against the default compiler plan.
//!
//! Like the plan and fault artifacts, the report round-trips through
//! [`crate::util::Json`] byte-stably (BTreeMap-ordered objects, no
//! wall-clock fields), so a repeated same-seed run diffs empty.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::compiler::AcceleratorPlan;
use crate::config::WeightPlacement;
use crate::tune::search::{Outcome, SearchResult};
use crate::tune::space::Genome;
use crate::util::Json;

/// Tune-report format tag; bump on incompatible schema changes.
pub const TUNE_FORMAT: &str = "h2pipe.tune/v1";

/// One evaluated candidate, as recorded in the report.
#[derive(Debug, Clone)]
pub struct CandidateRecord {
    /// Candidate id (index into [`TuneReport::candidates`]; 0 is always
    /// the default compiler plan).
    pub id: u32,
    /// Pareto-front member this genome was mutated from (`None` for the
    /// generation-0 axis seeds).
    pub parent: Option<u32>,
    pub genome: Genome,
    /// `"pareto"`, `"dominated"`, `"rejected"` or `"infeasible"`.
    pub outcome: String,
    /// Verifier codes (rejected) or the compile/sim error (infeasible).
    pub detail: String,
    /// Simulated throughput in im/s (0 unless scored).
    pub throughput: f64,
    /// Simulated latency in ms (0 unless scored).
    pub latency_ms: f64,
    /// M20K + chain-slot footprint (0 unless scored).
    pub footprint: u64,
    /// `CompilerOptions` FNV-1a hash (scored candidates only).
    pub options_hash: Option<u64>,
}

impl CandidateRecord {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", self.id);
        match self.parent {
            Some(p) => o.set("parent", p),
            None => o.set("parent", Json::Null),
        };
        o.set("genome", self.genome.to_json())
            .set("outcome", self.outcome.as_str())
            .set("detail", self.detail.as_str())
            .set("throughput", self.throughput)
            .set("latency_ms", self.latency_ms)
            .set("footprint", self.footprint);
        if let Some(h) = self.options_hash {
            o.set("options_hash", format!("{h:016x}"));
        }
        o
    }

    fn from_json(j: &Json) -> Result<Self> {
        let options_hash = match j.get("options_hash").and_then(Json::as_str) {
            Some(hex) => Some(
                u64::from_str_radix(hex, 16)
                    .with_context(|| format!("bad candidate options hash {hex:?}"))?,
            ),
            None => None,
        };
        let field = |k: &str| j.get(k).ok_or_else(|| anyhow!("candidate missing {k:?}"));
        Ok(Self {
            id: field("id")?.as_u32().ok_or_else(|| anyhow!("bad candidate id"))?,
            parent: j.get("parent").and_then(Json::as_u32),
            genome: Genome::from_json(field("genome")?)?,
            outcome: field("outcome")?
                .as_str()
                .ok_or_else(|| anyhow!("bad candidate outcome"))?
                .to_string(),
            detail: field("detail")?
                .as_str()
                .ok_or_else(|| anyhow!("bad candidate detail"))?
                .to_string(),
            throughput: field("throughput")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad candidate throughput"))?,
            latency_ms: field("latency_ms")?
                .as_f64()
                .ok_or_else(|| anyhow!("bad candidate latency"))?,
            footprint: field("footprint")?
                .as_u64()
                .ok_or_else(|| anyhow!("bad candidate footprint"))?,
            options_hash,
        })
    }
}

/// Tuner counters, exported to the metrics pipeline
/// ([`crate::obs::tune_prometheus_text`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TuneCounters {
    /// Candidates evaluated (compile attempted).
    pub evaluated: u64,
    /// Candidates that compiled, passed the gate and were simulated.
    pub scored: u64,
    /// Candidates denied by the verifier legality gate.
    pub rejected: u64,
    /// Candidates the compiler / partition planner / simulator refused.
    pub infeasible: u64,
    /// Search generations run.
    pub generations: u64,
    /// Final Pareto-front size.
    pub pareto_size: u64,
    /// Best simulated throughput seen (im/s).
    pub best_throughput: f64,
}

impl TuneCounters {
    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("evaluated", self.evaluated)
            .set("scored", self.scored)
            .set("rejected", self.rejected)
            .set("infeasible", self.infeasible)
            .set("generations", self.generations)
            .set("pareto_size", self.pareto_size)
            .set("best_throughput", self.best_throughput);
        o
    }

    fn from_json(j: &Json) -> Result<Self> {
        let u = |k: &str| {
            j.get(k).and_then(Json::as_u64).ok_or_else(|| anyhow!("counters missing {k:?}"))
        };
        Ok(Self {
            evaluated: u("evaluated")?,
            scored: u("scored")?,
            rejected: u("rejected")?,
            infeasible: u("infeasible")?,
            generations: u("generations")?,
            pareto_size: u("pareto_size")?,
            best_throughput: j
                .get("best_throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("counters missing best_throughput"))?,
        })
    }
}

/// The complete tuning run: inputs, every candidate, the front, the
/// winner, and its diff against the default plan.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub model: String,
    pub device: String,
    pub seed: u64,
    pub budget: u32,
    pub sim_images: u64,
    pub shards: usize,
    /// Every evaluated candidate, id order.
    pub candidates: Vec<CandidateRecord>,
    /// Pareto-front candidate ids, rank order (winner first).
    pub pareto: Vec<u32>,
    /// Winning candidate id (`None` only when nothing scored).
    pub winner: Option<u32>,
    /// Human-readable winner-vs-default diff (also printed by the CLI as
    /// the `plan-diff:` line).
    pub winner_diff: String,
    pub counters: TuneCounters,
}

impl TuneReport {
    pub fn to_json(&self) -> Json {
        let mut cands = Json::Arr(Vec::new());
        for c in &self.candidates {
            cands.push(c.to_json());
        }
        let mut o = Json::obj();
        o.set("format", TUNE_FORMAT)
            .set("model", self.model.as_str())
            .set("device", self.device.as_str())
            .set("seed", self.seed)
            .set("budget", self.budget)
            .set("sim_images", self.sim_images)
            .set("shards", self.shards)
            .set("candidates", cands)
            .set("pareto", Json::Arr(self.pareto.iter().map(|&i| Json::from(i)).collect()));
        match self.winner {
            Some(w) => o.set("winner", w),
            None => o.set("winner", Json::Null),
        };
        o.set("winner_diff", self.winner_diff.as_str()).set("counters", self.counters.to_json());
        o
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        match j.get("format").and_then(Json::as_str) {
            Some(TUNE_FORMAT) => {}
            Some(other) => bail!("unsupported tune format {other:?} (expected {TUNE_FORMAT:?})"),
            None => bail!("not a tune report (missing \"format\" tag)"),
        }
        let field = |k: &str| j.get(k).ok_or_else(|| anyhow!("tune report missing {k:?}"));
        let candidates = field("candidates")?
            .as_arr()
            .ok_or_else(|| anyhow!("candidates is not an array"))?
            .iter()
            .map(CandidateRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        let pareto = field("pareto")?
            .as_arr()
            .ok_or_else(|| anyhow!("pareto is not an array"))?
            .iter()
            .map(|v| v.as_u32().ok_or_else(|| anyhow!("bad pareto id")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            model: field("model")?.as_str().ok_or_else(|| anyhow!("bad model"))?.to_string(),
            device: field("device")?.as_str().ok_or_else(|| anyhow!("bad device"))?.to_string(),
            seed: field("seed")?.as_u64().ok_or_else(|| anyhow!("bad seed"))?,
            budget: field("budget")?.as_u32().ok_or_else(|| anyhow!("bad budget"))?,
            sim_images: field("sim_images")?.as_u64().ok_or_else(|| anyhow!("bad sim_images"))?,
            shards: field("shards")?.as_usize().ok_or_else(|| anyhow!("bad shards"))?,
            candidates,
            pareto,
            winner: j.get("winner").and_then(Json::as_u32),
            winner_diff: field("winner_diff")?
                .as_str()
                .ok_or_else(|| anyhow!("bad winner_diff"))?
                .to_string(),
            counters: TuneCounters::from_json(field("counters")?)?,
        })
    }

    /// Write the report as pretty-printed JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing tune report {}", path.display()))
    }

    /// Load a report written by [`Self::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tune report {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing tune report {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("loading tune report {}", path.display()))
    }

    /// Human-readable run summary: header, counters, the rank-ordered
    /// front (each member with its `old -> new` decision diff against
    /// candidate 0), the winner, and the `plan-diff:` section.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "=== h2pipe tune: {} on {} (seed {}, budget {}) ===",
            self.model, self.device, self.seed, self.budget
        );
        if self.shards > 1 {
            let _ = writeln!(s, "fleet mode: {} shards", self.shards);
        }
        let c = &self.counters;
        let _ = writeln!(
            s,
            "evaluated {} candidate(s) in {} generation(s): {} scored, {} rejected by \
             verify, {} infeasible",
            c.evaluated, c.generations, c.scored, c.rejected, c.infeasible
        );
        if let Some(base) = self.candidates.first() {
            let _ = writeln!(
                s,
                "baseline: {:.0} im/s  {:.3} ms  footprint {} ({})",
                base.throughput, base.latency_ms, base.footprint, base.outcome
            );
            for (rank, &id) in self.pareto.iter().enumerate() {
                let cand = &self.candidates[id as usize];
                let terms = cand.genome.diff_terms(&base.genome);
                let diff =
                    if terms.is_empty() { "(default)".to_string() } else { terms.join(", ") };
                let _ = writeln!(
                    s,
                    "pareto[{rank}] id={id} tp={:.0} im/s lat={:.3} ms fp={} {}",
                    cand.throughput, cand.latency_ms, cand.footprint, diff
                );
            }
        }
        match self.winner {
            Some(w) => {
                let cand = &self.candidates[w as usize];
                let _ = writeln!(s, "winner: id={w} ({:.0} im/s)", cand.throughput);
            }
            None => {
                let _ = writeln!(s, "winner: none (no candidate scored)");
            }
        }
        let _ = writeln!(s, "plan-diff: {}", self.winner_diff);
        s
    }

    /// Per-candidate scoring events for the dedicated `obs` trace track.
    pub fn trace_spans(&self) -> Vec<crate::obs::TuneSpan> {
        self.candidates
            .iter()
            .map(|c| crate::obs::TuneSpan {
                id: c.id,
                genome: c.genome.fingerprint(),
                outcome: c.outcome.clone(),
                throughput: c.throughput,
                latency_ms: c.latency_ms,
                footprint: c.footprint,
            })
            .collect()
    }
}

/// Assemble the report from a finished search. `winner_diff` is computed
/// by the caller (it needs the recompiled plans, which only exist in
/// single-device mode).
pub(crate) fn build(
    model: &str,
    device: &str,
    topts: &crate::tune::TuneOptions,
    sr: &SearchResult,
    winner_diff: String,
) -> TuneReport {
    let front_ids: std::collections::BTreeSet<u32> = sr.front.iter().map(|p| p.id).collect();
    let mut counters = TuneCounters {
        evaluated: sr.candidates.len() as u64,
        generations: sr.generations as u64,
        pareto_size: sr.front.len() as u64,
        ..TuneCounters::default()
    };
    let mut candidates = Vec::with_capacity(sr.candidates.len());
    for (i, (genome, parent, outcome)) in sr.candidates.iter().enumerate() {
        let id = i as u32;
        let (outcome_str, detail, tp, lat, fp, hash) = match outcome {
            Outcome::Scored(sc) => {
                counters.scored += 1;
                counters.best_throughput = counters.best_throughput.max(sc.throughput);
                let tag = if front_ids.contains(&id) { "pareto" } else { "dominated" };
                let hash = Some(sc.options_hash);
                (tag, String::new(), sc.throughput, sc.latency_ms, sc.footprint, hash)
            }
            Outcome::Rejected { codes } => {
                counters.rejected += 1;
                ("rejected", codes.join(","), 0.0, 0.0, 0, None)
            }
            Outcome::Infeasible { error } => {
                counters.infeasible += 1;
                ("infeasible", error.clone(), 0.0, 0.0, 0, None)
            }
        };
        candidates.push(CandidateRecord {
            id,
            parent: *parent,
            genome: genome.clone(),
            outcome: outcome_str.to_string(),
            detail,
            throughput: tp,
            latency_ms: lat,
            footprint: fp,
            options_hash: hash,
        });
    }
    TuneReport {
        model: model.to_string(),
        device: device.to_string(),
        seed: topts.seed,
        budget: topts.budget,
        sim_images: topts.sim_images,
        shards: topts.shards,
        candidates,
        pareto: sr.front.iter().map(|p| p.id).collect(),
        winner: sr.front.first().map(|p| p.id),
        winner_diff,
        counters,
    }
}

/// Explain how `tuned` differs from `base`, decision by decision: the
/// summary line first, then one indented `old -> new` term per changed
/// knob, per-layer placement flip, and per-layer parallelism change.
pub fn plan_diff(base: &AcceleratorPlan, tuned: &AcceleratorPlan) -> String {
    let mut terms: Vec<String> = Vec::new();
    if base.burst_len != tuned.burst_len {
        terms.push(format!("burst_len: {} -> {}", base.burst_len, tuned.burst_len));
    }
    if base.options.last_stage_fifo_depth != tuned.options.last_stage_fifo_depth {
        terms.push(format!(
            "fifo_depth: {} -> {}",
            base.options.last_stage_fifo_depth, tuned.options.last_stage_fifo_depth
        ));
    }
    if base.options.sparsity_fraction != tuned.options.sparsity_fraction {
        terms.push(format!(
            "sparsity: {:.3} -> {:.3}",
            base.options.sparsity_fraction, tuned.options.sparsity_fraction
        ));
    }
    if base.options.all_hbm != tuned.options.all_hbm {
        terms.push(format!("all_hbm: {} -> {}", base.options.all_hbm, tuned.options.all_hbm));
    }
    let place = |p: WeightPlacement| match p {
        WeightPlacement::Hbm => "hbm",
        WeightPlacement::OnChip => "chip",
    };
    let mut flips = 0usize;
    for (a, b) in base.layers.iter().zip(&tuned.layers) {
        if !a.stats.has_weights {
            continue;
        }
        if a.placement != b.placement {
            flips += 1;
            let (from, to) = (place(a.placement), place(b.placement));
            terms.push(format!("{}: {} -> {}", a.stats.name, from, to));
        } else if a.par != b.par {
            terms.push(format!(
                "{}: p=({},{}) -> p=({},{})",
                a.stats.name, a.par.p_i, a.par.p_o, b.par.p_i, b.par.p_o
            ));
        }
    }
    let mut s = if terms.is_empty() {
        "no decisions changed (the default plan is the winner)".to_string()
    } else {
        format!("{} decision(s) changed ({flips} placement flip(s))", terms.len())
    };
    for t in &terms {
        s.push_str("\n  ");
        s.push_str(t);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompilerOptions, DeviceConfig};
    use crate::nn::zoo;
    use crate::session::Session;

    fn sample_report() -> TuneReport {
        let base = Genome::baseline(&CompilerOptions::default(), Vec::new());
        let mut tuned = base.clone();
        tuned.burst = crate::config::BurstLengthPolicy::Fixed(16);
        tuned.overrides = vec![(3, true)];
        TuneReport {
            model: "resnet18".to_string(),
            device: "stratix10_nx2100".to_string(),
            seed: 7,
            budget: 8,
            sim_images: 3,
            shards: 1,
            candidates: vec![
                CandidateRecord {
                    id: 0,
                    parent: None,
                    genome: base,
                    outcome: "dominated".to_string(),
                    detail: String::new(),
                    throughput: 2400.0,
                    latency_ms: 2.5,
                    footprint: 7000,
                    options_hash: Some(0xdead_beef_0123_4567),
                },
                CandidateRecord {
                    id: 1,
                    parent: Some(0),
                    genome: tuned,
                    outcome: "pareto".to_string(),
                    detail: String::new(),
                    throughput: 2600.0,
                    latency_ms: 2.4,
                    footprint: 6900,
                    options_hash: Some(0x0123_4567_89ab_cdef),
                },
            ],
            pareto: vec![1],
            winner: Some(1),
            winner_diff: "1 decision(s) changed (0 placement flip(s))\n  burst_len: 8 -> 16"
                .to_string(),
            counters: TuneCounters {
                evaluated: 2,
                scored: 2,
                rejected: 0,
                infeasible: 0,
                generations: 1,
                pareto_size: 1,
                best_throughput: 2600.0,
            },
        }
    }

    #[test]
    fn report_round_trips_byte_stably() {
        let r = sample_report();
        let j = r.to_json();
        let text = j.to_pretty();
        let back = TuneReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_pretty(), text, "re-serialization must be byte-identical");
        assert_eq!(back.candidates.len(), 2);
        assert_eq!(back.winner, Some(1));
        assert_eq!(back.candidates[1].options_hash, Some(0x0123_4567_89ab_cdef));
        assert_eq!(back.counters, r.counters);
    }

    #[test]
    fn format_tag_is_enforced() {
        let mut j = sample_report().to_json();
        j.set("format", "h2pipe.tune/v0");
        assert!(TuneReport::from_json(&j).is_err());
        assert!(TuneReport::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn render_names_front_members_and_plan_diff() {
        let text = sample_report().render();
        assert!(text.contains("pareto[0] id=1"), "{text}");
        assert!(text.contains(" -> "), "front diffs must show old -> new terms: {text}");
        assert!(text.contains("plan-diff:"), "{text}");
        assert!(text.contains("winner: id=1"), "{text}");
    }

    #[test]
    fn plan_diff_names_changed_decisions() {
        let device = DeviceConfig::stratix10_nx2100();
        let compile = |opts: CompilerOptions| {
            Session::builder()
                .network(zoo::resnet18())
                .device(device.clone())
                .options(opts)
                .compile()
                .unwrap()
        };
        let base = compile(CompilerOptions::default());
        let mut opts = CompilerOptions::default();
        opts.burst_length = crate::config::BurstLengthPolicy::Fixed(16);
        let tuned = compile(opts);
        let d = plan_diff(base.plan(), tuned.plan());
        assert!(d.contains("burst_len: 8 -> 16"), "{d}");
        let same = plan_diff(base.plan(), base.plan());
        assert!(same.contains("no decisions changed"), "{same}");
    }

    #[test]
    fn trace_spans_cover_every_candidate() {
        let r = sample_report();
        let spans = r.trace_spans();
        assert_eq!(spans.len(), r.candidates.len());
        assert_eq!(spans[1].outcome, "pareto");
        assert!(spans[1].genome.contains("b=16"), "{}", spans[1].genome);
    }
}
