//! The search loop: seeded evolutionary exploration with a hard legality
//! gate, short cycle-simulation scoring on a worker pool, and a Pareto
//! front over throughput / latency / footprint.
//!
//! Determinism contract (same as `faults`): every random draw comes from
//! a [`XorShift64`] stream seeded via [`crate::faults::site_seed`] with a
//! monotonically assigned site, results are merged in candidate-id order
//! regardless of which worker produced them, and no wall-clock value ever
//! enters a score — so `--seed S` reproduces the whole run byte for byte
//! at any worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::{CompilerOptions, DeviceConfig};
use crate::nn::Network;
use crate::session::{codec, Session};
use crate::sim::pipeline::SimConfig;
use crate::tune::space::{Genome, SearchSpace};
use crate::tune::TuneOptions;
use crate::util::XorShift64;
use crate::verify::Severity;

/// Candidates evaluated per generation before the front is re-ranked and
/// new parents are drawn.
const GEN_SIZE: usize = 4;

/// Consecutive duplicate mutation draws before the space is declared
/// exhausted and the search stops early.
const MAX_DRY_DRAWS: u32 = 64;

/// Scored objectives of one feasible candidate.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Score {
    /// Simulated steady-state throughput (im/s) — maximized.
    pub throughput: f64,
    /// Simulated first-image latency (ms; summed over shards in fleet
    /// mode) — minimized.
    pub latency_ms: f64,
    /// M20K blocks plus chain slots in M20K-equivalents — minimized.
    pub footprint: u64,
    /// FNV-1a hash of the candidate's `CompilerOptions`.
    pub options_hash: u64,
}

/// What happened to one candidate.
#[derive(Debug, Clone)]
pub(crate) enum Outcome {
    /// Compiled, passed the verifier, simulated.
    Scored(Score),
    /// Compiled but denied by the `--deny warn` legality gate.
    Rejected { codes: Vec<String> },
    /// The compiler (or partition planner / simulator) refused it.
    Infeasible { error: String },
}

/// One point on the Pareto front.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ParetoPoint {
    pub id: u32,
    pub throughput: f64,
    pub latency_ms: f64,
    pub footprint: u64,
}

/// `a` is at least as good as `b` on every objective.
fn weakly_dominates(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    a.throughput >= b.throughput && a.latency_ms <= b.latency_ms && a.footprint <= b.footprint
}

/// `a` weakly dominates `b` and is strictly better somewhere.
fn strictly_dominates(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    weakly_dominates(a, b)
        && (a.throughput > b.throughput || a.latency_ms < b.latency_ms || a.footprint < b.footprint)
}

/// Insert `c` into the front. Rejected if any member weakly dominates it
/// (full ties keep the incumbent — candidates arrive in id order, so the
/// lowest id wins ties); on acceptance, members it strictly dominates are
/// evicted. Returns whether `c` joined.
pub(crate) fn pareto_insert(front: &mut Vec<ParetoPoint>, c: ParetoPoint) -> bool {
    if front.iter().any(|m| weakly_dominates(m, &c)) {
        return false;
    }
    front.retain(|m| !strictly_dominates(&c, m));
    front.push(c);
    true
}

/// Rank order of the front (and the winner rule: `front[0]` after this
/// sort): throughput down, then footprint up, then latency up, then id.
pub(crate) fn rank(front: &mut [ParetoPoint]) {
    front.sort_by(|a, b| {
        b.throughput
            .total_cmp(&a.throughput)
            .then(a.footprint.cmp(&b.footprint))
            .then(a.latency_ms.total_cmp(&b.latency_ms))
            .then(a.id.cmp(&b.id))
    });
}

/// Area-style scalar for the M20K+PC objective: M20K blocks consumed plus
/// occupied chain slots converted at the device's blocks-per-slot ratio
/// (6847 / 93 = 73 on the NX2100), so freeing a pseudo-channel and
/// freeing BRAM trade in one currency.
pub(crate) fn footprint(plan: &crate::compiler::AcceleratorPlan, device: &DeviceConfig) -> u64 {
    let cap = plan.bw_slot_capacity().max(1);
    let used = cap.saturating_sub(plan.free_bw_slots);
    let slot_equiv = (device.m20k_blocks as u64 / cap).max(1);
    plan.usage.m20k + slot_equiv * used
}

fn verify_codes(report: &crate::verify::Report) -> Vec<String> {
    let mut codes: Vec<String> = report
        .diagnostics
        .iter()
        .filter(|d| d.severity >= Severity::Warn)
        .map(|d| d.code.as_str().to_string())
        .collect();
    codes.sort_unstable();
    codes.dedup();
    codes
}

/// Evaluate one genome end to end: compile through the real session
/// pipeline, gate on the verifier at `--deny warn`, then score with a
/// short cycle simulation.
pub(crate) fn evaluate(
    net: &Network,
    device: &DeviceConfig,
    base: &CompilerOptions,
    genome: &Genome,
    sim_cfg: &SimConfig,
) -> Outcome {
    let opts = genome.apply(base);
    if let Err(e) = opts.validate() {
        return Outcome::Infeasible { error: format!("{e:#}") };
    }
    if genome.cuts.is_empty() {
        evaluate_single(net, device, opts, sim_cfg)
    } else {
        evaluate_fleet(net, device, opts, &genome.cuts, sim_cfg)
    }
}

fn evaluate_single(
    net: &Network,
    device: &DeviceConfig,
    opts: CompilerOptions,
    sim_cfg: &SimConfig,
) -> Outcome {
    let cm = match Session::builder()
        .network(net.clone())
        .device(device.clone())
        .options(opts)
        .compile()
    {
        Ok(cm) => cm,
        Err(e) => return Outcome::Infeasible { error: format!("{e:#}") },
    };
    let report = cm.verify();
    if report.denies(Severity::Warn) {
        return Outcome::Rejected { codes: verify_codes(&report) };
    }
    let sim = match cm.simulate(sim_cfg) {
        Ok(r) => r,
        Err(e) => return Outcome::Infeasible { error: format!("simulation: {e:#}") },
    };
    Outcome::Scored(Score {
        throughput: sim.throughput,
        latency_ms: sim.latency * 1e3,
        footprint: footprint(cm.plan(), device),
        options_hash: cm.provenance().options_hash,
    })
}

fn evaluate_fleet(
    net: &Network,
    device: &DeviceConfig,
    opts: CompilerOptions,
    cuts: &[usize],
    sim_cfg: &SimConfig,
) -> Outcome {
    let pp = match crate::cluster::partition_at(net, device, &opts, cuts) {
        Ok(p) => p,
        Err(e) => return Outcome::Infeasible { error: format!("{e:#}") },
    };
    let mut report = crate::verify::check_partition(net, &pp);
    for sh in &pp.shards {
        report.diagnostics.extend(crate::verify::check_plan(&sh.plan).diagnostics);
    }
    if report.denies(Severity::Warn) {
        return Outcome::Rejected { codes: verify_codes(&report) };
    }
    // Fleet objectives: the slowest shard paces throughput, fill latency
    // and footprint accumulate across devices.
    let mut throughput = f64::INFINITY;
    let mut latency_ms = 0.0;
    let mut fp = 0u64;
    for sh in &pp.shards {
        let sim = match crate::sim::pipeline::simulate(&sh.net, &sh.plan, sim_cfg) {
            Ok(r) => r,
            Err(e) => return Outcome::Infeasible { error: format!("simulation: {e:#}") },
        };
        throughput = throughput.min(sim.throughput);
        latency_ms += sim.latency * 1e3;
        fp += footprint(&sh.plan, device);
    }
    Outcome::Scored(Score {
        throughput,
        latency_ms,
        footprint: fp,
        options_hash: codec::options_hash(&opts),
    })
}

/// Evaluate a generation on a `std::thread` worker pool. Results land in
/// per-candidate slots and are read back in index order, so the output is
/// provably independent of worker count and scheduling.
fn evaluate_generation(
    net: &Network,
    device: &DeviceConfig,
    base: &CompilerOptions,
    genomes: &[Genome],
    sim_cfg: &SimConfig,
    workers: usize,
) -> Vec<Outcome> {
    let n = genomes.len();
    let workers = workers.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 {
        return genomes.iter().map(|g| evaluate(net, device, base, g, sim_cfg)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Outcome>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let out = evaluate(net, device, base, &genomes[i], sim_cfg);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker pool left an evaluation slot empty"))
        .collect()
}

/// Everything the search produced, in candidate-id order.
#[derive(Debug)]
pub(crate) struct SearchResult {
    /// `(genome, parent id, outcome)`; index == candidate id.
    pub candidates: Vec<(Genome, Option<u32>, Outcome)>,
    /// Rank-sorted Pareto front.
    pub front: Vec<ParetoPoint>,
    pub generations: u32,
}

/// Run the seeded search: generation 0 is the deterministic axis seed
/// set, later generations mutate parents drawn from the rank-sorted
/// front. Stops at the budget or when [`MAX_DRY_DRAWS`] consecutive
/// mutation draws produce nothing new.
pub(crate) fn run_search(
    net: &Network,
    device: &DeviceConfig,
    base: &CompilerOptions,
    space: &SearchSpace,
    topts: &TuneOptions,
    sim_cfg: &SimConfig,
    workers: usize,
) -> SearchResult {
    let budget = topts.budget.max(1) as usize;
    let mut seen = std::collections::BTreeSet::new();
    let mut candidates: Vec<(Genome, Option<u32>, Outcome)> = Vec::new();
    let mut front: Vec<ParetoPoint> = Vec::new();
    let mut generations = 0u32;
    // Monotonic site counter for mutation draws: one RNG stream per
    // attempt, never reused, never dependent on evaluation timing.
    let mut draw_site = 0u64;

    let mut gen: Vec<(Genome, Option<u32>)> = space
        .seeds(budget)
        .into_iter()
        .filter(|g| seen.insert(g.fingerprint()))
        .map(|g| (g, None))
        .collect();

    while !gen.is_empty() {
        generations += 1;
        let first_id = candidates.len() as u32;
        let genomes: Vec<Genome> = gen.iter().map(|(g, _)| g.clone()).collect();
        let outcomes = evaluate_generation(net, device, base, &genomes, sim_cfg, workers);
        for (k, out) in outcomes.into_iter().enumerate() {
            let id = first_id + k as u32;
            if let Outcome::Scored(sc) = &out {
                pareto_insert(
                    &mut front,
                    ParetoPoint {
                        id,
                        throughput: sc.throughput,
                        latency_ms: sc.latency_ms,
                        footprint: sc.footprint,
                    },
                );
            }
            let (g, parent) = gen[k].clone();
            candidates.push((g, parent, out));
        }

        let remaining = budget.saturating_sub(candidates.len());
        if remaining == 0 || front.is_empty() {
            break;
        }
        let mut ranked = front.clone();
        rank(&mut ranked);
        gen = Vec::new();
        let mut dry = 0u32;
        while gen.len() < remaining.min(GEN_SIZE) && dry < MAX_DRY_DRAWS {
            let mut rng = XorShift64::new(crate::faults::site_seed(topts.seed, draw_site));
            draw_site += 1;
            let parent = ranked[rng.next_below(ranked.len() as u64) as usize];
            let child = space.mutate(&candidates[parent.id as usize].0, &mut rng);
            if seen.insert(child.fingerprint()) {
                dry = 0;
                gen.push((child, Some(parent.id)));
            } else {
                dry += 1;
            }
        }
    }

    rank(&mut front);
    SearchResult { candidates, front, generations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn point(id: u32, tp: f64, lat: f64, fp: u64) -> ParetoPoint {
        ParetoPoint { id, throughput: tp, latency_ms: lat, footprint: fp }
    }

    #[test]
    fn pareto_keeps_tradeoffs_and_evicts_dominated() {
        let mut front = Vec::new();
        assert!(pareto_insert(&mut front, point(0, 100.0, 10.0, 1000)));
        // worse everywhere: rejected
        assert!(!pareto_insert(&mut front, point(1, 90.0, 11.0, 1100)));
        // exact tie: incumbent (lower id) wins
        assert!(!pareto_insert(&mut front, point(2, 100.0, 10.0, 1000)));
        // trade-off (slower but smaller): joins
        assert!(pareto_insert(&mut front, point(3, 80.0, 10.0, 500)));
        // strictly better than candidate 0: joins, evicts it
        assert!(pareto_insert(&mut front, point(4, 120.0, 9.0, 900)));
        let ids: Vec<u32> = front.iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![3, 4]);
    }

    #[test]
    fn rank_orders_throughput_then_footprint() {
        let mut front =
            vec![point(5, 80.0, 5.0, 500), point(1, 100.0, 10.0, 900), point(2, 100.0, 8.0, 700)];
        rank(&mut front);
        let ids: Vec<u32> = front.iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![2, 1, 5], "ties on throughput break on footprint");
    }

    #[test]
    fn worker_count_does_not_change_outcomes() {
        let net = zoo::resnet18();
        let device = DeviceConfig::stratix10_nx2100();
        let base = CompilerOptions::default();
        let space = SearchSpace::new(&net, &base, Vec::new());
        let genomes = space.seeds(4);
        let cfg = SimConfig { images: 2, warmup_images: 1, ..SimConfig::default() };
        let a = evaluate_generation(&net, &device, &base, &genomes, &cfg, 1);
        let b = evaluate_generation(&net, &device, &base, &genomes, &cfg, 3);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (Outcome::Scored(p), Outcome::Scored(q)) => {
                    assert_eq!(p.throughput.to_bits(), q.throughput.to_bits());
                    assert_eq!(p.latency_ms.to_bits(), q.latency_ms.to_bits());
                    assert_eq!(p.footprint, q.footprint);
                    assert_eq!(p.options_hash, q.options_hash);
                }
                (Outcome::Rejected { codes: p }, Outcome::Rejected { codes: q }) => {
                    assert_eq!(p, q)
                }
                (Outcome::Infeasible { error: p }, Outcome::Infeasible { error: q }) => {
                    assert_eq!(p, q)
                }
                other => panic!("outcome kind diverged across worker counts: {other:?}"),
            }
        }
    }

    #[test]
    fn shallow_fifo_candidate_is_rejected_not_scored() {
        // 128-word FIFOs sit below the H2P040 coverage bound whenever HBM
        // layers exist — the legality gate must catch what the compiler
        // accepts.
        let net = zoo::resnet50();
        let device = DeviceConfig::stratix10_nx2100();
        let base = CompilerOptions::default();
        let mut g = Genome::baseline(&base, Vec::new());
        g.fifo_depth = 128;
        let cfg = SimConfig { images: 2, warmup_images: 1, ..SimConfig::default() };
        match evaluate(&net, &device, &base, &g, &cfg) {
            Outcome::Rejected { codes } => {
                assert!(codes.iter().any(|c| c == "H2P040"), "expected H2P040, got {codes:?}")
            }
            other => panic!("128-word FIFO must be rejected, got {other:?}"),
        }
    }

    #[test]
    fn search_is_deterministic_and_budget_bounded() {
        let net = zoo::resnet18();
        let device = DeviceConfig::stratix10_nx2100();
        let base = CompilerOptions::default();
        let space = SearchSpace::new(&net, &base, Vec::new());
        let topts = TuneOptions { budget: 6, seed: 9, ..TuneOptions::default() };
        let cfg = SimConfig { images: 2, warmup_images: 1, ..SimConfig::default() };
        let a = run_search(&net, &device, &base, &space, &topts, &cfg, 2);
        let b = run_search(&net, &device, &base, &space, &topts, &cfg, 1);
        assert!(a.candidates.len() <= 6);
        assert_eq!(a.candidates.len(), b.candidates.len());
        assert!(!a.front.is_empty(), "baseline must be feasible");
        let ids = |sr: &SearchResult| sr.front.iter().map(|p| p.id).collect::<Vec<_>>();
        assert_eq!(ids(&a), ids(&b));
        for ((ga, pa, _), (gb, pb, _)) in a.candidates.iter().zip(&b.candidates) {
            assert_eq!(ga, gb);
            assert_eq!(pa, pb);
        }
        assert_eq!(&a.candidates[0].0, space.base(), "candidate 0 is the default plan");
    }
}
