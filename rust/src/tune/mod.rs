//! `h2pipe tune` — parallel plan-space autotuner with Pareto search.
//!
//! The H2PIPE compiler makes each decision with a local heuristic: Eq. 1
//! ranks offload candidates, §VI-A picks the burst length from one
//! bottleneck probe, the last-stage FIFOs are fixed at 512 words. Those
//! defaults are good but not jointly optimal — burst length changes the
//! FIFO bound, FIFO depth changes the M20K budget, the budget changes
//! which layers Algorithm 1 offloads. This module searches the joint
//! space instead:
//!
//! * [`SearchSpace`] enumerates mutations over the tunable knobs: burst
//!   policy, last-stage FIFO depth, the Eq. 1 sparsity discount,
//!   all-HBM, per-layer offload overrides, and fleet cut points.
//! * [`tune_network`] runs a seeded evolutionary search. Every candidate
//!   compiles through the real [`crate::session`] pipeline, must pass
//!   the static verifier at `--deny warn` (the H2P0xx rules are a hard
//!   legality gate), and is scored by a short [`crate::sim`] cycle
//!   simulation on a worker pool with deterministic merge order.
//! * A Pareto front over simulated throughput / latency / M20K+PC
//!   footprint survives; the ranked winner is re-compiled into a normal
//!   replayable plan artifact and diffed against the default plan.
//!
//! Determinism: the same `--seed` yields a byte-identical
//! [`TuneReport`] at any `--workers` setting (per-candidate RNG streams
//! via [`crate::faults::site_seed`], id-ordered merges, no wall-clock
//! fields). The report artifact (`h2pipe.tune/v1`) round-trips
//! byte-stably like every other artifact in the repo.

mod report;
mod search;
mod space;

pub use report::{plan_diff, CandidateRecord, TuneCounters, TuneReport, TUNE_FORMAT};
pub use space::{Genome, SearchSpace};

use anyhow::{ensure, Context, Result};

use crate::config::{CompilerOptions, DeviceConfig};
use crate::nn::{zoo, Network};
use crate::session::{CompiledModel, Session};
use crate::sim::pipeline::SimConfig;

/// Models swept by `h2pipe tune` when no `--model` is given: the paper's
/// headline hybrid case (ResNet-50), the BRAM-bound small net that still
/// offloads (ResNet-18), and the weight-heaviest zoo member (VGG-16).
pub const DEFAULT_SWEEP: &[&str] = &["resnet18", "resnet50", "vgg16"];

/// Tuner parameters.
#[derive(Debug, Clone)]
pub struct TuneOptions {
    /// Total candidates to evaluate (compile + gate + simulate).
    pub budget: u32,
    /// Root seed for every RNG stream in the run.
    pub seed: u64,
    /// Images per scoring simulation (short on purpose: steady state on
    /// these pipelines is reached within a few images).
    pub sim_images: u64,
    /// Worker threads; 0 picks `min(4, available_parallelism)`. Any
    /// value produces identical results.
    pub workers: usize,
    /// Devices to partition across; 1 tunes a single-device plan, >1
    /// opens the fleet cut-point axis (and closes the per-layer offload
    /// override axis, whose indices are not shard-portable).
    pub shards: usize,
}

impl Default for TuneOptions {
    fn default() -> Self {
        Self { budget: 12, seed: 7, sim_images: 4, workers: 0, shards: 1 }
    }
}

/// A finished tuning run.
#[derive(Debug)]
pub struct TuneOutcome {
    pub report: TuneReport,
    /// The winning plan as a normal replayable artifact — `None` in
    /// fleet mode, where the winner is a set of per-shard plans recorded
    /// in the report instead.
    pub winner: Option<CompiledModel>,
}

fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 4)
}

/// Tune a zoo model with default base options.
pub fn tune_model(model: &str, device: &DeviceConfig, topts: &TuneOptions) -> Result<TuneOutcome> {
    let net = zoo::by_name(model)
        .with_context(|| format!("unknown zoo model {model:?} (see `h2pipe tune --help`)"))?;
    tune_network(&net, device, &CompilerOptions::default(), topts)
}

/// Tune any network around a base option set. The base options are
/// candidate 0, so a feasible baseline guarantees the winner's simulated
/// throughput is at least the default plan's.
pub fn tune_network(
    net: &Network,
    device: &DeviceConfig,
    base: &CompilerOptions,
    topts: &TuneOptions,
) -> Result<TuneOutcome> {
    ensure!(topts.budget >= 1, "tune budget must be >= 1");
    ensure!(topts.shards >= 1, "shard count must be >= 1");
    ensure!(topts.sim_images >= 2, "scoring needs at least 2 images (1 warmup + 1 measured)");
    base.validate()?;
    net.validate()?;

    // Fleet mode: the planner's balanced cuts become the baseline genome.
    let base_cuts = if topts.shards > 1 {
        let popts = crate::cluster::PartitionOptions {
            shards: Some(topts.shards),
            max_shards: topts.shards,
        };
        let pp = crate::cluster::partition(net, device, base, &popts)
            .context("baseline fleet partition")?;
        pp.shards.iter().skip(1).map(|s| s.first_layer).collect()
    } else {
        Vec::new()
    };

    let space = SearchSpace::new(net, base, base_cuts);
    let sim_cfg = SimConfig { images: topts.sim_images, warmup_images: 1, ..SimConfig::default() };
    let workers = if topts.workers == 0 { default_workers() } else { topts.workers };
    let sr = search::run_search(net, device, base, &space, topts, &sim_cfg, workers);

    ensure!(
        !sr.front.is_empty(),
        "{}: no candidate survived the legality gate within budget {} (baseline included)",
        net.name,
        topts.budget
    );
    let winner_id = sr.front[0].id;
    let winner_genome = sr.candidates[winner_id as usize].0.clone();

    // Recompile the winner (and the default) for the artifact + diff.
    // Both compiles are deterministic replays of evaluations that already
    // succeeded, so errors here indicate a bug, not a bad candidate.
    let (winner, winner_diff) = if topts.shards == 1 {
        let compile = |opts: CompilerOptions| {
            Session::builder().network(net.clone()).device(device.clone()).options(opts).compile()
        };
        let base_cm = compile(space.base().apply(base)).context("recompiling default plan")?;
        let win_cm = compile(winner_genome.apply(base)).context("recompiling winning plan")?;
        let diff = plan_diff(base_cm.plan(), win_cm.plan());
        (Some(win_cm), diff)
    } else {
        let terms = winner_genome.diff_terms(space.base());
        let diff = if terms.is_empty() {
            "no decisions changed (the default plan is the winner)".to_string()
        } else {
            let mut s = format!("{} decision(s) changed", terms.len());
            for t in &terms {
                s.push_str("\n  ");
                s.push_str(t);
            }
            s
        };
        (None, diff)
    };

    let report = report::build(&net.name, &device.name, topts, &sr, winner_diff);
    Ok(TuneOutcome { report, winner })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_covers_resnet18() {
        assert!(DEFAULT_SWEEP.contains(&"resnet18"));
        for m in DEFAULT_SWEEP {
            assert!(zoo::by_name(m).is_some(), "sweep model {m} missing from zoo");
        }
    }

    #[test]
    fn tiny_budget_still_produces_a_winner() {
        let device = DeviceConfig::stratix10_nx2100();
        let topts = TuneOptions { budget: 1, sim_images: 2, ..TuneOptions::default() };
        let out = tune_model("resnet18", &device, &topts).unwrap();
        assert_eq!(out.report.winner, Some(0), "budget 1 evaluates exactly the baseline");
        assert_eq!(out.report.candidates.len(), 1);
        let cm = out.winner.expect("single-device run must emit a plan artifact");
        assert!(!cm.verify().denies(crate::verify::Severity::Warn));
        assert!(out.report.winner_diff.contains("no decisions changed"));
    }

    #[test]
    fn invalid_options_are_refused_up_front() {
        let device = DeviceConfig::stratix10_nx2100();
        assert!(tune_model("no_such_model", &device, &TuneOptions::default()).is_err());
        let topts = TuneOptions { budget: 0, ..TuneOptions::default() };
        assert!(tune_model("resnet18", &device, &topts).is_err());
        let topts = TuneOptions { sim_images: 1, ..TuneOptions::default() };
        assert!(tune_model("resnet18", &device, &topts).is_err());
    }

    #[test]
    fn fleet_mode_tunes_cut_points_without_plan_artifact() {
        let device = DeviceConfig::stratix10_nx2100();
        let topts = TuneOptions { budget: 4, sim_images: 2, shards: 2, ..TuneOptions::default() };
        let net = zoo::vgg16();
        let out = tune_network(&net, &device, &CompilerOptions::default(), &topts).unwrap();
        assert!(out.winner.is_none(), "fleet winners live in the report only");
        assert_eq!(out.report.shards, 2);
        let base = &out.report.candidates[0].genome;
        assert_eq!(base.cuts.len(), 1, "2 shards = 1 cut in the baseline genome");
        assert!(out.report.winner.is_some());
    }
}
