//! The plan-space genome: one point in the tuner's search space and the
//! mutation operators that move through it.
//!
//! A [`Genome`] is a compact, exactly-comparable encoding of every
//! decision the tuner may revisit: burst-length policy, last-stage FIFO
//! depth, the Eq. 1 sparsity discount (stored in per-mille so genomes
//! hash and compare exactly), the all-HBM toggle, per-layer offload
//! overrides, and fleet cut points. [`Genome::apply`] folds a genome into
//! a [`CompilerOptions`], so every candidate travels through the same
//! `session` pipeline a hand-written configuration would.

use anyhow::{anyhow, bail, Result};

use crate::config::{BurstLengthPolicy, CompilerOptions};
use crate::nn::Network;
use crate::util::{Json, XorShift64};

/// Burst lengths the mutation operator draws from (`Fixed` arms) plus the
/// §VI-A policy itself. BL1/BL2 are legal but never competitive (Fig. 3a
/// efficiency collapses below 0.5), so the space omits them.
const BURST_CHOICES: [BurstLengthPolicy; 5] = [
    BurstLengthPolicy::Auto,
    BurstLengthPolicy::Fixed(4),
    BurstLengthPolicy::Fixed(8),
    BurstLengthPolicy::Fixed(16),
    BurstLengthPolicy::Fixed(32),
];

/// Last-stage FIFO depths (80-bit words). 128 sits below the H2P040
/// latency-coverage bound whenever HBM layers exist — it stays in the
/// space deliberately, as a live test that the legality gate fires.
const FIFO_CHOICES: [u32; 4] = [128, 256, 512, 1024];

/// Sparsity fractions in per-mille.
const SPARSITY_CHOICES: [u32; 6] = [0, 125, 250, 375, 500, 750];

/// One candidate's decisions. Integer-only so equality, hashing and the
/// artifact encoding are all exact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Genome {
    /// Burst-length policy for offloaded layers.
    pub burst: BurstLengthPolicy,
    /// Last-stage weight-FIFO depth in 80-bit words.
    pub fifo_depth: u32,
    /// Eq. 1 sparsity discount in per-mille (250 = 0.25).
    pub sparsity_milli: u32,
    /// Offload everything bandwidth allows instead of Algorithm 1's
    /// hybrid split.
    pub all_hbm: bool,
    /// Forced placements `(layer index, offload_to_hbm)`, sorted by
    /// index (the canonical form `CompilerOptions` validation requires).
    pub overrides: Vec<(usize, bool)>,
    /// Fleet cut points (shard boundaries); empty in single-device mode.
    pub cuts: Vec<usize>,
}

impl Genome {
    /// The genome equivalent to compiling `base` unchanged (with the
    /// given fleet cuts, if any) — always candidate 0 of a search.
    pub fn baseline(base: &CompilerOptions, cuts: Vec<usize>) -> Self {
        Self {
            burst: base.burst_length,
            fifo_depth: base.last_stage_fifo_depth,
            sparsity_milli: (base.sparsity_fraction * 1000.0).round() as u32,
            all_hbm: base.all_hbm,
            overrides: base.offload_overrides.clone(),
            cuts,
        }
    }

    /// Fold this genome's decisions into a copy of `base`.
    pub fn apply(&self, base: &CompilerOptions) -> CompilerOptions {
        let mut o = base.clone();
        o.burst_length = self.burst;
        o.last_stage_fifo_depth = self.fifo_depth;
        o.sparsity_fraction = self.sparsity_milli as f64 / 1000.0;
        o.all_hbm = self.all_hbm;
        o.offload_overrides = self.overrides.clone();
        o
    }

    /// Canonical text form — the dedup key of the search loop. Two
    /// genomes produce the same compiled plan iff their fingerprints are
    /// equal (every field is integer-encoded, so no float aliasing).
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        match self.burst {
            BurstLengthPolicy::Auto => s.push_str("b=auto"),
            BurstLengthPolicy::Fixed(bl) => {
                let _ = write!(s, "b={bl}");
            }
        }
        let _ = write!(s, ";f={};s={};h={}", self.fifo_depth, self.sparsity_milli, self.all_hbm);
        s.push_str(";ov=");
        for (k, &(i, d)) in self.overrides.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            let _ = write!(s, "{i}{}", if d { '+' } else { '-' });
        }
        s.push_str(";c=");
        for (k, &c) in self.cuts.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            let _ = write!(s, "{c}");
        }
        s
    }

    /// Human-readable `old -> new` terms for every decision that differs
    /// from `base` (the Pareto-front listing and the fleet plan diff).
    pub fn diff_terms(&self, base: &Genome) -> Vec<String> {
        let burst_name = |b: BurstLengthPolicy| match b {
            BurstLengthPolicy::Auto => "auto".to_string(),
            BurstLengthPolicy::Fixed(bl) => format!("fixed{bl}"),
        };
        let mut terms = Vec::new();
        if self.burst != base.burst {
            terms.push(format!("burst: {} -> {}", burst_name(base.burst), burst_name(self.burst)));
        }
        if self.fifo_depth != base.fifo_depth {
            terms.push(format!("fifo: {} -> {}", base.fifo_depth, self.fifo_depth));
        }
        if self.sparsity_milli != base.sparsity_milli {
            terms.push(format!(
                "sparsity: {:.3} -> {:.3}",
                base.sparsity_milli as f64 / 1000.0,
                self.sparsity_milli as f64 / 1000.0
            ));
        }
        if self.all_hbm != base.all_hbm {
            terms.push(format!("all_hbm: {} -> {}", base.all_hbm, self.all_hbm));
        }
        for &(i, d) in &self.overrides {
            if !base.overrides.contains(&(i, d)) {
                terms.push(format!("layer{i}: forced -> {}", if d { "hbm" } else { "chip" }));
            }
        }
        for &(i, d) in &base.overrides {
            if !self.overrides.iter().any(|&(j, _)| j == i) {
                let _ = d;
                terms.push(format!("layer{i}: override -> dropped"));
            }
        }
        if self.cuts != base.cuts {
            terms.push(format!("cuts: {:?} -> {:?}", base.cuts, self.cuts));
        }
        terms
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self.burst {
            BurstLengthPolicy::Auto => o.set("burst", "auto"),
            BurstLengthPolicy::Fixed(bl) => o.set("burst", bl),
        };
        o.set("fifo_depth", self.fifo_depth)
            .set("sparsity_milli", self.sparsity_milli)
            .set("all_hbm", self.all_hbm)
            .set(
                "overrides",
                Json::Arr(
                    self.overrides
                        .iter()
                        .map(|&(i, d)| Json::Arr(vec![Json::from(i), Json::Bool(d)]))
                        .collect(),
                ),
            )
            .set("cuts", Json::Arr(self.cuts.iter().map(|&c| Json::from(c)).collect()));
        o
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let burst = match j.get("burst") {
            Some(Json::Str(s)) if s == "auto" => BurstLengthPolicy::Auto,
            Some(v) => BurstLengthPolicy::Fixed(
                v.as_u32().ok_or_else(|| anyhow!("genome burst is neither \"auto\" nor a u32"))?,
            ),
            None => bail!("genome missing burst"),
        };
        let overrides = j
            .get("overrides")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("genome overrides missing or not an array"))?
            .iter()
            .map(|pair| -> Result<(usize, bool)> {
                let p = pair.as_arr().ok_or_else(|| anyhow!("override entry is not a pair"))?;
                anyhow::ensure!(p.len() == 2, "override entry is not a pair");
                Ok((
                    p[0].as_usize().ok_or_else(|| anyhow!("bad override index"))?,
                    p[1].as_bool().ok_or_else(|| anyhow!("bad override flag"))?,
                ))
            })
            .collect::<Result<_>>()?;
        let cuts = j
            .get("cuts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("genome cuts missing or not an array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad cut position")))
            .collect::<Result<_>>()?;
        Ok(Self {
            burst,
            fifo_depth: j
                .get("fifo_depth")
                .and_then(Json::as_u32)
                .ok_or_else(|| anyhow!("genome missing fifo_depth"))?,
            sparsity_milli: j
                .get("sparsity_milli")
                .and_then(Json::as_u32)
                .ok_or_else(|| anyhow!("genome missing sparsity_milli"))?,
            all_hbm: j
                .get("all_hbm")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("genome missing all_hbm"))?,
            overrides,
            cuts,
        })
    }
}

/// The enumerable design space around one network: which layers can take
/// offload overrides, which cut positions are stream-legal, and the
/// baseline genome every diff is measured against.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Weight-layer indices (override targets). Emptied in fleet mode:
    /// override indices are network-global while each shard compiles its
    /// own sub-network, so the flip axis only exists on single devices.
    weight_layers: Vec<usize>,
    /// Stream-legal interior cut positions (fleet mode only).
    cut_positions: Vec<usize>,
    base: Genome,
}

impl SearchSpace {
    /// Build the space for `net` with the given baseline options. A
    /// non-empty `base_cuts` puts the search in fleet mode: the cut axis
    /// opens and the per-layer offload-override axis closes.
    pub fn new(net: &Network, base: &CompilerOptions, base_cuts: Vec<usize>) -> Self {
        let fleet = !base_cuts.is_empty();
        let weight_layers = if fleet {
            Vec::new()
        } else {
            net.layers()
                .iter()
                .filter(|l| l.weight_params() > 0)
                .map(|l| l.id)
                .collect()
        };
        let cut_positions = if fleet {
            let ok = crate::cluster::valid_cuts(net);
            (2..net.len()).filter(|&p| ok[p]).collect()
        } else {
            Vec::new()
        };
        Self { weight_layers, cut_positions, base: Genome::baseline(base, base_cuts) }
    }

    /// The baseline genome (candidate 0 of every search).
    pub fn base(&self) -> &Genome {
        &self.base
    }

    /// Deterministic generation-0 seed set: the baseline first, then one
    /// representative per axis (fixed bursts, FIFO resizes, sparsity
    /// discounts, all-HBM), truncated to `budget`.
    pub fn seeds(&self, budget: usize) -> Vec<Genome> {
        let mut v = vec![self.base.clone()];
        for bl in [8u32, 16, 32, 4] {
            if self.base.burst != BurstLengthPolicy::Fixed(bl) {
                let mut g = self.base.clone();
                g.burst = BurstLengthPolicy::Fixed(bl);
                v.push(g);
            }
        }
        for depth in [256u32, 1024] {
            if self.base.fifo_depth != depth {
                let mut g = self.base.clone();
                g.fifo_depth = depth;
                v.push(g);
            }
        }
        for sm in [250u32, 500] {
            if self.base.sparsity_milli != sm {
                let mut g = self.base.clone();
                g.sparsity_milli = sm;
                v.push(g);
            }
        }
        let mut g = self.base.clone();
        g.all_hbm = !g.all_hbm;
        v.push(g);
        v.truncate(budget.max(1));
        v
    }

    /// One mutation step: pick an applicable operator, draw its new value
    /// from `rng`. Identical `(parent, rng state)` always yields the same
    /// child — the search loop seeds `rng` per attempt via `site_seed`.
    pub fn mutate(&self, parent: &Genome, rng: &mut XorShift64) -> Genome {
        let mut g = parent.clone();
        let mut ops: Vec<u32> = vec![0, 1, 2, 3];
        if !self.weight_layers.is_empty() {
            ops.push(4);
        }
        if !g.overrides.is_empty() {
            ops.push(5);
        }
        if !self.cut_positions.is_empty() && !g.cuts.is_empty() {
            ops.push(6);
        }
        match *rng.choose(&ops) {
            0 => {
                g.burst = loop {
                    let c = *rng.choose(&BURST_CHOICES);
                    if c != g.burst {
                        break c;
                    }
                };
            }
            1 => {
                g.fifo_depth = loop {
                    let c = *rng.choose(&FIFO_CHOICES);
                    if c != g.fifo_depth {
                        break c;
                    }
                };
            }
            2 => {
                g.sparsity_milli = loop {
                    let c = *rng.choose(&SPARSITY_CHOICES);
                    if c != g.sparsity_milli {
                        break c;
                    }
                };
            }
            3 => g.all_hbm = !g.all_hbm,
            4 => {
                let li = *rng.choose(&self.weight_layers);
                match g.overrides.iter().position(|&(i, _)| i == li) {
                    Some(p) => g.overrides[p].1 = !g.overrides[p].1,
                    None => {
                        let to_hbm = rng.next_bool(0.5);
                        g.overrides.push((li, to_hbm));
                        g.overrides.sort_unstable_by_key(|&(i, _)| i);
                    }
                }
            }
            5 => {
                let p = rng.next_below(g.overrides.len() as u64) as usize;
                g.overrides.remove(p);
            }
            _ => {
                let ci = rng.next_below(g.cuts.len() as u64) as usize;
                let cand = *rng.choose(&self.cut_positions);
                if !g.cuts.contains(&cand) {
                    g.cuts[ci] = cand;
                    g.cuts.sort_unstable();
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn space() -> SearchSpace {
        SearchSpace::new(&zoo::resnet18(), &CompilerOptions::default(), Vec::new())
    }

    #[test]
    fn baseline_genome_round_trips_options() {
        let base = CompilerOptions::default();
        let g = Genome::baseline(&base, Vec::new());
        let applied = g.apply(&base);
        assert_eq!(applied.burst_length, base.burst_length);
        assert_eq!(applied.last_stage_fifo_depth, base.last_stage_fifo_depth);
        assert_eq!(applied.sparsity_fraction, base.sparsity_fraction);
        assert_eq!(applied.all_hbm, base.all_hbm);
        assert_eq!(applied.offload_overrides, base.offload_overrides);
    }

    #[test]
    fn fingerprint_distinguishes_every_axis() {
        let base = Genome::baseline(&CompilerOptions::default(), Vec::new());
        let mut seen = std::collections::BTreeSet::new();
        assert!(seen.insert(base.fingerprint()));
        let mut g = base.clone();
        g.burst = BurstLengthPolicy::Fixed(16);
        assert!(seen.insert(g.fingerprint()));
        let mut g = base.clone();
        g.fifo_depth = 256;
        assert!(seen.insert(g.fingerprint()));
        let mut g = base.clone();
        g.sparsity_milli = 250;
        assert!(seen.insert(g.fingerprint()));
        let mut g = base.clone();
        g.all_hbm = true;
        assert!(seen.insert(g.fingerprint()));
        let mut g = base.clone();
        g.overrides = vec![(3, true)];
        assert!(seen.insert(g.fingerprint()));
        let mut g = base.clone();
        g.overrides = vec![(3, false)];
        assert!(seen.insert(g.fingerprint()), "override direction must fingerprint");
        let mut g = base.clone();
        g.cuts = vec![6];
        assert!(seen.insert(g.fingerprint()));
    }

    #[test]
    fn genome_json_round_trip() {
        let mut g = Genome::baseline(&CompilerOptions::default(), vec![6, 12]);
        g.burst = BurstLengthPolicy::Fixed(32);
        g.sparsity_milli = 375;
        g.overrides = vec![(2, true), (9, false)];
        let j = g.to_json();
        let back = Genome::from_json(&j).unwrap();
        assert_eq!(back, g);
        assert_eq!(back.to_json().to_string(), j.to_string());
        assert!(Genome::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn seeds_start_with_baseline_and_stay_unique() {
        let sp = space();
        let seeds = sp.seeds(64);
        assert_eq!(&seeds[0], sp.base(), "candidate 0 is always the default plan");
        let fps: std::collections::BTreeSet<String> =
            seeds.iter().map(Genome::fingerprint).collect();
        assert_eq!(fps.len(), seeds.len(), "seed set must be duplicate-free");
        assert!(seeds.len() >= 8, "every axis is represented: {}", seeds.len());
        assert_eq!(sp.seeds(3).len(), 3, "budget truncates the seed set");
    }

    #[test]
    fn mutation_is_deterministic_per_rng_stream() {
        let sp = space();
        let parent = sp.base().clone();
        for site in 0..16u64 {
            let mut a = XorShift64::new(crate::faults::site_seed(7, site));
            let mut b = XorShift64::new(crate::faults::site_seed(7, site));
            assert_eq!(sp.mutate(&parent, &mut a), sp.mutate(&parent, &mut b));
        }
        // different streams explore different moves eventually
        let kids: std::collections::BTreeSet<String> = (0..16u64)
            .map(|site| {
                let mut rng = XorShift64::new(crate::faults::site_seed(7, site));
                sp.mutate(&parent, &mut rng).fingerprint()
            })
            .collect();
        assert!(kids.len() > 1, "16 streams produced a single child");
    }

    #[test]
    fn mutated_overrides_stay_canonical() {
        let sp = space();
        let mut g = sp.base().clone();
        for site in 0..64u64 {
            let mut rng = XorShift64::new(crate::faults::site_seed(11, site));
            g = sp.mutate(&g, &mut rng);
            for w in g.overrides.windows(2) {
                assert!(w[0].0 < w[1].0, "overrides must stay sorted: {:?}", g.overrides);
            }
            assert!(g.apply(&CompilerOptions::default()).validate().is_ok(), "{g:?}");
        }
    }

    #[test]
    fn fleet_space_swaps_override_axis_for_cut_axis() {
        let net = zoo::vgg16();
        let sp = SearchSpace::new(&net, &CompilerOptions::default(), vec![6]);
        assert!(sp.weight_layers.is_empty(), "no global offload flips across shards");
        assert!(!sp.cut_positions.is_empty(), "cut axis must open in fleet mode");
        // a cut mutation eventually moves the cut
        let mut moved = false;
        for site in 0..64u64 {
            let mut rng = XorShift64::new(crate::faults::site_seed(3, site));
            let g = sp.mutate(sp.base(), &mut rng);
            assert_eq!(g.cuts.len(), 1, "cut count is fixed by --shards");
            if g.cuts != sp.base().cuts {
                moved = true;
            }
        }
        assert!(moved, "64 mutation streams never moved the cut");
    }
}
