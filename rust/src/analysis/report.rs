//! Report generation: Fig. 6 JSON series and the Table III text table.

use crate::analysis::bounds::BoundsReport;
use crate::analysis::priorwork::{prior_work, speedup_vs_best_prior, Accelerator};
use crate::util::Json;

/// Measured H2PIPE results for one network (filled by the simulator).
#[derive(Debug, Clone)]
pub struct H2pipeResult {
    pub network: String,
    pub all_hbm_throughput: f64,
    pub hybrid_throughput: f64,
    pub latency_ms: f64,
    pub logic_util: f64,
    pub bram_util: f64,
    pub dsp_util: f64,
    pub freq_mhz: u32,
}

/// Fig. 6 as machine-readable JSON: per network the four bars.
pub fn fig6_json(results: &[(H2pipeResult, BoundsReport)]) -> Json {
    let mut arr = Json::Arr(vec![]);
    for (r, b) in results {
        let mut o = Json::obj();
        o.set("network", r.network.as_str())
            .set("hw_all_hbm_im_s", r.all_hbm_throughput)
            .set("hw_hybrid_im_s", r.hybrid_throughput)
            .set("bound_all_hbm_im_s", b.all_hbm_bound)
            .set("bound_unlimited_bw_im_s", b.unlimited_bw_bound)
            .set("eq2_traffic_mbytes", b.traffic_bytes as f64 / 1e6)
            .set("hw_over_bound", r.all_hbm_throughput / b.all_hbm_bound);
        arr.push(o);
    }
    let mut top = Json::obj();
    top.set("figure", "fig6").set("series", arr);
    top
}

/// GOPs at batch 1 for a network given measured throughput.
pub fn gops(total_macs: u64, throughput: f64) -> f64 {
    2.0 * total_macs as f64 * throughput / 1e9
}

/// Render Table III with our measured H2PIPE rows spliced in.
pub fn table3_text(ours: &[H2pipeResult], macs: &[(String, u64)]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{:<26} {:<14} {:>5} {:>6} {:>6} {:>5} {:>10} {:>9} {:>9} {:>8}",
        "Work", "Device", "Tech", "Freq", "DSP%", "Net", "Precision", "im/s", "lat(ms)", "GOPs"
    );
    let fmt_row = |s: &mut String, a: &Accelerator| {
        let _ = writeln!(
            s,
            "{:<26} {:<14} {:>4}n {:>5}M {:>5.0}% {:>5} {:>10} {:>9.1} {:>9} {:>8.0}",
            a.work,
            a.device,
            a.tech_nm,
            a.freq_mhz,
            a.dsp_util * 100.0,
            short_net(a.network),
            a.precision,
            a.throughput,
            a.latency_ms.map(|l| format!("{l:.2}")).unwrap_or_else(|| "-".into()),
            a.gops,
        );
    };
    for net in ["ResNet-18", "ResNet-50", "VGG-16"] {
        for a in prior_work().iter().filter(|a| a.network == net) {
            fmt_row(&mut s, a);
        }
        if let Some(r) = ours.iter().find(|r| r.network == net) {
            let total_macs =
                macs.iter().find(|(n, _)| n == net).map(|(_, m)| *m).unwrap_or(0);
            let _ = writeln!(
                s,
                "{:<26} {:<14} {:>4}n {:>5}M {:>5.0}% {:>5} {:>10} {:>9.1} {:>9.2} {:>8.0}",
                "H2PIPE (ours, simulated)",
                "Stratix 10 NX",
                14,
                r.freq_mhz,
                r.dsp_util * 100.0,
                short_net(net),
                "8-bit",
                r.hybrid_throughput,
                r.latency_ms,
                gops(total_macs, r.hybrid_throughput),
            );
            if let Some(sp) = speedup_vs_best_prior(net, r.hybrid_throughput) {
                let _ = writeln!(s, "  -> speedup vs best comparable prior work: {sp:.1}x");
            }
        }
        let _ = writeln!(s);
    }
    s
}

fn short_net(n: &str) -> &str {
    match n {
        "ResNet-18" => "R18",
        "ResNet-50" => "R50",
        "VGG-16" => "VGG",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(net: &str, hybrid: f64) -> H2pipeResult {
        H2pipeResult {
            network: net.to_string(),
            all_hbm_throughput: hybrid * 0.5,
            hybrid_throughput: hybrid,
            latency_ms: 2.0,
            logic_util: 0.7,
            bram_util: 0.95,
            dsp_util: 0.4,
            freq_mhz: 300,
        }
    }

    #[test]
    fn fig6_json_structure() {
        let b = BoundsReport {
            model: "ResNet-18".into(),
            traffic_bytes: 100_000_000,
            all_hbm_bound: 2500.0,
            unlimited_bw_bound: 9000.0,
        };
        let j = fig6_json(&[(result("ResNet-18", 4000.0), b)]);
        let text = j.to_string();
        assert!(text.contains("\"hw_hybrid_im_s\":4000"));
        assert!(text.contains("\"figure\":\"fig6\""));
    }

    #[test]
    fn table3_contains_all_works_and_speedups() {
        let ours = vec![
            result("ResNet-18", 4174.0),
            result("ResNet-50", 1004.0),
            result("VGG-16", 545.0),
        ];
        let macs = vec![
            ("ResNet-18".to_string(), 1_800_000_000u64),
            ("ResNet-50".to_string(), 4_100_000_000),
            ("VGG-16".to_string(), 15_500_000_000),
        ];
        let t = table3_text(&ours, &macs);
        assert!(t.contains("FILM-QNN"));
        assert!(t.contains("H2PIPE (ours, simulated)"));
        assert!(t.contains("19.4x"));
        assert!(t.contains("5.1x"));
        assert!(t.contains("10.5x"));
    }

    #[test]
    fn gops_arithmetic() {
        // 1.8 GMACs at 1000 im/s = 3600 GOPs
        assert!((gops(1_800_000_000, 1000.0) - 3600.0).abs() < 1.0);
    }
}
