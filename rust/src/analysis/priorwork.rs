//! The Table III prior-work dataset, plus an executable PE-style baseline.
//!
//! The paper compares H2PIPE against ten published FPGA CNN accelerators;
//! those columns are literature numbers in the paper too, so they are
//! encoded here as data. The H2PIPE columns are *regenerated* by our
//! simulator at bench time. We additionally implement an analytic
//! PE-style (single shared conv engine, layer-at-a-time) baseline so the
//! two architectural paradigms of §I can be compared in-simulator, not
//! just against citations.

use crate::compiler::LayerStats;
use crate::config::{CompilerOptions, DeviceConfig};
use crate::nn::Network;

/// One accelerator row of Table III.
#[derive(Debug, Clone)]
pub struct Accelerator {
    pub work: &'static str,
    pub device: &'static str,
    pub tech_nm: u32,
    pub bram_mb: f64,
    pub dsps: u32,
    pub logic_util: Option<f64>,
    pub bram_util: Option<f64>,
    pub dsp_util: f64,
    pub freq_mhz: u32,
    pub network: &'static str,
    pub precision: &'static str,
    /// Batch-1 images/s.
    pub throughput: f64,
    /// Batch-1 latency (ms) when reported.
    pub latency_ms: Option<f64>,
    pub gops: f64,
    pub uses_hbm: bool,
    pub dataflow: bool,
}

/// The prior-work rows of Table III (all literature numbers).
pub fn prior_work() -> Vec<Accelerator> {
    vec![
        Accelerator {
            work: "Venieris et al. [26]",
            device: "Z7045",
            tech_nm: 28,
            bram_mb: 19.2,
            dsps: 900,
            logic_util: None,
            bram_util: None,
            dsp_util: 1.00,
            freq_mhz: 150,
            network: "ResNet-18",
            precision: "16-bit",
            throughput: 59.7,
            latency_ms: Some(16.75),
            gops: 236.0,
            uses_hbm: false,
            dataflow: true,
        },
        Accelerator {
            work: "FILM-QNN [27]",
            device: "ZC102",
            tech_nm: 16,
            bram_mb: 32.1,
            dsps: 2520,
            logic_util: Some(0.66),
            bram_util: Some(0.48),
            dsp_util: 0.83,
            freq_mhz: 150,
            network: "ResNet-18",
            precision: "4/8-bit",
            throughput: 214.8,
            latency_ms: None,
            gops: 779.0,
            uses_hbm: false,
            dataflow: false,
        },
        Accelerator {
            work: "Venieris et al. [26]",
            device: "ZU7EV",
            tech_nm: 16,
            bram_mb: 38.0,
            dsps: 1728,
            logic_util: None,
            bram_util: None,
            dsp_util: 1.00,
            freq_mhz: 200,
            network: "ResNet-50",
            precision: "16-bit",
            throughput: 71.7,
            latency_ms: Some(13.95),
            gops: 603.0,
            uses_hbm: false,
            dataflow: true,
        },
        Accelerator {
            work: "Liu et al. [28]",
            device: "Arria 10 GX",
            tech_nm: 20,
            bram_mb: 65.7,
            dsps: 1518,
            logic_util: Some(0.71),
            bram_util: Some(0.86),
            dsp_util: 0.97,
            freq_mhz: 200,
            network: "ResNet-50",
            precision: "8-bit",
            throughput: 197.2,
            latency_ms: Some(5.07),
            gops: 1519.0,
            uses_hbm: false,
            dataflow: false,
        },
        Accelerator {
            work: "DNNVM [29]",
            device: "ZU9",
            tech_nm: 16,
            bram_mb: 164.0,
            dsps: 2520,
            logic_util: None,
            bram_util: Some(0.86),
            dsp_util: 0.61,
            freq_mhz: 500,
            network: "ResNet-50",
            precision: "8-bit",
            throughput: 88.3,
            latency_ms: None,
            gops: 680.0,
            uses_hbm: false,
            dataflow: false,
        },
        Accelerator {
            work: "FTDL [30]",
            device: "VU125",
            tech_nm: 20,
            bram_mb: 32.1,
            dsps: 1200,
            logic_util: Some(0.75),
            bram_util: Some(0.37),
            dsp_util: 1.00,
            freq_mhz: 650,
            network: "ResNet-50",
            precision: "16-bit",
            throughput: 151.2,
            latency_ms: Some(6.61),
            gops: 1164.0,
            uses_hbm: false,
            dataflow: false,
        },
        Accelerator {
            work: "BNN-PYNQ [4][31]",
            device: "Alveo U250",
            tech_nm: 16,
            bram_mb: 432.0,
            dsps: 11508,
            logic_util: Some(0.77),
            bram_util: Some(0.97),
            dsp_util: 0.14,
            freq_mhz: 195,
            network: "ResNet-50",
            precision: "1-bit",
            throughput: 527.0,
            latency_ms: Some(1.90),
            gops: 3567.0,
            uses_hbm: false,
            dataflow: true,
        },
        Accelerator {
            work: "fpgaconvnet [32]",
            device: "Z7045",
            tech_nm: 28,
            bram_mb: 19.2,
            dsps: 900,
            logic_util: None,
            bram_util: None,
            dsp_util: 0.95,
            freq_mhz: 125,
            network: "VGG-16",
            precision: "16-bit",
            throughput: 4.0,
            latency_ms: Some(249.5),
            gops: 156.0,
            uses_hbm: false,
            dataflow: true,
        },
        Accelerator {
            work: "Ma et al. [33]",
            device: "Stratix 10 GX",
            tech_nm: 14,
            bram_mb: 229.0,
            dsps: 5760,
            logic_util: Some(0.50),
            bram_util: Some(0.21),
            dsp_util: 0.71,
            freq_mhz: 300,
            network: "VGG-16",
            precision: "8-bit",
            throughput: 51.8,
            latency_ms: Some(19.29),
            gops: 1605.0,
            uses_hbm: false,
            dataflow: false,
        },
        Accelerator {
            work: "Nguyen & Nakashima [22]",
            device: "Alveo U280",
            tech_nm: 16,
            bram_mb: 357.0,
            dsps: 9024,
            logic_util: Some(0.55),
            bram_util: Some(0.92),
            dsp_util: 0.96,
            freq_mhz: 250,
            network: "VGG-16",
            precision: "16-bit",
            throughput: 29.5, // batch 128 in the original
            latency_ms: Some(33.92),
            gops: 913.0,
            uses_hbm: true,
            dataflow: false,
        },
    ]
}

/// Best prior throughput for a network among comparable-precision works
/// (the paper's speedup denominators: FILM-QNN for ResNet-18, Liu et al.
/// for ResNet-50, Ma et al. for VGG-16).
pub fn best_prior(network: &str) -> Option<Accelerator> {
    let comparable: Vec<Accelerator> = prior_work()
        .into_iter()
        .filter(|a| a.network == network && a.precision != "1-bit")
        .collect();
    comparable.into_iter().max_by(|a, b| a.throughput.total_cmp(&b.throughput))
}

/// Speedup of a measured H2PIPE throughput vs the best comparable prior
/// work (the paper's headline 19.4x / 5.1x / 10.5x numbers).
pub fn speedup_vs_best_prior(network: &str, h2pipe_throughput: f64) -> Option<f64> {
    best_prior(network).map(|a| h2pipe_throughput / a.throughput)
}

/// Notes on the in-simulator PE-style baseline.
pub const PE_BASELINE_NOTES: &str =
    "PE baseline: one shared convolution engine sized to the same device \
     (DLA-style, §I): layers run one at a time; per layer the engine is \
     limited by MACs (tensor blocks x 30 MAC/cycle) and by streaming the \
     layer's weights from HBM once per image batch.";

/// Analytic PE-style (one-layer-at-a-time) baseline on the same device:
/// the architectural counterpoint to layer-pipelined dataflow. A
/// DLA-class design instantiates one general 32x32 MAC array (it must
/// handle *any* layer geometry, so it cannot specialize the way HPIPE's
/// per-layer engines do) and streams each layer's weights from memory
/// once per image at batch 1.
pub fn pe_baseline_throughput(net: &Network, device: &DeviceConfig, opts: &CompilerOptions) -> f64 {
    let macs_per_cycle = 32.0 * 32.0; // general-purpose PE array
    let util = 0.85; // geometry edge losses
    let hz = device.core_mhz as f64 * 1e6;
    let hbm_bw = device.hbm.stack_peak_bw() * 0.85; // one stack's worth of ports
    let mut total_s = 0.0;
    for l in net.layers() {
        let s = LayerStats::from_layer(l, opts);
        if !s.has_weights {
            continue;
        }
        let compute_s = s.macs as f64 / (macs_per_cycle * util * hz);
        // weights fetched once per image (batch 1, no reuse across images)
        let weight_s = (s.weight_bits as f64 / 8.0) / hbm_bw;
        total_s += compute_s.max(weight_s);
    }
    1.0 / total_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn dataset_has_all_ten_prior_rows() {
        assert_eq!(prior_work().len(), 10);
    }

    #[test]
    fn best_prior_matches_paper_denominators() {
        assert_eq!(best_prior("ResNet-18").unwrap().work, "FILM-QNN [27]");
        assert_eq!(best_prior("ResNet-50").unwrap().work, "Liu et al. [28]");
        assert_eq!(best_prior("VGG-16").unwrap().work, "Ma et al. [33]");
    }

    #[test]
    fn paper_speedups_reproduced_from_paper_throughputs() {
        // sanity-check the dataset against the paper's own arithmetic
        let s18 = speedup_vs_best_prior("ResNet-18", 4174.0).unwrap();
        let s50 = speedup_vs_best_prior("ResNet-50", 1004.0).unwrap();
        let svgg = speedup_vs_best_prior("VGG-16", 545.0).unwrap();
        assert!((19.0..19.8).contains(&s18), "{s18}");
        assert!((5.0..5.2).contains(&s50), "{s50}");
        assert!((10.3..10.7).contains(&svgg), "{svgg}");
    }

    #[test]
    fn binarized_work_excluded_from_speedup_base() {
        // BNN-PYNQ (527 im/s, 1-bit) beats Liu et al. but is excluded as
        // non-comparable precision, exactly as the paper treats it.
        let b = best_prior("ResNet-50").unwrap();
        assert!(b.precision != "1-bit");
        assert_eq!(b.throughput, 197.2);
    }

    #[test]
    fn pe_baseline_far_below_dataflow() {
        let d = DeviceConfig::stratix10_nx2100();
        let o = CompilerOptions::default();
        let pe = pe_baseline_throughput(&zoo::resnet50(), &d, &o);
        // the PE baseline should land in the same order of magnitude as
        // the PE-style rows of Table III (tens to a few hundred im/s),
        // far below H2PIPE's ~1000
        assert!(pe > 20.0 && pe < 400.0, "PE baseline {pe:.0} im/s");
        let pe_vgg = pe_baseline_throughput(&zoo::vgg16(), &d, &o);
        assert!(pe_vgg < pe, "VGG heavier than R50 for a PE design");
    }

    #[test]
    fn nguyen_is_the_only_hbm_prior() {
        let hbm: Vec<_> = prior_work().into_iter().filter(|a| a.uses_hbm).collect();
        assert_eq!(hbm.len(), 1);
        assert_eq!(hbm[0].network, "VGG-16");
    }
}
