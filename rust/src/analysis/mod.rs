//! Evaluation analysis: the Eq. 2 theoretical bounds behind Fig. 6, the
//! prior-work comparison dataset of Table III, and report generation.

pub mod actoffload;
pub mod bounds;
pub mod priorwork;
pub mod report;

pub use actoffload::{
    activation_offload_penalty, fpgaconvnet_style, ActOffloadReport, BatchBaselineReport,
};
pub use bounds::{
    all_hbm_bound, bounds_report, unlimited_bw_bound, weight_traffic_bytes, BoundsReport,
};
pub use priorwork::{
    best_prior, pe_baseline_throughput, prior_work, speedup_vs_best_prior, Accelerator,
    PE_BASELINE_NOTES,
};
pub use report::{fig6_json, gops, table3_text, H2pipeResult};
