//! §III-B design-choice analysis: why H2PIPE offloads *weights*, not
//! *activations* — and the fpgaConvNet-style alternative it rejects.
//!
//! The paper's argument (§III-B): activation reads sit on the critical
//! path, so offloading every inter-layer activation buffer adds at least
//! one saturated HBM round trip (~400 ns at BL32) per convolutional
//! layer — "on MobileNetV2 ... 53 x 0.4 = 21 us ... an increase of at
//! least 11% in latency" — while weight reads are fully deterministic and
//! can be prefetched arbitrarily early. This module prices both choices,
//! plus the §II-B fpgaConvNet alternative (time-multiplexed layer subsets
//! with per-batch weight reloads).

use crate::compiler::LayerStats;
use crate::config::{CompilerOptions, DeviceConfig};
use crate::nn::Network;

/// Latency cost of moving inter-layer activations to HBM.
#[derive(Debug, Clone)]
pub struct ActOffloadReport {
    pub model: String,
    /// Weight-bearing (conv/FC) layers whose input buffers would move.
    pub layers: usize,
    /// Saturated HBM read latency assumed per layer (ns).
    pub hbm_latency_ns: f64,
    /// Added pipeline latency (s).
    pub added_latency: f64,
    /// Baseline latency used for the relative claim (s).
    pub base_latency: f64,
}

impl ActOffloadReport {
    /// Fractional latency increase.
    pub fn increase(&self) -> f64 {
        self.added_latency / self.base_latency
    }
}

/// Price the §III-B activation-offload alternative: one saturated HBM
/// read latency per weight layer, against a given baseline latency.
pub fn activation_offload_penalty(
    net: &Network,
    opts: &CompilerOptions,
    hbm_latency_ns: f64,
    base_latency: f64,
) -> ActOffloadReport {
    let layers = net
        .layers()
        .iter()
        .filter(|l| LayerStats::from_layer(l, opts).has_weights)
        .count();
    ActOffloadReport {
        model: net.name.clone(),
        layers,
        hbm_latency_ns,
        added_latency: layers as f64 * hbm_latency_ns * 1e-9,
        base_latency,
    }
}

/// fpgaConvNet-style baseline (§II-B): the network is split into the
/// fewest layer subsets whose weights fit on chip; each subset processes
/// a whole batch before the next subset's weights are loaded from
/// off-chip memory. Larger batches amortize the reloads — throughput
/// rises with batch size at the cost of latency, the trade-off H2PIPE's
/// always-resident pipeline avoids.
#[derive(Debug, Clone)]
pub struct BatchBaselineReport {
    pub model: String,
    pub subsets: usize,
    pub batch: u64,
    /// Images/s at this batch size.
    pub throughput: f64,
    /// End-to-end latency of a batch member (s) — the whole batch must
    /// finish every subset.
    pub latency: f64,
}

pub fn fpgaconvnet_style(
    net: &Network,
    device: &DeviceConfig,
    opts: &CompilerOptions,
    batch: u64,
) -> BatchBaselineReport {
    let stats: Vec<LayerStats> = net
        .layers()
        .iter()
        .map(|l| LayerStats::from_layer(l, opts))
        .filter(|s| s.has_weights)
        .collect();
    // greedily pack layers into on-chip-weight subsets (order preserved)
    let cap_bits = (device.bram_bits() as f64 * 0.8) as u64; // acts + margin
    let mut subsets: Vec<Vec<&LayerStats>> = vec![Vec::new()];
    let mut used = 0u64;
    for s in &stats {
        let bits = s.weight_m20k * crate::compiler::resources::M20K_BITS;
        if used + bits > cap_bits && !subsets.last().unwrap().is_empty() {
            subsets.push(Vec::new());
            used = 0;
        }
        subsets.last_mut().unwrap().push(s);
        used += bits;
    }
    // per subset: reload its weights once, then stream `batch` images
    // through its (sub)pipeline at the bottleneck-layer rate
    let hz = device.core_mhz as f64 * 1e6;
    let reload_bw = device.hbm.stack_peak_bw() * 0.8; // one stack of ports
    let mut total_s = 0.0;
    for sub in &subsets {
        let reload_bits: u64 = sub.iter().map(|s| s.weight_bits).sum();
        let reload_s = reload_bits as f64 / 8.0 / reload_bw;
        // same per-layer engine model as H2PIPE at modest parallelism
        let bottleneck: u64 =
            sub.iter().map(|s| s.cycles_per_image(1, 8)).max().unwrap_or(1);
        let stream_s = batch as f64 * bottleneck as f64 / hz;
        total_s += reload_s + stream_s;
    }
    BatchBaselineReport {
        model: net.name.clone(),
        subsets: subsets.len(),
        batch,
        throughput: batch as f64 / total_s,
        latency: total_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn paper_claim_mobilenetv2_11_percent() {
        // §III-B: 53 layers x 400 ns >= 11% of the 190 us HPIPE latency.
        let net = zoo::mobilenet_v2();
        let r = activation_offload_penalty(
            &net,
            &CompilerOptions::default(),
            400.0,
            190e-6,
        );
        assert_eq!(r.layers, 53, "paper counts 53 weight layers in V2");
        assert!(
            r.increase() >= 0.11,
            "increase {:.3} below the paper's >=11% claim",
            r.increase()
        );
        // "at least 53 x 0.4 = 21 us"
        assert!((r.added_latency - 21.2e-6).abs() < 1e-6, "{}", r.added_latency);
    }

    #[test]
    fn weight_offload_strictly_cheaper_than_activation_offload() {
        // weights prefetch deterministically: zero steady-state latency
        // cost; activations cost one round trip per layer. The analysis
        // must show a strictly positive penalty for every network.
        for net in zoo::eval_models() {
            let r = activation_offload_penalty(&net, &CompilerOptions::default(), 400.0, 1e-3);
            assert!(r.added_latency > 0.0, "{}", net.name);
        }
    }

    #[test]
    fn fpgaconvnet_baseline_scales_with_batch() {
        let d = DeviceConfig::stratix10_nx2100();
        let o = CompilerOptions::default();
        let net = zoo::vgg16();
        let b1 = fpgaconvnet_style(&net, &d, &o, 1);
        let b16 = fpgaconvnet_style(&net, &d, &o, 16);
        let b256 = fpgaconvnet_style(&net, &d, &o, 256);
        assert!(b1.subsets >= 2, "VGG-16 weights cannot fit one subset");
        assert!(b16.throughput > b1.throughput, "batching must help");
        assert!(b256.throughput > b16.throughput);
        assert!(b256.latency > b16.latency, "batching costs latency");
        // batch-1 throughput lands in the low-single-digit im/s range of
        // the fpgaconvnet Table III row (4.0 im/s on a much smaller chip)
        assert!(b1.throughput < 120.0, "{}", b1.throughput);
    }

    #[test]
    fn small_networks_fit_one_subset() {
        let d = DeviceConfig::stratix10_nx2100();
        let o = CompilerOptions::default();
        let r = fpgaconvnet_style(&zoo::mobilenet_v1(), &d, &o, 1);
        assert_eq!(r.subsets, 1);
    }
}
