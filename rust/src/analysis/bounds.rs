//! Theoretical throughput bounds (§VI-B, the light bars of Fig. 6).

use crate::compiler::{compile, LayerStats};
use crate::config::{CompilerOptions, DeviceConfig};
use crate::nn::Network;

/// Eq. 2: weight memory traffic required to process one image, in bytes
/// (8-bit weights):
///
/// MT_required = sum over layers of kh * kw * ci * co * output_height
///
/// HPIPE parallelizes across the activation width, so kernels are
/// reloaded once per output *line*.
pub fn weight_traffic_bytes(net: &Network, opts: &CompilerOptions) -> u64 {
    net.layers()
        .iter()
        .map(|l| LayerStats::from_layer(l, opts).weight_traffic_per_image)
        .sum::<u64>()
        * opts.weight_bits as u64
        / 8
}

/// Fig. 6 bounds for one network.
#[derive(Debug, Clone)]
pub struct BoundsReport {
    pub model: String,
    /// Eq. 2 traffic per image (bytes).
    pub traffic_bytes: u64,
    /// All-HBM upper bound: effective HBM bandwidth (31 PCs x 240 bits @
    /// core clock = 279 GB/s) / Eq. 2 traffic, with perfect efficiency.
    pub all_hbm_bound: f64,
    /// Unlimited-HBM-bandwidth bound: compute-limited throughput at 85%
    /// device utilization with zero weight-bandwidth constraints.
    pub unlimited_bw_bound: f64,
}

/// The all-HBM theoretical throughput bound (light blue bars of Fig. 6).
pub fn all_hbm_bound(net: &Network, device: &DeviceConfig, opts: &CompilerOptions) -> f64 {
    device.effective_hbm_bw() / weight_traffic_bytes(net, opts) as f64
}

/// The unlimited-HBM-bandwidth bound (light green bars of Fig. 6):
/// compile against a device with effectively infinite pseudo-channels and
/// take the compute-bound throughput (no HBM stall).
pub fn unlimited_bw_bound(
    net: &Network,
    device: &DeviceConfig,
    opts: &CompilerOptions,
) -> anyhow::Result<f64> {
    let unlimited = device.clone().with_unlimited_hbm();
    let mut o = opts.clone();
    o.all_hbm = true;
    let plan = compile(net, &unlimited, &o)?;
    // compute-bound: ignore any residual stall factor
    let hz = device.core_mhz as f64 * 1e6;
    Ok(hz / plan.bottleneck_cycles as f64)
}

/// Compute the full bounds report for one network.
pub fn bounds_report(
    net: &Network,
    device: &DeviceConfig,
    opts: &CompilerOptions,
) -> anyhow::Result<BoundsReport> {
    Ok(BoundsReport {
        model: net.name.clone(),
        traffic_bytes: weight_traffic_bytes(net, opts),
        all_hbm_bound: all_hbm_bound(net, device, opts),
        unlimited_bw_bound: unlimited_bw_bound(net, device, opts)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn dev() -> DeviceConfig {
        DeviceConfig::stratix10_nx2100()
    }

    #[test]
    fn eq2_traffic_increases_with_network_size() {
        let o = CompilerOptions::default();
        let r18 = weight_traffic_bytes(&zoo::resnet18(), &o);
        let r50 = weight_traffic_bytes(&zoo::resnet50(), &o);
        let vgg = weight_traffic_bytes(&zoo::vgg16(), &o);
        assert!(r18 < r50, "{r18} < {r50}");
        assert!(r50 < vgg, "{r50} < {vgg}");
    }

    #[test]
    fn all_hbm_bounds_bracket_paper_hw_results() {
        // paper: hardware all-HBM results are 68%-78% of the bound, i.e.
        // bound ~= hw / 0.73: R18 ~2400, R50 ~1050, VGG ~560. Allow 2x
        // model slack on each side.
        let o = CompilerOptions::default();
        let d = dev();
        let cases = [("resnet18", 2400.0), ("resnet50", 1050.0), ("vgg16", 560.0)];
        for (name, approx) in cases {
            let b = all_hbm_bound(&zoo::by_name(name).unwrap(), &d, &o);
            assert!(
                (approx * 0.5..approx * 2.0).contains(&b),
                "{name}: bound {b:.0} vs paper-implied {approx}"
            );
        }
    }

    #[test]
    fn unlimited_bw_exceeds_all_hbm_bound_for_big_nets() {
        let o = CompilerOptions::default();
        let d = dev();
        for name in ["resnet50", "vgg16"] {
            let net = zoo::by_name(name).unwrap();
            let a = all_hbm_bound(&net, &d, &o);
            let u = unlimited_bw_bound(&net, &d, &o).unwrap();
            assert!(u > a, "{name}: unlimited {u:.0} <= all-HBM bound {a:.0}");
        }
    }

    #[test]
    fn bounds_report_complete() {
        let o = CompilerOptions::default();
        let r = bounds_report(&zoo::resnet18(), &dev(), &o).unwrap();
        assert!(r.traffic_bytes > 10_000_000);
        assert!(r.all_hbm_bound > 0.0);
        assert!(r.unlimited_bw_bound > 0.0);
    }
}
