//! VGG-16 (Simonyan & Zisserman, 2015), configuration D.

use crate::nn::{ConvKind, LayerId, Network, OpKind, Shape};

/// VGG-16: 13 3x3 convolutions in 5 stages + 3 fully-connected layers.
///
/// The FC layers dominate weight storage (fc6 alone is 102.8M params),
/// which is why the paper's Table I puts VGG-16 at 1204 Mb of weight
/// memory — ~9x the NX2100's 140 Mb of BRAM.
pub fn vgg16() -> Network {
    let mut n = Network::new("VGG-16", Shape::new(224, 224, 3));
    let stages: [(u32, u32); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut x: LayerId = 0;
    for (si, (c, reps)) in stages.iter().enumerate() {
        for r in 0..*reps {
            x = n
                .add(
                    &format!("conv{}_{}", si + 1, r + 1),
                    OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: *c },
                    &[x],
                )
                .expect("vgg conv");
        }
        x = n
            .add(&format!("pool{}", si + 1), OpKind::MaxPool { k: 2, stride: 2, pad: 0 }, &[x])
            .expect("vgg pool");
    }
    // Classifier: 7x7x512 -> 4096 -> 4096 -> 1000.
    x = n.add("fc6", OpKind::Fc { out_features: 4096 }, &[x]).expect("fc6");
    x = n.add("fc7", OpKind::Fc { out_features: 4096 }, &[x]).expect("fc7");
    n.add("fc8", OpKind::Fc { out_features: 1000 }, &[x]).expect("fc8");
    n.validate().expect("vgg16 validates");
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_match_literature() {
        // VGG-16: 138.36M params (conv 14.71M + fc 123.64M), no-bias count
        // is ~138.34M.
        let m = vgg16().total_params() as f64 / 1e6;
        assert!((137.0..139.0).contains(&m), "params {m}M");
    }

    #[test]
    fn macs_match_literature() {
        // ~15.5 GMACs at 224x224.
        let g = vgg16().total_macs() as f64 / 1e9;
        assert!((15.0..16.0).contains(&g), "GMACs {g}");
    }

    #[test]
    fn fc6_is_the_biggest_layer() {
        let n = vgg16();
        let fc6 = n.layers().iter().find(|l| l.name == "fc6").unwrap();
        assert_eq!(fc6.weight_params(), 7 * 7 * 512 * 4096);
        let max = n.layers().iter().map(|l| l.weight_params()).max().unwrap();
        assert_eq!(max, fc6.weight_params());
    }

    #[test]
    fn feature_map_is_7x7_before_classifier() {
        let n = vgg16();
        let pool5 = n.layers().iter().find(|l| l.name == "pool5").unwrap();
        assert_eq!(pool5.out, Shape::new(7, 7, 512));
    }

    #[test]
    fn thirteen_convs_three_fcs() {
        let n = vgg16();
        let convs =
            n.layers().iter().filter(|l| matches!(l.op, OpKind::Conv { .. })).count();
        let fcs = n.layers().iter().filter(|l| matches!(l.op, OpKind::Fc { .. })).count();
        assert_eq!(convs, 13);
        assert_eq!(fcs, 3);
    }
}
