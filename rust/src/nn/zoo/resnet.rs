//! ResNet-18 and ResNet-50 (He et al., 2015) with exact ImageNet geometry.

use crate::nn::{ConvKind, LayerId, Network, OpKind, Shape};

fn conv(
    n: &mut Network,
    name: &str,
    from: LayerId,
    k: u32,
    stride: u32,
    pad: u32,
    out_c: u32,
) -> LayerId {
    let kind = if k == 1 { ConvKind::Pointwise } else { ConvKind::Standard };
    n.add(name, OpKind::Conv { kind, kh: k, kw: k, stride, pad, out_c }, &[from])
        .expect("resnet conv")
}

/// Basic block (two 3x3 convs) used by ResNet-18/34.
fn basic_block(n: &mut Network, name: &str, from: LayerId, out_c: u32, stride: u32) -> LayerId {
    let c1 = conv(n, &format!("{name}.conv1"), from, 3, stride, 1, out_c);
    let c2 = conv(n, &format!("{name}.conv2"), c1, 3, 1, 1, out_c);
    let skip = if stride != 1 || n.layer(from).out.c != out_c {
        conv(n, &format!("{name}.down"), from, 1, stride, 0, out_c)
    } else {
        from
    };
    n.add(&format!("{name}.add"), OpKind::Add, &[c2, skip]).expect("resnet add")
}

/// Bottleneck block (1x1 -> 3x3 -> 1x1, 4x expansion) used by ResNet-50+.
fn bottleneck(n: &mut Network, name: &str, from: LayerId, mid_c: u32, stride: u32) -> LayerId {
    let out_c = mid_c * 4;
    let c1 = conv(n, &format!("{name}.conv1"), from, 1, 1, 0, mid_c);
    let c2 = conv(n, &format!("{name}.conv2"), c1, 3, stride, 1, mid_c);
    let c3 = conv(n, &format!("{name}.conv3"), c2, 1, 1, 0, out_c);
    let skip = if stride != 1 || n.layer(from).out.c != out_c {
        conv(n, &format!("{name}.down"), from, 1, stride, 0, out_c)
    } else {
        from
    };
    n.add(&format!("{name}.add"), OpKind::Add, &[c3, skip]).expect("resnet add")
}

fn stem(n: &mut Network) -> LayerId {
    let c = conv(n, "conv1", 0, 7, 2, 3, 64);
    n.add("maxpool", OpKind::MaxPool { k: 3, stride: 2, pad: 1 }, &[c]).expect("stem pool")
}

fn head(n: &mut Network, from: LayerId) {
    let gap = n.add("avgpool", OpKind::GlobalAvgPool, &[from]).expect("gap");
    n.add("fc", OpKind::Fc { out_features: 1000 }, &[gap]).expect("fc");
}

/// ResNet-18: stages [2,2,2,2] of basic blocks, widths 64..512.
pub fn resnet18() -> Network {
    let mut n = Network::new("ResNet-18", Shape::new(224, 224, 3));
    let mut x = stem(&mut n);
    for (stage, (c, blocks)) in [(64u32, 2u32), (128, 2), (256, 2), (512, 2)].iter().enumerate() {
        for b in 0..*blocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            x = basic_block(&mut n, &format!("layer{}.{}", stage + 1, b), x, *c, stride);
        }
    }
    head(&mut n, x);
    n.validate().expect("resnet18 validates");
    n
}

/// ResNet-50: stages [3,4,6,3] of bottleneck blocks, mid widths 64..512.
pub fn resnet50() -> Network {
    let mut n = Network::new("ResNet-50", Shape::new(224, 224, 3));
    let mut x = stem(&mut n);
    for (stage, (c, blocks)) in [(64u32, 3u32), (128, 4), (256, 6), (512, 3)].iter().enumerate() {
        for b in 0..*blocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            x = bottleneck(&mut n, &format!("layer{}.{}", stage + 1, b), x, *c, stride);
        }
    }
    head(&mut n, x);
    n.validate().expect("resnet50 validates");
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_params_match_literature() {
        // torchvision resnet18: 11.69M params; ours has no batchnorm params
        // (folded into conv at int8 deploy) and no conv biases, so compare
        // to the conv+fc weight total: 11.68M.
        let n = resnet18();
        let m = n.total_params() as f64 / 1e6;
        assert!((11.0..12.0).contains(&m), "params {m}M");
    }

    #[test]
    fn resnet50_params_match_literature() {
        // torchvision resnet50: 25.56M params incl. BN; conv+fc ~25.5M.
        let n = resnet50();
        let m = n.total_params() as f64 / 1e6;
        assert!((25.0..26.0).contains(&m), "params {m}M");
    }

    #[test]
    fn resnet18_macs_match_literature() {
        // ~1.82 GMACs for ResNet-18 at 224x224.
        let g = resnet18().total_macs() as f64 / 1e9;
        assert!((1.7..1.95).contains(&g), "GMACs {g}");
    }

    #[test]
    fn resnet50_macs_match_literature() {
        // ~4.1 GMACs for ResNet-50 at 224x224.
        let g = resnet50().total_macs() as f64 / 1e9;
        assert!((3.9..4.3).contains(&g), "GMACs {g}");
    }

    #[test]
    fn resnet50_final_channels_are_2048() {
        let n = resnet50();
        // paper §II-A: "2048 in the case of ResNet-50"
        let gap = n.layers().iter().find(|l| l.name == "avgpool").unwrap();
        assert_eq!(n.layer(gap.inputs[0]).out.c, 2048);
    }

    #[test]
    fn stage_resolutions() {
        let n = resnet18();
        let l41 = n.layers().iter().find(|l| l.name == "layer4.1.add").unwrap();
        assert_eq!(l41.out, Shape::new(7, 7, 512));
        let l1 = n.layers().iter().find(|l| l.name == "layer1.1.add").unwrap();
        assert_eq!(l1.out, Shape::new(56, 56, 64));
    }
}
