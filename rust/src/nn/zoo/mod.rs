//! Model zoo: the six ImageNet CNNs of Table I.
//!
//! All builders produce 224x224x3-input networks with exact published
//! layer geometry; parameter totals are asserted against the literature in
//! the tests at the bottom of each builder module.

mod mobilenet;
mod resnet;
mod vgg;

pub use mobilenet::{mobilenet_edge, mobilenet_v1, mobilenet_v2, mobilenet_v3_large};
pub use resnet::{resnet18, resnet50};
pub use vgg::vgg16;

use crate::nn::Network;

/// All Table I networks, in the paper's row order.
pub fn table1_models() -> Vec<Network> {
    vec![mobilenet_v1(), mobilenet_v2(), mobilenet_v3_large(), resnet18(), resnet50(), vgg16()]
}

/// The three evaluation networks of §VI (Fig. 6, Tables II/III).
pub fn eval_models() -> Vec<Network> {
    vec![resnet18(), resnet50(), vgg16()]
}

/// Look a zoo model up by name (used by the CLI).
pub fn by_name(name: &str) -> Option<Network> {
    match name.to_ascii_lowercase().as_str() {
        "mobilenetv1" | "mobilenet_v1" => Some(mobilenet_v1()),
        "mobilenetv2" | "mobilenet_v2" => Some(mobilenet_v2()),
        "mobilenetv3" | "mobilenet_v3" => Some(mobilenet_v3_large()),
        "mobilenet_edge" | "mobilenetedge" => Some(mobilenet_edge()),
        "resnet18" | "resnet-18" => Some(resnet18()),
        "resnet50" | "resnet-50" => Some(resnet50()),
        "vgg16" | "vgg-16" => Some(vgg16()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_builds_and_validates() {
        for n in table1_models() {
            n.validate().unwrap_or_else(|e| panic!("{}: {e}", n.name));
            assert_eq!(n.input_shape().h, 224);
            assert_eq!(n.input_shape().c, 3);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["resnet18", "resnet50", "vgg16", "mobilenetv1", "mobilenetv2", "mobilenetv3"] {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("alexnet").is_none());
    }
}
