//! MobileNet V1/V2/V3-Large (Howard et al., Sandler et al.).
//!
//! These are the networks the original HPIPE NX port targeted; in this
//! reproduction they exercise the depthwise/pointwise engine paths and the
//! Table I accounting rows that *fit* on chip.

use crate::nn::{ConvKind, LayerId, Network, OpKind, Shape};

fn conv(
    n: &mut Network,
    name: &str,
    from: LayerId,
    k: u32,
    stride: u32,
    pad: u32,
    out_c: u32,
) -> LayerId {
    let kind = if k == 1 { ConvKind::Pointwise } else { ConvKind::Standard };
    n.add(name, OpKind::Conv { kind, kh: k, kw: k, stride, pad, out_c }, &[from])
        .expect("mobilenet conv")
}

fn dwconv(n: &mut Network, name: &str, from: LayerId, k: u32, stride: u32) -> LayerId {
    let c = n.layer(from).out.c;
    n.add(
        name,
        OpKind::Conv { kind: ConvKind::Depthwise, kh: k, kw: k, stride, pad: k / 2, out_c: c },
        &[from],
    )
    .expect("mobilenet dwconv")
}

/// MobileNetV1: 3x3 stem + 13 depthwise-separable blocks + classifier.
pub fn mobilenet_v1() -> Network {
    let mut n = Network::new("MobileNetV1", Shape::new(224, 224, 3));
    let mut x = conv(&mut n, "conv0", 0, 3, 2, 1, 32);
    // (out_c, stride) per separable block, width multiplier 1.0
    let blocks: [(u32, u32); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, (c, s)) in blocks.iter().enumerate() {
        x = dwconv(&mut n, &format!("block{i}.dw"), x, 3, *s);
        x = conv(&mut n, &format!("block{i}.pw"), x, 1, 1, 0, *c);
    }
    let gap = n.add("avgpool", OpKind::GlobalAvgPool, &[x]).expect("gap");
    n.add("fc", OpKind::Fc { out_features: 1000 }, &[gap]).expect("fc");
    n.validate().expect("mobilenetv1 validates");
    n
}

/// MobileNetV2 inverted-residual block: 1x1 expand (ratio `t`) -> 3x3
/// depthwise (stride `s`) -> 1x1 linear project; residual when the block
/// preserves shape.
fn inverted_residual(
    n: &mut Network,
    name: &str,
    from: LayerId,
    t: u32,
    out_c: u32,
    stride: u32,
) -> LayerId {
    let in_c = n.layer(from).out.c;
    let mid = in_c * t;
    let mut x = from;
    if t != 1 {
        x = conv(n, &format!("{name}.expand"), x, 1, 1, 0, mid);
    }
    x = dwconv(n, &format!("{name}.dw"), x, 3, stride);
    x = conv(n, &format!("{name}.project"), x, 1, 1, 0, out_c);
    if stride == 1 && in_c == out_c {
        n.add(&format!("{name}.add"), OpKind::Add, &[x, from]).expect("v2 add")
    } else {
        x
    }
}

/// MobileNetV2 (width 1.0): stem, 17 inverted-residual blocks, 1x1x1280
/// head, classifier.
pub fn mobilenet_v2() -> Network {
    let mut n = Network::new("MobileNetV2", Shape::new(224, 224, 3));
    let mut x = conv(&mut n, "conv0", 0, 3, 2, 1, 32);
    // (expand t, out_c, repeats, first-stride) per stage, per the paper.
    let cfg: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut bi = 0;
    for (t, c, reps, s) in cfg {
        for r in 0..reps {
            let stride = if r == 0 { s } else { 1 };
            x = inverted_residual(&mut n, &format!("block{bi}"), x, t, c, stride);
            bi += 1;
        }
    }
    x = conv(&mut n, "conv_last", x, 1, 1, 0, 1280);
    let gap = n.add("avgpool", OpKind::GlobalAvgPool, &[x]).expect("gap");
    n.add("fc", OpKind::Fc { out_features: 1000 }, &[gap]).expect("fc");
    n.validate().expect("mobilenetv2 validates");
    n
}

/// MobileNetV3 bneck: expand -> depthwise (k, stride) -> optional SE ->
/// project, residual when shape-preserving.
#[allow(clippy::too_many_arguments)]
fn bneck(
    n: &mut Network,
    name: &str,
    from: LayerId,
    k: u32,
    exp: u32,
    out_c: u32,
    se: bool,
    stride: u32,
) -> LayerId {
    let in_c = n.layer(from).out.c;
    let mut x = from;
    if exp != in_c {
        x = conv(n, &format!("{name}.expand"), x, 1, 1, 0, exp);
    }
    x = dwconv(n, &format!("{name}.dw"), x, k, stride);
    if se {
        x = n
            .add(&format!("{name}.se"), OpKind::SqueezeExcite { squeeze_c: exp / 4 }, &[x])
            .expect("v3 se");
    }
    x = conv(n, &format!("{name}.project"), x, 1, 1, 0, out_c);
    if stride == 1 && in_c == out_c {
        n.add(&format!("{name}.add"), OpKind::Add, &[x, from]).expect("v3 add")
    } else {
        x
    }
}

/// MobileNetV3-Large (width 1.0): the 15-bneck configuration from the
/// paper's Table 1 (Howard et al., 2019) plus the 960/1280 head.
pub fn mobilenet_v3_large() -> Network {
    let mut n = Network::new("MobileNetV3", Shape::new(224, 224, 3));
    let mut x = conv(&mut n, "conv0", 0, 3, 2, 1, 16);
    // (k, exp, out, se, stride)
    let cfg: [(u32, u32, u32, bool, u32); 15] = [
        (3, 16, 16, false, 1),
        (3, 64, 24, false, 2),
        (3, 72, 24, false, 1),
        (5, 72, 40, true, 2),
        (5, 120, 40, true, 1),
        (5, 120, 40, true, 1),
        (3, 240, 80, false, 2),
        (3, 200, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 480, 112, true, 1),
        (3, 672, 112, true, 1),
        (5, 672, 160, true, 2),
        (5, 960, 160, true, 1),
        (5, 960, 160, true, 1),
    ];
    for (i, (k, exp, c, se, s)) in cfg.iter().enumerate() {
        x = bneck(&mut n, &format!("bneck{i}"), x, *k, *exp, *c, *se, *s);
    }
    x = conv(&mut n, "conv_last", x, 1, 1, 0, 960);
    let gap = n.add("avgpool", OpKind::GlobalAvgPool, &[x]).expect("gap");
    let fc1 = n.add("fc1", OpKind::Fc { out_features: 1280 }, &[gap]).expect("fc1");
    n.add("fc2", OpKind::Fc { out_features: 1000 }, &[fc1]).expect("fc2");
    n.validate().expect("mobilenetv3 validates");
    n
}

/// MobileNet-Edge: a compact depthwise-separable stack (V1-style, no
/// residual path, no squeeze-excite) over a 32x32 input. This is the
/// third built-in serving model (`runtime::reference`): small enough to
/// execute per request on the functional backend, and it exercises the
/// depthwise engine path with *no* skip connection — the scenario the
/// Table I MobileNets cover in the compiler but the serving tests
/// previously did not.
pub fn mobilenet_edge() -> Network {
    let mut n = Network::new("mobilenet_edge", Shape::new(32, 32, 3));
    let mut x = conv(&mut n, "conv0", 0, 3, 2, 1, 8);
    // (out_c, stride) per separable block
    for (i, (c, s)) in [(16u32, 1u32), (32, 2), (64, 2)].iter().enumerate() {
        x = dwconv(&mut n, &format!("block{i}.dw"), x, 3, *s);
        x = conv(&mut n, &format!("block{i}.pw"), x, 1, 1, 0, *c);
    }
    let gap = n.add("avgpool", OpKind::GlobalAvgPool, &[x]).expect("gap");
    n.add("fc", OpKind::Fc { out_features: 10 }, &[gap]).expect("fc");
    n.validate().expect("mobilenet_edge validates");
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::OpKind;

    #[test]
    fn v1_params_match_literature() {
        // MobileNetV1 1.0: 4.23M params (incl. 1.0M classifier).
        let m = mobilenet_v1().total_params() as f64 / 1e6;
        assert!((4.0..4.4).contains(&m), "params {m}M");
    }

    #[test]
    fn v2_params_match_literature() {
        // MobileNetV2 1.0: 3.50M params.
        let m = mobilenet_v2().total_params() as f64 / 1e6;
        assert!((3.2..3.7).contains(&m), "params {m}M");
    }

    #[test]
    fn v3_params_match_literature() {
        // MobileNetV3-Large 1.0: 5.48M params.
        let m = mobilenet_v3_large().total_params() as f64 / 1e6;
        assert!((5.1..5.7).contains(&m), "params {m}M");
    }

    #[test]
    fn v1_macs_match_literature() {
        // ~569 MMACs.
        let m = mobilenet_v1().total_macs() as f64 / 1e6;
        assert!((540.0..600.0).contains(&m), "MMACs {m}");
    }

    #[test]
    fn v2_macs_match_literature() {
        // ~301 MMACs (+ elementwise adds in our accounting).
        let m = mobilenet_v2().total_macs() as f64 / 1e6;
        assert!((290.0..330.0).contains(&m), "MMACs {m}");
    }

    #[test]
    fn v2_has_53ish_conv_layers() {
        // paper §III-B: "each of the 53 convolutional layers" of V2.
        let n = mobilenet_v2();
        let convs =
            n.layers().iter().filter(|l| matches!(l.op, OpKind::Conv { .. })).count();
        assert_eq!(convs, 52); // 52 convs + 1 FC = 53 weight layers
        assert_eq!(n.weight_layers().count(), 53);
    }

    #[test]
    fn v3_has_se_blocks() {
        let n = mobilenet_v3_large();
        let se = n
            .layers()
            .iter()
            .filter(|l| matches!(l.op, OpKind::SqueezeExcite { .. }))
            .count();
        assert_eq!(se, 8);
    }

    #[test]
    fn edge_is_small_and_residual_free() {
        let n = mobilenet_edge();
        assert_eq!(n.input_shape(), crate::nn::Shape::new(32, 32, 3));
        assert!(n.layers().iter().all(|l| !matches!(l.op, OpKind::Add)), "no residual path");
        let dw = n
            .layers()
            .iter()
            .filter(|l| {
                matches!(l.op, OpKind::Conv { kind: crate::nn::ConvKind::Depthwise, .. })
            })
            .count();
        assert_eq!(dw, 3, "three depthwise stages");
        // small enough to execute per request on the functional backend
        assert!(n.total_macs() < 5_000_000, "{} MACs", n.total_macs());
        assert_eq!(n.layers().last().unwrap().out.c, 10);
    }

    #[test]
    fn depthwise_blocks_preserve_channels() {
        let n = mobilenet_v1();
        for l in n.layers() {
            if let OpKind::Conv { kind: crate::nn::ConvKind::Depthwise, out_c, .. } = l.op {
                assert_eq!(out_c, n.layer(l.inputs[0]).out.c, "{}", l.name);
            }
        }
    }
}
