//! The layer-graph IR.
//!
//! A [`Network`] is a DAG of [`Layer`]s in topological order (builders
//! append producers before consumers). Shape inference runs at
//! construction, so every layer carries its concrete output [`Shape`];
//! the compiler and simulator never re-derive geometry.

use anyhow::{bail, ensure, Result};

/// Index of a layer within its [`Network`].
pub type LayerId = usize;

/// A 3-D activation shape: height x width x channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    pub h: u32,
    pub w: u32,
    pub c: u32,
}

impl Shape {
    pub fn new(h: u32, w: u32, c: u32) -> Self {
        Self { h, w, c }
    }

    /// Total element count.
    pub fn elems(&self) -> u64 {
        self.h as u64 * self.w as u64 * self.c as u64
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.h, self.w, self.c)
    }
}

/// Convolution flavour; HPIPE instantiates a different compute unit for
/// each (§I), and they differ in weight volume and MAC count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConvKind {
    /// Traditional dense convolution over all input channels.
    Standard,
    /// Depthwise: one filter per channel, `c_o == c_i`.
    Depthwise,
    /// Pointwise: 1x1 standard convolution (kept distinct because HPIPE
    /// maps it to a dedicated engine).
    Pointwise,
}

/// Operator payload of a layer.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Network input placeholder.
    Input { shape: Shape },
    /// 2-D convolution (+ optional fused activation, which does not change
    /// memory/compute accounting and is therefore just a flag).
    Conv {
        kind: ConvKind,
        kh: u32,
        kw: u32,
        stride: u32,
        /// "same"-style symmetric padding amount.
        pad: u32,
        out_c: u32,
    },
    /// Max pooling.
    MaxPool { k: u32, stride: u32, pad: u32 },
    /// Global average pooling to 1x1.
    GlobalAvgPool,
    /// Elementwise residual addition of exactly two inputs.
    Add,
    /// Fully connected layer (HPIPE maps it as a 1x1 conv over 1x1xC).
    Fc { out_features: u32 },
    /// Squeeze-and-excite scale (MobileNetV3): global pool + two FCs +
    /// channelwise multiply. `squeeze_c` is the bottleneck width.
    SqueezeExcite { squeeze_c: u32 },
}

/// One node in the network DAG.
#[derive(Debug, Clone)]
pub struct Layer {
    pub id: LayerId,
    pub name: String,
    pub op: OpKind,
    /// Producer layers (empty for `Input`, two for `Add`, one otherwise).
    pub inputs: Vec<LayerId>,
    /// Inferred output shape.
    pub out: Shape,
    /// Shape of the first input, captured at insertion time so layers are
    /// self-contained for accounting.
    in_shape: Shape,
}

impl Layer {
    /// Number of weight parameters this layer stores.
    pub fn weight_params(&self) -> u64 {
        match &self.op {
            OpKind::Conv { kind, kh, kw, out_c, .. } => {
                let (kh, kw, out_c) = (*kh as u64, *kw as u64, *out_c as u64);
                match kind {
                    ConvKind::Depthwise => kh * kw * out_c,
                    _ => kh * kw * self.in_c() as u64 * out_c,
                }
            }
            OpKind::Fc { out_features } => self.in_elems() * *out_features as u64,
            OpKind::SqueezeExcite { squeeze_c } => {
                // two dense layers: C -> squeeze -> C
                let c = self.out.c as u64;
                let s = *squeeze_c as u64;
                c * s + s * c
            }
            _ => 0,
        }
    }

    /// Multiply-accumulate operations per inference for this layer.
    pub fn macs(&self) -> u64 {
        match &self.op {
            OpKind::Conv { kind, kh, kw, out_c, .. } => {
                let spatial = self.out.h as u64 * self.out.w as u64;
                let (kh, kw, out_c) = (*kh as u64, *kw as u64, *out_c as u64);
                match kind {
                    ConvKind::Depthwise => spatial * kh * kw * out_c,
                    _ => spatial * kh * kw * self.in_c() as u64 * out_c,
                }
            }
            OpKind::Fc { out_features } => self.in_elems() * *out_features as u64,
            OpKind::SqueezeExcite { squeeze_c } => {
                let c = self.out.c as u64;
                2 * c * *squeeze_c as u64
            }
            OpKind::Add => self.out.elems(),
            _ => 0,
        }
    }

    /// Input channel count (first input's shape channels); stored at build
    /// time so layers are self-contained.
    pub fn in_c(&self) -> u32 {
        self.in_shape.c
    }

    /// Total input element count.
    pub fn in_elems(&self) -> u64 {
        self.in_shape.elems()
    }

    /// Input shape (first input).
    pub fn in_shape(&self) -> Shape {
        self.in_shape
    }
}

/// A CNN as a topologically-ordered layer list.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    layers: Vec<Layer>,
}

impl Network {
    /// Start a new network with the given input shape.
    pub fn new(name: &str, input: Shape) -> Self {
        let mut n = Self { name: name.to_string(), layers: Vec::new() };
        n.layers.push(Layer {
            id: 0,
            name: "input".to_string(),
            op: OpKind::Input { shape: input },
            inputs: vec![],
            out: input,
            in_shape: input,
        });
        n
    }

    /// Append a layer consuming `inputs`; returns its id.
    ///
    /// Inputs must already exist (topological construction). Shape
    /// inference validates geometry and fails on mismatched residual adds
    /// or non-positive output sizes.
    pub fn add(&mut self, name: &str, op: OpKind, inputs: &[LayerId]) -> Result<LayerId> {
        let id = self.layers.len();
        for &i in inputs {
            ensure!(i < id, "layer {name}: input {i} does not precede {id}");
        }
        let in_shape = if inputs.is_empty() {
            bail!("layer {name}: non-input layer needs at least one input")
        } else {
            self.layers[inputs[0]].out
        };
        let out = self.infer_shape(name, &op, inputs, in_shape)?;
        self.layers.push(Layer { id, name: name.to_string(), op, inputs: inputs.to_vec(), out, in_shape });
        Ok(id)
    }

    fn infer_shape(&self, name: &str, op: &OpKind, inputs: &[LayerId], in_shape: Shape) -> Result<Shape> {
        let conv_out = |h: u32, w: u32, k: u32, s: u32, p: u32| -> Result<(u32, u32)> {
            ensure!(s >= 1, "layer {name}: stride 0");
            ensure!(h + 2 * p >= k && w + 2 * p >= k, "layer {name}: kernel larger than padded input");
            Ok(((h + 2 * p - k) / s + 1, (w + 2 * p - k) / s + 1))
        };
        Ok(match op {
            OpKind::Input { shape } => *shape,
            OpKind::Conv { kind, kh, kw, stride, pad, out_c } => {
                ensure!(*kh > 0 && *kw > 0, "layer {name}: zero kernel");
                if *kind == ConvKind::Pointwise {
                    ensure!(*kh == 1 && *kw == 1, "layer {name}: pointwise must be 1x1");
                }
                if *kind == ConvKind::Depthwise {
                    ensure!(*out_c == in_shape.c, "layer {name}: depthwise c_o must equal c_i");
                }
                let (h, w) = conv_out(in_shape.h, in_shape.w, *kh, *stride, *pad)?;
                ensure!(h > 0 && w > 0, "layer {name}: empty output");
                Shape::new(h, w, *out_c)
            }
            OpKind::MaxPool { k, stride, pad } => {
                let (h, w) = conv_out(in_shape.h, in_shape.w, *k, *stride, *pad)?;
                Shape::new(h, w, in_shape.c)
            }
            OpKind::GlobalAvgPool => Shape::new(1, 1, in_shape.c),
            OpKind::Add => {
                ensure!(inputs.len() == 2, "layer {name}: Add requires exactly 2 inputs");
                let a = self.layers[inputs[0]].out;
                let b = self.layers[inputs[1]].out;
                ensure!(a == b, "layer {name}: residual shape mismatch {a} vs {b}");
                a
            }
            OpKind::Fc { out_features } => {
                ensure!(*out_features > 0, "layer {name}: empty FC");
                Shape::new(1, 1, *out_features)
            }
            OpKind::SqueezeExcite { squeeze_c } => {
                ensure!(*squeeze_c > 0, "layer {name}: zero squeeze width");
                in_shape
            }
        })
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Layers that perform weight-bearing convolutions / FCs, in order —
    /// the units the H2PIPE compiler assigns engines and memory to.
    pub fn weight_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.weight_params() > 0)
    }

    /// Total weight parameters across the network.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_params()).sum()
    }

    /// Total MACs per inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Input shape of the network.
    pub fn input_shape(&self) -> Shape {
        match &self.layers[0].op {
            OpKind::Input { shape } => *shape,
            _ => unreachable!("layer 0 is always Input"),
        }
    }

    /// The consumers of each layer (adjacency of the DAG), index-aligned
    /// with `layers()`. Used by the simulator to wire activation queues.
    pub fn consumers(&self) -> Vec<Vec<LayerId>> {
        let mut out = vec![Vec::new(); self.layers.len()];
        for l in &self.layers {
            for &i in &l.inputs {
                out[i].push(l.id);
            }
        }
        out
    }

    /// Structural validation: every non-input layer reachable, exactly one
    /// sink, add-nodes well-formed. Builders call this before returning.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.layers.is_empty(), "empty network");
        let consumers = self.consumers();
        let sinks: Vec<_> =
            self.layers.iter().filter(|l| consumers[l.id].is_empty()).map(|l| l.id).collect();
        ensure!(sinks.len() == 1, "{}: expected 1 sink, found {:?}", self.name, sinks);
        for l in &self.layers[1..] {
            ensure!(!l.inputs.is_empty(), "{}: layer {} has no inputs", self.name, l.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        let mut n = Network::new("tiny", Shape::new(8, 8, 3));
        let c1 = n
            .add(
                "conv1",
                OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 16 },
                &[0],
            )
            .unwrap();
        let p = n.add("pool", OpKind::MaxPool { k: 2, stride: 2, pad: 0 }, &[c1]).unwrap();
        let g = n.add("gap", OpKind::GlobalAvgPool, &[p]).unwrap();
        n.add("fc", OpKind::Fc { out_features: 10 }, &[g]).unwrap();
        n
    }

    #[test]
    fn shape_inference_chain() {
        let n = tiny();
        assert_eq!(n.layer(1).out, Shape::new(8, 8, 16));
        assert_eq!(n.layer(2).out, Shape::new(4, 4, 16));
        assert_eq!(n.layer(3).out, Shape::new(1, 1, 16));
        assert_eq!(n.layer(4).out, Shape::new(1, 1, 10));
        n.validate().unwrap();
    }

    #[test]
    fn weight_and_mac_accounting() {
        let n = tiny();
        // conv1: 3*3*3*16 weights, 8*8 spatial
        assert_eq!(n.layer(1).weight_params(), 3 * 3 * 3 * 16);
        assert_eq!(n.layer(1).macs(), 8 * 8 * 3 * 3 * 3 * 16);
        // fc: 16 -> 10
        assert_eq!(n.layer(4).weight_params(), 160);
        assert_eq!(n.total_params(), 3 * 3 * 3 * 16 + 160);
    }

    #[test]
    fn depthwise_constraints() {
        let mut n = Network::new("t", Shape::new(8, 8, 4));
        // wrong out_c
        let err = n.add(
            "dw",
            OpKind::Conv { kind: ConvKind::Depthwise, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 8 },
            &[0],
        );
        assert!(err.is_err());
        let ok = n
            .add(
                "dw",
                OpKind::Conv { kind: ConvKind::Depthwise, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 4 },
                &[0],
            )
            .unwrap();
        assert_eq!(n.layer(ok).weight_params(), 3 * 3 * 4);
    }

    #[test]
    fn pointwise_must_be_1x1() {
        let mut n = Network::new("t", Shape::new(8, 8, 4));
        assert!(n
            .add(
                "pw",
                OpKind::Conv { kind: ConvKind::Pointwise, kh: 3, kw: 3, stride: 1, pad: 0, out_c: 8 },
                &[0],
            )
            .is_err());
    }

    #[test]
    fn residual_add_shape_check() {
        let mut n = Network::new("t", Shape::new(8, 8, 4));
        let a = n
            .add(
                "a",
                OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 4 },
                &[0],
            )
            .unwrap();
        let ok = n.add("add", OpKind::Add, &[a, 0]).unwrap();
        assert_eq!(n.layer(ok).out, Shape::new(8, 8, 4));
        // mismatched channels
        let b = n
            .add(
                "b",
                OpKind::Conv { kind: ConvKind::Standard, kh: 1, kw: 1, stride: 1, pad: 0, out_c: 8 },
                &[0],
            )
            .unwrap();
        assert!(n.add("bad", OpKind::Add, &[b, 0]).is_err());
    }

    #[test]
    fn topological_order_enforced() {
        let mut n = Network::new("t", Shape::new(8, 8, 3));
        assert!(n.add("x", OpKind::GlobalAvgPool, &[5]).is_err());
    }

    #[test]
    fn validate_rejects_two_sinks() {
        let mut n = Network::new("t", Shape::new(8, 8, 3));
        n.add("a", OpKind::GlobalAvgPool, &[0]).unwrap();
        n.add("b", OpKind::MaxPool { k: 2, stride: 2, pad: 0 }, &[0]).unwrap();
        assert!(n.validate().is_err());
    }
}
