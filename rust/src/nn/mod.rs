//! CNN graph intermediate representation and the paper's model zoo.
//!
//! The H2PIPE compiler consumes a [`Network`]: a topologically-ordered DAG
//! of layers with inferred activation shapes. The zoo provides the six
//! networks of Table I — MobileNetV1/V2/V3, ResNet-18, ResNet-50 and
//! VGG-16 — with exact ImageNet (224x224x3) shapes.

mod ir;
pub mod zoo;

pub use ir::{ConvKind, Layer, LayerId, Network, OpKind, Shape};
