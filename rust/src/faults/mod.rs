//! Deterministic fault injection and the accounting that proves recovery.
//!
//! H2PIPE sizes FIFOs so compute never stalls on an *imperfect* memory
//! system (§IV–VI) — but a reproduction that only ever simulates the
//! happy path cannot demonstrate that the margins hold. This module is
//! the seeded chaos layer for the whole stack:
//!
//! * **What to break** is a [`FaultPlan`]: a serializable
//!   `h2pipe.faults/v1` JSON artifact (same discipline as the
//!   `h2pipe.plan/v1` plan artifact) describing HBM transient read
//!   errors, per-PC thermal-throttle windows, inter-device link
//!   stall/credit-loss windows, cycle-domain replica outages, and
//!   wall-clock serving faults (replica crash / slow replica), plus the
//!   [`RecoveryPolicy`] the serving stack uses to survive them.
//! * **Where it breaks** is inside the real machinery, not a wrapper:
//!   the [`crate::hbm::controller`] replays faulted read bursts at full
//!   tRC/arbitration cost, [`crate::cluster::fleet`] stalls links and
//!   freezes crashed replicas, and [`crate::cluster::router`] +
//!   [`crate::coordinator::server`] exercise deadlines, retry with
//!   backoff, failover, watchdog reboot and admission control.
//! * **What must hold** is the conservation invariant carried by
//!   [`FaultTotals`]: every injected fault is accounted as a
//!   retried-success, a failover, or a counted drop —
//!   `injected == retried + failed_over + dropped`, `lost == 0`.
//!
//! Determinism: every random decision draws from per-site
//! [`crate::util::XorShift64`] streams derived from the plan seed, so the
//! same `FaultPlan` against the same workload produces byte-identical
//! cycle-domain reports (the CI chaos step diffs two same-seed runs).

mod plan;

pub use plan::{
    count_denied, next_allowed, FaultPlan, HbmFaultSpec, LinkFault, LinkFaultKind, RecoveryPolicy,
    ReplicaOutage, ServeFault, ServeFaultKind, ThrottleWindow, FAULT_FORMAT,
};

use crate::util::Json;

/// Per-PC RNG stream derivation: mixes the plan seed with a site index so
/// independent injection sites never share a random stream (golden-ratio
/// odd constant, same mixer family as `XorShift64`'s seed escape).
pub fn site_seed(seed: u64, site: u64) -> u64 {
    seed ^ (site.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// The conservation ledger: one accounting row summed across every
/// injection site of a run. The invariant proved by tests and asserted by
/// the CI chaos step is `lost() == 0` — no injected fault may vanish
/// without being attributed to a recovery path or a counted drop.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultTotals {
    /// Faults fired (HBM read errors, link stall windows entered,
    /// replica crashes, ...).
    pub injected: u64,
    /// Recovered by retrying the same resource (HBM burst replays,
    /// in-place request retries).
    pub retried: u64,
    /// Recovered by moving the work elsewhere (router failover, replica
    /// reboot absorbing queued work).
    pub failed_over: u64,
    /// Deliberately given up and *counted* (replay budget exhausted,
    /// admission-control shed). A drop is not a loss: the caller saw it.
    pub dropped: u64,
    /// Degradation-window cycles where a PC was denied CAS slots
    /// (thermal throttle). Informational — not part of conservation.
    pub throttled_cycles: u64,
    /// Base ticks where an inter-device link was stalled.
    pub link_stall_ticks: u64,
    /// Base ticks replicas spent down (outage window + reboot).
    pub outage_ticks: u64,
}

impl FaultTotals {
    /// Faults that ended well: retried successfully or failed over.
    pub fn recovered(&self) -> u64 {
        self.retried + self.failed_over
    }

    /// Conservation residue — anything injected but never accounted.
    /// Zero in every correct run.
    pub fn lost(&self) -> u64 {
        self.injected.saturating_sub(self.retried + self.failed_over + self.dropped)
    }

    /// Fold another site's ledger into this one.
    pub fn absorb(&mut self, other: &FaultTotals) {
        self.injected += other.injected;
        self.retried += other.retried;
        self.failed_over += other.failed_over;
        self.dropped += other.dropped;
        self.throttled_cycles += other.throttled_cycles;
        self.link_stall_ticks += other.link_stall_ticks;
        self.outage_ticks += other.outage_ticks;
    }

    /// Machine-scrapable form. The CI chaos step greps for `"lost":0`
    /// and a nonzero `"recovered"` — keep those keys literal.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("injected", self.injected)
            .set("retried", self.retried)
            .set("failed_over", self.failed_over)
            .set("dropped", self.dropped)
            .set("recovered", self.recovered())
            .set("lost", self.lost())
            .set("throttled_cycles", self.throttled_cycles)
            .set("link_stall_ticks", self.link_stall_ticks)
            .set("outage_ticks", self.outage_ticks);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conservation_arithmetic() {
        let mut t = FaultTotals { injected: 10, retried: 6, ..FaultTotals::default() };
        t.failed_over = 3;
        t.dropped = 1;
        assert_eq!(t.recovered(), 9);
        assert_eq!(t.lost(), 0);
        let mut sum = FaultTotals::default();
        sum.absorb(&t);
        sum.absorb(&t);
        assert_eq!(sum.injected, 20);
        assert_eq!(sum.lost(), 0);
    }

    #[test]
    fn lost_surfaces_unaccounted_faults() {
        let t = FaultTotals { injected: 5, retried: 2, dropped: 1, ..FaultTotals::default() };
        assert_eq!(t.lost(), 2);
        let j = t.to_json().to_string();
        assert!(j.contains("\"lost\":2"), "{j}");
        assert!(j.contains("\"recovered\":2"), "{j}");
    }

    #[test]
    fn site_seeds_diverge() {
        let a = site_seed(7, 0);
        let b = site_seed(7, 1);
        assert_ne!(a, b);
        assert_eq!(a, site_seed(7, 0), "derivation must be pure");
    }
}
