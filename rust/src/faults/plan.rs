//! The `h2pipe.faults/v1` artifact: what to break, when, and how hard —
//! plus the recovery policy the serving stack runs under.
//!
//! Same artifact discipline as [`crate::session::CompiledModel`]: a
//! format-tagged JSON document with a byte-stable round trip, strict
//! decoding (unknown format tags and malformed fields fail hard), and
//! semantic validation on every load so an impossible scenario (a
//! probability of 1.7, a throttle window denying more slots than its
//! period has) is rejected before it can poison a run.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::util::Json;

/// Artifact format tag; bump on incompatible schema changes.
pub const FAULT_FORMAT: &str = "h2pipe.faults/v1";

/// HBM transient read errors: within `[start, end)` controller cycles,
/// each read CAS issue fails with probability `prob`. A failed burst is
/// replayed — re-enqueued at the back of the PC queue, paying the full
/// re-arbitration + data-bus cost again — up to `max_replays` times per
/// request, after which the corrupt burst is delivered and *counted* as
/// a drop.
#[derive(Debug, Clone, PartialEq)]
pub struct HbmFaultSpec {
    /// First controller cycle (400 MHz domain) of the error window.
    pub start: u64,
    /// One past the last controller cycle of the error window.
    pub end: u64,
    /// Per-read-CAS error probability in `[0, 1]`.
    pub prob: f64,
    /// Replay budget per request before the fault is counted as dropped.
    pub max_replays: u32,
}

/// A per-PC bandwidth-degradation window (thermal throttle): within
/// `[start, end)`, the PC is denied column-command issue for `deny` out
/// of every `period` cycles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ThrottleWindow {
    /// Global pseudo-channel index (stack-major, as reported by
    /// `for_each_pc_stats`).
    pub pc: usize,
    pub start: u64,
    pub end: u64,
    /// Denied cycles per period; must be `< period`.
    pub deny: u64,
    pub period: u64,
}

impl ThrottleWindow {
    /// Is CAS issue denied at `cycle`?
    pub fn denies(&self, cycle: u64) -> bool {
        cycle >= self.start && cycle < self.end && cycle % self.period < self.deny
    }

    /// Denied cycles of this window alone within `[lo, hi)`, in closed
    /// form (no per-cycle loop). The deny pattern is anchored at absolute
    /// cycle 0 (`cycle % period < deny`), so the count is a difference of
    /// the pattern's prefix function.
    pub fn denied_in(&self, lo: u64, hi: u64) -> u64 {
        let lo = lo.max(self.start);
        let hi = hi.min(self.end);
        if lo >= hi {
            return 0;
        }
        let prefix = |x: u64| (x / self.period) * self.deny + (x % self.period).min(self.deny);
        prefix(hi) - prefix(lo)
    }
}

/// Denied cycles in `[lo, hi)` under the union of `windows` (a cycle
/// denied by two overlapping windows counts once, exactly as the
/// per-cycle `any(denies)` check the slow simulation path runs).
///
/// Non-overlapping windows sum in closed form; if two windows overlap
/// within the span, the overlapping region falls back to a bounded
/// per-cycle walk (window unions are finite, so this stays cheap and is
/// only ever paid inside armed fault plans).
pub fn count_denied(windows: &[ThrottleWindow], lo: u64, hi: u64) -> u64 {
    if lo >= hi || windows.is_empty() {
        return 0;
    }
    let hit: Vec<&ThrottleWindow> =
        windows.iter().filter(|w| w.start.max(lo) < w.end.min(hi)).collect();
    match hit.len() {
        0 => 0,
        1 => hit[0].denied_in(lo, hi),
        _ => {
            let overlapping = hit.iter().enumerate().any(|(i, a)| {
                hit.iter().skip(i + 1).any(|b| {
                    a.start.max(b.start).max(lo) < a.end.min(b.end).min(hi)
                })
            });
            if !overlapping {
                return hit.iter().map(|w| w.denied_in(lo, hi)).sum();
            }
            let a = hit.iter().map(|w| w.start).min().unwrap_or(lo).max(lo);
            let b = hit.iter().map(|w| w.end).max().unwrap_or(hi).min(hi);
            (a..b).filter(|&c| hit.iter().any(|w| w.denies(c))).count() as u64
        }
    }
}

/// First cycle `>= from` at which no window denies CAS issue.
///
/// Jump-based: each denied candidate skips to the end of the window's
/// current deny run. The iteration count is capped; on pathological
/// window sets the early (possibly still denied) candidate is returned,
/// which is safe for the event scheduler — waking early only costs a
/// no-op tick, never correctness.
pub fn next_allowed(windows: &[ThrottleWindow], from: u64) -> u64 {
    let mut c = from;
    for _ in 0..64 {
        let mut bumped = false;
        for w in windows {
            if w.denies(c) {
                // end of this deny run: either the deny phase boundary or
                // the window end, whichever is first
                c = (c - c % w.period + w.deny).min(w.end);
                bumped = true;
            }
        }
        if !bumped {
            return c;
        }
    }
    c
}

/// What goes wrong on an inter-device link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkFaultKind {
    /// The link is down: no lines move, no credits return. Upstream
    /// backpressure absorbs the window; nothing is dropped.
    Stall,
    /// `lost` credits are withheld (effective capacity shrinks, floor 1).
    CreditLoss(u32),
}

/// A fault window on one inter-device fleet link, in base ticks
/// (1200 MHz domain, matching `cluster::fleet`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LinkFault {
    /// Link index (between shard `link` and shard `link + 1`).
    pub link: usize,
    pub start: u64,
    pub end: u64,
    pub kind: LinkFaultKind,
}

/// A cycle-domain replica outage: the replica freezes for `[start, end)`
/// base ticks, then pays a reboot penalty (derived from the plan's
/// §IV-C boot-weights time) before resuming. Work queued behind it is
/// delayed, never lost.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaOutage {
    pub replica: usize,
    pub start: u64,
    pub end: u64,
}

/// What goes wrong in the wall-clock serving stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeFaultKind {
    /// The replica's worker thread exits after serving this many
    /// requests; the watchdog must detect and reboot it.
    Crash { after_requests: u64 },
    /// Every batch takes this much extra wall-clock time (a straggler).
    Slow { extra_ms: u64 },
}

/// A serving-side fault bound to one replica index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeFault {
    pub replica: usize,
    pub kind: ServeFaultKind,
}

/// How the serving stack is allowed to fight back. Every knob has a
/// production-shaped default so a plan may omit the whole block.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Per-request deadline for `InferenceServer::infer`'s
    /// `recv_timeout` and the router's total retry budget.
    pub request_deadline_ms: u64,
    /// Total attempts (first try + retries/failovers) per request.
    pub max_attempts: u32,
    /// Base backoff between attempts; doubles per attempt
    /// (`backoff_ms << attempt`), capped by the remaining deadline.
    pub backoff_ms: u64,
    /// Watchdog health-check period; a dead worker is re-booted from the
    /// plan artifact on the next check.
    pub watchdog_ms: u64,
    /// Admission control: reject new work when total in-flight requests
    /// across the fleet reach this bound (0 disables shedding).
    pub admission_max_outstanding: usize,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        Self {
            request_deadline_ms: 2_000,
            max_attempts: 4,
            backoff_ms: 2,
            watchdog_ms: 25,
            admission_max_outstanding: 0,
        }
    }
}

/// The full seeded fault scenario. See the field docs of the component
/// specs for semantics; empty sections mean "that layer stays healthy".
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Root seed; every injection site derives its own stream via
    /// [`crate::faults::site_seed`].
    pub seed: u64,
    /// HBM transient read errors (applies to every weight-reading PC).
    pub hbm: Option<HbmFaultSpec>,
    /// Per-PC thermal-throttle windows.
    pub throttle: Vec<ThrottleWindow>,
    /// Inter-device link faults.
    pub links: Vec<LinkFault>,
    /// Cycle-domain replica outages.
    pub replicas: Vec<ReplicaOutage>,
    /// Wall-clock serving faults.
    pub serve: Vec<ServeFault>,
    /// Recovery knobs for the serving stack.
    pub recovery: RecoveryPolicy,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::new(1)
    }
}

impl FaultPlan {
    /// An empty (all-healthy) plan with the given seed.
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            hbm: None,
            throttle: Vec::new(),
            links: Vec::new(),
            replicas: Vec::new(),
            serve: Vec::new(),
            recovery: RecoveryPolicy::default(),
        }
    }

    /// The CI chaos scenario: an aggressive HBM error burst early in the
    /// run, a thermal throttle on PC 0, a link stall, a mid-run outage of
    /// replica 1, and a serving-side crash of replica 1 — all from one
    /// seed. Kept in code so tests, docs and the workflow regenerate the
    /// identical scenario from `h2pipe faults --preset chaos`.
    pub fn chaos_preset(seed: u64) -> Self {
        Self {
            seed,
            hbm: Some(HbmFaultSpec { start: 0, end: 200_000, prob: 0.02, max_replays: 3 }),
            throttle: vec![ThrottleWindow { pc: 0, start: 0, end: 100_000, deny: 2, period: 8 }],
            links: vec![LinkFault {
                link: 0,
                start: 30_000,
                end: 60_000,
                kind: LinkFaultKind::Stall,
            }],
            replicas: vec![ReplicaOutage { replica: 1, start: 50_000, end: 250_000 }],
            serve: vec![ServeFault {
                replica: 1,
                kind: ServeFaultKind::Crash { after_requests: 8 },
            }],
            recovery: RecoveryPolicy {
                request_deadline_ms: 5_000,
                max_attempts: 5,
                backoff_ms: 1,
                watchdog_ms: 10,
                admission_max_outstanding: 0,
            },
        }
    }

    /// Does any section touch the cycle-domain simulators?
    pub fn touches_sim(&self) -> bool {
        self.hbm.is_some()
            || !self.throttle.is_empty()
            || !self.links.is_empty()
            || !self.replicas.is_empty()
    }

    /// Semantic validation; called on every load and before every run.
    pub fn validate(&self) -> Result<()> {
        if let Some(h) = &self.hbm {
            ensure!(h.end > h.start, "hbm fault window is empty ({}..{})", h.start, h.end);
            ensure!(
                (0.0..=1.0).contains(&h.prob) && h.prob.is_finite(),
                "hbm fault prob {} outside [0, 1]",
                h.prob
            );
            ensure!(h.max_replays <= 64, "hbm max_replays {} is absurd (cap 64)", h.max_replays);
        }
        for (i, t) in self.throttle.iter().enumerate() {
            ensure!(t.end > t.start, "throttle[{i}] window is empty");
            ensure!(t.period > 0, "throttle[{i}] period must be positive");
            ensure!(
                t.deny < t.period,
                "throttle[{i}] denies {} of every {} cycles — that is an outage, not a throttle",
                t.deny,
                t.period
            );
        }
        for (i, l) in self.links.iter().enumerate() {
            ensure!(l.end > l.start, "links[{i}] window is empty");
            if let LinkFaultKind::CreditLoss(n) = l.kind {
                ensure!(n > 0, "links[{i}] credit_loss of 0 is a no-op");
            }
        }
        for (i, r) in self.replicas.iter().enumerate() {
            ensure!(r.end > r.start, "replicas[{i}] outage window is empty");
        }
        for (i, s) in self.serve.iter().enumerate() {
            match s.kind {
                ServeFaultKind::Crash { after_requests } => {
                    ensure!(after_requests > 0, "serve[{i}] crash after 0 requests never boots")
                }
                ServeFaultKind::Slow { extra_ms } => {
                    ensure!(extra_ms > 0, "serve[{i}] slow fault of 0 ms is a no-op")
                }
            }
        }
        let r = &self.recovery;
        ensure!(r.request_deadline_ms > 0, "recovery.request_deadline_ms must be positive");
        ensure!(r.max_attempts > 0, "recovery.max_attempts must be at least 1");
        ensure!(r.watchdog_ms > 0, "recovery.watchdog_ms must be positive");
        Ok(())
    }

    /// Serialize (byte-stable: object keys are BTreeMap-ordered, empty
    /// sections are omitted).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("format", FAULT_FORMAT).set("seed", self.seed);
        if let Some(h) = &self.hbm {
            let mut hj = Json::obj();
            hj.set("start", h.start)
                .set("end", h.end)
                .set("prob", h.prob)
                .set("max_replays", u64::from(h.max_replays));
            o.set("hbm", hj);
        }
        if !self.throttle.is_empty() {
            let mut arr = Json::Arr(Vec::new());
            for t in &self.throttle {
                let mut tj = Json::obj();
                tj.set("pc", t.pc as u64)
                    .set("start", t.start)
                    .set("end", t.end)
                    .set("deny", t.deny)
                    .set("period", t.period);
                arr.push(tj);
            }
            o.set("throttle", arr);
        }
        if !self.links.is_empty() {
            let mut arr = Json::Arr(Vec::new());
            for l in &self.links {
                let mut lj = Json::obj();
                lj.set("link", l.link as u64).set("start", l.start).set("end", l.end);
                match l.kind {
                    LinkFaultKind::Stall => {
                        lj.set("kind", "stall");
                    }
                    LinkFaultKind::CreditLoss(n) => {
                        lj.set("kind", "credit_loss").set("lost", u64::from(n));
                    }
                }
                arr.push(lj);
            }
            o.set("links", arr);
        }
        if !self.replicas.is_empty() {
            let mut arr = Json::Arr(Vec::new());
            for r in &self.replicas {
                let mut rj = Json::obj();
                rj.set("replica", r.replica as u64).set("start", r.start).set("end", r.end);
                arr.push(rj);
            }
            o.set("replicas", arr);
        }
        if !self.serve.is_empty() {
            let mut arr = Json::Arr(Vec::new());
            for s in &self.serve {
                let mut sj = Json::obj();
                sj.set("replica", s.replica as u64);
                match s.kind {
                    ServeFaultKind::Crash { after_requests } => {
                        sj.set("kind", "crash").set("after_requests", after_requests);
                    }
                    ServeFaultKind::Slow { extra_ms } => {
                        sj.set("kind", "slow").set("extra_ms", extra_ms);
                    }
                }
                arr.push(sj);
            }
            o.set("serve", arr);
        }
        let r = &self.recovery;
        let mut rj = Json::obj();
        rj.set("request_deadline_ms", r.request_deadline_ms)
            .set("max_attempts", u64::from(r.max_attempts))
            .set("backoff_ms", r.backoff_ms)
            .set("watchdog_ms", r.watchdog_ms)
            .set("admission_max_outstanding", r.admission_max_outstanding as u64);
        o.set("recovery", rj);
        o
    }

    /// Decode and validate an artifact.
    pub fn from_json(j: &Json) -> Result<Self> {
        match j.get("format").and_then(Json::as_str) {
            Some(FAULT_FORMAT) => {}
            Some(other) => bail!("unsupported fault format {other:?} (expected {FAULT_FORMAT:?})"),
            None => bail!("not a fault artifact (missing \"format\" tag)"),
        }
        let seed = j.get("seed").and_then(Json::as_u64).context("missing seed")?;
        let hbm = match j.get("hbm") {
            None => None,
            Some(h) => Some(HbmFaultSpec {
                start: h.get("start").and_then(Json::as_u64).context("hbm.start")?,
                end: h.get("end").and_then(Json::as_u64).context("hbm.end")?,
                prob: h.get("prob").and_then(Json::as_f64).context("hbm.prob")?,
                max_replays: h
                    .get("max_replays")
                    .and_then(Json::as_u32)
                    .context("hbm.max_replays")?,
            }),
        };
        let mut throttle = Vec::new();
        for (i, t) in j.get("throttle").and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate() {
            throttle.push(ThrottleWindow {
                pc: t.get("pc").and_then(Json::as_usize).with_context(|| format!("throttle[{i}].pc"))?,
                start: t
                    .get("start")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("throttle[{i}].start"))?,
                end: t
                    .get("end")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("throttle[{i}].end"))?,
                deny: t
                    .get("deny")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("throttle[{i}].deny"))?,
                period: t
                    .get("period")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("throttle[{i}].period"))?,
            });
        }
        let mut links = Vec::new();
        for (i, l) in j.get("links").and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate() {
            let kind = match l.get("kind").and_then(Json::as_str) {
                Some("stall") => LinkFaultKind::Stall,
                Some("credit_loss") => LinkFaultKind::CreditLoss(
                    l.get("lost")
                        .and_then(Json::as_u32)
                        .with_context(|| format!("links[{i}].lost"))?,
                ),
                other => bail!("links[{i}].kind {other:?} is not \"stall\" or \"credit_loss\""),
            };
            links.push(LinkFault {
                link: l
                    .get("link")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("links[{i}].link"))?,
                start: l
                    .get("start")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("links[{i}].start"))?,
                end: l
                    .get("end")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("links[{i}].end"))?,
                kind,
            });
        }
        let mut replicas = Vec::new();
        for (i, r) in j.get("replicas").and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate() {
            replicas.push(ReplicaOutage {
                replica: r
                    .get("replica")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("replicas[{i}].replica"))?,
                start: r
                    .get("start")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("replicas[{i}].start"))?,
                end: r
                    .get("end")
                    .and_then(Json::as_u64)
                    .with_context(|| format!("replicas[{i}].end"))?,
            });
        }
        let mut serve = Vec::new();
        for (i, s) in j.get("serve").and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate() {
            let kind = match s.get("kind").and_then(Json::as_str) {
                Some("crash") => ServeFaultKind::Crash {
                    after_requests: s
                        .get("after_requests")
                        .and_then(Json::as_u64)
                        .with_context(|| format!("serve[{i}].after_requests"))?,
                },
                Some("slow") => ServeFaultKind::Slow {
                    extra_ms: s
                        .get("extra_ms")
                        .and_then(Json::as_u64)
                        .with_context(|| format!("serve[{i}].extra_ms"))?,
                },
                other => bail!("serve[{i}].kind {other:?} is not \"crash\" or \"slow\""),
            };
            serve.push(ServeFault {
                replica: s
                    .get("replica")
                    .and_then(Json::as_usize)
                    .with_context(|| format!("serve[{i}].replica"))?,
                kind,
            });
        }
        let recovery = match j.get("recovery") {
            None => RecoveryPolicy::default(),
            Some(r) => RecoveryPolicy {
                request_deadline_ms: r
                    .get("request_deadline_ms")
                    .and_then(Json::as_u64)
                    .context("recovery.request_deadline_ms")?,
                max_attempts: r
                    .get("max_attempts")
                    .and_then(Json::as_u32)
                    .context("recovery.max_attempts")?,
                backoff_ms: r
                    .get("backoff_ms")
                    .and_then(Json::as_u64)
                    .context("recovery.backoff_ms")?,
                watchdog_ms: r
                    .get("watchdog_ms")
                    .and_then(Json::as_u64)
                    .context("recovery.watchdog_ms")?,
                admission_max_outstanding: r
                    .get("admission_max_outstanding")
                    .and_then(Json::as_usize)
                    .context("recovery.admission_max_outstanding")?,
            },
        };
        let plan = Self { seed, hbm, throttle, links, replicas, serve, recovery };
        plan.validate().context("fault plan failed validation")?;
        Ok(plan)
    }

    /// Write the artifact as pretty-printed JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        self.validate().context("refusing to save an invalid fault plan")?;
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing fault plan {}", path.display()))
    }

    /// Load and validate an artifact written by [`Self::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing fault plan {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("loading fault plan {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_preset_round_trips_byte_identically() {
        let p = FaultPlan::chaos_preset(42);
        let j = p.to_json();
        let back = FaultPlan::from_json(&j).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.to_json().to_string(), j.to_string(), "stable re-serialization");
    }

    #[test]
    fn empty_sections_are_omitted_and_default_on_load() {
        let p = FaultPlan::new(7);
        let s = p.to_json().to_string();
        assert!(!s.contains("\"hbm\""), "{s}");
        assert!(!s.contains("\"links\""), "{s}");
        let back = FaultPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.recovery, RecoveryPolicy::default());
    }

    #[test]
    fn format_tag_is_enforced() {
        let mut j = FaultPlan::new(1).to_json();
        j.set("format", "h2pipe.faults/v999");
        let err = FaultPlan::from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported fault format"), "{err:#}");
        let err = FaultPlan::from_json(&Json::obj()).unwrap_err();
        assert!(format!("{err:#}").contains("missing \"format\""), "{err:#}");
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut p = FaultPlan::new(1);
        p.hbm = Some(HbmFaultSpec { start: 10, end: 10, prob: 0.5, max_replays: 2 });
        assert!(p.validate().is_err(), "empty window");
        p.hbm = Some(HbmFaultSpec { start: 0, end: 10, prob: 1.5, max_replays: 2 });
        assert!(p.validate().is_err(), "prob > 1");
        p.hbm = None;
        p.throttle.push(ThrottleWindow { pc: 0, start: 0, end: 10, deny: 8, period: 8 });
        assert!(p.validate().is_err(), "deny == period is an outage");
        p.throttle.clear();
        p.recovery.max_attempts = 0;
        assert!(p.validate().is_err(), "zero attempts");
    }

    #[test]
    fn throttle_window_denies_deterministically() {
        let t = ThrottleWindow { pc: 0, start: 100, end: 200, deny: 2, period: 8 };
        assert!(!t.denies(99), "before window");
        assert!(t.denies(104), "104 % 8 == 0 < 2");
        assert!(t.denies(105), "105 % 8 == 1 < 2");
        assert!(!t.denies(106), "106 % 8 == 2");
        assert!(!t.denies(200), "after window");
    }

    #[test]
    fn save_load_round_trip() {
        let p = FaultPlan::chaos_preset(9);
        let path = std::env::temp_dir().join("h2pipe_fault_plan_test.json");
        p.save(&path).unwrap();
        let back = FaultPlan::load(&path).unwrap();
        assert_eq!(back, p);
        let _ = std::fs::remove_file(&path);
    }
}
