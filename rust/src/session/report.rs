//! The terminal stage: one report type for every deployment target.
//!
//! `RunReport` subsumes the previous ad-hoc outputs (`SimReport` printed
//! by `simulate`, `FleetReport`/`FleetServeReport` JSON printed by
//! `serve`): the headline scalars live at the top level with identical
//! keys across targets, and the target-specific payload is embedded
//! verbatim under `detail`, so downstream tooling can diff/plot any run
//! of any kind with one scraper.

use crate::util::Json;
use crate::verify::{Diagnostic, Severity};

/// Unified result of running a [`crate::session::Deployment`].
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Model name (from the artifact's provenance).
    pub model: String,
    /// Device name (from the artifact's provenance).
    pub device: String,
    /// Deployment target kind: `"simulate"`, `"fleet"` or `"serve"`.
    pub target: String,
    /// Provenance options hash — ties every report back to the exact
    /// compiler configuration that produced its plan.
    pub options_hash: u64,
    /// Headline throughput in images/s (steady-state sim rate, fleet
    /// aggregate, or wall-clock serving rate, per target).
    pub throughput: f64,
    /// Headline latency in milliseconds (first-image pipeline latency for
    /// simulations, mean client latency for serving).
    pub latency_ms: f64,
    /// Target-specific payload (`SimReport`/`FleetReport`/
    /// `FleetServeReport` JSON).
    pub detail: Json,
    /// Flight-recorder profile summary (`obs::Recorder::profile`) when the
    /// run was traced; `Json::Null` (and omitted from the JSON form)
    /// otherwise, so untraced reports are byte-identical to before.
    pub profile: Json,
    /// Findings from the automatic post-compile verifier pass
    /// (`h2pipe check` run over the artifact before execution). Empty
    /// for a clean plan.
    pub diagnostics: Vec<Diagnostic>,
}

impl RunReport {
    /// Machine-scrapable form: headline scalars + embedded detail.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", self.model.as_str())
            .set("device", self.device.as_str())
            .set("target", self.target.as_str())
            .set("options_hash", format!("{:016x}", self.options_hash))
            .set("throughput", self.throughput)
            .set("latency_ms", self.latency_ms)
            .set("detail", self.detail.clone());
        if !matches!(self.profile, Json::Null) {
            o.set("profile", self.profile.clone());
        }
        let mut diags = Json::Arr(Vec::new());
        for d in &self.diagnostics {
            diags.push(d.to_json());
        }
        o.set("diagnostics", diags);
        o
    }

    /// One human-readable headline line; appends the verifier finding
    /// count when the post-compile check was not clean.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} [{}] on {}: {:.0} im/s, {:.2} ms (options {:016x})",
            self.model, self.target, self.device, self.throughput, self.latency_ms,
            self.options_hash
        );
        if !self.diagnostics.is_empty() {
            let errors =
                self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count();
            let warns =
                self.diagnostics.iter().filter(|d| d.severity == Severity::Warn).count();
            s.push_str(&format!(" — check: {errors} error(s), {warns} warning(s)"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_and_summary_carry_headlines() {
        let r = RunReport {
            model: "ResNet-18".into(),
            device: "Stratix 10 NX2100".into(),
            target: "simulate".into(),
            options_hash: 0xdead_beef,
            throughput: 4174.0,
            latency_ms: 1.25,
            detail: Json::obj(),
            profile: Json::Null,
            diagnostics: Vec::new(),
        };
        let j = r.to_json().to_string();
        assert!(!j.contains("\"profile\""), "null profile must be omitted: {j}");
        assert!(j.contains("\"target\":\"simulate\""), "{j}");
        assert!(j.contains("\"throughput\":4174"), "{j}");
        assert!(j.contains("\"options_hash\":\"00000000deadbeef\""), "{j}");
        assert!(r.summary().contains("4174 im/s"));
    }
}
