//! The deployment stage: run a [`CompiledModel`] on a chosen target.
//!
//! One enum picks between the three execution paths that used to be wired
//! by hand per CLI subcommand:
//!
//! * [`DeploymentTarget::SingleDevice`] — the cycle-level pipeline
//!   simulator on one FPGA;
//! * [`DeploymentTarget::Fleet`] — shard via [`crate::cluster::partition`]
//!   and co-simulate the shards with credit-based inter-device links;
//! * [`DeploymentTarget::Serve`] — live serving through replica
//!   [`crate::coordinator::InferenceServer`]s behind the
//!   [`crate::cluster::FleetRouter`], with the modelled FPGA rate derived
//!   from the compiled plan (or a sharded partition of it).
//!
//! Every path terminates in the same [`RunReport`].

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::cluster::{partition, FleetConfig, FleetRouter, FleetSim, PartitionOptions};
use crate::coordinator::ServerConfig;
use crate::faults::FaultPlan;
use crate::obs::{MetricsServer, Recorder};
use crate::session::compiled::CompiledModel;
use crate::session::report::RunReport;
use crate::sim::pipeline::{PipelineSim, SimConfig};
use crate::util::XorShift64;

/// Flight-recorder / trace-export options (`--trace`, `--trace-window`).
///
/// Attached to a [`Deployment`] with [`Deployment::with_trace`]: the run
/// executes with an `obs::Recorder` probe, the Chrome/Perfetto JSON and/or
/// CSV renderings are written to the given paths, and the [`RunReport`]
/// gains the recorder's `profile` summary.
#[derive(Debug, Clone)]
pub struct TraceOptions {
    /// Chrome/Perfetto `trace_event` JSON output path.
    pub json_path: Option<String>,
    /// Compact CSV output path (cycle-domain targets only).
    pub csv_path: Option<String>,
    /// Sampling window in core cycles.
    pub window: u64,
}

impl Default for TraceOptions {
    fn default() -> Self {
        Self { json_path: None, csv_path: None, window: 4096 }
    }
}

/// Serving parameters for [`DeploymentTarget::Serve`].
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Built-in reference-backend model executed for numerics (the
    /// compiled plan supplies the modelled FPGA timing).
    pub serve_model: String,
    /// Artifact directory for the runtime backend.
    pub artifact_dir: String,
    /// Total requests to drive through the fleet.
    pub requests: usize,
    /// Dynamic batch size per replica.
    pub batch: usize,
    /// Replica servers behind the router.
    pub replicas: usize,
    /// When > 1, the modelled FPGA rate comes from a pipeline-parallel
    /// partition of the compiled network into this many shards.
    pub shards: usize,
    /// Closed-loop client threads generating the request stream.
    pub clients: usize,
    /// RNG seed for the synthetic request images.
    pub seed: u64,
    /// Explicit modelled per-image service time override (e.g. a cycle
    /// sim's measured rate); `None` derives it from the plan/partition.
    pub modelled_image_s: Option<f64>,
    /// When set, expose live Prometheus metrics on `127.0.0.1:port` for
    /// the duration of the run (`serve --metrics-port`; 0 = any free
    /// port).
    pub metrics_port: Option<u16>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            serve_model: "cifarnet".to_string(),
            artifact_dir: "artifacts".to_string(),
            requests: 64,
            batch: 8,
            replicas: 1,
            shards: 1,
            clients: 1,
            seed: 7,
            modelled_image_s: None,
            metrics_port: None,
        }
    }
}

/// Where (and how) to run a compiled model.
#[derive(Debug, Clone)]
pub enum DeploymentTarget {
    /// Single-device cycle simulation.
    SingleDevice(SimConfig),
    /// Multi-FPGA sharded co-simulation.
    Fleet { partition: PartitionOptions, fleet: FleetConfig },
    /// Live serving through the fleet router.
    Serve(ServeOptions),
}

/// A compiled model bound to a deployment target; [`Deployment::run`]
/// executes it and produces the unified [`RunReport`].
#[derive(Debug)]
pub struct Deployment<'a> {
    compiled: &'a CompiledModel,
    target: DeploymentTarget,
    trace: Option<TraceOptions>,
    faults: Option<FaultPlan>,
}

impl<'a> Deployment<'a> {
    pub(crate) fn new(compiled: &'a CompiledModel, target: DeploymentTarget) -> Self {
        Self { compiled, target, trace: None, faults: None }
    }

    /// Attach flight-recorder tracing to this deployment (see
    /// [`TraceOptions`]).
    pub fn with_trace(mut self, trace: TraceOptions) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Arm a fault-injection plan (`--faults f.json`) for this
    /// deployment. Cycle-domain sections drive the simulators; serve
    /// sections drive the router's crash/recovery machinery. The plan is
    /// validated at run time; an empty plan is a healthy run that still
    /// reports the (all-zero) fault ledger.
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = Some(faults);
        self
    }

    pub fn target(&self) -> &DeploymentTarget {
        &self.target
    }

    /// Execute the deployment.
    pub fn run(&self) -> Result<RunReport> {
        match &self.target {
            DeploymentTarget::SingleDevice(cfg) => self.run_single(cfg),
            DeploymentTarget::Fleet { partition, fleet } => self.run_fleet(partition, fleet),
            DeploymentTarget::Serve(opts) => self.run_serve(opts),
        }
    }

    fn report(
        &self,
        target: &str,
        throughput: f64,
        latency_ms: f64,
        detail: crate::util::Json,
    ) -> RunReport {
        let prov = self.compiled.provenance();
        // Automatic post-compile verification: every deployment report
        // carries the static checker's findings so analytically suspect
        // plans surface even when the run itself succeeds.
        let diagnostics = crate::verify::check_artifact(self.compiled).diagnostics;
        RunReport {
            model: prov.model.clone(),
            device: prov.device.clone(),
            target: target.to_string(),
            options_hash: prov.options_hash,
            throughput,
            latency_ms,
            detail,
            profile: crate::util::Json::Null,
            diagnostics,
        }
    }

    /// Write the recorder's trace renderings to the paths in `t`.
    fn write_trace(&self, t: &TraceOptions, rec: &Recorder) -> Result<()> {
        let d = &self.compiled.plan().device;
        if let Some(path) = &t.json_path {
            let j = crate::obs::trace::chrome_trace(rec, d.core_mhz, d.hbm.controller_mhz);
            std::fs::write(path, j.to_string())
                .with_context(|| format!("writing trace JSON to {path}"))?;
        }
        if let Some(path) = &t.csv_path {
            std::fs::write(path, crate::obs::trace::csv(rec))
                .with_context(|| format!("writing trace CSV to {path}"))?;
        }
        Ok(())
    }

    fn run_single(&self, cfg: &SimConfig) -> Result<RunReport> {
        match (&self.trace, &self.faults) {
            (None, None) => {
                let rep = self.compiled.simulate(cfg)?;
                Ok(self.report("simulate", rep.throughput, rep.latency * 1e3, rep.to_json()))
            }
            (trace, faults) => {
                let mut sim =
                    PipelineSim::new(self.compiled.network(), self.compiled.plan())?;
                if let Some(fp) = faults {
                    fp.validate()?;
                    sim.apply_faults(fp);
                }
                match trace {
                    None => {
                        let rep = sim.run(cfg)?;
                        Ok(self.report(
                            "simulate",
                            rep.throughput,
                            rep.latency * 1e3,
                            rep.to_json(),
                        ))
                    }
                    Some(t) => {
                        let mut rec = Recorder::new(t.window);
                        let rep = sim.run_probed(cfg, &mut rec)?;
                        let mut run = self.report(
                            "simulate",
                            rep.throughput,
                            rep.latency * 1e3,
                            rep.to_json(),
                        );
                        run.profile = rec.profile();
                        self.write_trace(t, &rec)?;
                        Ok(run)
                    }
                }
            }
        }
    }

    fn run_fleet(&self, popts: &PartitionOptions, fcfg: &FleetConfig) -> Result<RunReport> {
        let plan = self.compiled.plan();
        let pp = partition(self.compiled.network(), &plan.device, &plan.options, popts)
            .context("partitioning for fleet deployment")?;
        let mut fleet = FleetSim::new(&pp)?;
        if let Some(fp) = &self.faults {
            fleet.apply_faults(fp).context("arming the fault plan on the fleet")?;
        }
        let mut rec = self.trace.as_ref().map(|t| Recorder::new(t.window));
        let rep = match rec.as_mut() {
            None => fleet.run(fcfg)?,
            Some(r) => fleet.run_probed(fcfg, r)?,
        };
        let mut detail = rep.to_json();
        detail.set("est_throughput", pp.est_throughput());
        let mut run = self.report("fleet", rep.aggregate_throughput, rep.latency * 1e3, detail);
        if let (Some(t), Some(r)) = (&self.trace, &rec) {
            run.profile = r.profile();
            self.write_trace(t, r)?;
        }
        Ok(run)
    }

    fn run_serve(&self, opts: &ServeOptions) -> Result<RunReport> {
        ensure!(opts.replicas >= 1, "need at least one replica");
        ensure!(opts.clients >= 1, "need at least one client");
        let plan = self.compiled.plan();

        let mut cfg = ServerConfig::builtin(&opts.serve_model, &opts.artifact_dir)?;
        cfg.batch_size = opts.batch;
        // Modelled FPGA service time: explicit override, a sharded
        // partition's bound, or the compiled plan's estimate.
        let modelled_src = match opts.modelled_image_s {
            Some(v) => {
                cfg.modelled_image_s = v;
                "override".to_string()
            }
            None if opts.shards > 1 => {
                let pp = partition(
                    self.compiled.network(),
                    &plan.device,
                    &plan.options,
                    &PartitionOptions { shards: Some(opts.shards), max_shards: opts.shards },
                )
                .context("partitioning for the modelled serving rate")?;
                let est = pp.est_throughput();
                cfg.modelled_image_s = if est > 0.0 { 1.0 / est } else { 0.0 };
                format!("{}-shard partition", opts.shards)
            }
            None => {
                cfg = cfg.with_modelled_plan(plan);
                "compiled plan".to_string()
            }
        };
        let pixels: usize = cfg.input_dims.iter().product();

        let router = Arc::new(match &self.faults {
            None => FleetRouter::start_with_tracing(cfg, opts.replicas, self.trace.is_some())?,
            Some(fp) => {
                FleetRouter::start_with_faults(cfg, opts.replicas, self.trace.is_some(), fp)?
            }
        });
        // Live Prometheus exposition for the duration of the run. The
        // server's closure holds its own Arc over the router, so it must
        // be stopped before the router can be unwrapped for shutdown.
        let metrics_srv = match opts.metrics_port {
            None => None,
            Some(port) => {
                let r = router.clone();
                let srv = MetricsServer::start(port, Arc::new(move || r.prometheus()))
                    .context("starting the metrics endpoint")?;
                eprintln!("metrics: http://{}/metrics", srv.addr());
                Some(srv)
            }
        };
        // Spread requests over the clients without dropping the remainder:
        // the first `requests % clients` threads take one extra.
        let base = opts.requests / opts.clients;
        let rem = opts.requests % opts.clients;
        let mut handles = Vec::new();
        for t in 0..opts.clients {
            let r = router.clone();
            let seed = opts.seed.wrapping_add(t as u64);
            let per_client = base + usize::from(t < rem);
            handles.push(std::thread::spawn(move || {
                let mut rng = XorShift64::new(seed);
                let mut ok = 0usize;
                for _ in 0..per_client {
                    let img: Vec<i32> =
                        (0..pixels).map(|_| rng.next_range(0, 255) as i32 - 128).collect();
                    if r.infer(img).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let mut ok = 0usize;
        for h in handles {
            ok += h.join().expect("serve client thread panicked");
        }
        if let Some(srv) = metrics_srv {
            srv.stop();
        }
        let rep = Arc::into_inner(router)
            .expect("all clients joined and the metrics endpoint stopped")
            .shutdown();

        // Serving traces are wall-clock request spans (the cycle-domain
        // CSV form does not apply here).
        if let Some(t) = &self.trace {
            if let Some(path) = &t.json_path {
                let j = crate::obs::trace::chrome_serve_trace(&rep.request_spans, opts.replicas);
                std::fs::write(path, j.to_string())
                    .with_context(|| format!("writing serve trace JSON to {path}"))?;
            }
        }

        let mut detail = rep.to_json();
        detail
            .set("serve_model", opts.serve_model.as_str())
            .set("submitted", opts.requests)
            .set("ok", ok)
            .set("shards", opts.shards)
            .set("modelled_source", modelled_src);
        Ok(self.report("serve", rep.wall_throughput, rep.mean_latency_ms, detail))
    }
}
