//! The compiled artifact stage: a plan plus the network it was compiled
//! from plus provenance, persistable as a JSON document.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::compiler::AcceleratorPlan;
use crate::config::{EfficiencyTable, WeightPlacement};
use crate::coordinator::{boot_weights, BootReport};
use crate::nn::Network;
use crate::session::codec;
use crate::session::deploy::{Deployment, DeploymentTarget};
use crate::sim::pipeline::{SimConfig, SimReport};
use crate::util::Json;

/// Artifact format tag; bump on incompatible schema changes.
pub const PLAN_FORMAT: &str = "h2pipe.plan/v1";

/// Where a compiled model came from: enough to reproduce (or refuse to
/// trust) an artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Provenance {
    /// Model name (a zoo name for built-ins, the network name otherwise).
    pub model: String,
    /// Device the plan targets.
    pub device: String,
    /// FNV-1a hash over the serialized `CompilerOptions` (including the
    /// HBM efficiency calibration table).
    pub options_hash: u64,
}

/// A compiled H2PIPE instance: the [`AcceleratorPlan`], the network IR it
/// was compiled from, and provenance. This is the pipeline's central
/// artifact — everything downstream ([`Deployment`] simulation, fleet
/// sharding, serving) consumes it, and it round-trips through JSON
/// bit-for-bit so `h2pipe compile --out plan.json` followed by
/// `h2pipe simulate --plan plan.json` reproduces the in-memory path.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    pub(crate) network: Network,
    pub(crate) plan: AcceleratorPlan,
    pub(crate) provenance: Provenance,
}

impl CompiledModel {
    /// The network IR this plan was compiled from.
    pub fn network(&self) -> &Network {
        &self.network
    }

    /// The compiled accelerator plan.
    pub fn plan(&self) -> &AcceleratorPlan {
        &self.plan
    }

    pub fn provenance(&self) -> &Provenance {
        &self.provenance
    }

    /// The HBM read-efficiency calibration the plan was compiled with.
    pub fn efficiency_table(&self) -> &EfficiencyTable {
        &self.plan.options.efficiency
    }

    /// Stage transition: pick a deployment target for this artifact.
    pub fn deploy(&self, target: DeploymentTarget) -> Deployment<'_> {
        Deployment::new(self, target)
    }

    /// Typed single-device cycle simulation (the [`Deployment`] route
    /// wraps this into a unified [`crate::session::RunReport`]).
    pub fn simulate(&self, cfg: &SimConfig) -> Result<SimReport> {
        crate::sim::pipeline::PipelineSim::new(&self.network, &self.plan)?.run(cfg)
    }

    /// [`Self::simulate`] with an observability probe attached (the
    /// flight-recorder path behind `simulate --trace`).
    pub fn simulate_probed(
        &self,
        cfg: &SimConfig,
        probe: &mut dyn crate::obs::Probe,
    ) -> Result<SimReport> {
        crate::sim::pipeline::PipelineSim::new(&self.network, &self.plan)?.run_probed(cfg, probe)
    }

    /// §IV-C boot-time weight download for this plan.
    pub fn boot(&self) -> BootReport {
        boot_weights(&self.plan)
    }

    /// One line per weight layer: placement, parallelism and PC slots —
    /// the compiler's offload decisions in a diffable, golden-snapshot
    /// friendly form.
    pub fn offload_fingerprint(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "# {} burst_len={}", self.plan.network, self.plan.burst_len);
        for l in &self.plan.layers {
            if !l.stats.has_weights {
                continue;
            }
            let place = match l.placement {
                WeightPlacement::Hbm => "hbm ",
                WeightPlacement::OnChip => "chip",
            };
            let _ = writeln!(
                s,
                "{:<28} {place} p_i={} p_o={} pcs={:?}",
                l.stats.name, l.par.p_i, l.par.p_o, l.pcs
            );
        }
        s
    }

    /// Serialize the whole artifact (envelope + network + plan).
    pub fn to_json(&self) -> Json {
        let mut prov = Json::obj();
        prov.set("model", self.provenance.model.as_str())
            .set("device", self.provenance.device.as_str())
            .set("options_hash", format!("{:016x}", self.provenance.options_hash));
        let mut o = Json::obj();
        o.set("format", PLAN_FORMAT)
            .set("provenance", prov)
            .set("network", codec::network_to_json(&self.network))
            .set("plan", codec::plan_to_json(&self.plan));
        o
    }

    /// Decode an artifact without running the verifier. Schema errors
    /// (wrong format tag, missing fields) still fail hard; everything
    /// that *decodes* is returned, however inconsistent. This is the
    /// entry point for `h2pipe check --plan`, which must be able to load
    /// a broken artifact in order to diagnose it.
    pub fn from_json_unchecked(j: &Json) -> Result<Self> {
        match j.get("format").and_then(Json::as_str) {
            Some(PLAN_FORMAT) => {}
            Some(other) => bail!("unsupported plan format {other:?} (expected {PLAN_FORMAT:?})"),
            None => bail!("not a plan artifact (missing \"format\" tag)"),
        }
        let prov = j.get("provenance").context("missing provenance")?;
        let hash_hex = prov
            .get("options_hash")
            .and_then(Json::as_str)
            .context("missing provenance.options_hash")?;
        let options_hash = u64::from_str_radix(hash_hex, 16)
            .with_context(|| format!("bad options hash {hash_hex:?}"))?;
        let provenance = Provenance {
            model: prov
                .get("model")
                .and_then(Json::as_str)
                .context("missing provenance.model")?
                .to_string(),
            device: prov
                .get("device")
                .and_then(Json::as_str)
                .context("missing provenance.device")?
                .to_string(),
            options_hash,
        };
        let network =
            codec::network_from_json(j.get("network").context("missing network")?)
                .context("decoding artifact network")?;
        let plan = codec::plan_from_json(j.get("plan").context("missing plan")?)
            .context("decoding artifact plan")?;
        Ok(Self { network, plan, provenance })
    }

    /// Decode and integrity-check an artifact.
    ///
    /// The integrity gate is the verifier's tamper subset
    /// ([`crate::verify::Code::is_integrity`]): stored usage that does
    /// not recompute, an options hash that does not match the embedded
    /// options, or provenance/network identity mismatches refuse to
    /// load. Feasibility findings (overcommit, bandwidth, deadlock, …)
    /// do NOT block loading — they describe a well-formed but bad plan,
    /// and are reported by [`Self::verify`] / `h2pipe check` instead.
    pub fn from_json(j: &Json) -> Result<Self> {
        let cm = Self::from_json_unchecked(j)?;
        let integrity: Vec<_> = crate::verify::check_artifact(&cm)
            .diagnostics
            .into_iter()
            .filter(|d| d.code.is_integrity())
            .collect();
        if !integrity.is_empty() {
            let mut msg = String::from("artifact failed integrity verification:");
            for d in &integrity {
                msg.push('\n');
                msg.push_str(&d.render());
            }
            bail!(msg);
        }
        Ok(cm)
    }

    /// Run the full static verifier over this artifact (all rule
    /// families, not just the integrity subset).
    pub fn verify(&self) -> crate::verify::Report {
        crate::verify::check_artifact(self)
    }

    /// Assemble from parts without verification — the entry point for
    /// plan generators (autotuners, test fixtures) that mutate a decoded
    /// plan and re-serialize it. Pair with [`Self::verify`].
    pub fn from_parts(network: Network, plan: AcceleratorPlan, provenance: Provenance) -> Self {
        Self { network, plan, provenance }
    }

    /// Decompose into parts for mutation; inverse of [`Self::from_parts`].
    pub fn into_parts(self) -> (Network, AcceleratorPlan, Provenance) {
        (self.network, self.plan, self.provenance)
    }

    /// Write the artifact as pretty-printed JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing plan artifact {}", path.display()))
    }

    /// Load and integrity-check an artifact written by [`Self::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan artifact {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing plan artifact {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("loading plan artifact {}", path.display()))
    }

    /// Load without the integrity gate — for `h2pipe check --plan`,
    /// which diagnoses broken artifacts instead of refusing them.
    pub fn load_unchecked(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan artifact {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing plan artifact {}", path.display()))?;
        Self::from_json_unchecked(&j)
            .with_context(|| format!("loading plan artifact {}", path.display()))
    }
}
