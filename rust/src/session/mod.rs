//! The typed end-to-end pipeline API.
//!
//! H2PIPE's value is one flow — network IR → Algorithm 1 weight placement
//! → FIFO sizing → pipelined execution — but the crate historically
//! exposed it as disconnected free functions that every caller re-wired
//! by hand. This module redesigns the public surface around staged types,
//! in the spirit of HPIPE's domain-specific compiler (whose output
//! artifact drives everything downstream) and FINN-style flows (staged
//! transformations over one serializable design artifact):
//!
//! ```text
//! Session::builder()            model + DeviceConfig + CompilerOptions
//!        |                      + burst/offload/efficiency knobs
//!        v  .compile()
//! CompiledModel                 AcceleratorPlan + network IR + provenance
//!        |                      (save()/load(): persistable JSON artifact,
//!        |                       bit-for-bit round trip)
//!        v  .deploy(target)
//! Deployment                    SingleDevice sim | Fleet shard co-sim
//!        |                      | live Serve behind the FleetRouter
//!        v  .run()
//! RunReport                     unified headline scalars + per-target
//!                               detail JSON
//! ```
//!
//! A saved `CompiledModel` is a reproducible, diffable experiment
//! artifact: `h2pipe compile --model resnet50 --out plan.json` followed
//! by `h2pipe simulate --plan plan.json` produces a report identical to
//! the in-memory `h2pipe simulate --model resnet50` path. See DESIGN.md
//! §"Session API" for the artifact schema.
//!
//! The pre-session free functions ([`crate::compiler::compile`],
//! [`crate::sim::pipeline::simulate`], [`crate::coordinator::boot_weights`],
//! ...) remain as the underlying engines for benches and low-level
//! callers, but new code should enter through [`Session::builder`].

mod builder;
pub mod codec;
mod compiled;
mod deploy;
mod report;

pub use builder::{Session, SessionBuilder};
pub use compiled::{CompiledModel, Provenance, PLAN_FORMAT};
pub use deploy::{Deployment, DeploymentTarget, ServeOptions, TraceOptions};
pub use report::RunReport;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BurstLengthPolicy, EfficiencyTable};
    use crate::sim::pipeline::SimConfig;

    #[test]
    fn builder_requires_a_model() {
        let err = Session::builder().compile().unwrap_err();
        assert!(format!("{err:#}").contains("no model set"), "{err:#}");
    }

    #[test]
    fn builder_rejects_unknown_zoo_name() {
        let err = Session::builder().model("alexnet").compile().unwrap_err();
        assert!(format!("{err:#}").contains("alexnet"), "{err:#}");
    }

    #[test]
    fn builder_validates_knobs() {
        let err = Session::builder().model("resnet18").fixed_burst(3).compile().unwrap_err();
        assert!(format!("{err:#}").contains("burst"), "{err:#}");
    }

    #[test]
    fn compile_carries_provenance_and_efficiency_table() {
        let cm = Session::builder().model("resnet18").compile().unwrap();
        assert_eq!(cm.provenance().model, "ResNet-18");
        assert_eq!(cm.provenance().device, "Stratix 10 NX2100");
        assert_eq!(cm.efficiency_table(), &EfficiencyTable::calibrated());
        assert_eq!(
            cm.provenance().options_hash,
            codec::options_hash(&cm.plan().options),
            "hash must cover the exact options embedded in the plan"
        );
    }

    #[test]
    fn artifact_json_round_trips_in_memory() {
        let cm = Session::builder()
            .model("resnet18")
            .burst_policy(BurstLengthPolicy::Fixed(8))
            .compile()
            .unwrap();
        let j = cm.to_json();
        let back = CompiledModel::from_json(&j).unwrap();
        assert_eq!(back.to_json().to_string(), j.to_string(), "stable re-serialization");
        assert_eq!(back.offload_fingerprint(), cm.offload_fingerprint());
        assert_eq!(back.plan().est_throughput, cm.plan().est_throughput);
    }

    #[test]
    fn from_json_rejects_wrong_format_and_tampering() {
        let cm = Session::builder().model("resnet18").compile().unwrap();
        let mut j = cm.to_json();
        j.set("format", "h2pipe.plan/v999");
        assert!(CompiledModel::from_json(&j).is_err(), "unknown format version");

        // tamper with the resource usage: integrity check must trip
        let mut j = cm.to_json();
        let mut plan = j.get("plan").unwrap().clone();
        let mut usage = plan.get("usage").unwrap().clone();
        usage.set("m20k", 1u64);
        plan.set("usage", usage);
        j.set("plan", plan);
        let err = CompiledModel::from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("recompute"), "{err:#}");
    }

    #[test]
    fn deployment_single_device_report() {
        let cm = Session::builder().model("resnet18").compile().unwrap();
        let rep = cm
            .deploy(DeploymentTarget::SingleDevice(SimConfig {
                images: 3,
                warmup_images: 1,
                ..SimConfig::default()
            }))
            .run()
            .unwrap();
        assert_eq!(rep.target, "simulate");
        assert_eq!(rep.model, "ResNet-18");
        assert!(rep.throughput > 500.0, "{}", rep.throughput);
        let j = rep.to_json().to_string();
        assert!(j.contains("\"target\":\"simulate\""), "{j}");
        assert!(j.contains("\"engines\""), "detail must embed the sim payload: {j}");
    }

    #[test]
    fn traced_deployment_embeds_profile_and_writes_trace() {
        let cm = Session::builder().model("resnet18").compile().unwrap();
        let path = std::env::temp_dir().join("h2pipe_session_trace_test.json");
        let rep = cm
            .deploy(DeploymentTarget::SingleDevice(SimConfig {
                images: 3,
                warmup_images: 1,
                ..SimConfig::default()
            }))
            .with_trace(TraceOptions {
                json_path: Some(path.display().to_string()),
                csv_path: None,
                window: 2048,
            })
            .run()
            .unwrap();
        assert!(!matches!(rep.profile, crate::util::Json::Null), "traced run carries a profile");
        let j = rep.to_json().to_string();
        assert!(j.contains("\"profile\""), "{j}");
        assert!(j.contains("\"bottlenecks\""), "{j}");
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::Json::parse(&text).unwrap();
        assert!(parsed.get("traceEvents").is_some(), "trace file must be valid trace JSON");
        let _ = std::fs::remove_file(&path);
    }
}
