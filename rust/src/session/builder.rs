//! The entry stage: gather model + device + compiler knobs, then compile
//! into a [`CompiledModel`].

use anyhow::{bail, Context, Result};

use crate::config::{
    BurstLengthPolicy, CompilerOptions, DeviceConfig, EfficiencyTable, FlowControl,
};
use crate::nn::{zoo, Network};
use crate::session::codec;
use crate::session::compiled::{CompiledModel, Provenance};

/// Entry point of the typed pipeline:
/// `Session::builder() -> CompiledModel -> Deployment -> RunReport`.
#[derive(Debug)]
pub struct Session;

impl Session {
    /// Start a new pipeline: pick a model, a device and compiler options,
    /// then [`SessionBuilder::compile`].
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            source: ModelSource::Unset,
            device: DeviceConfig::stratix10_nx2100(),
            options: CompilerOptions::default(),
        }
    }
}

#[derive(Debug)]
enum ModelSource {
    Unset,
    Zoo(String),
    Custom(Network),
}

/// Accumulates the compile-stage inputs. Defaults: the paper's Stratix 10
/// NX2100 testbed and default [`CompilerOptions`]; the model must be set.
#[derive(Debug)]
pub struct SessionBuilder {
    source: ModelSource,
    device: DeviceConfig,
    options: CompilerOptions,
}

impl SessionBuilder {
    /// Use a model-zoo network by name (resolved at compile time, so an
    /// unknown name fails with the list of valid ones).
    pub fn model(mut self, name: &str) -> Self {
        self.source = ModelSource::Zoo(name.to_string());
        self
    }

    /// Use a custom network IR.
    pub fn network(mut self, net: Network) -> Self {
        self.source = ModelSource::Custom(net);
        self
    }

    pub fn device(mut self, device: DeviceConfig) -> Self {
        self.device = device;
        self
    }

    /// Replace the whole option set (individual knobs below tweak it).
    pub fn options(mut self, options: CompilerOptions) -> Self {
        self.options = options;
        self
    }

    /// The paper's all-HBM configuration (offload everything bandwidth
    /// allows) instead of the hybrid Algorithm 1 memory system.
    pub fn all_hbm(mut self, yes: bool) -> Self {
        self.options.all_hbm = yes;
        self
    }

    /// Force a fixed HBM burst length (legal values: 1,2,4,8,16,32;
    /// validated at compile time).
    pub fn fixed_burst(mut self, burst_len: u32) -> Self {
        self.options.burst_length = BurstLengthPolicy::Fixed(burst_len);
        self
    }

    pub fn burst_policy(mut self, policy: BurstLengthPolicy) -> Self {
        self.options.burst_length = policy;
        self
    }

    /// §IV-C boot write-path width in bits.
    pub fn write_path_bits(mut self, bits: u32) -> Self {
        self.options.write_path_bits = bits;
        self
    }

    /// Override the HBM read-efficiency calibration (fig3a recalibration).
    pub fn efficiency_table(mut self, table: EfficiencyTable) -> Self {
        self.options.efficiency = table;
        self
    }

    /// Weight-network flow control. [`FlowControl::Credit`] (default) is
    /// the §V-A deadlock fix; [`FlowControl::ReadyValid`] reproduces the
    /// Fig. 5 hazard and is flagged by the verifier (H2P030).
    pub fn flow_control(mut self, flow: FlowControl) -> Self {
        self.options.flow_control = flow;
        self
    }

    /// Last-stage weight-FIFO depth in 80-bit words (§IV-A default 512;
    /// must be a power of two). Shallower FIFOs save M20Ks but trip the
    /// H2P040 latency-coverage bound when HBM layers exist.
    pub fn last_stage_fifo_depth(mut self, depth: u32) -> Self {
        self.options.last_stage_fifo_depth = depth;
        self
    }

    /// HPIPE-style assumed weight sparsity in `[0, 1)`: discounts the
    /// Eq. 1 score numerator, re-ranking Algorithm 1's offload order
    /// without changing dense storage accounting.
    pub fn sparsity_fraction(mut self, sparsity: f64) -> Self {
        self.options.sparsity_fraction = sparsity;
        self
    }

    /// Force per-layer placements after Algorithm 1 (the autotuner's
    /// offload-flip axis). Indices must be strictly increasing and name
    /// weight layers; violations fail at compile time.
    pub fn offload_overrides(mut self, overrides: Vec<(usize, bool)>) -> Self {
        self.options.offload_overrides = overrides;
        self
    }

    /// Run the H2PIPE compiler, producing the persistable artifact stage.
    pub fn compile(self) -> Result<CompiledModel> {
        let net = match self.source {
            ModelSource::Unset => bail!(
                "no model set: call SessionBuilder::model(\"resnet50\" | ...) or \
                 SessionBuilder::network(net)"
            ),
            ModelSource::Zoo(name) => zoo::by_name(&name).with_context(|| {
                format!(
                    "unknown zoo model {name:?} (try resnet18, resnet50, vgg16, \
                     mobilenetv1, mobilenetv2, mobilenetv3, mobilenet_edge)"
                )
            })?,
            ModelSource::Custom(net) => net,
        };
        self.options.validate()?;
        let plan = crate::compiler::compile(&net, &self.device, &self.options)
            .with_context(|| format!("compiling {}", net.name))?;
        let provenance = Provenance {
            model: net.name.clone(),
            device: self.device.name.clone(),
            options_hash: codec::options_hash(&self.options),
        };
        Ok(CompiledModel { network: net, plan, provenance })
    }
}
