//! JSON wire format for plan artifacts.
//!
//! Every type a [`crate::session::CompiledModel`] persists round-trips
//! through [`crate::util::Json`] losslessly: integers are emitted exactly,
//! f64s in Rust's shortest round-trip form, and the few legitimately
//! non-finite values (Eq. 1 scores of weightless layers) are tagged
//! strings. `plan_from_json(plan_to_json(p))` reconstructs a plan that is
//! bit-identical for every field the simulator and serving runtime read —
//! that is what makes `compile --out` / `simulate --plan` reproduce the
//! in-memory pipeline exactly.
//!
//! Schema versioning: the artifact envelope (see
//! [`crate::session::CompiledModel::to_json`]) carries a `format` tag;
//! loaders reject unknown versions instead of misreading them.

use anyhow::{anyhow, bail, Context, Result};

use crate::compiler::{AcceleratorPlan, LayerPlan, LayerStats, Parallelism, ResourceUsage};
use crate::config::{
    BurstLengthPolicy, CompilerOptions, DeviceConfig, EfficiencyTable, FlowControl, HbmGeometry,
    HbmTiming, WeightPlacement,
};
use crate::nn::{ConvKind, Network, OpKind, Shape};
use crate::util::Json;

// ---------------------------------------------------------------- helpers

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
}

fn f64_field(j: &Json, key: &str) -> Result<f64> {
    field(j, key)?.as_f64().ok_or_else(|| anyhow!("field {key:?} is not a number"))
}

fn u64_field(j: &Json, key: &str) -> Result<u64> {
    field(j, key)?.as_u64().ok_or_else(|| anyhow!("field {key:?} is not a non-negative integer"))
}

fn u32_field(j: &Json, key: &str) -> Result<u32> {
    field(j, key)?.as_u32().ok_or_else(|| anyhow!("field {key:?} is not a u32"))
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    field(j, key)?.as_usize().ok_or_else(|| anyhow!("field {key:?} is not an index"))
}

fn bool_field(j: &Json, key: &str) -> Result<bool> {
    field(j, key)?.as_bool().ok_or_else(|| anyhow!("field {key:?} is not a bool"))
}

fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    field(j, key)?.as_str().ok_or_else(|| anyhow!("field {key:?} is not a string"))
}

fn arr_field<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
    field(j, key)?.as_arr().ok_or_else(|| anyhow!("field {key:?} is not an array"))
}

/// Eq. 1 scores are `-inf` for weightless layers; JSON has no non-finite
/// numbers, so those are tagged strings.
fn score_to_json(s: f64) -> Json {
    if s.is_finite() {
        Json::Num(s)
    } else if s == f64::NEG_INFINITY {
        Json::Str("-inf".to_string())
    } else if s == f64::INFINITY {
        Json::Str("inf".to_string())
    } else {
        Json::Str("nan".to_string())
    }
}

fn score_from_json(j: &Json) -> Result<f64> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Str(s) if s == "-inf" => Ok(f64::NEG_INFINITY),
        Json::Str(s) if s == "inf" => Ok(f64::INFINITY),
        Json::Str(s) if s == "nan" => Ok(f64::NAN),
        other => bail!("score is neither a number nor a non-finite tag: {other:?}"),
    }
}

/// FNV-1a 64-bit, used for the provenance options hash (serialized as a
/// hex string — raw u64s above 2^53 would lose precision as JSON numbers).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable hash of a whole `CompilerOptions` (including the efficiency
/// table): two plans with the same hash were compiled with identical
/// knobs.
pub fn options_hash(o: &CompilerOptions) -> u64 {
    fnv1a64(options_to_json(o).to_string().as_bytes())
}

// ---------------------------------------------------------------- network

pub fn network_to_json(net: &Network) -> Json {
    let input = net.input_shape();
    let mut in_shape = Json::obj();
    in_shape.set("h", input.h).set("w", input.w).set("c", input.c);

    let mut layers = Json::Arr(Vec::new());
    for l in &net.layers()[1..] {
        let mut o = Json::obj();
        o.set("name", l.name.as_str());
        o.set("inputs", Json::Arr(l.inputs.iter().map(|&i| Json::from(i)).collect()));
        match &l.op {
            OpKind::Input { .. } => unreachable!("layer 0 is the only Input"),
            OpKind::Conv { kind, kh, kw, stride, pad, out_c } => {
                let kind = match kind {
                    ConvKind::Standard => "standard",
                    ConvKind::Depthwise => "depthwise",
                    ConvKind::Pointwise => "pointwise",
                };
                o.set("op", "conv")
                    .set("conv", kind)
                    .set("kh", *kh)
                    .set("kw", *kw)
                    .set("stride", *stride)
                    .set("pad", *pad)
                    .set("out_c", *out_c);
            }
            OpKind::MaxPool { k, stride, pad } => {
                o.set("op", "maxpool").set("k", *k).set("stride", *stride).set("pad", *pad);
            }
            OpKind::GlobalAvgPool => {
                o.set("op", "global_avg_pool");
            }
            OpKind::Add => {
                o.set("op", "add");
            }
            OpKind::Fc { out_features } => {
                o.set("op", "fc").set("out_features", *out_features);
            }
            OpKind::SqueezeExcite { squeeze_c } => {
                o.set("op", "squeeze_excite").set("squeeze_c", *squeeze_c);
            }
        }
        layers.push(o);
    }

    let mut o = Json::obj();
    o.set("name", net.name.as_str()).set("input", in_shape).set("layers", layers);
    o
}

pub fn network_from_json(j: &Json) -> Result<Network> {
    let name = str_field(j, "name")?;
    let input = field(j, "input")?;
    let shape =
        Shape::new(u32_field(input, "h")?, u32_field(input, "w")?, u32_field(input, "c")?);
    let mut net = Network::new(name, shape);
    for (pos, l) in arr_field(j, "layers")?.iter().enumerate() {
        let lname = str_field(l, "name")?;
        let inputs: Vec<usize> = arr_field(l, "inputs")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("layer {lname:?}: bad input id")))
            .collect::<Result<_>>()?;
        let op = match str_field(l, "op")? {
            "conv" => {
                let kind = match str_field(l, "conv")? {
                    "standard" => ConvKind::Standard,
                    "depthwise" => ConvKind::Depthwise,
                    "pointwise" => ConvKind::Pointwise,
                    k => bail!("layer {lname:?}: unknown conv kind {k:?}"),
                };
                OpKind::Conv {
                    kind,
                    kh: u32_field(l, "kh")?,
                    kw: u32_field(l, "kw")?,
                    stride: u32_field(l, "stride")?,
                    pad: u32_field(l, "pad")?,
                    out_c: u32_field(l, "out_c")?,
                }
            }
            "maxpool" => OpKind::MaxPool {
                k: u32_field(l, "k")?,
                stride: u32_field(l, "stride")?,
                pad: u32_field(l, "pad")?,
            },
            "global_avg_pool" => OpKind::GlobalAvgPool,
            "add" => OpKind::Add,
            "fc" => OpKind::Fc { out_features: u32_field(l, "out_features")? },
            "squeeze_excite" => {
                OpKind::SqueezeExcite { squeeze_c: u32_field(l, "squeeze_c")? }
            }
            op => bail!("layer {lname:?}: unknown op {op:?}"),
        };
        let id = net
            .add(lname, op, &inputs)
            .with_context(|| format!("rebuilding layer {pos} ({lname:?})"))?;
        anyhow::ensure!(id == pos + 1, "layer id drift while rebuilding {lname:?}");
    }
    net.validate().context("rebuilt network fails validation")?;
    Ok(net)
}

// ----------------------------------------------------------------- device

pub fn device_to_json(d: &DeviceConfig) -> Json {
    let g = &d.hbm;
    let mut hbm = Json::obj();
    hbm.set("stacks", g.stacks)
        .set("pcs_per_stack", g.pcs_per_stack)
        .set("banks_per_pc", g.banks_per_pc)
        .set("bank_groups", g.bank_groups)
        .set("row_bytes", g.row_bytes)
        .set("interface_bits", g.interface_bits)
        .set("controller_mhz", g.controller_mhz)
        .set("pc_capacity_bytes", g.pc_capacity_bytes);

    let t = &d.hbm_timing;
    let mut timing = Json::obj();
    timing
        .set("t_rcd", t.t_rcd)
        .set("t_rp", t.t_rp)
        .set("t_ras", t.t_ras)
        .set("t_cl", t.t_cl)
        .set("t_cwl", t.t_cwl)
        .set("t_ccd_s", t.t_ccd_s)
        .set("t_ccd_l", t.t_ccd_l)
        .set("t_rrd", t.t_rrd)
        .set("t_faw", t.t_faw)
        .set("t_wr", t.t_wr)
        .set("t_wtr", t.t_wtr)
        .set("t_rtw", t.t_rtw)
        .set("t_refi", t.t_refi)
        .set("t_rfc", t.t_rfc)
        .set("t_rd_gap", t.t_rd_gap)
        .set("t_wr_gap", t.t_wr_gap);

    let mut o = Json::obj();
    o.set("name", d.name.as_str())
        .set("m20k_blocks", d.m20k_blocks)
        .set("m20k_bits", d.m20k_bits)
        .set("tensor_blocks", d.tensor_blocks)
        .set("alms", d.alms)
        .set("core_mhz", d.core_mhz)
        .set("hbm", hbm)
        .set("hbm_timing", timing)
        .set(
            "excluded_pcs",
            Json::Arr(d.excluded_pcs.iter().map(|&p| Json::from(p)).collect()),
        );
    o
}

pub fn device_from_json(j: &Json) -> Result<DeviceConfig> {
    let h = field(j, "hbm")?;
    let hbm = HbmGeometry {
        stacks: u32_field(h, "stacks")?,
        pcs_per_stack: u32_field(h, "pcs_per_stack")?,
        banks_per_pc: u32_field(h, "banks_per_pc")?,
        bank_groups: u32_field(h, "bank_groups")?,
        row_bytes: u32_field(h, "row_bytes")?,
        interface_bits: u32_field(h, "interface_bits")?,
        controller_mhz: u32_field(h, "controller_mhz")?,
        pc_capacity_bytes: u64_field(h, "pc_capacity_bytes")?,
    };
    let t = field(j, "hbm_timing")?;
    let hbm_timing = HbmTiming {
        t_rcd: u32_field(t, "t_rcd")?,
        t_rp: u32_field(t, "t_rp")?,
        t_ras: u32_field(t, "t_ras")?,
        t_cl: u32_field(t, "t_cl")?,
        t_cwl: u32_field(t, "t_cwl")?,
        t_ccd_s: u32_field(t, "t_ccd_s")?,
        t_ccd_l: u32_field(t, "t_ccd_l")?,
        t_rrd: u32_field(t, "t_rrd")?,
        t_faw: u32_field(t, "t_faw")?,
        t_wr: u32_field(t, "t_wr")?,
        t_wtr: u32_field(t, "t_wtr")?,
        t_rtw: u32_field(t, "t_rtw")?,
        t_refi: u32_field(t, "t_refi")?,
        t_rfc: u32_field(t, "t_rfc")?,
        t_rd_gap: u32_field(t, "t_rd_gap")?,
        t_wr_gap: u32_field(t, "t_wr_gap")?,
    };
    let excluded_pcs = arr_field(j, "excluded_pcs")?
        .iter()
        .map(|v| v.as_u32().ok_or_else(|| anyhow!("bad excluded PC id")))
        .collect::<Result<_>>()?;
    Ok(DeviceConfig {
        name: str_field(j, "name")?.to_string(),
        m20k_blocks: u32_field(j, "m20k_blocks")?,
        m20k_bits: u32_field(j, "m20k_bits")?,
        tensor_blocks: u32_field(j, "tensor_blocks")?,
        alms: u32_field(j, "alms")?,
        core_mhz: u32_field(j, "core_mhz")?,
        hbm,
        hbm_timing,
        excluded_pcs,
    })
}

// ---------------------------------------------------------------- options

pub fn options_to_json(o: &CompilerOptions) -> Json {
    let mut eff = Json::Arr(Vec::new());
    for &(bl, e) in &o.efficiency.entries {
        eff.push(Json::Arr(vec![Json::from(bl), Json::from(e)]));
    }
    let mut j = Json::obj();
    match o.burst_length {
        BurstLengthPolicy::Auto => {
            j.set("burst_policy", "auto");
        }
        BurstLengthPolicy::Fixed(bl) => {
            j.set("burst_policy", "fixed").set("burst_fixed", bl);
        }
    }
    j.set("all_hbm", o.all_hbm)
        .set("write_path_bits", o.write_path_bits)
        .set("last_stage_fifo_depth", o.last_stage_fifo_depth)
        .set("fifo_group_size", o.fifo_group_size)
        .set("max_utilization", o.max_utilization)
        .set("weight_bits", o.weight_bits)
        .set("max_parallelism_steps", o.max_parallelism_steps)
        .set("max_chains_per_layer", o.max_chains_per_layer)
        .set("efficiency", eff)
        .set(
            "flow_control",
            match o.flow_control {
                FlowControl::Credit => "credit",
                FlowControl::ReadyValid => "ready_valid",
            },
        );
    // Tuner-era knobs are emitted only at non-default values so every
    // pre-tuner artifact (and its provenance hash) stays byte-identical;
    // any tuned value lands in the JSON and therefore in the FNV-1a
    // options hash, so differently-tuned plans can never alias.
    if o.sparsity_fraction != 0.0 {
        j.set("sparsity_fraction", o.sparsity_fraction);
    }
    if !o.offload_overrides.is_empty() {
        let mut ov = Json::Arr(Vec::new());
        for &(idx, hbm) in &o.offload_overrides {
            ov.push(Json::Arr(vec![Json::from(idx), Json::Bool(hbm)]));
        }
        j.set("offload_overrides", ov);
    }
    j
}

pub fn options_from_json(j: &Json) -> Result<CompilerOptions> {
    let burst_length = match str_field(j, "burst_policy")? {
        "auto" => BurstLengthPolicy::Auto,
        "fixed" => BurstLengthPolicy::Fixed(u32_field(j, "burst_fixed")?),
        p => bail!("unknown burst policy {p:?}"),
    };
    let entries = arr_field(j, "efficiency")?
        .iter()
        .map(|pair| -> Result<(u32, f64)> {
            let p = pair.as_arr().ok_or_else(|| anyhow!("efficiency entry is not a pair"))?;
            anyhow::ensure!(p.len() == 2, "efficiency entry is not a pair");
            Ok((
                p[0].as_u32().ok_or_else(|| anyhow!("bad efficiency burst length"))?,
                p[1].as_f64().ok_or_else(|| anyhow!("bad efficiency value"))?,
            ))
        })
        .collect::<Result<_>>()?;
    let flow_control = match str_field(j, "flow_control")? {
        "credit" => FlowControl::Credit,
        "ready_valid" => FlowControl::ReadyValid,
        p => bail!("unknown flow control {p:?}"),
    };
    let o = CompilerOptions {
        burst_length,
        all_hbm: bool_field(j, "all_hbm")?,
        write_path_bits: u32_field(j, "write_path_bits")?,
        last_stage_fifo_depth: u32_field(j, "last_stage_fifo_depth")?,
        fifo_group_size: u32_field(j, "fifo_group_size")?,
        max_utilization: f64_field(j, "max_utilization")?,
        weight_bits: u32_field(j, "weight_bits")?,
        max_parallelism_steps: u32_field(j, "max_parallelism_steps")?,
        max_chains_per_layer: u32_field(j, "max_chains_per_layer")?,
        efficiency: EfficiencyTable { entries },
        flow_control,
        sparsity_fraction: match j.get("sparsity_fraction") {
            None => 0.0,
            Some(v) => v.as_f64().ok_or_else(|| anyhow!("sparsity_fraction is not a number"))?,
        },
        offload_overrides: match j.get("offload_overrides") {
            None => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or_else(|| anyhow!("offload_overrides is not an array"))?
                .iter()
                .map(|pair| -> Result<(usize, bool)> {
                    let p =
                        pair.as_arr().ok_or_else(|| anyhow!("override entry is not a pair"))?;
                    anyhow::ensure!(p.len() == 2, "override entry is not a pair");
                    Ok((
                        p[0].as_usize().ok_or_else(|| anyhow!("bad override layer index"))?,
                        p[1].as_bool().ok_or_else(|| anyhow!("bad override placement flag"))?,
                    ))
                })
                .collect::<Result<_>>()?,
        },
    };
    o.validate().context("loaded compiler options fail validation")?;
    Ok(o)
}

// ------------------------------------------------------------------- plan

fn stats_to_json(s: &LayerStats) -> Json {
    let mut o = Json::obj();
    o.set("layer", s.layer)
        .set("name", s.name.as_str())
        .set("weight_bits", s.weight_bits)
        .set("weight_m20k", s.weight_m20k)
        .set("dup", s.dup)
        .set("act_bits", s.act_bits)
        .set("weight_traffic_per_image", s.weight_traffic_per_image)
        .set("macs", s.macs)
        .set("out_h", s.out_h)
        .set("out_w", s.out_w)
        .set("kh", s.kh)
        .set("kw", s.kw)
        .set("ci", s.ci)
        .set("co", s.co)
        .set("has_weights", s.has_weights)
        .set("depthwise", s.depthwise);
    o
}

fn stats_from_json(j: &Json) -> Result<LayerStats> {
    Ok(LayerStats {
        layer: usize_field(j, "layer")?,
        name: str_field(j, "name")?.to_string(),
        weight_bits: u64_field(j, "weight_bits")?,
        weight_m20k: u64_field(j, "weight_m20k")?,
        dup: u64_field(j, "dup")?,
        act_bits: u64_field(j, "act_bits")?,
        weight_traffic_per_image: u64_field(j, "weight_traffic_per_image")?,
        macs: u64_field(j, "macs")?,
        out_h: u32_field(j, "out_h")?,
        out_w: u32_field(j, "out_w")?,
        kh: u32_field(j, "kh")?,
        kw: u32_field(j, "kw")?,
        ci: u32_field(j, "ci")?,
        co: u32_field(j, "co")?,
        has_weights: bool_field(j, "has_weights")?,
        depthwise: bool_field(j, "depthwise")?,
    })
}

fn layer_plan_to_json(l: &LayerPlan) -> Json {
    let mut pcs = Json::Arr(Vec::new());
    for &(pc, slots) in &l.pcs {
        pcs.push(Json::Arr(vec![Json::from(pc), Json::from(slots)]));
    }
    let mut o = Json::obj();
    o.set("stats", stats_to_json(&l.stats))
        .set("p_i", l.par.p_i)
        .set("p_o", l.par.p_o)
        .set(
            "placement",
            match l.placement {
                WeightPlacement::OnChip => "onchip",
                WeightPlacement::Hbm => "hbm",
            },
        )
        .set("pcs", pcs)
        .set("score", score_to_json(l.score));
    o
}

fn layer_plan_from_json(j: &Json) -> Result<LayerPlan> {
    let placement = match str_field(j, "placement")? {
        "onchip" => WeightPlacement::OnChip,
        "hbm" => WeightPlacement::Hbm,
        p => bail!("unknown weight placement {p:?}"),
    };
    let pcs = arr_field(j, "pcs")?
        .iter()
        .map(|pair| -> Result<(u32, u32)> {
            let p = pair.as_arr().ok_or_else(|| anyhow!("PC entry is not a pair"))?;
            anyhow::ensure!(p.len() == 2, "PC entry is not a pair");
            Ok((
                p[0].as_u32().ok_or_else(|| anyhow!("bad PC id"))?,
                p[1].as_u32().ok_or_else(|| anyhow!("bad PC slot count"))?,
            ))
        })
        .collect::<Result<_>>()?;
    Ok(LayerPlan {
        stats: stats_from_json(field(j, "stats")?)?,
        par: Parallelism { p_i: u32_field(j, "p_i")?, p_o: u32_field(j, "p_o")? },
        placement,
        pcs,
        score: score_from_json(field(j, "score")?)?,
    })
}

pub fn plan_to_json(p: &AcceleratorPlan) -> Json {
    let mut layers = Json::Arr(Vec::new());
    for l in &p.layers {
        layers.push(layer_plan_to_json(l));
    }
    let mut usage = Json::obj();
    usage
        .set("m20k", p.usage.m20k)
        .set("tensor_blocks", p.usage.tensor_blocks)
        .set("alms", p.usage.alms);
    let mut o = Json::obj();
    o.set("network", p.network.as_str())
        .set("device", device_to_json(&p.device))
        .set("options", options_to_json(&p.options))
        .set("layers", layers)
        .set("burst_len", p.burst_len)
        .set("usage", usage)
        .set("bottleneck_cycles", p.bottleneck_cycles)
        .set("est_throughput", p.est_throughput)
        .set("est_latency", p.est_latency)
        .set("hbm_read_efficiency", p.hbm_read_efficiency)
        .set("free_bw_slots", p.free_bw_slots);
    o
}

pub fn plan_from_json(j: &Json) -> Result<AcceleratorPlan> {
    let layers = arr_field(j, "layers")?
        .iter()
        .enumerate()
        .map(|(i, l)| layer_plan_from_json(l).with_context(|| format!("plan layer {i}")))
        .collect::<Result<_>>()?;
    let u = field(j, "usage")?;
    Ok(AcceleratorPlan {
        network: str_field(j, "network")?.to_string(),
        device: device_from_json(field(j, "device")?).context("plan device")?,
        options: options_from_json(field(j, "options")?).context("plan options")?,
        layers,
        burst_len: u32_field(j, "burst_len")?,
        usage: ResourceUsage {
            m20k: u64_field(u, "m20k")?,
            tensor_blocks: u64_field(u, "tensor_blocks")?,
            alms: u64_field(u, "alms")?,
        },
        bottleneck_cycles: u64_field(j, "bottleneck_cycles")?,
        est_throughput: f64_field(j, "est_throughput")?,
        est_latency: f64_field(j, "est_latency")?,
        hbm_read_efficiency: f64_field(j, "hbm_read_efficiency")?,
        free_bw_slots: u64_field(j, "free_bw_slots")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn network_round_trips_all_zoo_models() {
        for net in zoo::table1_models().into_iter().chain([zoo::mobilenet_edge()]) {
            let j = network_to_json(&net);
            let back = network_from_json(&j).unwrap_or_else(|e| panic!("{}: {e:#}", net.name));
            assert_eq!(back.name, net.name);
            assert_eq!(back.len(), net.len());
            for (a, b) in net.layers().iter().zip(back.layers().iter()) {
                assert_eq!(a.name, b.name, "{}", net.name);
                assert_eq!(a.op, b.op);
                assert_eq!(a.inputs, b.inputs);
                assert_eq!(a.out, b.out);
                assert_eq!(a.in_shape(), b.in_shape());
            }
            // serialized form is stable
            assert_eq!(network_to_json(&back).to_string(), j.to_string());
        }
    }

    #[test]
    fn device_round_trips() {
        let d = DeviceConfig::stratix10_nx2100();
        let back = device_from_json(&device_to_json(&d)).unwrap();
        assert_eq!(back, d);
        let unlimited = d.with_unlimited_hbm();
        assert_eq!(device_from_json(&device_to_json(&unlimited)).unwrap(), unlimited);
    }

    #[test]
    fn options_round_trip_both_burst_policies() {
        let mut o = CompilerOptions::default();
        o.all_hbm = true;
        o.write_path_bits = 64;
        let back = options_from_json(&options_to_json(&o)).unwrap();
        assert_eq!(back.all_hbm, o.all_hbm);
        assert_eq!(back.burst_length, o.burst_length);
        assert_eq!(back.efficiency, o.efficiency);
        assert_eq!(options_hash(&back), options_hash(&o));

        o.burst_length = BurstLengthPolicy::Fixed(16);
        let back = options_from_json(&options_to_json(&o)).unwrap();
        assert_eq!(back.burst_length, BurstLengthPolicy::Fixed(16));
    }

    #[test]
    fn options_hash_sensitive_to_every_knob() {
        let base = options_hash(&CompilerOptions::default());
        let mut o = CompilerOptions::default();
        o.all_hbm = true;
        assert_ne!(options_hash(&o), base);
        let mut o = CompilerOptions::default();
        o.efficiency.entries[3].1 = 0.5;
        assert_ne!(options_hash(&o), base, "efficiency table must be hashed");
        let mut o = CompilerOptions::default();
        o.flow_control = FlowControl::ReadyValid;
        assert_ne!(options_hash(&o), base, "flow control must be hashed");
        let mut o = CompilerOptions::default();
        o.burst_length = BurstLengthPolicy::Fixed(16);
        assert_ne!(options_hash(&o), base, "burst policy must be hashed");
        let mut o = CompilerOptions::default();
        o.last_stage_fifo_depth = 256;
        assert_ne!(options_hash(&o), base, "FIFO depth override must be hashed");
        let mut o = CompilerOptions::default();
        o.sparsity_fraction = 0.25;
        assert_ne!(options_hash(&o), base, "sparsity fraction must be hashed");
        let mut o = CompilerOptions::default();
        o.offload_overrides = vec![(3, true)];
        assert_ne!(options_hash(&o), base, "offload overrides must be hashed");
        let mut flipped = CompilerOptions::default();
        flipped.offload_overrides = vec![(3, false)];
        assert_ne!(
            options_hash(&flipped),
            options_hash(&o),
            "override direction must be hashed"
        );
    }

    #[test]
    fn tuner_knobs_round_trip_and_defaults_stay_byte_identical() {
        // Absent keys decode to the dense/no-override defaults, so every
        // pre-tuner artifact keeps its serialized form and hash.
        let dflt = CompilerOptions::default();
        let j = options_to_json(&dflt);
        assert!(j.get("sparsity_fraction").is_none(), "default knobs must not serialize");
        assert!(j.get("offload_overrides").is_none(), "default knobs must not serialize");
        let back = options_from_json(&j).unwrap();
        assert_eq!(back.sparsity_fraction, 0.0);
        assert!(back.offload_overrides.is_empty());
        assert_eq!(options_to_json(&back).to_string(), j.to_string());

        let mut o = CompilerOptions::default();
        o.sparsity_fraction = 0.375;
        o.offload_overrides = vec![(2, true), (7, false)];
        let back = options_from_json(&options_to_json(&o)).unwrap();
        assert_eq!(back.sparsity_fraction, 0.375);
        assert_eq!(back.offload_overrides, vec![(2, true), (7, false)]);
        assert_eq!(options_hash(&back), options_hash(&o));
    }

    #[test]
    fn scores_round_trip_including_neg_inf() {
        for s in [1.25, 0.0, -3.5, f64::NEG_INFINITY, f64::INFINITY] {
            let back = score_from_json(&score_to_json(s)).unwrap();
            assert_eq!(back, s);
        }
        assert!(score_from_json(&score_to_json(f64::NAN)).unwrap().is_nan());
        assert!(score_from_json(&Json::Null).is_err());
    }

    #[test]
    fn malformed_plan_fields_are_rejected() {
        let mut j = Json::obj();
        j.set("network", "x");
        assert!(plan_from_json(&j).is_err(), "missing fields must not decode");
    }
}
