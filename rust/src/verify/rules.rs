//! Plan-level rule families 1 (resources), 2 (PC structure + HBM
//! bandwidth), 4 (FIFO depth) and 5 (internal consistency / provenance).
//!
//! Every rule re-derives its expected value from first principles (layer
//! plans, device description, options) through the *same* functions the
//! compiler uses — `recompute_usage`, `recompute_bottleneck_cycles`,
//! `analytic_estimates` — so a fresh `compile()` is clean by
//! construction and any disagreement localises to the stored scalar.

use crate::compiler::AcceleratorPlan;
use crate::config::{BurstLengthPolicy, DeviceConfig, WeightPlacement};
use crate::session::{codec, CompiledModel};
use crate::util::ceil_div;

use super::{Code, Diagnostic, Report};

/// Weight-stream demand of one chain slot, in bits per core cycle
/// (§IV-A: each tensor chain consumes one 80-bit word per cycle).
const CHAIN_DEMAND_BITS: u64 = 80;

/// Relative f64 comparison for recomputed scalars. The recomputation path
/// is bit-identical to the compiler's, so equality normally holds
/// exactly; the epsilon only guards against platform-level FP drift.
fn close(a: f64, b: f64) -> bool {
    a == b || (a - b).abs() <= 1e-9 * a.abs().max(b.abs())
}

// ------------------------------------------------- family 1: resources

pub(super) fn check_resources(plan: &AcceleratorPlan, r: &mut Report) {
    let d = &plan.device;
    let u = &plan.usage;
    if u.m20k > d.m20k_blocks as u64 {
        r.push(
            Diagnostic::new(
                Code::M20kOvercommit,
                "usage.m20k",
                format!("plan uses {} M20K blocks but {} has {}", u.m20k, d.name, d.m20k_blocks),
            )
            .hint("offload more weight layers to HBM (all_hbm) or lower max_chains_per_layer"),
        );
    }
    if u.tensor_blocks > d.tensor_blocks as u64 {
        r.push(
            Diagnostic::new(
                Code::TensorBlockOvercommit,
                "usage.tensor_blocks",
                format!(
                    "plan uses {} AI tensor blocks but {} has {}",
                    u.tensor_blocks, d.name, d.tensor_blocks
                ),
            )
            .hint("lower max_utilization or max_chains_per_layer"),
        );
    }
    if u.alms > d.alms as u64 {
        r.push(
            Diagnostic::new(
                Code::AlmOvercommit,
                "usage.alms",
                format!("plan uses {} ALMs but {} has {}", u.alms, d.name, d.alms),
            )
            .hint("lower max_utilization or narrow write_path_bits"),
        );
    }
    let rec = plan.recompute_usage();
    if rec.m20k != u.m20k || rec.tensor_blocks != u.tensor_blocks || rec.alms != u.alms {
        r.push(
            Diagnostic::new(
                Code::UsageMismatch,
                "usage",
                format!(
                    "stored resource usage (m20k {}, tensor_blocks {}, alms {}) does not \
                     recompute from the layer plans (recomputed: m20k {}, tensor_blocks {}, \
                     alms {})",
                    u.m20k, u.tensor_blocks, u.alms, rec.m20k, rec.tensor_blocks, rec.alms
                ),
            )
            .hint("the artifact was tampered with or hand-edited; recompile the model"),
        );
    }
}

// --------------------------- family 2: PC structure + HBM bandwidth

pub(super) fn check_pcs(plan: &AcceleratorPlan, r: &mut Report) {
    let d = &plan.device;
    let total = d.hbm.total_pcs();
    let cap = d.chains_per_pc() as u64;

    // Per-layer structural checks, accumulating per-PC slot totals.
    let mut slots = vec![0u64; total as usize];
    for l in &plan.layers {
        let is_hbm = l.placement == WeightPlacement::Hbm;
        if is_hbm && l.stats.has_weights {
            let covered: u32 = l.pcs.iter().map(|&(_, s)| s).sum();
            if covered != l.par.chains() {
                r.push(
                    Diagnostic::new(
                        Code::PcSlotMismatch,
                        &l.stats.name,
                        format!(
                            "HBM layer needs {} chain slots but its PC list {:?} covers {}",
                            l.par.chains(),
                            l.pcs,
                            covered
                        ),
                    )
                    .hint("re-run the §V-B clockwise PC assignment"),
                );
            }
            for &(pc, s) in &l.pcs {
                if pc >= total || d.excluded_pcs.contains(&pc) {
                    r.push(
                        Diagnostic::new(
                            Code::IllegalPc,
                            format!("{}:PC{pc}", l.stats.name),
                            format!(
                                "pseudo-channel {pc} is {} on {}",
                                if pc >= total { "out of range" } else { "excluded" },
                                d.name
                            ),
                        )
                        .hint(format!(
                            "usable PCs: 0..{} minus excluded {:?}",
                            total, d.excluded_pcs
                        )),
                    );
                } else {
                    slots[pc as usize] += s as u64;
                }
            }
        } else if !l.pcs.is_empty() {
            r.push(
                Diagnostic::new(
                    Code::PcSlotMismatch,
                    &l.stats.name,
                    format!(
                        "layer is {} yet carries PC slots {:?}",
                        if l.stats.has_weights { "on-chip" } else { "weightless" },
                        l.pcs
                    ),
                )
                .hint("clear the PC list or mark the layer as HBM-placed"),
            );
        }
    }

    // Per-PC chain-slot budget.
    for (pc, &used) in slots.iter().enumerate() {
        if used > cap {
            r.push(
                Diagnostic::new(
                    Code::PcOversubscribed,
                    format!("PC{pc}"),
                    format!("{used} chain slots assigned but each pseudo-channel has {cap}"),
                )
                .hint("each 256-bit PC feeds floor(256/80) = 3 chains at full rate (§V-B)"),
            );
        }
    }

    // Aggregate bandwidth feasibility at the plan's burst length. PCs
    // already flagged above are skipped so a structurally broken channel
    // produces exactly one diagnostic.
    let eff = plan.options.efficiency.lookup(plan.burst_len);
    let supply = d.hbm.interface_bits as f64 * (d.hbm.controller_mhz as f64 / d.core_mhz as f64)
        * eff;
    let mut short = 0usize;
    let mut worst: Option<(usize, f64)> = None;
    for (pc, &used) in slots.iter().enumerate() {
        if used == 0 || used > cap {
            continue;
        }
        let demand = (used * CHAIN_DEMAND_BITS) as f64;
        if demand > supply {
            short += 1;
            match worst {
                Some((_, w)) if demand <= w => {}
                _ => worst = Some((pc, demand)),
            }
        }
    }
    if let Some((pc, demand)) = worst {
        r.push(
            Diagnostic::new(
                Code::BandwidthInfeasible,
                format!("PC{pc}"),
                format!(
                    "at BL{} (read efficiency {eff:.3}) {short} pseudo-channel(s) demand more \
                     weight bandwidth than HBM supplies; worst is PC{pc}: {demand:.0} vs \
                     {supply:.1} bits/core-cycle",
                    plan.burst_len
                ),
            )
            .hint("raise the burst length — read efficiency saturates upward (§VI-A)"),
        );
    }
}

pub(super) fn check_burst_policy(plan: &AcceleratorPlan, r: &mut Report) {
    let bl = plan.burst_len;
    if !BurstLengthPolicy::LEGAL.contains(&bl) {
        r.push(
            Diagnostic::new(
                Code::BurstPolicyMismatch,
                "burst_len",
                format!("burst length {bl} is not supported by the hardened controller"),
            )
            .hint(format!("legal burst lengths: {:?}", BurstLengthPolicy::LEGAL)),
        );
        return;
    }
    match plan.options.burst_length {
        BurstLengthPolicy::Fixed(want) if want != bl => {
            r.push(
                Diagnostic::new(
                    Code::BurstPolicyMismatch,
                    "burst_len",
                    format!("plan burst length {bl} contradicts the Fixed({want}) policy"),
                )
                .hint("the burst length is a compile output of the policy; recompile"),
            );
        }
        BurstLengthPolicy::Auto if bl != 8 && bl != 32 => {
            r.push(
                Diagnostic::new(
                    Code::BurstPolicyMismatch,
                    "burst_len",
                    format!(
                        "the Auto policy only selects BL8 (on-chip bottleneck) or BL32 \
                         (HBM bottleneck), never BL{bl} (§VI-A)"
                    ),
                )
                .hint("recompile, or pin the burst with Fixed(n)"),
            );
        }
        _ => {}
    }
}

// ------------------------------------------------ family 4: FIFO depth

/// Fig. 6 analytic lower bound on the last-stage FIFO depth, in 80-bit
/// words. A chain drains one word per core cycle, so the FIFO must cover
/// the worst-case HBM read service time: a refresh blackout (`t_rfc`)
/// plus queueing behind the channel's other chain slots, each paying a
/// full row cycle (`t_rc + t_rcd + t_cl + t_rd_gap`) and its burst
/// transfer — the same ~1214 ns worst case that sized the paper's
/// 512-word FIFOs (§IV-A).
pub fn last_stage_depth_bound(device: &DeviceConfig, burst_len: u32) -> u64 {
    let t = &device.hbm_timing;
    let per_burst = (t.t_rc() + t.t_rcd + t.t_cl + t.t_rd_gap + burst_len) as u64;
    let ctrl_cycles = t.t_rfc as u64 + 4 * per_burst;
    // controller cycles -> core cycles (words drained during the wait)
    ceil_div(ctrl_cycles * device.core_mhz as u64, device.hbm.controller_mhz as u64)
}

pub(super) fn check_fifo_depth(plan: &AcceleratorPlan, r: &mut Report) {
    if plan.hbm_layers().next().is_none() {
        return; // no HBM streams, last-stage depth is irrelevant
    }
    let bound = last_stage_depth_bound(&plan.device, plan.burst_len);
    let depth = plan.options.last_stage_fifo_depth as u64;
    if depth < bound {
        r.push(
            Diagnostic::new(
                Code::FifoDepthShortfall,
                "options.last_stage_fifo_depth",
                format!(
                    "depth {depth} words is below the analytic lower bound {bound} at BL{}: a \
                     worst-case HBM read (refresh + same-channel queueing) would underrun the \
                     tensor chains",
                    plan.burst_len
                ),
            )
            .hint(format!(
                "set last_stage_fifo_depth to at least {} (next power of two covering the bound)",
                bound.next_power_of_two()
            )),
        );
    }
}

// ------------------------------------- family 5: internal consistency

pub(super) fn check_consistency(plan: &AcceleratorPlan, r: &mut Report) {
    let bc = plan.recompute_bottleneck_cycles();
    if bc != plan.bottleneck_cycles {
        r.push(
            Diagnostic::new(
                Code::BottleneckMismatch,
                "bottleneck_cycles",
                format!("stored {} but the layer plans recompute {bc}", plan.bottleneck_cycles),
            )
            .hint("the artifact was tampered with; recompile the model"),
        );
    }
    let fb = plan.recompute_free_bw_slots();
    if fb != plan.free_bw_slots {
        r.push(
            Diagnostic::new(
                Code::FreeBwMismatch,
                "free_bw_slots",
                format!(
                    "stored {} but capacity minus offloaded chains recomputes {fb}",
                    plan.free_bw_slots
                ),
            )
            .hint("the artifact was tampered with; recompile the model"),
        );
    }
    let eff = plan.options.efficiency.lookup(plan.burst_len);
    if !close(eff, plan.hbm_read_efficiency) {
        r.push(
            Diagnostic::new(
                Code::EfficiencyMismatch,
                "hbm_read_efficiency",
                format!(
                    "stored {} but the embedded efficiency table gives {eff} at BL{}",
                    plan.hbm_read_efficiency, plan.burst_len
                ),
            )
            .hint("the estimate scalars derive from the table; recompile the model"),
        );
    }
    let (tp, lat) = plan.analytic_estimates();
    let mut bad = Vec::new();
    if !close(tp, plan.est_throughput) {
        bad.push(format!("est_throughput stored {} vs recomputed {tp}", plan.est_throughput));
    }
    if !close(lat, plan.est_latency) {
        bad.push(format!("est_latency stored {} vs recomputed {lat}", plan.est_latency));
    }
    if !bad.is_empty() {
        r.push(
            Diagnostic::new(Code::EstimateMismatch, "estimates", bad.join("; "))
                .hint("analytic estimates must recompute from the layer plans; recompile"),
        );
    }
}

pub(super) fn check_provenance(cm: &CompiledModel, r: &mut Report) {
    let plan = cm.plan();
    let net = cm.network();
    let prov = cm.provenance();
    let mut idents = Vec::new();
    if plan.network != net.name {
        idents.push(format!(
            "plan targets network {:?} but the artifact carries {:?}",
            plan.network, net.name
        ));
    }
    if plan.layers.len() != net.len() {
        idents.push(format!(
            "plan has {} layers but the network has {}",
            plan.layers.len(),
            net.len()
        ));
    }
    if prov.model != net.name {
        idents.push(format!(
            "provenance model {:?} does not match the network {:?}",
            prov.model, net.name
        ));
    }
    if prov.device != plan.device.name {
        idents.push(format!(
            "provenance device {:?} does not match the plan device {:?}",
            prov.device, plan.device.name
        ));
    }
    if !idents.is_empty() {
        r.push(
            Diagnostic::new(Code::ProvenanceMismatch, "provenance", idents.join("; "))
                .hint("the artifact envelope was edited; regenerate it with save()"),
        );
    }
    let rehash = codec::options_hash(&plan.options);
    if rehash != prov.options_hash {
        r.push(
            Diagnostic::new(
                Code::OptionsHashMismatch,
                "provenance.options_hash",
                format!(
                    "provenance options hash {:016x} does not match the embedded options \
                     ({rehash:016x})",
                    prov.options_hash
                ),
            )
            .hint("either the options or the hash were edited after compile"),
        );
    }
}
