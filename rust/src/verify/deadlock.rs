//! Rule family 3: static structural deadlock analysis (Fig. 5).
//!
//! The weight distribution network forms a dependency graph per
//! pseudo-channel: the HBM prefetcher interleaves bursts for every chain
//! slot into one dual-clock FIFO, whose *head* word belongs to exactly
//! one layer's burst-matching FIFO. Under plain ready/valid flow control
//! the prefetcher issues reads without knowing whether that burst FIFO
//! has room, so the §V-A cycle can close: layer A starves for weights →
//! A's activations back-pressure downstream layer B → B stops draining
//! its burst FIFO → the DCFIFO head (a B word) cannot advance → A's
//! words behind it never arrive. Credit-based flow control breaks the
//! cycle by construction — a burst is only fetched after the target FIFO
//! reserved space, so the DCFIFO head is always drainable and the wait
//! graph stays acyclic.
//!
//! The static rule is *conservative*: a ready/valid plan is flagged
//! whenever two layers share a pseudo-channel and some sharing layer's
//! burst FIFO cannot absorb its entire per-image weight stream (the only
//! regime in which head-of-line blocking provably cannot occur is a FIFO
//! deep enough to never refuse the DCFIFO head). The
//! `fabric::deadlock` Fig. 5 repro is the executable ground truth this
//! rule is cross-validated against in `tests/integration_verify.rs`.

use std::collections::BTreeMap;

use crate::compiler::AcceleratorPlan;
use crate::config::{FlowControl, WeightPlacement};
use crate::fabric::deadlock::ScenarioConfig;

use super::{Code, Diagnostic, Report};

/// Outcome of the static analysis, exposed so callers (and the
/// cross-validation test) can distinguish *why* a plan is safe.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeadlockVerdict {
    /// Credit flow control: cycle-free by construction (§V-A).
    CreditCycleFree,
    /// Ready/valid, but no pseudo-channel carries more than one layer, so
    /// every DCFIFO head word targets its only consumer — no cross-layer
    /// head-of-line dependency exists.
    NoSharedChannel,
    /// Ready/valid with shared channels, but every sharing layer's burst
    /// FIFO holds its whole stream — the Fig. 5 cycle cannot close.
    FifosSufficient,
    /// The Fig. 5 cycle is admissible on `pc`.
    Hazard {
        pc: u32,
        /// Names of the layers sharing the hazardous channel.
        layers: Vec<String>,
        /// Burst-matching FIFO capacity, in 80-bit weight words.
        capacity_words: u64,
        /// Largest per-image weight stream among the sharing layers.
        required_words: u64,
    },
}

/// Core predicate, shared between the plan rule and the Fig. 5 scenario
/// mapping: given layers that share one channel, each streaming
/// `stream_words` through a burst FIFO of `capacity_words`, is the
/// head-of-line cycle admissible?
pub fn shared_channel_hazard(
    flow: FlowControl,
    capacity_words: u64,
    stream_words: &[u64],
) -> bool {
    match flow {
        FlowControl::Credit => false,
        FlowControl::ReadyValid => {
            stream_words.len() >= 2 && stream_words.iter().any(|&w| w > capacity_words)
        }
    }
}

/// Statically analyze one plan's weight network.
pub fn analyze_plan(plan: &AcceleratorPlan) -> DeadlockVerdict {
    if plan.options.flow_control == FlowControl::Credit {
        return DeadlockVerdict::CreditCycleFree;
    }
    // Group offloaded layers by the pseudo-channels they draw from.
    let mut by_pc: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
    for (i, l) in plan.layers.iter().enumerate() {
        if l.placement == WeightPlacement::Hbm && l.stats.has_weights {
            for &(pc, _) in &l.pcs {
                by_pc.entry(pc).or_default().push(i);
            }
        }
    }
    let shared: Vec<(&u32, &Vec<usize>)> =
        by_pc.iter().filter(|(_, idxs)| idxs.len() >= 2).collect();
    if shared.is_empty() {
        return DeadlockVerdict::NoSharedChannel;
    }
    // Burst-matching FIFO capacity in 80-bit words (its M20K sizing in
    // LayerStats::hbm_weight_m20k is 4 x burst_len x 256 bits per stream).
    let capacity_words = 4 * plan.burst_len as u64 * 256 / 80;
    for (&pc, idxs) in &shared {
        // Per-image stream of a layer: its chains each pull one 80-bit
        // word per compute cycle.
        let streams: Vec<u64> = idxs
            .iter()
            .map(|&i| {
                let l = &plan.layers[i];
                l.par.chains() as u64 * l.compute_cycles()
            })
            .collect();
        if shared_channel_hazard(FlowControl::ReadyValid, capacity_words, &streams) {
            return DeadlockVerdict::Hazard {
                pc,
                layers: idxs.iter().map(|&i| plan.layers[i].stats.name.clone()).collect(),
                capacity_words,
                required_words: streams.iter().copied().max().unwrap_or(0),
            };
        }
    }
    DeadlockVerdict::FifosSufficient
}

/// Map the executable Fig. 5 scenario (`fabric::deadlock`) onto the
/// static rule: three layers share one pseudo-channel, layer `l`
/// streaming `weights_per_item[l] x items` words through a burst FIFO
/// holding `burst_fifo_capacity` words. Used by the cross-validation
/// test to prove the static verdict matches the simulated outcome.
pub fn scenario_has_hazard(flow: FlowControl, cfg: &ScenarioConfig) -> bool {
    let streams: Vec<u64> =
        cfg.weights_per_item.iter().map(|&w| w as u64 * cfg.items).collect();
    shared_channel_hazard(flow, cfg.burst_fifo_capacity as u64, &streams)
}

pub(super) fn check(plan: &AcceleratorPlan, r: &mut Report) {
    if let DeadlockVerdict::Hazard { pc, layers, capacity_words, required_words } =
        analyze_plan(plan)
    {
        r.push(
            Diagnostic::new(
                Code::ReadyValidDeadlock,
                format!("PC{pc}"),
                format!(
                    "ready/valid flow control with layers {layers:?} sharing the channel: a \
                     burst FIFO of {capacity_words} words cannot absorb a {required_words}-word \
                     stream, so the Fig. 5 head-of-line cycle is admissible"
                ),
            )
            .hint("set flow_control to Credit (§V-A) — credits keep the wait graph acyclic"),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn credit_never_hazards() {
        assert!(!shared_channel_hazard(FlowControl::Credit, 1, &[1000, 1000]));
    }

    #[test]
    fn ready_valid_needs_sharing_and_shallow_fifos() {
        // a lone stream has no cross-layer head-of-line dependency
        assert!(!shared_channel_hazard(FlowControl::ReadyValid, 4, &[1000]));
        // sharing + any stream overflowing its FIFO admits the cycle
        assert!(shared_channel_hazard(FlowControl::ReadyValid, 4, &[1000, 10]));
        // FIFOs holding the whole stream can never refuse the DCFIFO head
        assert!(!shared_channel_hazard(FlowControl::ReadyValid, 1000, &[1000, 10]));
    }
}
