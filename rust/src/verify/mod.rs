//! `h2pipe check`: static verification of accelerator plans.
//!
//! A simulation-free analysis pass over any [`AcceleratorPlan`] —
//! in-memory or loaded from a `h2pipe.plan/v1` artifact — plus optional
//! fleet shard sets. It re-derives every invariant the paper states and
//! the compiler assumes, and reports violations as structured
//! diagnostics: a stable code (`H2P0xx`), a severity, a layer/field
//! anchor, a message, and a fix hint, renderable as human text or JSON.
//!
//! Rule families (see the registry table in DESIGN.md):
//!
//! 1. **Resource overcommit** (H2P001–H2P004) — M20K / AI tensor block /
//!    ALM totals vs [`crate::config::DeviceConfig`], cross-checked
//!    against the stored [`crate::compiler::ResourceUsage`].
//! 2. **HBM bandwidth feasibility** (H2P010–H2P021) — pseudo-channel
//!    structure (legal ids, chain-slot budgets, slot/chain coverage) and
//!    per-PC aggregate read demand at the plan's burst length vs the
//!    [`crate::config::EfficiencyTable`]-derated channel bandwidth.
//! 3. **Structural deadlock** (H2P030) — the Fig. 5 head-of-line cycle
//!    through the DCFIFO → burst-matching FIFO → layer-engine dependency
//!    graph; see [`deadlock`].
//! 4. **FIFO depth sufficiency** (H2P040) — the Fig. 6 analytic
//!    last-stage depth bound vs the planned depth.
//! 5. **Internal consistency** (H2P050–H2P055) — stored scalars
//!    (`est_throughput`, `bottleneck_cycles`, `free_bw_slots`,
//!    `hbm_read_efficiency`) recomputed from the `LayerPlan`s, and
//!    artifact provenance (options hash, model/device identity) vs the
//!    embedded options.
//! 6. **Fleet legality** (H2P060–H2P062) — shard cuts at single-stream
//!    boundaries, contiguous coverage, per-shard budgets; see [`fleet`].
//!
//! The checker never mutates a plan and spends no simulator cycles; it
//! is the trust layer that lets plan generators (the autotuner, the
//! multi-tenant placer) reject broken candidates cheaply, in the spirit
//! of the analytic buffer-sufficiency proofs of Petrica et al.

pub mod deadlock;
pub mod fleet;
mod rules;

pub use deadlock::{analyze_plan, shared_channel_hazard, DeadlockVerdict};
pub use fleet::check_partition;
pub use rules::last_stage_depth_bound;

use crate::compiler::AcceleratorPlan;
use crate::session::CompiledModel;
use crate::util::Json;

/// How bad a finding is. Ordered: `Note < Warn < Error`, so a deny
/// threshold is a simple `>=` comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Informational; never fails a check.
    Note,
    /// The plan is loadable and simulable but analytically suspect;
    /// fails `h2pipe check --deny warn`.
    Warn,
    /// The plan violates a hard invariant; always fails `h2pipe check`.
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// Stable diagnostic codes. The numeric ranges group the rule families;
/// codes are append-only — a released code never changes meaning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Code {
    /// H2P001: M20K blocks overcommitted vs the device.
    M20kOvercommit,
    /// H2P002: AI tensor blocks overcommitted vs the device.
    TensorBlockOvercommit,
    /// H2P003: ALMs overcommitted vs the device.
    AlmOvercommit,
    /// H2P004: stored `ResourceUsage` does not recompute from the layers.
    UsageMismatch,
    /// H2P010: a layer references an illegal pseudo-channel id.
    IllegalPc,
    /// H2P011: a pseudo-channel's chain slots are oversubscribed.
    PcOversubscribed,
    /// H2P012: a layer's PC slot total does not cover its chain demand.
    PcSlotMismatch,
    /// H2P020: per-PC read demand exceeds derated HBM bandwidth.
    BandwidthInfeasible,
    /// H2P021: burst length contradicts the burst policy, or is illegal.
    BurstPolicyMismatch,
    /// H2P030: ready/valid flow control admits the Fig. 5 deadlock cycle.
    ReadyValidDeadlock,
    /// H2P040: last-stage FIFO depth below the Fig. 6 analytic bound.
    FifoDepthShortfall,
    /// H2P050: stored analytic estimates do not recompute.
    EstimateMismatch,
    /// H2P051: stored `bottleneck_cycles` does not recompute.
    BottleneckMismatch,
    /// H2P052: stored `free_bw_slots` does not recompute.
    FreeBwMismatch,
    /// H2P053: stored `hbm_read_efficiency` contradicts the table.
    EfficiencyMismatch,
    /// H2P054: provenance options hash does not match embedded options.
    OptionsHashMismatch,
    /// H2P055: provenance / network / plan identity mismatch.
    ProvenanceMismatch,
    /// H2P060: a shard cut is crossed by a residual edge.
    IllegalCut,
    /// H2P061: shards do not tile the network contiguously.
    ShardCoverage,
    /// H2P062: a shard holds no weight layer.
    WeightlessShard,
}

impl Code {
    /// Every registered code, in registry order.
    pub const ALL: [Code; 20] = [
        Code::M20kOvercommit,
        Code::TensorBlockOvercommit,
        Code::AlmOvercommit,
        Code::UsageMismatch,
        Code::IllegalPc,
        Code::PcOversubscribed,
        Code::PcSlotMismatch,
        Code::BandwidthInfeasible,
        Code::BurstPolicyMismatch,
        Code::ReadyValidDeadlock,
        Code::FifoDepthShortfall,
        Code::EstimateMismatch,
        Code::BottleneckMismatch,
        Code::FreeBwMismatch,
        Code::EfficiencyMismatch,
        Code::OptionsHashMismatch,
        Code::ProvenanceMismatch,
        Code::IllegalCut,
        Code::ShardCoverage,
        Code::WeightlessShard,
    ];

    /// The stable wire identifier.
    pub fn as_str(self) -> &'static str {
        match self {
            Code::M20kOvercommit => "H2P001",
            Code::TensorBlockOvercommit => "H2P002",
            Code::AlmOvercommit => "H2P003",
            Code::UsageMismatch => "H2P004",
            Code::IllegalPc => "H2P010",
            Code::PcOversubscribed => "H2P011",
            Code::PcSlotMismatch => "H2P012",
            Code::BandwidthInfeasible => "H2P020",
            Code::BurstPolicyMismatch => "H2P021",
            Code::ReadyValidDeadlock => "H2P030",
            Code::FifoDepthShortfall => "H2P040",
            Code::EstimateMismatch => "H2P050",
            Code::BottleneckMismatch => "H2P051",
            Code::FreeBwMismatch => "H2P052",
            Code::EfficiencyMismatch => "H2P053",
            Code::OptionsHashMismatch => "H2P054",
            Code::ProvenanceMismatch => "H2P055",
            Code::IllegalCut => "H2P060",
            Code::ShardCoverage => "H2P061",
            Code::WeightlessShard => "H2P062",
        }
    }

    /// Severity a rule assigns when it emits this code.
    pub fn default_severity(self) -> Severity {
        match self {
            Code::BandwidthInfeasible | Code::FifoDepthShortfall => Severity::Warn,
            _ => Severity::Error,
        }
    }

    /// One-line registry meaning (mirrored in DESIGN.md).
    pub fn meaning(self) -> &'static str {
        match self {
            Code::M20kOvercommit => "M20K blocks exceed the device budget",
            Code::TensorBlockOvercommit => "AI tensor blocks exceed the device budget",
            Code::AlmOvercommit => "ALMs exceed the device budget",
            Code::UsageMismatch => "stored resource usage does not recompute from the layers",
            Code::IllegalPc => "layer references an out-of-range or excluded pseudo-channel",
            Code::PcOversubscribed => "pseudo-channel chain slots oversubscribed",
            Code::PcSlotMismatch => "layer PC slots do not cover its chain demand",
            Code::BandwidthInfeasible => "per-PC read demand exceeds derated HBM bandwidth",
            Code::BurstPolicyMismatch => "burst length contradicts the burst policy",
            Code::ReadyValidDeadlock => "ready/valid flow control admits the Fig. 5 deadlock",
            Code::FifoDepthShortfall => "last-stage FIFO depth below the analytic bound",
            Code::EstimateMismatch => "stored throughput/latency estimates do not recompute",
            Code::BottleneckMismatch => "stored bottleneck cycles do not recompute",
            Code::FreeBwMismatch => "stored free chain slots do not recompute",
            Code::EfficiencyMismatch => "stored read efficiency contradicts the table",
            Code::OptionsHashMismatch => "provenance options hash does not match the options",
            Code::ProvenanceMismatch => "provenance / network / plan identity mismatch",
            Code::IllegalCut => "shard cut crossed by a residual edge",
            Code::ShardCoverage => "shards do not tile the network contiguously",
            Code::WeightlessShard => "shard holds no weight layer",
        }
    }

    /// True for codes whose presence means the artifact itself is corrupt
    /// or tampered with (as opposed to describing an infeasible but
    /// well-formed plan). [`CompiledModel::from_json`] refuses to load on
    /// these; everything else loads and is reported by `h2pipe check`.
    pub fn is_integrity(self) -> bool {
        matches!(
            self,
            Code::UsageMismatch | Code::OptionsHashMismatch | Code::ProvenanceMismatch
        )
    }
}

/// One finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: Code,
    pub severity: Severity,
    /// What the finding anchors to: a layer name, `PC<n>`, a plan field
    /// path, or `shard<i>/...` for fleet findings.
    pub anchor: String,
    pub message: String,
    /// Suggested fix, when the rule knows one.
    pub hint: Option<String>,
}

impl Diagnostic {
    pub(crate) fn new(code: Code, anchor: impl Into<String>, message: impl Into<String>) -> Self {
        Self {
            code,
            severity: code.default_severity(),
            anchor: anchor.into(),
            message: message.into(),
            hint: None,
        }
    }

    pub(crate) fn hint(mut self, hint: impl Into<String>) -> Self {
        self.hint = Some(hint.into());
        self
    }

    /// `error[H2P001] usage.m20k: message` (+ indented hint line).
    pub fn render(&self) -> String {
        let mut s = format!(
            "{}[{}] {}: {}",
            self.severity.as_str(),
            self.code.as_str(),
            self.anchor,
            self.message
        );
        if let Some(h) = &self.hint {
            s.push_str("\n  hint: ");
            s.push_str(h);
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("code", self.code.as_str())
            .set("severity", self.severity.as_str())
            .set("anchor", self.anchor.as_str())
            .set("message", self.message.as_str());
        if let Some(h) = &self.hint {
            o.set("hint", h.as_str());
        }
        o
    }
}

/// The outcome of a check run: all findings, in rule order.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub(crate) fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }

    /// No findings at all (any severity).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == sev).count()
    }

    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    /// Would a run with this deny threshold fail?
    pub fn denies(&self, deny: Severity) -> bool {
        self.diagnostics.iter().any(|d| d.severity >= deny)
    }

    /// Human rendering: one block per diagnostic plus a summary line.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for d in &self.diagnostics {
            let _ = writeln!(s, "{}", d.render());
        }
        let _ = writeln!(
            s,
            "check: {} error(s), {} warning(s), {} note(s)",
            self.count(Severity::Error),
            self.count(Severity::Warn),
            self.count(Severity::Note)
        );
        s
    }

    pub fn to_json(&self) -> Json {
        let mut arr = Json::Arr(Vec::new());
        for d in &self.diagnostics {
            arr.push(d.to_json());
        }
        let mut o = Json::obj();
        o.set("diagnostics", arr)
            .set("errors", self.count(Severity::Error) as u64)
            .set("warnings", self.count(Severity::Warn) as u64)
            .set("notes", self.count(Severity::Note) as u64);
        o
    }
}

/// Run every plan-level rule family (1–5) over one accelerator plan.
pub fn check_plan(plan: &AcceleratorPlan) -> Report {
    let mut r = Report::default();
    rules::check_resources(plan, &mut r);
    rules::check_pcs(plan, &mut r);
    rules::check_burst_policy(plan, &mut r);
    deadlock::check(plan, &mut r);
    rules::check_fifo_depth(plan, &mut r);
    rules::check_consistency(plan, &mut r);
    r
}

/// Run the plan rules plus the artifact-level provenance rules (family 5)
/// over a compiled model.
pub fn check_artifact(cm: &CompiledModel) -> Report {
    let mut r = check_plan(cm.plan());
    rules::check_provenance(cm, &mut r);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_stable() {
        let mut seen = std::collections::HashSet::new();
        for c in Code::ALL {
            assert!(seen.insert(c.as_str()), "duplicate code {}", c.as_str());
            assert!(c.as_str().starts_with("H2P"), "{}", c.as_str());
            assert!(!c.meaning().is_empty());
        }
        assert_eq!(seen.len(), Code::ALL.len());
    }

    #[test]
    fn severity_orders_for_deny_thresholds() {
        assert!(Severity::Error > Severity::Warn);
        assert!(Severity::Warn > Severity::Note);
        let mut r = Report::default();
        assert!(!r.denies(Severity::Note));
        r.push(Diagnostic::new(Code::BandwidthInfeasible, "PC0", "demand over supply"));
        assert!(r.denies(Severity::Warn), "warn-severity finding trips --deny warn");
        assert!(!r.denies(Severity::Error), "but not the default error threshold");
    }

    #[test]
    fn render_carries_code_anchor_and_hint() {
        let d = Diagnostic::new(Code::M20kOvercommit, "usage.m20k", "7000 > 6847")
            .hint("offload more layers");
        let s = d.render();
        assert!(s.contains("error[H2P001]"), "{s}");
        assert!(s.contains("usage.m20k"), "{s}");
        assert!(s.contains("hint: offload"), "{s}");
        let j = d.to_json().to_string();
        assert!(j.contains("\"H2P001\""), "{j}");
    }

    #[test]
    fn integrity_codes_are_the_tamper_set() {
        let integrity: Vec<&str> =
            Code::ALL.iter().filter(|c| c.is_integrity()).map(|c| c.as_str()).collect();
        assert_eq!(integrity, ["H2P004", "H2P054", "H2P055"]);
    }
}
