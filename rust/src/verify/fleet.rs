//! Rule family 6: fleet legality of a [`PartitionPlan`].
//!
//! Shards must tile the original network contiguously (layer 0, the
//! input placeholder, belongs to no shard), every cut must fall on a
//! single-stream boundary — re-derived through the same
//! [`valid_cuts`] definition the planner uses, so a residual-spanning
//! cut cannot pass here and fail there — every shard must hold at least
//! one weight layer, and every shard plan must satisfy the full
//! single-device rule set against its own per-device budget.

use crate::cluster::partition::{valid_cuts, PartitionPlan};
use crate::nn::Network;

use super::{check_plan, Code, Diagnostic, Report};

/// Check a partition of `net` for fleet legality plus per-shard budgets.
pub fn check_partition(net: &Network, pp: &PartitionPlan) -> Report {
    let mut r = Report::default();
    if pp.network != net.name {
        r.push(Diagnostic::new(
            Code::ShardCoverage,
            "partition",
            format!("partition is for {:?} but checked against {:?}", pp.network, net.name),
        ));
    }
    if pp.shards.is_empty() {
        r.push(Diagnostic::new(Code::ShardCoverage, "partition", "partition has no shards"));
        return r;
    }

    let cuts = valid_cuts(net);
    let n = net.len();
    let mut expect = 1usize; // first real layer; 0 is the input placeholder
    for (i, s) in pp.shards.iter().enumerate() {
        let anchor = format!("shard{i}");
        if s.first_layer != expect || s.last_layer < s.first_layer || s.last_layer >= n {
            r.push(
                Diagnostic::new(
                    Code::ShardCoverage,
                    &anchor,
                    format!(
                        "shards must tile layers 1..={} contiguously: shard {i} claims \
                         {}..={} but layer {expect} is the next uncovered",
                        n - 1,
                        s.first_layer,
                        s.last_layer
                    ),
                )
                .hint("regenerate the partition with partition()/partition_at()"),
            );
        }
        expect = s.last_layer.saturating_add(1);
        if i > 0 {
            let c = s.first_layer;
            if c >= cuts.len() || !cuts[c] {
                r.push(
                    Diagnostic::new(
                        Code::IllegalCut,
                        &anchor,
                        format!(
                            "cut before layer {c} is crossed by a residual edge — more than \
                             one activation stream would span the inter-device link"
                        ),
                    )
                    .hint("cut only where valid_cuts() allows (single-stream boundaries)"),
                );
            }
        }
        if s.net.weight_layers().next().is_none() {
            r.push(
                Diagnostic::new(
                    Code::WeightlessShard,
                    &anchor,
                    format!(
                        "shard {i} (layers {}..={}) holds no weight layer; it would idle a \
                         whole device",
                        s.first_layer, s.last_layer
                    ),
                )
                .hint("merge the shard into a neighbour"),
            );
        }
        // Per-shard budgets: the full single-device rule set against the
        // shard's own device.
        let shard_report = check_plan(&s.plan);
        for mut d in shard_report.diagnostics {
            d.anchor = format!("{anchor}/{}", d.anchor);
            r.push(d);
        }
    }
    if expect != n {
        r.push(Diagnostic::new(
            Code::ShardCoverage,
            "partition",
            format!(
                "shards cover layers up to {} but the network has layers 1..={}",
                expect - 1,
                n - 1
            ),
        ));
    }
    r
}
