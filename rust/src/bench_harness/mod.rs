//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target regenerates one paper table/figure: benches
//! print a human-readable table to stdout AND write machine-readable JSON
//! under `target/bench_results/` so EXPERIMENTS.md numbers can be traced
//! to artifacts.

use std::time::Instant;

use crate::util::{Json, Percentiles};

/// True for a full paper-figure run, false for the 1-iteration smoke
/// configuration.
///
/// Cargo passes `--bench` to `harness = false` targets only under
/// `cargo bench`; the same binaries run under `cargo test` (they are
/// registered with `test = true`) receive no such flag and default to the
/// smoke configuration, so every bench target's entry path is compiled
/// AND executed by the tier-1 gate and cannot silently rot. Set
/// `H2PIPE_BENCH_FULL=1` to force a full run regardless of invocation.
pub fn full_run() -> bool {
    std::env::args().any(|a| a == "--bench")
        || matches!(std::env::var("H2PIPE_BENCH_FULL"), Ok(v) if !v.is_empty() && v != "0")
}

/// `full` when [`full_run`], else `quick` — for scaling bench workloads.
pub fn scaled(full: u64, quick: u64) -> u64 {
    if full_run() { full } else { quick }
}

/// Timing statistics for one measured closure.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    pub iters: u32,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// A named bench run collecting measurements and result rows.
#[derive(Debug)]
pub struct Bench {
    name: String,
    measurements: Vec<Measurement>,
    results: Json,
    started: Instant,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("== bench: {name} ==");
        Self {
            name: name.to_string(),
            measurements: Vec::new(),
            results: Json::obj(),
            started: Instant::now(),
        }
    }

    /// Time `f` for `iters` iterations after `warmup` unmeasured runs.
    pub fn time(&mut self, label: &str, warmup: u32, iters: u32, mut f: impl FnMut()) -> Measurement {
        for _ in 0..warmup {
            f();
        }
        let mut p = Percentiles::new();
        let mut mean = crate::util::OnlineStats::new();
        for _ in 0..iters.max(1) {
            let t = Instant::now();
            f();
            let dt = t.elapsed().as_secs_f64();
            p.push(dt);
            mean.push(dt);
        }
        let m = Measurement {
            label: label.to_string(),
            iters,
            mean_s: mean.mean(),
            median_s: p.median(),
            stddev_s: mean.stddev(),
            min_s: p.min(),
        };
        println!(
            "  {label:40} mean {:>10.3} ms   median {:>10.3} ms   sd {:>8.3} ms",
            m.mean_s * 1e3,
            m.median_s * 1e3,
            m.stddev_s * 1e3
        );
        self.measurements.push(m.clone());
        m
    }

    /// Attach a result value (a table row, a figure series...) to the
    /// bench's JSON output.
    pub fn record(&mut self, key: &str, value: impl Into<Json>) {
        self.results.set(key, value);
    }

    /// Print a fixed-width table of rows.
    pub fn table(&self, headers: &[&str], rows: &[Vec<String>]) {
        let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
        for r in rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let fmt_row = |cells: Vec<String>| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(headers.iter().map(|s| s.to_string()).collect()));
        for r in rows {
            println!("{}", fmt_row(r.clone()));
        }
    }

    /// Write JSON results to `target/bench_results/<name>.json` for full
    /// runs; smoke runs (see [`full_run`]) go to
    /// `target/bench_results/smoke/<name>.json` so `cargo test` can never
    /// clobber recorded paper-figure data with scaled-down numbers.
    pub fn finish(mut self) {
        let full = full_run();
        let mut meas = Json::Arr(vec![]);
        for m in &self.measurements {
            let mut o = Json::obj();
            o.set("label", m.label.as_str())
                .set("iters", m.iters)
                .set("mean_s", m.mean_s)
                .set("median_s", m.median_s)
                .set("stddev_s", m.stddev_s)
                .set("min_s", m.min_s);
            meas.push(o);
        }
        self.results.set("bench", self.name.as_str());
        self.results.set("mode", if full { "full" } else { "smoke" });
        self.results.set("measurements", meas);
        self.results.set("wall_s", self.started.elapsed().as_secs_f64());
        let dir = if full {
            std::path::Path::new("target/bench_results")
        } else {
            std::path::Path::new("target/bench_results/smoke")
        };
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, self.results.to_pretty()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("results -> {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_measures_something() {
        let mut b = Bench::new("test_bench_unit");
        let m = b.time("spin", 1, 5, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.mean_s * 1.5);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn record_and_finish_writes_json() {
        let mut b = Bench::new("test_bench_json");
        b.record("answer", 42u64);
        b.finish();
        // under `cargo test` (no --bench flag) results land in smoke/
        let p = std::path::Path::new("target/bench_results/smoke/test_bench_json.json");
        let s = std::fs::read_to_string(p).unwrap();
        assert!(s.contains("\"answer\": 42"));
        assert!(s.contains("\"mode\": \"smoke\""));
    }

    #[test]
    fn table_renders_without_panic() {
        let b = Bench::new("test_bench_table");
        b.table(
            &["model", "im/s"],
            &[vec!["ResNet-18".into(), "4174".into()], vec!["VGG-16".into(), "545".into()]],
        );
    }
}
