//! DRAM bank state machine.
//!
//! Each HBM2 pseudo-channel owns 16 banks (4 groups of 4). A bank is a
//! row-addressed array: a row must be ACTIVATEd into the row buffer before
//! column reads/writes, and PRECHARGEd before a different row can open.
//! The controller consults [`Bank`] for *when* each command becomes legal;
//! the bank enforces tRCD / tRP / tRAS and write-recovery locally, while
//! inter-bank constraints (tRRD, tFAW, bus contention) live in the
//! controller.

use crate::config::HbmTiming;

/// Observable bank state (for tests and debugging).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BankState {
    /// No row open.
    Idle,
    /// Row open and usable (possibly still settling tRCD — check
    /// `ready_for_cas`).
    Active(u64),
}

/// One DRAM bank.
#[derive(Debug, Clone)]
pub struct Bank {
    /// Open row, if any.
    open_row: Option<u64>,
    /// Cycle at which the most recent ACTIVATE was issued.
    act_cycle: u64,
    /// Earliest cycle a CAS (RD/WR) may issue (tRCD after ACT).
    cas_ready_at: u64,
    /// Earliest cycle a PRECHARGE may issue (tRAS after ACT, and write
    /// recovery tWR after the last write burst ends).
    pre_ready_at: u64,
    /// Earliest cycle an ACTIVATE may issue (tRP after PRE).
    act_ready_at: u64,
}

impl Default for Bank {
    fn default() -> Self {
        Self::new()
    }
}

impl Bank {
    pub fn new() -> Self {
        Self { open_row: None, act_cycle: 0, cas_ready_at: 0, pre_ready_at: 0, act_ready_at: 0 }
    }

    pub fn state(&self) -> BankState {
        match self.open_row {
            Some(r) => BankState::Active(r),
            None => BankState::Idle,
        }
    }

    /// Is `row` open in the row buffer (a "row hit")?
    pub fn row_hit(&self, row: u64) -> bool {
        self.open_row == Some(row)
    }

    /// True if the bank is idle (no open row) and an ACT may issue at
    /// `cycle`.
    pub fn can_activate(&self, cycle: u64) -> bool {
        self.open_row.is_none() && cycle >= self.act_ready_at
    }

    /// True if a PRECHARGE may issue at `cycle` (row open, tRAS and tWR
    /// satisfied).
    pub fn can_precharge(&self, cycle: u64) -> bool {
        self.open_row.is_some() && cycle >= self.pre_ready_at
    }

    /// True if a CAS to `row` may issue at `cycle`.
    pub fn can_cas(&self, row: u64, cycle: u64) -> bool {
        self.row_hit(row) && cycle >= self.cas_ready_at
    }

    /// Earliest cycle a CAS to the open row may issue (tRCD stamp) — the
    /// event scheduler's wake bound; only meaningful while a row is open.
    pub fn cas_ready_at(&self) -> u64 {
        self.cas_ready_at
    }

    /// Earliest cycle a PRECHARGE may issue (tRAS / tWR stamp).
    pub fn pre_ready_at(&self) -> u64 {
        self.pre_ready_at
    }

    /// Earliest cycle an ACTIVATE may issue (tRP stamp).
    pub fn act_ready_at(&self) -> u64 {
        self.act_ready_at
    }

    /// Issue ACTIVATE of `row` at `cycle`. Caller must have checked
    /// `can_activate`.
    pub fn activate(&mut self, row: u64, cycle: u64, t: &HbmTiming) {
        debug_assert!(self.can_activate(cycle), "illegal ACT at {cycle}");
        self.open_row = Some(row);
        self.act_cycle = cycle;
        self.cas_ready_at = cycle + t.t_rcd as u64;
        self.pre_ready_at = cycle + t.t_ras as u64;
    }

    /// Issue PRECHARGE at `cycle`. Caller must have checked
    /// `can_precharge`.
    pub fn precharge(&mut self, cycle: u64, t: &HbmTiming) {
        debug_assert!(self.can_precharge(cycle), "illegal PRE at {cycle}");
        self.open_row = None;
        self.act_ready_at = cycle + t.t_rp as u64;
    }

    /// Record a read CAS at `cycle` (no extra bank-local constraint beyond
    /// tRAS already tracked; data-bus scheduling is the controller's job).
    pub fn read_cas(&mut self, _cycle: u64) {}

    /// Record a write CAS at `cycle` whose data burst ends at
    /// `data_end`: precharge must additionally wait tWR after the burst.
    pub fn write_cas(&mut self, data_end: u64, t: &HbmTiming) {
        self.pre_ready_at = self.pre_ready_at.max(data_end + t.t_wr as u64);
    }

    /// Force-close for refresh bookkeeping.
    pub fn close_for_refresh(&mut self, cycle: u64, t: &HbmTiming) {
        self.open_row = None;
        self.act_ready_at = self.act_ready_at.max(cycle + t.t_rp as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> HbmTiming {
        HbmTiming::hbm2_default()
    }

    #[test]
    fn fresh_bank_is_idle_and_activatable() {
        let b = Bank::new();
        assert_eq!(b.state(), BankState::Idle);
        assert!(b.can_activate(0));
        assert!(!b.can_precharge(0));
        assert!(!b.can_cas(3, 0));
    }

    #[test]
    fn act_then_cas_respects_trcd() {
        let timing = t();
        let mut b = Bank::new();
        b.activate(7, 100, &timing);
        assert_eq!(b.state(), BankState::Active(7));
        assert!(!b.can_cas(7, 100 + timing.t_rcd as u64 - 1));
        assert!(b.can_cas(7, 100 + timing.t_rcd as u64));
        // wrong row is never CAS-able
        assert!(!b.can_cas(8, 100 + timing.t_rcd as u64));
    }

    #[test]
    fn precharge_respects_tras_then_act_respects_trp() {
        let timing = t();
        let mut b = Bank::new();
        b.activate(7, 100, &timing);
        let pre_at = 100 + timing.t_ras as u64;
        assert!(!b.can_precharge(pre_at - 1));
        assert!(b.can_precharge(pre_at));
        b.precharge(pre_at, &timing);
        assert_eq!(b.state(), BankState::Idle);
        assert!(!b.can_activate(pre_at + timing.t_rp as u64 - 1));
        assert!(b.can_activate(pre_at + timing.t_rp as u64));
    }

    #[test]
    fn write_recovery_delays_precharge() {
        let timing = t();
        let mut b = Bank::new();
        b.activate(1, 0, &timing);
        let data_end = 50;
        b.write_cas(data_end, &timing);
        let want = data_end + timing.t_wr as u64;
        assert!(!b.can_precharge(want - 1));
        assert!(b.can_precharge(want));
    }

    #[test]
    fn refresh_close_requires_trp_before_act() {
        let timing = t();
        let mut b = Bank::new();
        b.activate(1, 0, &timing);
        b.close_for_refresh(200, &timing);
        assert_eq!(b.state(), BankState::Idle);
        assert!(!b.can_activate(200 + timing.t_rp as u64 - 1));
        assert!(b.can_activate(200 + timing.t_rp as u64));
    }
}
