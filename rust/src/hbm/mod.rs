//! Cycle-level HBM2 substrate.
//!
//! The paper characterizes one pseudo-channel of the Stratix 10 NX's HBM2
//! (§III-A, Fig. 3) and builds the whole H2PIPE memory system on the
//! result. We do not have the silicon, so this module implements the
//! substrate the paper measured: DRAM banks with JEDEC-style timing
//! ([`bank`]), a pseudo-channel controller with a row/column command bus
//! shared between the two PCs of a channel ([`controller`]), 4-Hi stacks
//! ([`stack`]), and the AXI traffic generator used to regenerate
//! Fig. 3a/3b ([`traffic`]).
//!
//! All time is in *controller clock cycles* (400 MHz, 2.5 ns).

pub mod bank;
pub mod controller;
pub mod stack;
pub mod traffic;

pub use bank::{Bank, BankState};
pub use controller::{Completion, Dir, PcFaultEvent, PcStats, PseudoChannel, Request};
pub use stack::{CmdBus, Channel, HbmStack};
pub use traffic::{AddressPattern, TrafficConfig, TrafficGen, TrafficReport};

/// Convert controller cycles to nanoseconds (2.5 ns per cycle at 400 MHz).
pub fn cycles_to_ns(cycles: u64, controller_mhz: u32) -> f64 {
    cycles as f64 * 1e3 / controller_mhz as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_conversion() {
        assert_eq!(cycles_to_ns(400, 400), 1000.0);
        assert_eq!(cycles_to_ns(160, 400), 400.0);
    }
}
