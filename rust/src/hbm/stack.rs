//! HBM channel and stack composition.
//!
//! A 4-Hi HBM2 stack has 4 dies x 2 channels; each channel splits into two
//! pseudo-channels with private data paths but a *shared* row/column
//! command bus (§II-C, Fig. 2). [`CmdBus`] models that sharing: per cycle
//! there is one row-command slot and one column-command slot for the two
//! PCs of a channel, with alternating priority for fairness.

use crate::config::{HbmGeometry, HbmTiming};
use crate::hbm::controller::{PcTuning, PseudoChannel};

/// Per-cycle command-slot availability for one channel.
#[derive(Debug)]
pub struct CmdBus {
    row_free: bool,
    col_free: bool,
}

impl Default for CmdBus {
    fn default() -> Self {
        Self::new()
    }
}

impl CmdBus {
    pub fn new() -> Self {
        Self { row_free: true, col_free: true }
    }

    /// Claim this cycle's row-command slot (ACT/PRE/REF).
    pub fn take_row_slot(&mut self) -> bool {
        std::mem::take(&mut self.row_free)
    }

    /// Claim this cycle's column-command slot (RD/WR).
    pub fn take_col_slot(&mut self) -> bool {
        std::mem::take(&mut self.col_free)
    }
}

/// One HBM channel: two pseudo-channels sharing a command bus.
#[derive(Debug, Clone)]
pub struct Channel {
    pub pcs: [PseudoChannel; 2],
    /// Alternates each cycle so neither PC starves on command slots.
    priority: usize,
}

impl Channel {
    pub fn new(geom: &HbmGeometry, timing: &HbmTiming, tuning: PcTuning) -> Self {
        Self {
            pcs: [
                PseudoChannel::new(geom, timing, tuning.clone()),
                PseudoChannel::new(geom, timing, tuning),
            ],
            priority: 0,
        }
    }

    /// Advance both PCs one cycle, arbitrating the shared command bus.
    pub fn tick(&mut self) {
        let first = self.priority;
        self.tick_with_priority(first);
        self.priority = 1 - first;
    }

    /// One channel cycle with the command-bus priority given explicitly.
    ///
    /// The event-driven simulation path ticks channels sparsely; since
    /// [`Self::tick`] alternates priority every cycle starting from PC 0,
    /// the priority at controller cycle `h` is exactly `h % 2`, which the
    /// caller passes here. Does not advance the internal alternation
    /// state (the fast path derives it from the cycle instead).
    pub fn tick_with_priority(&mut self, first: usize) {
        let mut bus = CmdBus::new();
        let second = 1 - first;
        self.pcs[first].tick(&mut bus);
        self.pcs[second].tick(&mut bus);
    }
}

/// A full HBM stack: `pcs_per_stack / 2` channels.
#[derive(Debug, Clone)]
pub struct HbmStack {
    pub channels: Vec<Channel>,
}

impl HbmStack {
    pub fn new(geom: &HbmGeometry, timing: &HbmTiming, tuning: PcTuning) -> Self {
        let n_ch = (geom.pcs_per_stack / 2) as usize;
        Self {
            channels: (0..n_ch).map(|_| Channel::new(geom, timing, tuning.clone())).collect(),
        }
    }

    /// Pseudo-channel count.
    pub fn num_pcs(&self) -> usize {
        self.channels.len() * 2
    }

    /// Borrow a PC by stack-local index (0..num_pcs).
    pub fn pc(&mut self, idx: usize) -> &mut PseudoChannel {
        &mut self.channels[idx / 2].pcs[idx % 2]
    }

    /// Advance the whole stack one controller cycle.
    pub fn tick(&mut self) {
        for ch in &mut self.channels {
            ch.tick();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::hbm::controller::{Dir, Request};

    #[test]
    fn stack_has_16_pcs() {
        let d = DeviceConfig::stratix10_nx2100();
        let s = HbmStack::new(&d.hbm, &d.hbm_timing, PcTuning::default());
        assert_eq!(s.num_pcs(), 16);
        assert_eq!(s.channels.len(), 8);
    }

    #[test]
    fn cmd_bus_slots_are_single_use() {
        let mut bus = CmdBus::new();
        assert!(bus.take_row_slot());
        assert!(!bus.take_row_slot());
        assert!(bus.take_col_slot());
        assert!(!bus.take_col_slot());
    }

    #[test]
    fn shared_command_bus_throttles_paired_pcs() {
        // Saturate both PCs of one channel with small random bursts, then
        // compare against a PC that owns its command bus alone: sharing
        // must cost efficiency at small burst lengths.
        let d = DeviceConfig::stratix10_nx2100();
        let run_shared = || {
            let mut ch = Channel::new(&d.hbm, &d.hbm_timing, PcTuning::default());
            let mut rng = crate::util::XorShift64::new(3);
            let mut id = 0;
            for _ in 0..40_000 {
                for pc in ch.pcs.iter_mut() {
                    if pc.can_accept(8) {
                        let addr = rng.next_below(1 << 26) & !31;
                        pc.push(Request { id, dir: Dir::Read, addr, burst: 2 });
                        id += 1;
                    }
                }
                ch.tick();
            }
            (ch.pcs[0].stats.efficiency() + ch.pcs[1].stats.efficiency()) / 2.0
        };
        let run_alone = || {
            let mut pc = PseudoChannel::new(&d.hbm, &d.hbm_timing, PcTuning::default());
            let mut rng = crate::util::XorShift64::new(3);
            let mut id = 0;
            for _ in 0..40_000 {
                if pc.can_accept(8) {
                    let addr = rng.next_below(1 << 26) & !31;
                    pc.push(Request { id, dir: Dir::Read, addr, burst: 2 });
                    id += 1;
                }
                let mut bus = CmdBus::new();
                pc.tick(&mut bus);
            }
            pc.stats.efficiency()
        };
        let shared = run_shared();
        let alone = run_alone();
        assert!(
            shared < alone,
            "shared command bus ({shared:.3}) should be slower than dedicated ({alone:.3})"
        );
    }

    #[test]
    fn both_pcs_make_progress() {
        let d = DeviceConfig::stratix10_nx2100();
        let mut ch = Channel::new(&d.hbm, &d.hbm_timing, PcTuning::default());
        let mut id = 0;
        let mut rng = crate::util::XorShift64::new(11);
        for _ in 0..20_000 {
            for pc in ch.pcs.iter_mut() {
                if pc.can_accept(8) {
                    let addr = rng.next_below(1 << 24) & !31;
                    pc.push(Request { id, dir: Dir::Read, addr, burst: 8 });
                    id += 1;
                }
            }
            ch.tick();
        }
        let r0 = ch.pcs[0].stats.reads;
        let r1 = ch.pcs[1].stats.reads;
        assert!(r0 > 0 && r1 > 0);
        let ratio = r0 as f64 / r1 as f64;
        assert!((0.8..1.25).contains(&ratio), "unfair arbitration: {r0} vs {r1}");
    }
}
