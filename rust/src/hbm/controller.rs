//! Pseudo-channel controller.
//!
//! Models the hardened HBM2 controller behind the 256-bit / 400 MHz user
//! interface of the Stratix 10 NX (§II-C): a data-outstanding-limited
//! request queue (back-pressure is the AXI `!ready` the paper's traffic
//! generator polls), a shallow-reorder FR-FCFS scheduler over 16 banks, a
//! single data bus with per-burst gaps (DQS preamble / tCCD) and
//! read/write turnaround penalties, inter-bank tRRD / tFAW constraints,
//! and all-bank refresh every tREFI.
//!
//! The two PCs of a channel share a row/column command bus; the controller
//! asks the [`super::stack::CmdBus`] for a slot before issuing a command.
//! Together with the shallow reorder window, this is what makes small
//! random bursts pay ~2x the per-beat cost of long bursts (Fig. 3a).
//!
//! Calibration targets (paper §III-A, Fig. 3): random saturated reads
//! ~0.83 efficiency at BL8 rising to ~0.93 at BL32, BL<4 around half the
//! BL>=8 level; writes peaking ~15 pp below reads; saturated average read
//! latency ~400 ns at BL32 and rising as bursts shrink; worst-case read
//! latency at BL>=8 around 1.2 us (the paper's 512-deep FIFO bound).

use std::collections::VecDeque;

use crate::config::{HbmGeometry, HbmTiming};
use crate::faults::{HbmFaultSpec, ThrottleWindow};
use crate::hbm::bank::Bank;
use crate::hbm::stack::CmdBus;
use crate::util::XorShift64;

/// Transfer direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
}

/// One AXI burst request presented to the controller.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    /// Caller-assigned id, returned in the [`Completion`].
    pub id: u64,
    pub dir: Dir,
    /// Byte address within the pseudo-channel.
    pub addr: u64,
    /// Burst length in 256-bit beats (1..=32).
    pub burst: u32,
}

/// A finished request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: u64,
    pub dir: Dir,
    /// Cycle the request was accepted into the queue.
    pub accept_cycle: u64,
    /// Cycle the last data beat transferred.
    pub done_cycle: u64,
}

/// Aggregate controller statistics.
#[derive(Debug, Clone, Default)]
pub struct PcStats {
    /// Data beats actually transferred.
    pub data_cycles: u64,
    /// Cycles with at least one request queued or data in flight.
    pub busy_cycles: u64,
    /// Total cycles ticked.
    pub total_cycles: u64,
    /// Commands issued.
    pub acts: u64,
    pub pres: u64,
    pub reads: u64,
    pub writes: u64,
    pub refreshes: u64,
    /// Requests that reused an already-open row.
    pub row_hits: u64,
    pub row_misses: u64,
    /// Fault injection (`simulate --faults`): transient read errors fired
    /// by the plan's HBM spec. Conservation invariant:
    /// `faults_injected == fault_replays + faults_dropped`.
    pub faults_injected: u64,
    /// Faulted bursts re-enqueued for replay (each pays the full
    /// re-arbitration + data-bus cost again).
    pub fault_replays: u64,
    /// Faulted bursts whose replay budget was exhausted — delivered
    /// corrupt and *counted*, never silently lost.
    pub faults_dropped: u64,
    /// Cycles a thermal-throttle window denied CAS issue while work was
    /// queued.
    pub throttled_cycles: u64,
}

impl PcStats {
    /// Efficiency as the paper measures it: data-beat cycles over total
    /// observed cycles.
    pub fn efficiency(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.data_cycles as f64 / self.total_cycles as f64
    }

    /// Efficiency over busy cycles only — for workloads with idle gaps.
    pub fn busy_efficiency(&self) -> f64 {
        if self.busy_cycles == 0 {
            return 0.0;
        }
        self.data_cycles as f64 / self.busy_cycles as f64
    }

    /// Open-row hit rate over all row events (0 when no row was touched).
    pub fn row_hit_rate(&self) -> f64 {
        let events = self.row_hits + self.row_misses;
        if events == 0 {
            return 0.0;
        }
        self.row_hits as f64 / events as f64
    }
}

/// Internal per-request bookkeeping.
#[derive(Debug, Clone, Copy)]
struct Pending {
    req: Request,
    accept_cycle: u64,
    bank: usize,
    row: u64,
    /// Set when the scheduler issued an ACT on behalf of this request —
    /// used to classify row hits/misses at CAS time.
    caused_act: bool,
    /// Times this request's burst was replayed after a transient read
    /// error (fault injection only; always 0 on the happy path).
    replays: u32,
}

/// Seeded fault state attached to one PC by `simulate --faults`.
#[derive(Debug, Clone)]
struct PcFaults {
    spec: Option<HbmFaultSpec>,
    throttle: Vec<ThrottleWindow>,
    rng: XorShift64,
}

/// A discrete injection event, drained like completions so the weight
/// subsystem can forward it to the observability probe.
#[derive(Debug, Clone, Copy)]
pub struct PcFaultEvent {
    /// Controller cycle the faulted CAS issued.
    pub cycle: u64,
    /// The faulted request's caller-assigned id.
    pub id: u64,
    /// `true` → re-enqueued for replay; `false` → replay budget
    /// exhausted, delivered and counted as dropped.
    pub replayed: bool,
}

/// Scheduling/capacity knobs of the hardened controller model.
#[derive(Debug, Clone)]
pub struct PcTuning {
    /// Outstanding-data limit in beats (the AXI read-data reorder buffer
    /// of the hardened controller). 144 beats = 4.5 KiB.
    pub outstanding_beats: u32,
    /// How many queue entries the row-prep pass may look ahead — the
    /// controller's shallow reorder window.
    pub lookahead: usize,
}

impl Default for PcTuning {
    fn default() -> Self {
        Self { outstanding_beats: 144, lookahead: 6 }
    }
}

/// The pseudo-channel controller.
#[derive(Debug, Clone)]
pub struct PseudoChannel {
    timing: HbmTiming,
    tuning: PcTuning,
    banks: Vec<Bank>,
    bank_groups: u32,
    row_bytes: u64,
    queue: VecDeque<Pending>,
    queued_beats: u32,
    /// Cycle at which the data bus becomes free.
    data_free_at: u64,
    /// Direction of the last data burst (turnaround penalties).
    last_dir: Option<Dir>,
    /// (bank, row) of the last CAS: consecutive same-row bursts stream
    /// without the pipeline re-steer gap.
    last_loc: Option<(usize, u64)>,
    /// Cycle of last ACT (tRRD) and sliding window of ACT times (tFAW).
    last_act_at: u64,
    act_window: VecDeque<u64>,
    /// Refresh bookkeeping.
    next_refresh_at: u64,
    refresh_until: u64,
    cycle: u64,
    completions: Vec<Completion>,
    faults: Option<PcFaults>,
    fault_events: Vec<PcFaultEvent>,
    pub stats: PcStats,
}

impl PseudoChannel {
    pub fn new(geom: &HbmGeometry, timing: &HbmTiming, tuning: PcTuning) -> Self {
        Self {
            timing: timing.clone(),
            tuning,
            banks: (0..geom.banks_per_pc).map(|_| Bank::new()).collect(),
            bank_groups: geom.bank_groups,
            row_bytes: geom.row_bytes as u64,
            queue: VecDeque::new(),
            queued_beats: 0,
            data_free_at: 0,
            last_dir: None,
            last_loc: None,
            last_act_at: 0,
            act_window: VecDeque::new(),
            next_refresh_at: timing.t_refi as u64,
            refresh_until: 0,
            cycle: 0,
            completions: Vec::new(),
            faults: None,
            fault_events: Vec::new(),
            stats: PcStats::default(),
        }
    }

    /// Arm fault injection on this PC: a transient read-error spec, the
    /// throttle windows addressed to it, and the per-site RNG seed
    /// (derive with [`crate::faults::site_seed`] so PCs never share a
    /// stream). Passing `None` and an empty window list is a no-op.
    pub fn inject_faults(
        &mut self,
        spec: Option<HbmFaultSpec>,
        throttle: Vec<ThrottleWindow>,
        seed: u64,
    ) {
        if spec.is_none() && throttle.is_empty() {
            return;
        }
        self.faults = Some(PcFaults { spec, throttle, rng: XorShift64::new(seed) });
    }

    /// Current cycle.
    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// AXI back-pressure for a burst of `burst` beats.
    pub fn can_accept(&self, burst: u32) -> bool {
        self.queued_beats + burst <= self.tuning.outstanding_beats
    }

    /// Number of queued (not yet CAS-issued) requests.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Beats currently held in the queue — the quantity bounded by
    /// [`PcTuning::outstanding_beats`]. Exposed so property tests can
    /// assert the bound is never exceeded (fault replays restore exactly
    /// what the faulted issue subtracted, so the invariant holds under
    /// injection too).
    pub fn queued_beats(&self) -> u32 {
        self.queued_beats
    }

    /// The configured outstanding-beats capacity.
    pub fn outstanding_limit(&self) -> u32 {
        self.tuning.outstanding_beats
    }

    /// Accept a request. Returns false (and drops it) when back-pressured —
    /// callers should check [`Self::can_accept`] first, mirroring AXI
    /// `valid && ready`.
    pub fn push(&mut self, req: Request) -> bool {
        if !self.can_accept(req.burst) {
            return false;
        }
        debug_assert!((1..=32).contains(&req.burst), "burst {} out of range", req.burst);
        let (bank, row) = self.map_addr(req.addr);
        self.queued_beats += req.burst;
        self.queue.push_back(Pending {
            req,
            accept_cycle: self.cycle,
            bank,
            row,
            caused_act: false,
            replays: 0,
        });
        true
    }

    /// Address mapping: low bits select the column within a row, then the
    /// bank (bank-interleaved rows spread sequential bursts across banks),
    /// then the row — the standard BRC-ish mapping an FPGA HBM IP uses.
    fn map_addr(&self, addr: u64) -> (usize, u64) {
        let nb = self.banks.len() as u64;
        let row_addr = addr / self.row_bytes;
        let bank = (row_addr % nb) as usize;
        let row = row_addr / nb;
        (bank, row)
    }

    /// Drain completions recorded since the last call.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Drain fault-injection events recorded since the last call.
    pub fn drain_fault_events(&mut self) -> Vec<PcFaultEvent> {
        std::mem::take(&mut self.fault_events)
    }

    /// Does the plan's read-error window fire for the CAS issuing now?
    fn roll_fault(&mut self) -> bool {
        let cycle = self.cycle;
        match &mut self.faults {
            Some(f) => match &f.spec {
                Some(s) if cycle >= s.start && cycle < s.end => f.rng.next_bool(s.prob),
                _ => false,
            },
            None => false,
        }
    }

    /// Is CAS issue denied this cycle by a thermal-throttle window?
    fn cas_throttled(&self) -> bool {
        match &self.faults {
            Some(f) => f.throttle.iter().any(|t| t.denies(self.cycle)),
            None => false,
        }
    }

    /// True if the controller has no queued requests and the data bus is
    /// idle.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.cycle >= self.data_free_at
    }

    fn trim_act_window(&mut self) {
        let faw = self.timing.t_faw as u64;
        while let Some(&t0) = self.act_window.front() {
            if t0 + faw <= self.cycle {
                self.act_window.pop_front();
            } else {
                break;
            }
        }
    }

    fn can_act_interbank(&self) -> bool {
        self.cycle >= self.last_act_at + self.timing.t_rrd as u64 && self.act_window.len() < 4
    }

    /// Check whether a CAS issued *this cycle* lands its data legally on
    /// the bus, and return the data start cycle if so.
    ///
    /// DDR timing is rigid: a CAS at cycle `c` produces data exactly at
    /// `c + CL` (reads) / `c + CWL` (writes); it may only issue if the bus
    /// is clear of the previous burst plus the inter-burst gap (DQS
    /// preamble / tCCD) and any direction-turnaround penalty.
    fn cas_data_start(&self, dir: Dir, bank: usize, row: u64) -> Option<u64> {
        let cas_lat = match dir {
            Dir::Read => self.timing.t_cl as u64,
            Dir::Write => self.timing.t_cwl as u64,
        };
        let start = self.cycle + cas_lat;
        (start >= self.bus_ready_for(dir, bank, row)).then_some(start)
    }

    /// Earliest cycle the data bus could carry a new burst to
    /// `(bank, row)` in direction `dir`: the bus-free stamp plus re-steer,
    /// bank-group, and turnaround gaps. Pure function of bus state, so the
    /// event scheduler can solve `cycle + CAS latency >= bus_ready` for
    /// the earliest legal CAS cycle in closed form.
    fn bus_ready_for(&self, dir: Dir, bank: usize, row: u64) -> u64 {
        let mut bus_ready = self.data_free_at;
        // Streaming within one open row continues gap-free (the hardened
        // controller keeps its pipeline steered); switching transaction
        // target pays the re-steer gap plus, within a bank group, the
        // tCCD_L - tCCD_S spread.
        if self.last_loc != Some((bank, row)) {
            bus_ready += match dir {
                Dir::Read => self.timing.t_rd_gap as u64,
                Dir::Write => self.timing.t_wr_gap as u64,
            };
            if let Some((b, _)) = self.last_loc {
                if b != bank && b as u32 % self.bank_groups == bank as u32 % self.bank_groups {
                    bus_ready += (self.timing.t_ccd_l - self.timing.t_ccd_s) as u64;
                }
            }
        }
        // direction turnaround
        if let Some(prev) = self.last_dir {
            if prev != dir {
                let turn = match dir {
                    Dir::Read => self.timing.t_wtr as u64,
                    Dir::Write => self.timing.t_rtw as u64,
                };
                bus_ready += turn;
            }
        }
        bus_ready
    }

    /// Fast-forward this controller over `[self.cycle, to)` — a span the
    /// event scheduler has proven command-inert (no CAS / ACT / PRE / REF
    /// can issue and no request arrives; see [`Self::next_wake`]). Only
    /// the per-cycle counters advance, applied here in closed form. The
    /// queue, bank stamps, bus stamps, and refresh bookkeeping are all
    /// constant across such a span by construction.
    pub(crate) fn catch_up(&mut self, to: u64) {
        if to <= self.cycle {
            return;
        }
        let span = to - self.cycle;
        self.stats.total_cycles += span;
        let busy = if self.queue.is_empty() {
            self.data_free_at.saturating_sub(self.cycle).min(span)
        } else {
            span
        };
        self.stats.busy_cycles += busy;
        // Throttle denial is only accounted in the normal scheduling phase
        // (the slow path early-returns before the throttle check while
        // refresh-blocked or refresh-urgent) and only with work queued.
        if !self.queue.is_empty() {
            if let Some(f) = &self.faults {
                let lo = self.cycle.max(self.refresh_until);
                let hi = to.min(self.next_refresh_at);
                if lo < hi {
                    self.stats.throttled_cycles +=
                        crate::faults::count_denied(&f.throttle, lo, hi);
                }
            }
        }
        self.cycle = to;
    }

    /// Conservative next-event bound: the earliest cycle `>= now` at
    /// which this controller could issue *any* command (CAS, ACT, PRE, or
    /// REF), assuming no new requests arrive. Never late — every cycle
    /// strictly before the bound is command-inert, so [`Self::catch_up`]
    /// may skip it; waking early is harmless (the real tick no-ops and
    /// the bound is recomputed).
    pub(crate) fn next_wake(&self, now: u64) -> u64 {
        // No commands issue before an in-progress refresh block ends.
        let start = now.max(self.refresh_until);
        // REF: urgent from next_refresh_at on, firing once in-flight data
        // is within CL of draining (the row slot is free on a tick where
        // neither PC commands; contended ticks are real ticks anyway).
        let ref_at = start
            .max(self.next_refresh_at)
            .max(self.data_free_at.saturating_sub(self.timing.t_cl as u64));
        let mut w = ref_at;
        if start < self.next_refresh_at {
            let look = self.tuning.lookahead.max(1);
            for p in self.queue.iter().take(look) {
                let bank = &self.banks[p.bank];
                let cand = if bank.row_hit(p.row) {
                    let cas_lat = match p.req.dir {
                        Dir::Read => self.timing.t_cl as u64,
                        Dir::Write => self.timing.t_cwl as u64,
                    };
                    let c = start
                        .max(bank.cas_ready_at())
                        .max(
                            self.bus_ready_for(p.req.dir, p.bank, p.row)
                                .saturating_sub(cas_lat),
                        );
                    match &self.faults {
                        Some(f) => crate::faults::next_allowed(&f.throttle, c),
                        None => c,
                    }
                } else if self.banks[p.bank].state() == crate::hbm::bank::BankState::Idle {
                    // ACT path: bank tRP plus inter-bank tRRD / tFAW gates.
                    let mut c = start
                        .max(bank.act_ready_at())
                        .max(self.last_act_at + self.timing.t_rrd as u64);
                    if self.act_window.len() >= 4 {
                        if let Some(&t0) = self.act_window.front() {
                            c = c.max(t0 + self.timing.t_faw as u64);
                        }
                    }
                    c
                } else {
                    // PRE path (row open on another row).
                    start.max(bank.pre_ready_at())
                };
                // A candidate at or past next_refresh_at never issues —
                // the urgent-refresh branch preempts normal scheduling.
                if cand < self.next_refresh_at && cand < w {
                    w = cand;
                }
            }
        }
        w
    }

    /// Advance one controller cycle. `cmd` is this PC's view of the shared
    /// channel command bus for the current cycle.
    pub fn tick(&mut self, cmd: &mut CmdBus) {
        self.stats.total_cycles += 1;
        if !self.queue.is_empty() || self.cycle < self.data_free_at {
            self.stats.busy_cycles += 1;
        }

        // Refresh window blocks all commands.
        if self.cycle < self.refresh_until {
            self.cycle += 1;
            return;
        }
        // Refresh handling: once tREFI expires the refresh is *urgent* —
        // the controller stops issuing new CAS commands, lets in-flight
        // data land (last beats are already latched by the PHY, so REF may
        // issue as soon as the bus is within CL of draining), and blocks
        // the PC for tRFC. Under saturating traffic this is what produces
        // the paper's worst-case ~1.2 us read latencies (Fig. 3b / §III-B
        // FIFO sizing).
        let refresh_urgent = self.cycle >= self.next_refresh_at;
        if refresh_urgent {
            if self.data_free_at <= self.cycle + self.timing.t_cl as u64 {
                if cmd.take_row_slot() {
                    for b in &mut self.banks {
                        b.close_for_refresh(self.cycle, &self.timing);
                    }
                    self.refresh_until = self.cycle + self.timing.t_rfc as u64;
                    self.next_refresh_at += self.timing.t_refi as u64;
                    self.stats.refreshes += 1;
                }
            }
            // While a refresh is pending, no new CAS/ACT/PRE issues.
            self.cycle += 1;
            return;
        }

        self.trim_act_window();

        // Thermal-throttle window: CAS issue denied this cycle (row prep
        // below still proceeds, as real throttling gates data, not
        // maintenance). Only counted as degradation when work was queued.
        let cas_denied = self.cas_throttled();
        if cas_denied && !self.queue.is_empty() {
            self.stats.throttled_cycles += 1;
        }

        // --- FR-FCFS with a shallow reorder window ---------------------
        // Pass 1 (column): oldest CAS-ready request whose data lands
        // legally on the bus, if a column slot exists.
        let look = self.tuning.lookahead.max(1);
        let mut cas: Option<(usize, u64)> = None;
        if !cas_denied {
            for (i, p) in self.queue.iter().take(look).enumerate() {
                if self.banks[p.bank].can_cas(p.row, self.cycle) {
                    if let Some(start) = self.cas_data_start(p.req.dir, p.bank, p.row) {
                        cas = Some((i, start));
                        break;
                    }
                }
            }
        }
        if let Some((i, start)) = cas {
            if cmd.take_col_slot() {
                let mut p = self.queue.remove(i).expect("index valid");
                self.queued_beats -= p.req.burst;
                if p.caused_act {
                    self.stats.row_misses += 1;
                } else {
                    self.stats.row_hits += 1;
                }
                let end = start + p.req.burst as u64;
                self.data_free_at = end;
                self.last_dir = Some(p.req.dir);
                self.last_loc = Some((p.bank, p.row));
                match p.req.dir {
                    Dir::Read => {
                        self.banks[p.bank].read_cas(self.cycle);
                        self.stats.reads += 1;
                    }
                    Dir::Write => {
                        self.banks[p.bank].write_cas(end, &self.timing);
                        self.stats.writes += 1;
                    }
                }
                // Transient read error (fault injection): the corrupt
                // burst already occupied the data bus, so its beats are
                // *not* counted as useful data. Within budget the request
                // re-enqueues at the queue back — the replay pays the full
                // re-arbitration + bus cost again (the real tRC-scale
                // penalty). Out of budget, the burst is delivered and
                // counted as dropped: conservation, never silence.
                let faulted = p.req.dir == Dir::Read && self.roll_fault();
                if faulted {
                    self.stats.faults_injected += 1;
                    let budget = self
                        .faults
                        .as_ref()
                        .and_then(|f| f.spec.as_ref())
                        .map_or(0, |s| s.max_replays);
                    if p.replays < budget {
                        p.replays += 1;
                        self.stats.fault_replays += 1;
                        self.fault_events.push(PcFaultEvent {
                            cycle: self.cycle,
                            id: p.req.id,
                            replayed: true,
                        });
                        // Restores exactly what the issue subtracted, so
                        // queued_beats never exceeds the accept bound.
                        self.queued_beats += p.req.burst;
                        self.queue.push_back(p);
                        self.cycle += 1;
                        return;
                    }
                    self.stats.faults_dropped += 1;
                    self.fault_events.push(PcFaultEvent {
                        cycle: self.cycle,
                        id: p.req.id,
                        replayed: false,
                    });
                } else {
                    self.stats.data_cycles += p.req.burst as u64;
                }
                self.completions.push(Completion {
                    id: p.req.id,
                    dir: p.req.dir,
                    accept_cycle: p.accept_cycle,
                    done_cycle: end,
                });
                self.cycle += 1;
                return;
            }
        }

        // Pass 2 (row): oldest request within the reorder window needing
        // bank preparation; one row command per cycle.
        let mut prepared_banks = [false; 64];
        let mut row_action: Option<(usize, usize, RowCmd)> = None;
        for (qi, p) in self.queue.iter().take(look).enumerate() {
            if prepared_banks[p.bank] {
                continue;
            }
            prepared_banks[p.bank] = true;
            let bank = &self.banks[p.bank];
            if bank.row_hit(p.row) {
                continue; // waiting on tRCD or a data-bus slot
            }
            if bank.can_activate(self.cycle) && self.can_act_interbank() {
                row_action = Some((qi, p.bank, RowCmd::Act(p.row)));
                break;
            }
            if bank.state() != crate::hbm::bank::BankState::Idle
                && bank.can_precharge(self.cycle)
            {
                row_action = Some((qi, p.bank, RowCmd::Pre));
                break;
            }
        }
        if let Some((qi, bank, rc)) = row_action {
            if cmd.take_row_slot() {
                match rc {
                    RowCmd::Act(row) => {
                        self.banks[bank].activate(row, self.cycle, &self.timing);
                        self.queue[qi].caused_act = true;
                        self.last_act_at = self.cycle;
                        self.act_window.push_back(self.cycle);
                        self.stats.acts += 1;
                    }
                    RowCmd::Pre => {
                        self.banks[bank].precharge(self.cycle, &self.timing);
                        self.stats.pres += 1;
                    }
                }
            }
        }
        self.cycle += 1;
    }
}

#[derive(Debug, Clone, Copy)]
enum RowCmd {
    Act(u64),
    Pre,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn pc() -> PseudoChannel {
        let d = DeviceConfig::stratix10_nx2100();
        PseudoChannel::new(&d.hbm, &d.hbm_timing, PcTuning::default())
    }

    fn pc_tuned(t: PcTuning) -> PseudoChannel {
        let d = DeviceConfig::stratix10_nx2100();
        PseudoChannel::new(&d.hbm, &d.hbm_timing, t)
    }

    /// Tick with a dedicated (uncontended) command bus.
    fn tick_free(p: &mut PseudoChannel) {
        let mut bus = CmdBus::new();
        p.tick(&mut bus);
    }

    #[test]
    fn accepts_until_outstanding_beats_full() {
        let mut p = pc_tuned(PcTuning { outstanding_beats: 32, lookahead: 4 });
        for i in 0..4 {
            assert!(p.can_accept(8));
            assert!(p.push(Request { id: i, dir: Dir::Read, addr: i * 4096, burst: 8 }));
        }
        assert!(!p.can_accept(8));
        assert!(!p.push(Request { id: 99, dir: Dir::Read, addr: 0, burst: 8 }));
        // a smaller burst that still fits is also rejected (beats full)
        assert!(!p.can_accept(1));
    }

    #[test]
    fn single_read_completes_with_expected_latency() {
        let mut p = pc();
        p.push(Request { id: 1, dir: Dir::Read, addr: 0, burst: 8 });
        let mut done = None;
        for _ in 0..200 {
            tick_free(&mut p);
            if let Some(c) = p.drain_completions().pop() {
                done = Some(c);
                break;
            }
        }
        let c = done.expect("read completed");
        let t = HbmTiming::hbm2_default();
        // idle-bank read: ACT at ~0, CAS at tRCD, data from CAS+CL, 8 beats
        let min = (t.t_rcd + t.t_cl + 8) as u64;
        assert!(c.done_cycle >= min, "done {} < min {min}", c.done_cycle);
        assert!(c.done_cycle <= min + 6, "done {} unexpectedly late", c.done_cycle);
    }

    #[test]
    fn sequential_same_row_reads_hit() {
        let mut p = pc();
        // Two bursts within one 1 KiB row (32-byte beats, BL8 = 256 B).
        p.push(Request { id: 1, dir: Dir::Read, addr: 0, burst: 8 });
        p.push(Request { id: 2, dir: Dir::Read, addr: 256, burst: 8 });
        for _ in 0..200 {
            tick_free(&mut p);
        }
        assert_eq!(p.stats.row_hits, 1, "second access should hit the open row");
        assert_eq!(p.stats.reads, 2);
        assert_eq!(p.stats.acts, 1, "one ACT serves both row-hit reads");
    }

    #[test]
    fn random_rows_miss_and_reactivate() {
        let mut p = pc();
        // same bank, different rows: row_bytes*banks apart
        let stride = 1024 * 16;
        p.push(Request { id: 1, dir: Dir::Read, addr: 0, burst: 8 });
        p.push(Request { id: 2, dir: Dir::Read, addr: stride, burst: 8 });
        for _ in 0..400 {
            tick_free(&mut p);
        }
        assert_eq!(p.stats.reads, 2);
        assert_eq!(p.stats.acts, 2);
        assert_eq!(p.stats.pres, 1, "second access forces a precharge");
    }

    #[test]
    fn refresh_fires_periodically() {
        let mut p = pc();
        let t = HbmTiming::hbm2_default();
        for _ in 0..(t.t_refi as u64 * 3 + 100) {
            tick_free(&mut p);
        }
        assert!(p.stats.refreshes >= 2, "refreshes {}", p.stats.refreshes);
    }

    #[test]
    fn data_bus_never_overbooked() {
        // Property: completions' data intervals [done-burst, done) never
        // overlap — the bus carries one beat per cycle.
        let mut p = pc();
        let mut rng = crate::util::XorShift64::new(5);
        let mut id = 0;
        let mut intervals: Vec<(u64, u64)> = Vec::new();
        for _ in 0..30_000 {
            if p.can_accept(8) && rng.next_bool(0.7) {
                let addr = rng.next_below(1 << 26) & !31;
                let dir = if rng.next_bool(0.3) { Dir::Write } else { Dir::Read };
                p.push(Request { id, dir, addr, burst: 8 });
                id += 1;
            }
            tick_free(&mut p);
            for c in p.drain_completions() {
                intervals.push((c.done_cycle - 8, c.done_cycle));
            }
        }
        intervals.sort_unstable();
        for w in intervals.windows(2) {
            assert!(w[0].1 <= w[1].0, "overlapping bursts {w:?}");
        }
    }

    #[test]
    fn efficiency_increases_with_burst_length() {
        // Saturating random reads: BL32 must beat BL4 substantially.
        let eff = |bl: u32| {
            let mut p = pc();
            let mut rng = crate::util::XorShift64::new(42);
            let mut id = 0;
            for _ in 0..60_000 {
                if p.can_accept(bl) {
                    let addr = rng.next_below(1 << 26) & !31;
                    p.push(Request { id, dir: Dir::Read, addr, burst: bl });
                    id += 1;
                }
                tick_free(&mut p);
            }
            p.stats.efficiency()
        };
        let e4 = eff(4);
        let e32 = eff(32);
        assert!(e32 > 0.85, "BL32 efficiency {e32}");
        assert!(e4 < 0.85 * e32, "BL4 {e4} should be well under BL32 {e32}");
    }

    #[test]
    fn writes_less_efficient_than_reads() {
        let run = |dir: Dir| {
            let mut p = pc();
            let mut rng = crate::util::XorShift64::new(7);
            let mut id = 0;
            for _ in 0..60_000u64 {
                if p.can_accept(8) {
                    let addr = rng.next_below(1 << 26) & !31;
                    p.push(Request { id, dir, addr, burst: 8 });
                    id += 1;
                }
                tick_free(&mut p);
            }
            p.stats.efficiency()
        };
        let w = run(Dir::Write);
        let r = run(Dir::Read);
        assert!(w < r, "writes {w:.3} must trail reads {r:.3}");
    }

    #[test]
    fn address_mapping_spreads_banks() {
        let p = pc();
        let mut banks = std::collections::HashSet::new();
        for i in 0..16u64 {
            banks.insert(p.map_addr(i * 1024).0);
        }
        assert_eq!(banks.len(), 16, "sequential rows should interleave banks");
    }

    /// Saturate a PC with random BL8 reads for `ticks` cycles and return
    /// it (fault knobs applied first via `arm`).
    fn soak(arm: impl FnOnce(&mut PseudoChannel), ticks: u64) -> (PseudoChannel, u64, u64) {
        let mut p = pc();
        arm(&mut p);
        let mut rng = crate::util::XorShift64::new(13);
        let mut id = 0;
        let mut pushed = 0u64;
        let mut completed = 0u64;
        for _ in 0..ticks {
            if p.can_accept(8) {
                let addr = rng.next_below(1 << 26) & !31;
                p.push(Request { id, dir: Dir::Read, addr, burst: 8 });
                id += 1;
                pushed += 1;
            }
            assert!(p.queued_beats() <= p.outstanding_limit(), "accept bound violated");
            tick_free(&mut p);
            completed += p.drain_completions().len() as u64;
        }
        while !p.is_idle() {
            tick_free(&mut p);
            completed += p.drain_completions().len() as u64;
        }
        (p, pushed, completed)
    }

    #[test]
    fn injected_read_faults_are_conserved_and_deterministic() {
        let arm = |p: &mut PseudoChannel| {
            p.inject_faults(
                Some(HbmFaultSpec { start: 0, end: 30_000, prob: 0.05, max_replays: 2 }),
                Vec::new(),
                crate::faults::site_seed(42, 0),
            );
        };
        let (p1, pushed, completed) = soak(arm, 30_000);
        assert_eq!(pushed, completed, "every accepted request still completes under faults");
        let s = &p1.stats;
        assert!(s.faults_injected > 0, "window+prob must fire");
        assert_eq!(
            s.faults_injected,
            s.fault_replays + s.faults_dropped,
            "conservation: {} != {} + {}",
            s.faults_injected,
            s.fault_replays,
            s.faults_dropped
        );
        let (p2, _, _) = soak(arm, 30_000);
        assert_eq!(s.faults_injected, p2.stats.faults_injected, "same seed, same faults");
        assert_eq!(s.reads, p2.stats.reads);
        assert_eq!(s.data_cycles, p2.stats.data_cycles);
    }

    #[test]
    fn fault_replays_cost_efficiency() {
        let (healthy, ..) = soak(|_| {}, 40_000);
        let (faulty, ..) = soak(
            |p| {
                p.inject_faults(
                    Some(HbmFaultSpec { start: 0, end: 40_000, prob: 0.1, max_replays: 3 }),
                    Vec::new(),
                    1,
                )
            },
            40_000,
        );
        assert!(
            faulty.stats.efficiency() < healthy.stats.efficiency(),
            "replays must burn bus time: {} !< {}",
            faulty.stats.efficiency(),
            healthy.stats.efficiency()
        );
    }

    #[test]
    fn throttle_window_degrades_bandwidth() {
        let (healthy, ..) = soak(|_| {}, 40_000);
        let (throttled, ..) = soak(
            |p| {
                p.inject_faults(
                    None,
                    vec![ThrottleWindow { pc: 0, start: 0, end: 40_000, deny: 4, period: 8 }],
                    1,
                )
            },
            40_000,
        );
        assert!(throttled.stats.throttled_cycles > 0);
        assert_eq!(throttled.stats.faults_injected, 0, "throttle is not an error");
        assert!(
            throttled.stats.efficiency() < 0.75 * healthy.stats.efficiency(),
            "denying half the CAS slots must show up: {} vs {}",
            throttled.stats.efficiency(),
            healthy.stats.efficiency()
        );
    }

    #[test]
    fn fault_events_drain_and_match_stats() {
        let mut p = pc();
        p.inject_faults(
            Some(HbmFaultSpec { start: 0, end: 20_000, prob: 0.1, max_replays: 1 }),
            Vec::new(),
            7,
        );
        let mut rng = crate::util::XorShift64::new(3);
        let mut id = 0;
        let mut replay_events = 0u64;
        let mut drop_events = 0u64;
        for _ in 0..20_000 {
            if p.can_accept(8) {
                p.push(Request { id, dir: Dir::Read, addr: rng.next_below(1 << 24) & !31, burst: 8 });
                id += 1;
            }
            tick_free(&mut p);
            for e in p.drain_fault_events() {
                if e.replayed {
                    replay_events += 1;
                } else {
                    drop_events += 1;
                }
            }
        }
        assert_eq!(replay_events, p.stats.fault_replays);
        assert_eq!(drop_events, p.stats.faults_dropped);
    }

    #[test]
    fn queued_beats_conserved() {
        let mut p = pc();
        let mut rng = crate::util::XorShift64::new(9);
        let mut id = 0;
        let mut pushed = 0u64;
        let mut completed = 0u64;
        for _ in 0..20_000 {
            if p.can_accept(4) && rng.next_bool(0.5) {
                p.push(Request { id, dir: Dir::Read, addr: rng.next_below(1 << 24) & !31, burst: 4 });
                id += 1;
                pushed += 1;
            }
            tick_free(&mut p);
            completed += p.drain_completions().len() as u64;
        }
        while !p.is_idle() {
            tick_free(&mut p);
            completed += p.drain_completions().len() as u64;
        }
        assert_eq!(pushed, completed, "every accepted request completes");
        assert_eq!(p.queued(), 0);
    }
}
