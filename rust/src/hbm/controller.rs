//! Pseudo-channel controller.
//!
//! Models the hardened HBM2 controller behind the 256-bit / 400 MHz user
//! interface of the Stratix 10 NX (§II-C): a data-outstanding-limited
//! request queue (back-pressure is the AXI `!ready` the paper's traffic
//! generator polls), a shallow-reorder FR-FCFS scheduler over 16 banks, a
//! single data bus with per-burst gaps (DQS preamble / tCCD) and
//! read/write turnaround penalties, inter-bank tRRD / tFAW constraints,
//! and all-bank refresh every tREFI.
//!
//! The two PCs of a channel share a row/column command bus; the controller
//! asks the [`super::stack::CmdBus`] for a slot before issuing a command.
//! Together with the shallow reorder window, this is what makes small
//! random bursts pay ~2x the per-beat cost of long bursts (Fig. 3a).
//!
//! Calibration targets (paper §III-A, Fig. 3): random saturated reads
//! ~0.83 efficiency at BL8 rising to ~0.93 at BL32, BL<4 around half the
//! BL>=8 level; writes peaking ~15 pp below reads; saturated average read
//! latency ~400 ns at BL32 and rising as bursts shrink; worst-case read
//! latency at BL>=8 around 1.2 us (the paper's 512-deep FIFO bound).

use std::collections::VecDeque;

use crate::config::{HbmGeometry, HbmTiming};
use crate::hbm::bank::Bank;
use crate::hbm::stack::CmdBus;

/// Transfer direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
}

/// One AXI burst request presented to the controller.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    /// Caller-assigned id, returned in the [`Completion`].
    pub id: u64,
    pub dir: Dir,
    /// Byte address within the pseudo-channel.
    pub addr: u64,
    /// Burst length in 256-bit beats (1..=32).
    pub burst: u32,
}

/// A finished request.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub id: u64,
    pub dir: Dir,
    /// Cycle the request was accepted into the queue.
    pub accept_cycle: u64,
    /// Cycle the last data beat transferred.
    pub done_cycle: u64,
}

/// Aggregate controller statistics.
#[derive(Debug, Clone, Default)]
pub struct PcStats {
    /// Data beats actually transferred.
    pub data_cycles: u64,
    /// Cycles with at least one request queued or data in flight.
    pub busy_cycles: u64,
    /// Total cycles ticked.
    pub total_cycles: u64,
    /// Commands issued.
    pub acts: u64,
    pub pres: u64,
    pub reads: u64,
    pub writes: u64,
    pub refreshes: u64,
    /// Requests that reused an already-open row.
    pub row_hits: u64,
    pub row_misses: u64,
}

impl PcStats {
    /// Efficiency as the paper measures it: data-beat cycles over total
    /// observed cycles.
    pub fn efficiency(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.data_cycles as f64 / self.total_cycles as f64
    }

    /// Efficiency over busy cycles only — for workloads with idle gaps.
    pub fn busy_efficiency(&self) -> f64 {
        if self.busy_cycles == 0 {
            return 0.0;
        }
        self.data_cycles as f64 / self.busy_cycles as f64
    }

    /// Open-row hit rate over all row events (0 when no row was touched).
    pub fn row_hit_rate(&self) -> f64 {
        let events = self.row_hits + self.row_misses;
        if events == 0 {
            return 0.0;
        }
        self.row_hits as f64 / events as f64
    }
}

/// Internal per-request bookkeeping.
#[derive(Debug, Clone, Copy)]
struct Pending {
    req: Request,
    accept_cycle: u64,
    bank: usize,
    row: u64,
    /// Set when the scheduler issued an ACT on behalf of this request —
    /// used to classify row hits/misses at CAS time.
    caused_act: bool,
}

/// Scheduling/capacity knobs of the hardened controller model.
#[derive(Debug, Clone)]
pub struct PcTuning {
    /// Outstanding-data limit in beats (the AXI read-data reorder buffer
    /// of the hardened controller). 144 beats = 4.5 KiB.
    pub outstanding_beats: u32,
    /// How many queue entries the row-prep pass may look ahead — the
    /// controller's shallow reorder window.
    pub lookahead: usize,
}

impl Default for PcTuning {
    fn default() -> Self {
        Self { outstanding_beats: 144, lookahead: 6 }
    }
}

/// The pseudo-channel controller.
#[derive(Debug, Clone)]
pub struct PseudoChannel {
    timing: HbmTiming,
    tuning: PcTuning,
    banks: Vec<Bank>,
    bank_groups: u32,
    row_bytes: u64,
    queue: VecDeque<Pending>,
    queued_beats: u32,
    /// Cycle at which the data bus becomes free.
    data_free_at: u64,
    /// Direction of the last data burst (turnaround penalties).
    last_dir: Option<Dir>,
    /// (bank, row) of the last CAS: consecutive same-row bursts stream
    /// without the pipeline re-steer gap.
    last_loc: Option<(usize, u64)>,
    /// Cycle of last ACT (tRRD) and sliding window of ACT times (tFAW).
    last_act_at: u64,
    act_window: VecDeque<u64>,
    /// Refresh bookkeeping.
    next_refresh_at: u64,
    refresh_until: u64,
    cycle: u64,
    completions: Vec<Completion>,
    pub stats: PcStats,
}

impl PseudoChannel {
    pub fn new(geom: &HbmGeometry, timing: &HbmTiming, tuning: PcTuning) -> Self {
        Self {
            timing: timing.clone(),
            tuning,
            banks: (0..geom.banks_per_pc).map(|_| Bank::new()).collect(),
            bank_groups: geom.bank_groups,
            row_bytes: geom.row_bytes as u64,
            queue: VecDeque::new(),
            queued_beats: 0,
            data_free_at: 0,
            last_dir: None,
            last_loc: None,
            last_act_at: 0,
            act_window: VecDeque::new(),
            next_refresh_at: timing.t_refi as u64,
            refresh_until: 0,
            cycle: 0,
            completions: Vec::new(),
            stats: PcStats::default(),
        }
    }

    /// Current cycle.
    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// AXI back-pressure for a burst of `burst` beats.
    pub fn can_accept(&self, burst: u32) -> bool {
        self.queued_beats + burst <= self.tuning.outstanding_beats
    }

    /// Number of queued (not yet CAS-issued) requests.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Accept a request. Returns false (and drops it) when back-pressured —
    /// callers should check [`Self::can_accept`] first, mirroring AXI
    /// `valid && ready`.
    pub fn push(&mut self, req: Request) -> bool {
        if !self.can_accept(req.burst) {
            return false;
        }
        debug_assert!((1..=32).contains(&req.burst), "burst {} out of range", req.burst);
        let (bank, row) = self.map_addr(req.addr);
        self.queued_beats += req.burst;
        self.queue
            .push_back(Pending { req, accept_cycle: self.cycle, bank, row, caused_act: false });
        true
    }

    /// Address mapping: low bits select the column within a row, then the
    /// bank (bank-interleaved rows spread sequential bursts across banks),
    /// then the row — the standard BRC-ish mapping an FPGA HBM IP uses.
    fn map_addr(&self, addr: u64) -> (usize, u64) {
        let nb = self.banks.len() as u64;
        let row_addr = addr / self.row_bytes;
        let bank = (row_addr % nb) as usize;
        let row = row_addr / nb;
        (bank, row)
    }

    /// Drain completions recorded since the last call.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// True if the controller has no queued requests and the data bus is
    /// idle.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.cycle >= self.data_free_at
    }

    fn trim_act_window(&mut self) {
        let faw = self.timing.t_faw as u64;
        while let Some(&t0) = self.act_window.front() {
            if t0 + faw <= self.cycle {
                self.act_window.pop_front();
            } else {
                break;
            }
        }
    }

    fn can_act_interbank(&self) -> bool {
        self.cycle >= self.last_act_at + self.timing.t_rrd as u64 && self.act_window.len() < 4
    }

    /// Check whether a CAS issued *this cycle* lands its data legally on
    /// the bus, and return the data start cycle if so.
    ///
    /// DDR timing is rigid: a CAS at cycle `c` produces data exactly at
    /// `c + CL` (reads) / `c + CWL` (writes); it may only issue if the bus
    /// is clear of the previous burst plus the inter-burst gap (DQS
    /// preamble / tCCD) and any direction-turnaround penalty.
    fn cas_data_start(&self, dir: Dir, bank: usize, row: u64) -> Option<u64> {
        let cas_lat = match dir {
            Dir::Read => self.timing.t_cl as u64,
            Dir::Write => self.timing.t_cwl as u64,
        };
        let start = self.cycle + cas_lat;
        let mut bus_ready = self.data_free_at;
        // Streaming within one open row continues gap-free (the hardened
        // controller keeps its pipeline steered); switching transaction
        // target pays the re-steer gap plus, within a bank group, the
        // tCCD_L - tCCD_S spread.
        if self.last_loc != Some((bank, row)) {
            bus_ready += match dir {
                Dir::Read => self.timing.t_rd_gap as u64,
                Dir::Write => self.timing.t_wr_gap as u64,
            };
            if let Some((b, _)) = self.last_loc {
                if b != bank && b as u32 % self.bank_groups == bank as u32 % self.bank_groups {
                    bus_ready += (self.timing.t_ccd_l - self.timing.t_ccd_s) as u64;
                }
            }
        }
        // direction turnaround
        if let Some(prev) = self.last_dir {
            if prev != dir {
                let turn = match dir {
                    Dir::Read => self.timing.t_wtr as u64,
                    Dir::Write => self.timing.t_rtw as u64,
                };
                bus_ready += turn;
            }
        }
        (start >= bus_ready).then_some(start)
    }

    /// Advance one controller cycle. `cmd` is this PC's view of the shared
    /// channel command bus for the current cycle.
    pub fn tick(&mut self, cmd: &mut CmdBus) {
        self.stats.total_cycles += 1;
        if !self.queue.is_empty() || self.cycle < self.data_free_at {
            self.stats.busy_cycles += 1;
        }

        // Refresh window blocks all commands.
        if self.cycle < self.refresh_until {
            self.cycle += 1;
            return;
        }
        // Refresh handling: once tREFI expires the refresh is *urgent* —
        // the controller stops issuing new CAS commands, lets in-flight
        // data land (last beats are already latched by the PHY, so REF may
        // issue as soon as the bus is within CL of draining), and blocks
        // the PC for tRFC. Under saturating traffic this is what produces
        // the paper's worst-case ~1.2 us read latencies (Fig. 3b / §III-B
        // FIFO sizing).
        let refresh_urgent = self.cycle >= self.next_refresh_at;
        if refresh_urgent {
            if self.data_free_at <= self.cycle + self.timing.t_cl as u64 {
                if cmd.take_row_slot() {
                    for b in &mut self.banks {
                        b.close_for_refresh(self.cycle, &self.timing);
                    }
                    self.refresh_until = self.cycle + self.timing.t_rfc as u64;
                    self.next_refresh_at += self.timing.t_refi as u64;
                    self.stats.refreshes += 1;
                }
            }
            // While a refresh is pending, no new CAS/ACT/PRE issues.
            self.cycle += 1;
            return;
        }

        self.trim_act_window();

        // --- FR-FCFS with a shallow reorder window ---------------------
        // Pass 1 (column): oldest CAS-ready request whose data lands
        // legally on the bus, if a column slot exists.
        let look = self.tuning.lookahead.max(1);
        let mut cas: Option<(usize, u64)> = None;
        for (i, p) in self.queue.iter().take(look).enumerate() {
            if self.banks[p.bank].can_cas(p.row, self.cycle) {
                if let Some(start) = self.cas_data_start(p.req.dir, p.bank, p.row) {
                    cas = Some((i, start));
                    break;
                }
            }
        }
        if let Some((i, start)) = cas {
            if cmd.take_col_slot() {
                let p = self.queue.remove(i).expect("index valid");
                self.queued_beats -= p.req.burst;
                if p.caused_act {
                    self.stats.row_misses += 1;
                } else {
                    self.stats.row_hits += 1;
                }
                let end = start + p.req.burst as u64;
                self.data_free_at = end;
                self.last_dir = Some(p.req.dir);
                self.last_loc = Some((p.bank, p.row));
                self.stats.data_cycles += p.req.burst as u64;
                match p.req.dir {
                    Dir::Read => {
                        self.banks[p.bank].read_cas(self.cycle);
                        self.stats.reads += 1;
                    }
                    Dir::Write => {
                        self.banks[p.bank].write_cas(end, &self.timing);
                        self.stats.writes += 1;
                    }
                }
                self.completions.push(Completion {
                    id: p.req.id,
                    dir: p.req.dir,
                    accept_cycle: p.accept_cycle,
                    done_cycle: end,
                });
                self.cycle += 1;
                return;
            }
        }

        // Pass 2 (row): oldest request within the reorder window needing
        // bank preparation; one row command per cycle.
        let mut prepared_banks = [false; 64];
        let mut row_action: Option<(usize, usize, RowCmd)> = None;
        for (qi, p) in self.queue.iter().take(look).enumerate() {
            if prepared_banks[p.bank] {
                continue;
            }
            prepared_banks[p.bank] = true;
            let bank = &self.banks[p.bank];
            if bank.row_hit(p.row) {
                continue; // waiting on tRCD or a data-bus slot
            }
            if bank.can_activate(self.cycle) && self.can_act_interbank() {
                row_action = Some((qi, p.bank, RowCmd::Act(p.row)));
                break;
            }
            if bank.state() != crate::hbm::bank::BankState::Idle
                && bank.can_precharge(self.cycle)
            {
                row_action = Some((qi, p.bank, RowCmd::Pre));
                break;
            }
        }
        if let Some((qi, bank, rc)) = row_action {
            if cmd.take_row_slot() {
                match rc {
                    RowCmd::Act(row) => {
                        self.banks[bank].activate(row, self.cycle, &self.timing);
                        self.queue[qi].caused_act = true;
                        self.last_act_at = self.cycle;
                        self.act_window.push_back(self.cycle);
                        self.stats.acts += 1;
                    }
                    RowCmd::Pre => {
                        self.banks[bank].precharge(self.cycle, &self.timing);
                        self.stats.pres += 1;
                    }
                }
            }
        }
        self.cycle += 1;
    }
}

#[derive(Debug, Clone, Copy)]
enum RowCmd {
    Act(u64),
    Pre,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn pc() -> PseudoChannel {
        let d = DeviceConfig::stratix10_nx2100();
        PseudoChannel::new(&d.hbm, &d.hbm_timing, PcTuning::default())
    }

    fn pc_tuned(t: PcTuning) -> PseudoChannel {
        let d = DeviceConfig::stratix10_nx2100();
        PseudoChannel::new(&d.hbm, &d.hbm_timing, t)
    }

    /// Tick with a dedicated (uncontended) command bus.
    fn tick_free(p: &mut PseudoChannel) {
        let mut bus = CmdBus::new();
        p.tick(&mut bus);
    }

    #[test]
    fn accepts_until_outstanding_beats_full() {
        let mut p = pc_tuned(PcTuning { outstanding_beats: 32, lookahead: 4 });
        for i in 0..4 {
            assert!(p.can_accept(8));
            assert!(p.push(Request { id: i, dir: Dir::Read, addr: i * 4096, burst: 8 }));
        }
        assert!(!p.can_accept(8));
        assert!(!p.push(Request { id: 99, dir: Dir::Read, addr: 0, burst: 8 }));
        // a smaller burst that still fits is also rejected (beats full)
        assert!(!p.can_accept(1));
    }

    #[test]
    fn single_read_completes_with_expected_latency() {
        let mut p = pc();
        p.push(Request { id: 1, dir: Dir::Read, addr: 0, burst: 8 });
        let mut done = None;
        for _ in 0..200 {
            tick_free(&mut p);
            if let Some(c) = p.drain_completions().pop() {
                done = Some(c);
                break;
            }
        }
        let c = done.expect("read completed");
        let t = HbmTiming::hbm2_default();
        // idle-bank read: ACT at ~0, CAS at tRCD, data from CAS+CL, 8 beats
        let min = (t.t_rcd + t.t_cl + 8) as u64;
        assert!(c.done_cycle >= min, "done {} < min {min}", c.done_cycle);
        assert!(c.done_cycle <= min + 6, "done {} unexpectedly late", c.done_cycle);
    }

    #[test]
    fn sequential_same_row_reads_hit() {
        let mut p = pc();
        // Two bursts within one 1 KiB row (32-byte beats, BL8 = 256 B).
        p.push(Request { id: 1, dir: Dir::Read, addr: 0, burst: 8 });
        p.push(Request { id: 2, dir: Dir::Read, addr: 256, burst: 8 });
        for _ in 0..200 {
            tick_free(&mut p);
        }
        assert_eq!(p.stats.row_hits, 1, "second access should hit the open row");
        assert_eq!(p.stats.reads, 2);
        assert_eq!(p.stats.acts, 1, "one ACT serves both row-hit reads");
    }

    #[test]
    fn random_rows_miss_and_reactivate() {
        let mut p = pc();
        // same bank, different rows: row_bytes*banks apart
        let stride = 1024 * 16;
        p.push(Request { id: 1, dir: Dir::Read, addr: 0, burst: 8 });
        p.push(Request { id: 2, dir: Dir::Read, addr: stride, burst: 8 });
        for _ in 0..400 {
            tick_free(&mut p);
        }
        assert_eq!(p.stats.reads, 2);
        assert_eq!(p.stats.acts, 2);
        assert_eq!(p.stats.pres, 1, "second access forces a precharge");
    }

    #[test]
    fn refresh_fires_periodically() {
        let mut p = pc();
        let t = HbmTiming::hbm2_default();
        for _ in 0..(t.t_refi as u64 * 3 + 100) {
            tick_free(&mut p);
        }
        assert!(p.stats.refreshes >= 2, "refreshes {}", p.stats.refreshes);
    }

    #[test]
    fn data_bus_never_overbooked() {
        // Property: completions' data intervals [done-burst, done) never
        // overlap — the bus carries one beat per cycle.
        let mut p = pc();
        let mut rng = crate::util::XorShift64::new(5);
        let mut id = 0;
        let mut intervals: Vec<(u64, u64)> = Vec::new();
        for _ in 0..30_000 {
            if p.can_accept(8) && rng.next_bool(0.7) {
                let addr = rng.next_below(1 << 26) & !31;
                let dir = if rng.next_bool(0.3) { Dir::Write } else { Dir::Read };
                p.push(Request { id, dir, addr, burst: 8 });
                id += 1;
            }
            tick_free(&mut p);
            for c in p.drain_completions() {
                intervals.push((c.done_cycle - 8, c.done_cycle));
            }
        }
        intervals.sort_unstable();
        for w in intervals.windows(2) {
            assert!(w[0].1 <= w[1].0, "overlapping bursts {w:?}");
        }
    }

    #[test]
    fn efficiency_increases_with_burst_length() {
        // Saturating random reads: BL32 must beat BL4 substantially.
        let eff = |bl: u32| {
            let mut p = pc();
            let mut rng = crate::util::XorShift64::new(42);
            let mut id = 0;
            for _ in 0..60_000 {
                if p.can_accept(bl) {
                    let addr = rng.next_below(1 << 26) & !31;
                    p.push(Request { id, dir: Dir::Read, addr, burst: bl });
                    id += 1;
                }
                tick_free(&mut p);
            }
            p.stats.efficiency()
        };
        let e4 = eff(4);
        let e32 = eff(32);
        assert!(e32 > 0.85, "BL32 efficiency {e32}");
        assert!(e4 < 0.85 * e32, "BL4 {e4} should be well under BL32 {e32}");
    }

    #[test]
    fn writes_less_efficient_than_reads() {
        let run = |dir: Dir| {
            let mut p = pc();
            let mut rng = crate::util::XorShift64::new(7);
            let mut id = 0;
            for _ in 0..60_000u64 {
                if p.can_accept(8) {
                    let addr = rng.next_below(1 << 26) & !31;
                    p.push(Request { id, dir, addr, burst: 8 });
                    id += 1;
                }
                tick_free(&mut p);
            }
            p.stats.efficiency()
        };
        let w = run(Dir::Write);
        let r = run(Dir::Read);
        assert!(w < r, "writes {w:.3} must trail reads {r:.3}");
    }

    #[test]
    fn address_mapping_spreads_banks() {
        let p = pc();
        let mut banks = std::collections::HashSet::new();
        for i in 0..16u64 {
            banks.insert(p.map_addr(i * 1024).0);
        }
        assert_eq!(banks.len(), 16, "sequential rows should interleave banks");
    }

    #[test]
    fn queued_beats_conserved() {
        let mut p = pc();
        let mut rng = crate::util::XorShift64::new(9);
        let mut id = 0;
        let mut pushed = 0u64;
        let mut completed = 0u64;
        for _ in 0..20_000 {
            if p.can_accept(4) && rng.next_bool(0.5) {
                p.push(Request { id, dir: Dir::Read, addr: rng.next_below(1 << 24) & !31, burst: 4 });
                id += 1;
                pushed += 1;
            }
            tick_free(&mut p);
            completed += p.drain_completions().len() as u64;
        }
        while !p.is_idle() {
            tick_free(&mut p);
            completed += p.drain_completions().len() as u64;
        }
        assert_eq!(pushed, completed, "every accepted request completes");
        assert_eq!(p.queued(), 0);
    }
}
