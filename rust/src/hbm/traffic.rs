//! AXI traffic generator — the §III-A characterization instrument.
//!
//! The paper: "we create an AXI traffic generator with selectable address
//! patterns and burst lengths ... we issue reads and writes to random HBM
//! addresses whenever the controller does not assert the back-pressure
//! signal, saturating its bandwidth. We collect data over 10,000 write
//! transactions first, followed by another 10,000 read transactions."
//!
//! [`TrafficGen::run`] reproduces that procedure against one simulated
//! pseudo-channel (paired with a phantom sibling PC on the shared command
//! bus, which is what the real measurement sees too) and reports
//! efficiency + latency statistics for Fig. 3a / Fig. 3b.

use crate::config::{DeviceConfig, HbmGeometry, HbmTiming};
use crate::hbm::controller::{Dir, PcTuning, PseudoChannel, Request};
use crate::hbm::stack::CmdBus;
use crate::util::{Percentiles, XorShift64};

/// Address pattern of the generator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AddressPattern {
    /// Uniformly random burst-aligned addresses: models multiple HPIPE
    /// layers sharing a PC (the paper's primary pattern).
    Random,
    /// Sequential addresses: the best case the paper contrasts against.
    Sequential,
    /// `n` interleaved sequential streams: the §III-B case of 3 tensor
    /// chains sharing one PC.
    Interleaved(u32),
}

/// Experiment configuration.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    pub pattern: AddressPattern,
    pub burst: u32,
    /// Transactions per phase (paper: 10,000 writes then 10,000 reads).
    pub transactions: u64,
    /// Controller tuning (outstanding data window, reorder lookahead).
    pub tuning: PcTuning,
    /// Address space exercised (bytes); paper uses the whole PC.
    pub addr_space: u64,
    pub seed: u64,
    /// Model the sibling PC contending on the shared command bus with the
    /// same workload (hardware measurements always have the sibling
    /// present; set false for an idealized solo-PC run).
    pub sibling_active: bool,
}

impl TrafficConfig {
    pub fn new(pattern: AddressPattern, burst: u32) -> Self {
        Self {
            pattern,
            burst,
            transactions: 10_000,
            tuning: PcTuning::default(),
            addr_space: 256 << 20,
            seed: 0x4832_5049_5045, // "H2PIPE"
            sibling_active: true,
        }
    }
}

/// Results of one run.
#[derive(Debug, Clone)]
pub struct TrafficReport {
    pub pattern: AddressPattern,
    pub burst: u32,
    /// Write-phase efficiency (accepted-beat cycles / cycles).
    pub write_efficiency: f64,
    /// Read-phase efficiency.
    pub read_efficiency: f64,
    /// Saturated read latency in ns (min / mean / max), measured accept ->
    /// last beat like the paper's Fig. 3b.
    pub read_lat_min_ns: f64,
    pub read_lat_avg_ns: f64,
    pub read_lat_max_ns: f64,
    /// p50/p99 for the serving-style analyses.
    pub read_lat_p50_ns: f64,
    pub read_lat_p99_ns: f64,
    /// Achieved read bandwidth in bytes/s.
    pub read_bw_bytes: f64,
}

/// The traffic generator.
#[derive(Debug)]
pub struct TrafficGen {
    geom: HbmGeometry,
    timing: HbmTiming,
}

struct AddrStream {
    pattern: AddressPattern,
    rng: XorShift64,
    space: u64,
    align: u64,
    seq_next: u64,
    ileave_next: Vec<u64>,
    ileave_idx: usize,
}

impl AddrStream {
    fn new(cfg: &TrafficConfig, geom: &HbmGeometry, salt: u64) -> Self {
        let align = (geom.beat_bytes() as u64) * cfg.burst as u64;
        let n = match cfg.pattern {
            AddressPattern::Interleaved(n) => n.max(1),
            _ => 1,
        };
        // interleaved streams start far apart (different rows/banks)
        let stride = cfg.addr_space / n as u64;
        Self {
            pattern: cfg.pattern,
            rng: XorShift64::new(cfg.seed ^ salt.wrapping_mul(0x9E37)),
            space: cfg.addr_space,
            align,
            seq_next: 0,
            ileave_next: (0..n as u64).map(|i| i * stride).collect(),
            ileave_idx: 0,
        }
    }

    fn next(&mut self) -> u64 {
        match self.pattern {
            AddressPattern::Random => {
                let slots = self.space / self.align;
                self.rng.next_below(slots) * self.align
            }
            AddressPattern::Sequential => {
                let a = self.seq_next;
                self.seq_next = (self.seq_next + self.align) % self.space;
                a
            }
            AddressPattern::Interleaved(_) => {
                let i = self.ileave_idx;
                self.ileave_idx = (self.ileave_idx + 1) % self.ileave_next.len();
                let a = self.ileave_next[i] % self.space;
                self.ileave_next[i] += self.align;
                a
            }
        }
    }
}

impl TrafficGen {
    pub fn new(device: &DeviceConfig) -> Self {
        Self { geom: device.hbm.clone(), timing: device.hbm_timing.clone() }
    }

    /// Run the paper's measurement: `transactions` writes to saturation,
    /// then `transactions` reads, against one PC (with an optionally
    /// contending sibling PC on the shared command bus).
    pub fn run(&self, cfg: &TrafficConfig) -> TrafficReport {
        let mut pc = PseudoChannel::new(&self.geom, &self.timing, cfg.tuning.clone());
        let mut sib = PseudoChannel::new(&self.geom, &self.timing, cfg.tuning.clone());
        let mut addrs = AddrStream::new(cfg, &self.geom, 1);
        let mut sib_addrs = AddrStream::new(cfg, &self.geom, 2);

        let write_eff = self.phase(
            &mut pc,
            &mut sib,
            &mut addrs,
            &mut sib_addrs,
            cfg,
            Dir::Write,
            &mut Percentiles::new(),
        );

        let mut lat = Percentiles::new();
        let read = self.phase(&mut pc, &mut sib, &mut addrs, &mut sib_addrs, cfg, Dir::Read, &mut lat);

        let mhz = self.geom.controller_mhz;
        let to_ns = |c: f64| c * 1e3 / mhz as f64;
        let beats = cfg.transactions * cfg.burst as u64;
        let bw = beats as f64 * self.geom.beat_bytes() as f64 * read.1;
        TrafficReport {
            pattern: cfg.pattern,
            burst: cfg.burst,
            write_efficiency: write_eff.0,
            read_efficiency: read.0,
            read_lat_min_ns: to_ns(lat.min()),
            read_lat_avg_ns: to_ns(lat.mean()),
            read_lat_max_ns: to_ns(lat.max()),
            read_lat_p50_ns: to_ns(lat.median()),
            read_lat_p99_ns: to_ns(lat.percentile(99.0)),
            read_bw_bytes: bw,
        }
    }

    /// One measurement phase. Returns (efficiency, cycles_per_second).
    fn phase(
        &self,
        pc: &mut PseudoChannel,
        sib: &mut PseudoChannel,
        addrs: &mut AddrStream,
        sib_addrs: &mut AddrStream,
        cfg: &TrafficConfig,
        dir: Dir,
        lat: &mut Percentiles,
    ) -> (f64, f64) {
        let start_cycle = pc.now();
        let data_before = pc.stats.data_cycles;
        let mut issued: u64 = 0;
        let mut completed: u64 = 0;
        let mut id: u64 = 0;
        let mut priority = 0usize;
        // hard stop so a controller bug cannot hang the experiment
        let limit = cfg.transactions * (cfg.burst as u64 * 8 + 200) + 100_000;
        let mut guard = 0u64;
        while completed < cfg.transactions {
            guard += 1;
            assert!(guard < limit, "traffic run exceeded cycle guard — controller livelock?");
            if issued < cfg.transactions && pc.can_accept(cfg.burst) {
                pc.push(Request { id, dir, addr: addrs.next(), burst: cfg.burst });
                id += 1;
                issued += 1;
            }
            if cfg.sibling_active && sib.can_accept(cfg.burst) {
                sib.push(Request { id: u64::MAX - id, dir, addr: sib_addrs.next(), burst: cfg.burst });
            }
            // shared command bus, alternating priority (as in Channel)
            let mut bus = CmdBus::new();
            if priority == 0 {
                pc.tick(&mut bus);
                if cfg.sibling_active {
                    sib.tick(&mut bus);
                }
            } else {
                if cfg.sibling_active {
                    sib.tick(&mut bus);
                }
                pc.tick(&mut bus);
            }
            priority = 1 - priority;
            for c in pc.drain_completions() {
                completed += 1;
                lat.push((c.done_cycle - c.accept_cycle) as f64);
            }
            sib.drain_completions();
        }
        // run the bus dry so the efficiency denominator covers the tail
        while !pc.is_idle() {
            let mut bus = CmdBus::new();
            pc.tick(&mut bus);
            if cfg.sibling_active {
                sib.tick(&mut bus);
            }
            for c in pc.drain_completions() {
                lat.push((c.done_cycle - c.accept_cycle) as f64);
            }
            sib.drain_completions();
        }
        let cycles = pc.now() - start_cycle;
        let data = pc.stats.data_cycles - data_before;
        let eff = data as f64 / cycles.max(1) as f64;
        let secs = cycles as f64 / (self.geom.controller_mhz as f64 * 1e6);
        (eff, 1.0 / secs.max(1e-12))
    }

    /// Sweep burst lengths for Fig. 3a/3b.
    pub fn sweep_bursts(&self, pattern: AddressPattern, bursts: &[u32]) -> Vec<TrafficReport> {
        bursts
            .iter()
            .map(|&b| {
                let mut cfg = TrafficConfig::new(pattern, b);
                cfg.transactions = 10_000;
                self.run(&cfg)
            })
            .collect()
    }

    /// Expected per-chain sustained read bandwidth (bytes/s) for `n`
    /// tensor-chain streams interleaved on one PC at burst `bl` — the
    /// §III-B provisioning question the offload algorithm needs answered.
    pub fn interleaved_read_bw(&self, n_chains: u32, bl: u32) -> f64 {
        let mut cfg = TrafficConfig::new(AddressPattern::Interleaved(n_chains), bl);
        cfg.transactions = 4_000;
        let rep = self.run(&cfg);
        rep.read_efficiency * self.geom.pc_peak_bw()
    }
}

/// Convert a latency expressed in controller cycles to core-clock cycles
/// (how long a 300 MHz layer engine waits — the §III-B FIFO sizing input).
pub fn controller_to_core_cycles(cycles: u64, controller_mhz: u32, core_mhz: u32) -> u64 {
    (cycles as f64 * core_mhz as f64 / controller_mhz as f64).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> TrafficGen {
        TrafficGen::new(&DeviceConfig::stratix10_nx2100())
    }

    #[test]
    fn fig3a_shape_read_efficiency_rises_with_burst() {
        let g = gen();
        let reps = g.sweep_bursts(AddressPattern::Random, &[2, 4, 8, 32]);
        let e = |i: usize| reps[i].read_efficiency;
        assert!(e(2) > e(1), "BL8 {:.3} should beat BL4 {:.3}", e(2), e(1));
        assert!(e(3) > e(2), "BL32 {:.3} should beat BL8 {:.3}", e(3), e(2));
        // paper: BL<4 is "slightly more than half" of the BL>=8 level
        let ratio = e(0) / e(2);
        assert!((0.35..0.75).contains(&ratio), "BL2/BL8 ratio {ratio:.3}");
        // paper: ~83% at BL8, ~93% at BL32 (tolerate calibration slack)
        assert!((0.70..0.95).contains(&e(2)), "BL8 read eff {:.3}", e(2));
        assert!(e(3) > 0.85, "BL32 read eff {:.3}", e(3));
    }

    #[test]
    fn fig3a_shape_writes_below_reads() {
        let g = gen();
        let mut cfg = TrafficConfig::new(AddressPattern::Random, 8);
        cfg.transactions = 6_000;
        let rep = g.run(&cfg);
        assert!(
            rep.write_efficiency < rep.read_efficiency,
            "writes {:.3} must trail reads {:.3}",
            rep.write_efficiency,
            rep.read_efficiency
        );
    }

    #[test]
    fn fig3b_shape_latency_decreases_with_burst() {
        let g = gen();
        let reps = g.sweep_bursts(AddressPattern::Random, &[4, 32]);
        assert!(
            reps[1].read_lat_avg_ns < reps[0].read_lat_avg_ns,
            "BL32 avg {:.0}ns should be below BL4 {:.0}ns",
            reps[1].read_lat_avg_ns,
            reps[0].read_lat_avg_ns
        );
        // min latency well below saturated average
        assert!(reps[1].read_lat_min_ns < 0.7 * reps[1].read_lat_avg_ns);
    }

    #[test]
    fn sequential_beats_random() {
        let g = gen();
        let mut c_seq = TrafficConfig::new(AddressPattern::Sequential, 4);
        c_seq.transactions = 6_000;
        let mut c_rnd = TrafficConfig::new(AddressPattern::Random, 4);
        c_rnd.transactions = 6_000;
        let seq = g.run(&c_seq);
        let rnd = g.run(&c_rnd);
        assert!(
            seq.read_efficiency > rnd.read_efficiency,
            "sequential {:.3} vs random {:.3}",
            seq.read_efficiency,
            rnd.read_efficiency
        );
    }

    #[test]
    fn interleaved_three_chains_close_to_random() {
        // §III-B: interleaving 3 chains "will achieve bandwidth at least
        // as good as the random read accesses".
        let g = gen();
        let mut c_il = TrafficConfig::new(AddressPattern::Interleaved(3), 8);
        c_il.transactions = 6_000;
        let mut c_rnd = TrafficConfig::new(AddressPattern::Random, 8);
        c_rnd.transactions = 6_000;
        let il = g.run(&c_il).read_efficiency;
        let rnd = g.run(&c_rnd).read_efficiency;
        assert!(il >= rnd * 0.97, "interleaved {il:.3} vs random {rnd:.3}");
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen();
        let mut cfg = TrafficConfig::new(AddressPattern::Random, 8);
        cfg.transactions = 2_000;
        let a = g.run(&cfg);
        let b = g.run(&cfg);
        assert_eq!(a.read_efficiency, b.read_efficiency);
        assert_eq!(a.read_lat_avg_ns, b.read_lat_avg_ns);
    }

    #[test]
    fn core_cycle_conversion() {
        // 486 controller cycles @400MHz = 1215 ns = 365 core cycles @300MHz
        assert_eq!(controller_to_core_cycles(486, 400, 300), 365);
    }
}
