//! The probe hook: how the cycle-domain simulators publish samples.
//!
//! Every instrumented component ([`crate::sim::pipeline::PipelineSim`],
//! [`crate::sim::weights::WeightSubsystem`], [`crate::cluster::FleetSim`])
//! takes an `Option<&mut dyn Probe>`. The `None` path is the production
//! path — one branch per base tick, nothing else — so the hooks stay
//! wired in permanently (the disabled-mode overhead test and the
//! `perf_hotpath` bench enforce that the regression stays under 5%).
//!
//! All counter arguments are **cumulative**: a probe implementation that
//! wants per-window rates (the [`crate::obs::Recorder`]) subtracts its
//! previous sample, which makes the conservation property — window sums
//! equal end-of-run aggregates — hold by construction rather than by
//! sampling luck.

use crate::hbm::controller::PcStats;
use crate::sim::engine::EngineStats;

/// Receiver for cycle-domain observability samples.
///
/// `now` is the core-domain (300 MHz) cycle count of the emitting
/// simulator; HBM burst events carry controller-domain (400 MHz) cycles
/// instead, because that is the clock their latency is defined in.
pub trait Probe {
    /// Sampling window in core cycles. The simulator calls the sample
    /// hooks once every `window()` core cycles (and once more at the end
    /// of the run, so the last partial window is never lost).
    fn window(&self) -> u64;

    /// One engine's cumulative stall breakdown at core cycle `now`.
    fn engine_sample(&mut self, _now: u64, _idx: usize, _name: &str, _cum: &EngineStats) {}

    /// One HBM pseudo-channel's cumulative controller stats at core cycle
    /// `now`. `pc` is the global pseudo-channel id.
    fn pc_sample(&mut self, _now: u64, _pc: u32, _cum: &PcStats) {}

    /// One weight layer's last-stage FIFO at core cycle `now`:
    /// current occupancy, compiled capacity, and the cumulative
    /// high-water mark, all in 80-bit words.
    fn fifo_sample(&mut self, _now: u64, _layer: usize, _name: &str, _occ: u64, _cap: u64, _peak: u64) {
    }

    /// One inter-device credit link at core cycle `now`: lines currently
    /// in flight, cumulative lines transferred, and cumulative core
    /// cycles the upstream sink spent blocked on link credit.
    fn link_sample(&mut self, _now: u64, _link: usize, _occupancy: u64, _lines: u64, _blocked: u64) {
    }

    /// One completed HBM weight burst: global pseudo-channel id, accept
    /// and completion cycles in the controller (400 MHz) domain, and the
    /// burst length in 256-bit beats.
    fn hbm_burst(&mut self, _pc: u32, _accept_cycle: u64, _done_cycle: u64, _beats: u32) {}

    /// One discrete fault-injection or recovery event (`--faults` runs
    /// only). `site` is the faulting resource index in its own namespace
    /// (PC id for `hbm_*`, link index for `link_*`, replica index for
    /// `replica_*`); `now` is in the emitting site's clock domain;
    /// `kind` is a stable label (`"hbm_replay"`, `"hbm_drop"`,
    /// `"link_stall"`, `"replica_down"`, `"replica_up"`, ...); `detail`
    /// is a kind-specific payload (request id, window length, ...).
    /// Unlike the sample hooks these are events, not cumulative counters.
    fn fault_event(&mut self, _site: u32, _now: u64, _kind: &str, _detail: u64) {}
}

/// A probe that records nothing — for overhead measurements of the
/// probed code path itself (every hook is a no-op, so any cost measured
/// against the unprobed path is pure plumbing).
#[derive(Debug, Clone, Default)]
pub struct NullProbe {
    window: u64,
}

impl NullProbe {
    pub fn new(window: u64) -> Self {
        Self { window: window.max(1) }
    }
}

impl Probe for NullProbe {
    fn window(&self) -> u64 {
        self.window.max(1)
    }
}
