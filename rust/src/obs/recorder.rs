//! The flight recorder: a windowed time-series [`Probe`] implementation.
//!
//! The recorder receives cumulative counters from the simulators and
//! stores per-window *deltas*, keyed by track (engine / pseudo-channel /
//! FIFO / link). Because every window is the difference of two cumulative
//! samples and the simulators emit one final sample at the end of the
//! run, the sum of a track's windows is exactly the end-of-run aggregate
//! — the conservation property `integration_obs` asserts against
//! [`crate::sim::pipeline::SimReport`].

use std::collections::BTreeMap;

use crate::hbm::controller::PcStats;
use crate::obs::probe::Probe;
use crate::sim::engine::EngineStats;
use crate::util::Json;

/// Cap on stored HBM burst events: bursts are per-request (not
/// per-window), so an uncapped recording of a long run would dominate
/// memory and trace size. Overflow is counted, never silent.
pub const MAX_BURSTS: usize = 20_000;

/// Cap on stored fault events, same rationale (a high-probability error
/// window can fire tens of thousands of times).
pub const MAX_FAULT_EVENTS: usize = 20_000;

/// One engine stall-breakdown window (core-cycle deltas over
/// `[start, end)`).
#[derive(Debug, Clone, Default)]
pub struct EngineWindow {
    pub start: u64,
    pub end: u64,
    pub active: u64,
    pub input_starved: u64,
    pub output_blocked: u64,
    pub weight_frozen: u64,
}

#[derive(Debug, Clone, Default)]
pub struct EngineTrack {
    pub name: String,
    last_now: u64,
    last: EngineStats,
    pub windows: Vec<EngineWindow>,
}

/// One pseudo-channel window: controller-cycle deltas sampled at core
/// cycle boundaries `[start, end)`.
#[derive(Debug, Clone, Default)]
pub struct PcWindow {
    pub start: u64,
    pub end: u64,
    /// Data beats transferred this window.
    pub data_cycles: u64,
    /// Controller cycles with work queued or in flight this window.
    pub busy_cycles: u64,
    /// Controller cycles elapsed this window.
    pub total_cycles: u64,
    pub row_hits: u64,
    pub row_misses: u64,
}

impl PcWindow {
    /// Issued-vs-ideal bandwidth: data beats over elapsed controller
    /// cycles (an idle PC scores 0, matching [`PcStats::efficiency`]).
    pub fn efficiency(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.data_cycles as f64 / self.total_cycles as f64
    }

    /// Open-row hit rate over the window's CAS commands.
    pub fn row_hit_rate(&self) -> f64 {
        let n = self.row_hits + self.row_misses;
        if n == 0 { 0.0 } else { self.row_hits as f64 / n as f64 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct PcTrack {
    last_now: u64,
    last: PcStats,
    pub windows: Vec<PcWindow>,
}

/// One FIFO occupancy sample (instantaneous, not a delta).
#[derive(Debug, Clone, Default)]
pub struct FifoSample {
    pub now: u64,
    pub occupancy: u64,
}

#[derive(Debug, Clone, Default)]
pub struct FifoTrack {
    pub name: String,
    /// Compiled capacity in 80-bit words (credit counter max).
    pub capacity: u64,
    /// Cumulative high-water mark at the last sample.
    pub peak: u64,
    pub samples: Vec<FifoSample>,
}

/// One inter-device link window.
#[derive(Debug, Clone, Default)]
pub struct LinkWindow {
    pub start: u64,
    pub end: u64,
    /// Lines in flight at the sample point (instantaneous).
    pub occupancy: u64,
    /// Lines transferred this window.
    pub lines: u64,
    /// Upstream credit-blocked core cycles this window.
    pub blocked: u64,
}

#[derive(Debug, Clone, Default)]
pub struct LinkTrack {
    last_now: u64,
    last_lines: u64,
    last_blocked: u64,
    pub windows: Vec<LinkWindow>,
}

/// One completed HBM weight burst (controller-domain cycles).
#[derive(Debug, Clone, Copy)]
pub struct BurstEvent {
    pub pc: u32,
    pub accept_cycle: u64,
    pub done_cycle: u64,
    pub beats: u32,
}

/// One fault-injection / recovery event (`--faults` runs only). Cycles
/// are in the emitting site's clock domain (see [`Probe::fault_event`]).
#[derive(Debug, Clone)]
pub struct FaultRecord {
    pub site: u32,
    pub now: u64,
    pub kind: String,
    pub detail: u64,
}

/// The windowed time-series collector.
#[derive(Debug, Clone)]
pub struct Recorder {
    window: u64,
    pub engines: BTreeMap<usize, EngineTrack>,
    pub pcs: BTreeMap<u32, PcTrack>,
    pub fifos: BTreeMap<usize, FifoTrack>,
    pub links: BTreeMap<usize, LinkTrack>,
    pub bursts: Vec<BurstEvent>,
    pub bursts_dropped: u64,
    pub fault_events: Vec<FaultRecord>,
    pub fault_events_dropped: u64,
}

impl Recorder {
    /// Recorder sampling every `window` core cycles (clamped to >= 1).
    pub fn new(window: u64) -> Self {
        Self {
            window: window.max(1),
            engines: BTreeMap::new(),
            pcs: BTreeMap::new(),
            fifos: BTreeMap::new(),
            links: BTreeMap::new(),
            bursts: Vec::new(),
            bursts_dropped: 0,
            fault_events: Vec::new(),
            fault_events_dropped: 0,
        }
    }

    /// Sum of an engine track's window deltas — by construction equal to
    /// the engine's cumulative counters at the last sample, which is what
    /// the conservation test checks against `SimReport`.
    pub fn engine_totals(&self, idx: usize) -> Option<EngineStats> {
        let t = self.engines.get(&idx)?;
        let mut s = EngineStats::default();
        for w in &t.windows {
            s.active += w.active;
            s.input_starved += w.input_starved;
            s.output_blocked += w.output_blocked;
            s.weight_frozen += w.weight_frozen;
        }
        Some(s)
    }

    /// Total data beats across every PC track's windows.
    pub fn pc_data_cycles_total(&self) -> u64 {
        self.pcs.values().flat_map(|t| t.windows.iter()).map(|w| w.data_cycles).sum()
    }

    /// The `profile` summary block embedded in
    /// [`crate::session::RunReport`]: top stall causes of the busiest
    /// engines, the worst HBM window, and peak FIFO occupancy against the
    /// compiled depth.
    pub fn profile(&self) -> Json {
        // Busiest engines by total stalled cycles, top 3, each with its
        // stall causes ranked.
        let mut ranked: Vec<(u64, usize)> = self
            .engines
            .iter()
            .map(|(&i, _)| {
                let s = self.engine_totals(i).unwrap_or_default();
                (s.input_starved + s.output_blocked + s.weight_frozen, i)
            })
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut bottlenecks = Json::Arr(Vec::new());
        for &(stalled, i) in ranked.iter().take(3) {
            let t = &self.engines[&i];
            let s = self.engine_totals(i).unwrap_or_default();
            let mut causes = vec![
                ("input_starved", s.input_starved),
                ("output_blocked", s.output_blocked),
                ("weight_frozen", s.weight_frozen),
            ];
            causes.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
            let mut top = Json::Arr(Vec::new());
            for (cause, cycles) in causes {
                let mut c = Json::obj();
                c.set("cause", cause).set("cycles", cycles);
                top.push(c);
            }
            let mut e = Json::obj();
            e.set("engine", i)
                .set("name", t.name.as_str())
                .set("active", s.active)
                .set("stalled", stalled)
                .set("top_causes", top);
            bottlenecks.push(e);
        }

        // Worst-window HBM efficiency over windows where the PC was busy.
        let mut worst: Option<(f64, u32, &PcWindow)> = None;
        for (&pc, t) in &self.pcs {
            for w in &t.windows {
                if w.busy_cycles == 0 {
                    continue;
                }
                let eff = w.efficiency();
                if worst.as_ref().map_or(true, |(e, _, _)| eff < *e) {
                    worst = Some((eff, pc, w));
                }
            }
        }
        let worst_hbm = match worst {
            None => Json::Null,
            Some((eff, pc, w)) => {
                let mut o = Json::obj();
                o.set("pc", pc)
                    .set("start", w.start)
                    .set("end", w.end)
                    .set("efficiency", eff)
                    .set("row_hit_rate", w.row_hit_rate());
                o
            }
        };

        // FIFO peaks vs compiled depth — the §IV-A depth bounds checked
        // dynamically rather than statically.
        let mut fifos = Json::Arr(Vec::new());
        let mut max_fill = 0.0f64;
        for (&layer, t) in &self.fifos {
            let fill = if t.capacity == 0 { 0.0 } else { t.peak as f64 / t.capacity as f64 };
            max_fill = max_fill.max(fill);
            let mut o = Json::obj();
            o.set("layer", layer)
                .set("name", t.name.as_str())
                .set("peak_words", t.peak)
                .set("capacity_words", t.capacity)
                .set("fill", fill);
            fifos.push(o);
        }

        let mut o = Json::obj();
        o.set("window", self.window)
            .set("bottlenecks", bottlenecks)
            .set("worst_hbm_window", worst_hbm)
            .set("fifo_peaks", fifos)
            .set("max_fifo_fill", max_fill)
            .set("bursts_recorded", self.bursts.len())
            .set("bursts_dropped", self.bursts_dropped);
        if !self.fault_events.is_empty() || self.fault_events_dropped > 0 {
            o.set("fault_events_recorded", self.fault_events.len())
                .set("fault_events_dropped", self.fault_events_dropped);
        }
        o
    }
}

impl Probe for Recorder {
    fn window(&self) -> u64 {
        self.window
    }

    fn engine_sample(&mut self, now: u64, idx: usize, name: &str, cum: &EngineStats) {
        let t = self.engines.entry(idx).or_default();
        if t.name.is_empty() {
            t.name = name.to_string();
        }
        if now == t.last_now && !t.windows.is_empty() {
            return; // duplicate flush at an exact window boundary
        }
        let w = EngineWindow {
            start: t.last_now,
            end: now,
            active: cum.active - t.last.active,
            input_starved: cum.input_starved - t.last.input_starved,
            output_blocked: cum.output_blocked - t.last.output_blocked,
            weight_frozen: cum.weight_frozen - t.last.weight_frozen,
        };
        t.last_now = now;
        t.last = cum.clone();
        // zero-delta windows still advance `last_now` above but need not
        // be stored — dropping them keeps idle tails out of the trace
        // without breaking conservation (their contribution is zero).
        if w.active + w.input_starved + w.output_blocked + w.weight_frozen > 0 {
            t.windows.push(w);
        }
    }

    fn pc_sample(&mut self, now: u64, pc: u32, cum: &PcStats) {
        let t = self.pcs.entry(pc).or_default();
        if now == t.last_now && !t.windows.is_empty() {
            return;
        }
        let w = PcWindow {
            start: t.last_now,
            end: now,
            data_cycles: cum.data_cycles - t.last.data_cycles,
            busy_cycles: cum.busy_cycles - t.last.busy_cycles,
            total_cycles: cum.total_cycles - t.last.total_cycles,
            row_hits: cum.row_hits - t.last.row_hits,
            row_misses: cum.row_misses - t.last.row_misses,
        };
        t.last_now = now;
        t.last = cum.clone();
        if w.total_cycles > 0 {
            t.windows.push(w);
        }
    }

    fn fifo_sample(&mut self, now: u64, layer: usize, name: &str, occ: u64, cap: u64, peak: u64) {
        let t = self.fifos.entry(layer).or_default();
        if t.name.is_empty() {
            t.name = name.to_string();
        }
        t.capacity = cap;
        t.peak = t.peak.max(peak);
        if t.samples.last().map_or(true, |s| s.now != now) {
            t.samples.push(FifoSample { now, occupancy: occ });
        }
    }

    fn link_sample(&mut self, now: u64, link: usize, occupancy: u64, lines: u64, blocked: u64) {
        let t = self.links.entry(link).or_default();
        if now == t.last_now && !t.windows.is_empty() {
            return;
        }
        let w = LinkWindow {
            start: t.last_now,
            end: now,
            occupancy,
            lines: lines - t.last_lines,
            blocked: blocked - t.last_blocked,
        };
        t.last_now = now;
        t.last_lines = lines;
        t.last_blocked = blocked;
        t.windows.push(w);
    }

    fn hbm_burst(&mut self, pc: u32, accept_cycle: u64, done_cycle: u64, beats: u32) {
        if self.bursts.len() >= MAX_BURSTS {
            self.bursts_dropped += 1;
            return;
        }
        self.bursts.push(BurstEvent { pc, accept_cycle, done_cycle, beats });
    }

    fn fault_event(&mut self, site: u32, now: u64, kind: &str, detail: u64) {
        if self.fault_events.len() >= MAX_FAULT_EVENTS {
            self.fault_events_dropped += 1;
            return;
        }
        self.fault_events.push(FaultRecord { site, now, kind: kind.to_string(), detail });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cum(a: u64, s: u64, b: u64, f: u64) -> EngineStats {
        EngineStats { active: a, input_starved: s, output_blocked: b, weight_frozen: f }
    }

    #[test]
    fn engine_windows_are_deltas_and_conserve() {
        let mut r = Recorder::new(100);
        r.engine_sample(100, 0, "conv1", &cum(60, 30, 10, 0));
        r.engine_sample(200, 0, "conv1", &cum(100, 70, 20, 10));
        r.engine_sample(200, 0, "conv1", &cum(100, 70, 20, 10)); // duplicate flush
        let t = &r.engines[&0];
        assert_eq!(t.windows.len(), 2);
        assert_eq!(t.windows[1].active, 40);
        assert_eq!(t.windows[1].weight_frozen, 10);
        let total = r.engine_totals(0).unwrap();
        assert_eq!(
            (total.active, total.input_starved, total.output_blocked, total.weight_frozen),
            (100, 70, 20, 10)
        );
    }

    #[test]
    fn pc_windows_compute_efficiency_and_hit_rate() {
        let mut r = Recorder::new(100);
        let mut s = PcStats::default();
        s.data_cycles = 80;
        s.busy_cycles = 100;
        s.total_cycles = 160;
        s.row_hits = 9;
        s.row_misses = 1;
        r.pc_sample(100, 3, &s);
        let w = &r.pcs[&3].windows[0];
        assert!((w.efficiency() - 0.5).abs() < 1e-12);
        assert!((w.row_hit_rate() - 0.9).abs() < 1e-12);
        assert_eq!(r.pc_data_cycles_total(), 80);
    }

    #[test]
    fn burst_cap_counts_overflow() {
        let mut r = Recorder::new(1);
        for i in 0..(MAX_BURSTS as u64 + 5) {
            r.hbm_burst(0, i, i + 10, 8);
        }
        assert_eq!(r.bursts.len(), MAX_BURSTS);
        assert_eq!(r.bursts_dropped, 5);
    }

    #[test]
    fn profile_ranks_stall_causes() {
        let mut r = Recorder::new(100);
        r.engine_sample(100, 0, "conv1", &cum(50, 5, 40, 0));
        r.engine_sample(100, 1, "conv2", &cum(20, 80, 0, 0));
        r.fifo_sample(100, 1, "conv2", 128, 512, 300);
        let p = r.profile();
        let bn = p.get("bottlenecks").and_then(Json::as_arr).unwrap();
        // conv2 has more stalled cycles -> ranked first
        assert_eq!(bn[0].get("name").and_then(Json::as_str), Some("conv2"));
        let causes = bn[0].get("top_causes").and_then(Json::as_arr).unwrap();
        assert_eq!(causes[0].get("cause").and_then(Json::as_str), Some("input_starved"));
        assert!((p.get("max_fifo_fill").and_then(Json::as_f64).unwrap() - 300.0 / 512.0).abs()
            < 1e-12);
    }
}
