//! Serving metrics exposition: Prometheus text format over HTTP.
//!
//! [`prometheus_text`] renders [`crate::coordinator::MetricsSnapshot`]s
//! (the router's merged view plus one per replica) in the Prometheus
//! text exposition format 0.0.4, and [`MetricsServer`] serves it from a
//! plain-`std` TCP listener so the workload harness (ROADMAP item 3) can
//! scrape live p50/p99, queue pressure, and reject rate instead of
//! waiting for the end-of-run report. Zero dependencies: the protocol
//! needs one request line and one response, which `std::net` covers.

use std::fmt::Write as _;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::MetricsSnapshot;

/// Render labelled metrics snapshots as Prometheus exposition text.
///
/// The first entry is conventionally the merged/router view labelled
/// `"router"`; per-replica entries are labelled `"replica0"`, … .
/// Ordering is the caller's slice order, so output is deterministic.
pub fn prometheus_text(snaps: &[(String, MetricsSnapshot)]) -> String {
    let mut s = String::new();
    let gauge = |s: &mut String, name: &str, help: &str| {
        let _ = writeln!(s, "# HELP h2pipe_{name} {help}");
        let _ = writeln!(s, "# TYPE h2pipe_{name} gauge");
    };
    let counter = |s: &mut String, name: &str, help: &str| {
        let _ = writeln!(s, "# HELP h2pipe_{name} {help}");
        let _ = writeln!(s, "# TYPE h2pipe_{name} counter");
    };

    counter(&mut s, "requests_completed_total", "Requests completed.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_requests_completed_total{{scope=\"{label}\"}} {}", m.completed);
    }
    counter(&mut s, "requests_rejected_total", "Requests rejected by back-pressure.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_requests_rejected_total{{scope=\"{label}\"}} {}", m.rejected);
    }
    counter(&mut s, "retries_total", "Retry attempts beyond a request's first try.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_retries_total{{scope=\"{label}\"}} {}", m.retries);
    }
    counter(&mut s, "failovers_total", "Requests completed on a later attempt than their first.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_failovers_total{{scope=\"{label}\"}} {}", m.failovers);
    }
    counter(&mut s, "timeouts_total", "Requests that hit the per-request deadline.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_timeouts_total{{scope=\"{label}\"}} {}", m.timeouts);
    }
    counter(&mut s, "shed_total", "Requests shed by admission control.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_shed_total{{scope=\"{label}\"}} {}", m.shed);
    }
    counter(&mut s, "reboots_total", "Watchdog reboots of crashed replicas.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_reboots_total{{scope=\"{label}\"}} {}", m.reboots);
    }
    gauge(&mut s, "mttr_ms", "Mean time to recovery across reboots (ms).");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_mttr_ms{{scope=\"{label}\"}} {:.3}", m.mttr_ms);
    }
    counter(&mut s, "batches_total", "Batches dispatched.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_batches_total{{scope=\"{label}\"}} {}", m.batches);
    }
    gauge(&mut s, "drop_rate", "rejected / (completed + rejected).");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_drop_rate{{scope=\"{label}\"}} {}", m.drop_rate);
    }
    gauge(&mut s, "uptime_seconds", "Seconds since the metrics window opened.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_uptime_seconds{{scope=\"{label}\"}} {:.3}", m.uptime_s);
    }
    gauge(&mut s, "throughput_rps", "Completed requests per second.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_throughput_rps{{scope=\"{label}\"}} {:.3}", m.throughput_rps);
    }
    gauge(&mut s, "batch_fill", "Mean batch size over the configured capacity.");
    for (label, m) in snaps {
        let _ = writeln!(s, "h2pipe_batch_fill{{scope=\"{label}\"}} {:.4}", m.batch_fill);
    }
    gauge(&mut s, "request_latency_ms", "Request latency quantiles (ms).");
    for (label, m) in snaps {
        for (q, v) in
            [("0.5", m.p50_ms), ("0.99", m.p99_ms)]
        {
            if v.is_finite() {
                let _ = writeln!(
                    s,
                    "h2pipe_request_latency_ms{{scope=\"{label}\",quantile=\"{q}\"}} {v:.4}"
                );
            }
        }
        if m.mean_latency_ms.is_finite() {
            let _ = writeln!(
                s,
                "h2pipe_request_latency_ms{{scope=\"{label}\",quantile=\"mean\"}} {:.4}",
                m.mean_latency_ms
            );
        }
    }
    s
}

/// Render one model's autotuner counters in the same exposition format,
/// labelled by model (`h2pipe tune --metrics out.prom`). Several runs
/// concatenate by rendering each and joining — series names repeat but
/// label sets differ, which Prometheus accepts.
pub fn tune_prometheus_text(model: &str, c: &crate::tune::TuneCounters) -> String {
    let mut s = String::new();
    let series = [
        (
            "tune_candidates_total",
            "counter",
            "Candidates evaluated by the autotuner.",
            c.evaluated as f64,
        ),
        (
            "tune_scored_total",
            "counter",
            "Candidates that passed the legality gate and were simulated.",
            c.scored as f64,
        ),
        (
            "tune_rejected_total",
            "counter",
            "Candidates denied by the static verifier.",
            c.rejected as f64,
        ),
        (
            "tune_infeasible_total",
            "counter",
            "Candidates the compiler or simulator refused.",
            c.infeasible as f64,
        ),
        ("tune_generations_total", "counter", "Search generations run.", c.generations as f64),
        ("tune_pareto_size", "gauge", "Final Pareto-front size.", c.pareto_size as f64),
        (
            "tune_best_throughput",
            "gauge",
            "Best simulated throughput found (im/s).",
            c.best_throughput,
        ),
    ];
    for (name, kind, help, value) in series {
        let _ = writeln!(s, "# HELP h2pipe_{name} {help}");
        let _ = writeln!(s, "# TYPE h2pipe_{name} {kind}");
        let _ = writeln!(s, "h2pipe_{name}{{model=\"{model}\"}} {value}");
    }
    s
}

/// A minimal HTTP exposition endpoint: every GET on any path returns the
/// current rendering of `source` as `text/plain; version=0.0.4`.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for MetricsServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsServer").field("addr", &self.addr).finish()
    }
}

impl MetricsServer {
    /// Bind `127.0.0.1:port` (port 0 picks a free port — use
    /// [`Self::addr`] to discover it) and serve `source()` per request.
    pub fn start(port: u16, source: Arc<dyn Fn() -> String + Send + Sync>) -> Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding metrics endpoint on 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("metrics endpoint local addr")?;
        listener.set_nonblocking(true).context("metrics endpoint nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let s2 = stop.clone();
        let handle = std::thread::spawn(move || {
            while !s2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // One request per connection; errors only affect
                        // that scrape.
                        let _ = respond(stream, &source());
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            }
        });
        Ok(Self { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves the port when started with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread and release the closure (and anything it
    /// captures, e.g. an `Arc` over the router).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn respond(mut stream: TcpStream, body: &str) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    // Drain the request line + headers (best effort — the response does
    // not depend on them).
    let mut buf = [0u8; 1024];
    let _ = stream.read(&mut buf);
    let header = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(completed: u64, rejected: u64) -> MetricsSnapshot {
        MetricsSnapshot {
            completed,
            rejected,
            retries: 1,
            failovers: 1,
            timeouts: 0,
            shed: 0,
            reboots: 1,
            mttr_ms: 12.5,
            batches: 2,
            batched_requests: completed,
            uptime_s: 1.5,
            throughput_rps: completed as f64 / 1.5,
            mean_latency_ms: 2.0,
            p50_ms: 1.8,
            p99_ms: 4.2,
            drop_rate: rejected as f64 / (completed + rejected).max(1) as f64,
            batch_fill: 0.5,
        }
    }

    #[test]
    fn exposition_text_carries_scoped_series() {
        let text = prometheus_text(&[
            ("router".to_string(), snap(10, 2)),
            ("replica0".to_string(), snap(10, 2)),
        ]);
        assert!(text.contains("# TYPE h2pipe_requests_completed_total counter"), "{text}");
        assert!(text.contains("h2pipe_requests_completed_total{scope=\"router\"} 10"), "{text}");
        assert!(
            text.contains("h2pipe_request_latency_ms{scope=\"replica0\",quantile=\"0.99\"}"),
            "{text}"
        );
        assert!(text.contains("h2pipe_drop_rate{scope=\"router\"} 0.16666666666666666"), "{text}");
        assert!(text.contains("# TYPE h2pipe_failovers_total counter"), "{text}");
        assert!(text.contains("h2pipe_retries_total{scope=\"router\"} 1"), "{text}");
        assert!(text.contains("h2pipe_reboots_total{scope=\"router\"} 1"), "{text}");
        assert!(text.contains("h2pipe_mttr_ms{scope=\"router\"} 12.500"), "{text}");
    }

    #[test]
    fn nan_latency_series_are_omitted() {
        let mut m = snap(0, 0);
        m.p50_ms = f64::NAN;
        m.p99_ms = f64::NAN;
        m.mean_latency_ms = f64::NAN;
        let text = prometheus_text(&[("router".to_string(), m)]);
        assert!(!text.contains("quantile"), "NaN series must be omitted: {text}");
    }

    #[test]
    fn tune_counters_expose_per_model_series() {
        let c = crate::tune::TuneCounters {
            evaluated: 12,
            scored: 8,
            rejected: 3,
            infeasible: 1,
            generations: 4,
            pareto_size: 2,
            best_throughput: 2600.5,
        };
        let text = tune_prometheus_text("resnet50", &c);
        assert!(text.contains("# TYPE h2pipe_tune_candidates_total counter"), "{text}");
        assert!(text.contains("h2pipe_tune_candidates_total{model=\"resnet50\"} 12"), "{text}");
        assert!(text.contains("h2pipe_tune_rejected_total{model=\"resnet50\"} 3"), "{text}");
        assert!(text.contains("# TYPE h2pipe_tune_pareto_size gauge"), "{text}");
        assert!(text.contains("h2pipe_tune_best_throughput{model=\"resnet50\"} 2600.5"), "{text}");
        assert_eq!(tune_prometheus_text("resnet50", &c), text, "deterministic");
    }

    #[test]
    fn http_endpoint_serves_the_rendering() {
        let srv = MetricsServer::start(
            0,
            Arc::new(|| prometheus_text(&[("router".to_string(), snap(3, 1))])),
        )
        .unwrap();
        let addr = srv.addr();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK"), "{out}");
        assert!(out.contains("text/plain; version=0.0.4"), "{out}");
        assert!(out.contains("h2pipe_requests_completed_total{scope=\"router\"} 3"), "{out}");
        srv.stop();
    }
}
