//! Trace export: Chrome/Perfetto `trace_event` JSON and a compact CSV.
//!
//! The Chrome trace uses one process per track family (engines, HBM,
//! FIFOs, links) and one thread per track, so `chrome://tracing` /
//! Perfetto render each engine as its own row with stall reasons as
//! colored spans. Per-window stall deltas are laid out as consecutive
//! spans inside each window — a windowed approximation of the true
//! interleaving whose *durations* are exact (they are the recorder's
//! conservation-checked deltas).
//!
//! Everything is built through [`crate::util::Json`] (BTreeMap-ordered
//! objects, shortest-round-trip floats), so the output is byte-stable
//! across runs of the same plan and always parses with the strict
//! parser — both properties are asserted by `integration_obs`.

use std::fmt::Write as _;

use crate::obs::recorder::Recorder;
use crate::util::Json;

/// Process ids of the trace's track families.
const PID_ENGINES: u64 = 1;
const PID_HBM: u64 = 2;
const PID_FIFOS: u64 = 3;
const PID_LINKS: u64 = 4;
const PID_FAULTS: u64 = 5;
const PID_TUNE: u64 = 6;

fn meta(pid: u64, tid: u64, what: &str, name: &str) -> Json {
    let mut args = Json::obj();
    args.set("name", name);
    let mut o = Json::obj();
    o.set("ph", "M").set("pid", pid).set("tid", tid).set("name", what).set("args", args);
    o
}

fn span(pid: u64, tid: u64, name: &str, cname: &str, ts_us: f64, dur_us: f64, cycles: u64) -> Json {
    let mut args = Json::obj();
    args.set("cycles", cycles);
    let mut o = Json::obj();
    o.set("ph", "X")
        .set("cat", "stall")
        .set("pid", pid)
        .set("tid", tid)
        .set("name", name)
        .set("cname", cname)
        .set("ts", ts_us)
        .set("dur", dur_us)
        .set("args", args);
    o
}

fn counter(pid: u64, name: &str, ts_us: f64, args: Json) -> Json {
    let mut o = Json::obj();
    o.set("ph", "C").set("pid", pid).set("tid", 0u64).set("name", name).set("ts", ts_us).set(
        "args", args,
    );
    o
}

/// Render a [`Recorder`] as a Chrome `trace_event` document.
///
/// `core_mhz` converts core-domain cycles to microseconds,
/// `controller_mhz` converts HBM burst timestamps.
pub fn chrome_trace(rec: &Recorder, core_mhz: u32, controller_mhz: u32) -> Json {
    let core_us = |c: u64| c as f64 / core_mhz.max(1) as f64;
    let hbm_us = |c: u64| c as f64 / controller_mhz.max(1) as f64;
    let mut ev = Json::Arr(Vec::new());

    ev.push(meta(PID_ENGINES, 0, "process_name", "engines"));
    ev.push(meta(PID_HBM, 0, "process_name", "hbm"));
    ev.push(meta(PID_FIFOS, 0, "process_name", "weight_fifos"));
    ev.push(meta(PID_LINKS, 0, "process_name", "links"));
    ev.push(meta(PID_FAULTS, 0, "process_name", "faults"));

    // Engine stall spans: each window's deltas partition [start, end) in
    // a fixed category order (active first).
    for (&idx, t) in &rec.engines {
        let tid = idx as u64 + 1;
        ev.push(meta(PID_ENGINES, tid, "thread_name", &t.name));
        for w in &t.windows {
            let mut at = w.start;
            for (name, cname, cycles) in [
                ("active", "good", w.active),
                ("input_starved", "yellow", w.input_starved),
                ("output_blocked", "bad", w.output_blocked),
                ("weight_frozen", "terrible", w.weight_frozen),
            ] {
                if cycles == 0 {
                    continue;
                }
                ev.push(span(
                    PID_ENGINES,
                    tid,
                    name,
                    cname,
                    core_us(at),
                    core_us(at + cycles) - core_us(at),
                    cycles,
                ));
                at += cycles;
            }
        }
    }

    // Per-PC bandwidth / row-hit counters, one counter track per PC.
    for (&pc, t) in &rec.pcs {
        for w in &t.windows {
            let mut args = Json::obj();
            args.set("efficiency_pct", (w.efficiency() * 100.0 * 10.0).round() / 10.0)
                .set("row_hit_pct", (w.row_hit_rate() * 100.0 * 10.0).round() / 10.0)
                .set("data_beats", w.data_cycles);
            ev.push(counter(PID_HBM, &format!("pc{pc}"), core_us(w.end), args));
        }
    }

    // HBM bursts as async begin/end pairs on the PC's thread.
    for (i, b) in rec.bursts.iter().enumerate() {
        let tid = b.pc as u64 + 1;
        for (ph, ts) in [("b", b.accept_cycle), ("e", b.done_cycle)] {
            let mut o = Json::obj();
            o.set("ph", ph)
                .set("cat", "hbm_burst")
                .set("pid", PID_HBM)
                .set("tid", tid)
                .set("id", i as u64)
                .set("name", format!("burst_bl{}", b.beats))
                .set("ts", hbm_us(ts));
            ev.push(o);
        }
    }

    // FIFO occupancy counters, one per weight layer.
    for (&layer, t) in &rec.fifos {
        for s in &t.samples {
            let mut args = Json::obj();
            args.set("words", s.occupancy);
            ev.push(counter(PID_FIFOS, &format!("fifo{layer} {}", t.name), core_us(s.now), args));
        }
    }

    // Inter-device link occupancy counters.
    for (&link, t) in &rec.links {
        for w in &t.windows {
            let mut args = Json::obj();
            args.set("lines_in_flight", w.occupancy).set("blocked_cycles", w.blocked);
            ev.push(counter(PID_LINKS, &format!("link{link}"), core_us(w.end), args));
        }
    }

    // Fault-injection / recovery events as Perfetto instants on the
    // dedicated faults track (one thread per site). `hbm_*` events carry
    // controller-domain cycles; everything else is core-domain.
    for f in &rec.fault_events {
        let ts = if f.kind.starts_with("hbm_") { hbm_us(f.now) } else { core_us(f.now) };
        let mut args = Json::obj();
        args.set("detail", f.detail).set("site", f.site);
        let mut o = Json::obj();
        o.set("ph", "i")
            .set("cat", "fault")
            .set("pid", PID_FAULTS)
            .set("tid", f.site as u64 + 1)
            .set("s", "t")
            .set("name", f.kind.as_str())
            .set("ts", ts)
            .set("args", args);
        ev.push(o);
    }

    let mut o = Json::obj();
    o.set("traceEvents", ev)
        .set("displayTimeUnit", "ms")
        .set("otherData", {
            let mut d = Json::obj();
            d.set("generator", "h2pipe obs")
                .set("core_mhz", core_mhz)
                .set("controller_mhz", controller_mhz)
                .set("bursts_dropped", rec.bursts_dropped);
            d
        });
    o
}

/// Render a [`Recorder`] as a flat CSV (one row per window/sample) for
/// quick plotting without a trace viewer.
pub fn csv(rec: &Recorder) -> String {
    let mut s = String::from("kind,track,name,start,end,metric,value\n");
    for (&idx, t) in &rec.engines {
        for w in &t.windows {
            for (metric, v) in [
                ("active", w.active),
                ("input_starved", w.input_starved),
                ("output_blocked", w.output_blocked),
                ("weight_frozen", w.weight_frozen),
            ] {
                let _ = writeln!(s, "engine,{idx},{},{},{},{metric},{v}", t.name, w.start, w.end);
            }
        }
    }
    for (&pc, t) in &rec.pcs {
        for w in &t.windows {
            let _ = writeln!(
                s,
                "pc,{pc},pc{pc},{},{},efficiency,{:.6}",
                w.start,
                w.end,
                w.efficiency()
            );
            let _ = writeln!(
                s,
                "pc,{pc},pc{pc},{},{},row_hit_rate,{:.6}",
                w.start,
                w.end,
                w.row_hit_rate()
            );
        }
    }
    for (&layer, t) in &rec.fifos {
        for smp in &t.samples {
            let _ = writeln!(
                s,
                "fifo,{layer},{},{},{},words,{}",
                t.name, smp.now, smp.now, smp.occupancy
            );
        }
    }
    for (&link, t) in &rec.links {
        for w in &t.windows {
            let _ = writeln!(s, "link,{link},link{link},{},{},lines,{}", w.start, w.end, w.lines);
            let _ = writeln!(
                s,
                "link,{link},link{link},{},{},blocked,{}",
                w.start, w.end, w.blocked
            );
        }
    }
    for f in &rec.fault_events {
        let _ = writeln!(
            s,
            "fault,{},{},{},{},{},{}",
            f.site, f.kind, f.now, f.now, f.kind, f.detail
        );
    }
    s
}

/// Wall-clock request span recorded by the serving router.
#[derive(Debug, Clone, Copy)]
pub struct RequestSpan {
    /// Microseconds since the router started.
    pub start_us: f64,
    pub dur_us: f64,
    /// Replica index that served the request.
    pub replica: usize,
}

/// Render serving request spans as a Chrome trace (one thread per
/// replica). Wall-clock timestamps are inherently run-dependent — the
/// byte-stability guarantee applies to the cycle-domain trace only.
pub fn chrome_serve_trace(spans: &[RequestSpan], replicas: usize) -> Json {
    let mut ev = Json::Arr(Vec::new());
    ev.push(meta(1, 0, "process_name", "serve"));
    for r in 0..replicas {
        ev.push(meta(1, r as u64 + 1, "thread_name", &format!("replica{r}")));
    }
    for s in spans {
        let mut o = Json::obj();
        o.set("ph", "X")
            .set("cat", "request")
            .set("pid", 1u64)
            .set("tid", s.replica as u64 + 1)
            .set("name", "infer")
            .set("ts", s.start_us)
            .set("dur", s.dur_us);
        ev.push(o);
    }
    let mut o = Json::obj();
    o.set("traceEvents", ev).set("displayTimeUnit", "ms");
    o
}

/// One autotuner candidate evaluation, as published by
/// [`crate::tune::TuneReport::trace_spans`].
#[derive(Debug, Clone)]
pub struct TuneSpan {
    /// Candidate id (0 is the default compiler plan).
    pub id: u32,
    /// Genome fingerprint (`b=8;f=512;...`).
    pub genome: String,
    /// `"pareto"`, `"dominated"`, `"rejected"` or `"infeasible"`.
    pub outcome: String,
    /// Simulated throughput in im/s (0 unless scored).
    pub throughput: f64,
    /// Simulated latency in ms (0 unless scored).
    pub latency_ms: f64,
    /// M20K + chain-slot footprint (0 unless scored).
    pub footprint: u64,
}

/// Render tuner candidate evaluations as a Chrome trace on a dedicated
/// track. The time axis is the candidate index (10 µs per candidate), not
/// wall clock, so the trace is byte-stable for a given seed like the
/// cycle-domain traces; a `best_throughput` counter tracks the running
/// maximum over scored candidates.
pub fn chrome_tune_trace(spans: &[TuneSpan]) -> Json {
    const SLOT_US: f64 = 10.0;
    let mut ev = Json::Arr(Vec::new());
    ev.push(meta(PID_TUNE, 0, "process_name", "tune"));
    ev.push(meta(PID_TUNE, 1, "thread_name", "candidates"));
    let mut best = 0.0f64;
    for s in spans {
        let cname = match s.outcome.as_str() {
            "pareto" => "good",
            "dominated" => "yellow",
            "rejected" => "bad",
            _ => "terrible",
        };
        let mut args = Json::obj();
        args.set("genome", s.genome.as_str())
            .set("outcome", s.outcome.as_str())
            .set("throughput", s.throughput)
            .set("latency_ms", s.latency_ms)
            .set("footprint", s.footprint);
        let mut o = Json::obj();
        o.set("ph", "X")
            .set("cat", "tune")
            .set("pid", PID_TUNE)
            .set("tid", 1u64)
            .set("name", format!("cand{}", s.id))
            .set("cname", cname)
            .set("ts", s.id as f64 * SLOT_US)
            .set("dur", SLOT_US)
            .set("args", args);
        ev.push(o);
        if s.outcome == "pareto" || s.outcome == "dominated" {
            best = best.max(s.throughput);
            let mut args = Json::obj();
            args.set("im_per_s", best);
            ev.push(counter(PID_TUNE, "best_throughput", s.id as f64 * SLOT_US, args));
        }
    }
    let mut o = Json::obj();
    o.set("traceEvents", ev).set("displayTimeUnit", "ms");
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::probe::Probe;
    use crate::sim::engine::EngineStats;

    fn recorded() -> Recorder {
        let mut r = Recorder::new(100);
        let cum = EngineStats { active: 60, input_starved: 30, output_blocked: 10, weight_frozen: 0 };
        r.engine_sample(100, 0, "conv1", &cum);
        r.hbm_burst(2, 5, 45, 8);
        r.fifo_sample(100, 0, "conv1", 64, 512, 200);
        r.link_sample(100, 0, 2, 50, 7);
        r
    }

    #[test]
    fn chrome_trace_is_strict_parseable_and_partitions_windows() {
        let j = chrome_trace(&recorded(), 300, 400);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j, "round trip through the strict parser");
        let ev = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        let spans: Vec<&Json> =
            ev.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("X")).collect();
        assert_eq!(spans.len(), 3, "one span per nonzero stall category");
        let total: f64 =
            spans.iter().map(|s| s.get("dur").and_then(Json::as_f64).unwrap()).sum();
        assert!((total - 100.0 / 300.0).abs() < 1e-9, "spans cover the window: {total}");
    }

    #[test]
    fn trace_is_deterministic() {
        let a = chrome_trace(&recorded(), 300, 400).to_string();
        let b = chrome_trace(&recorded(), 300, 400).to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn csv_has_unified_header_and_rows() {
        let text = csv(&recorded());
        assert!(text.starts_with("kind,track,name,start,end,metric,value\n"));
        assert!(text.contains("engine,0,conv1,0,100,active,60"), "{text}");
        assert!(text.contains("link,0,link0,0,100,lines,50"), "{text}");
    }

    #[test]
    fn fault_events_render_as_instants_and_csv_rows() {
        let mut r = recorded();
        r.fault_event(3, 800, "hbm_replay", 17);
        r.fault_event(0, 200, "replica_down", 1);
        let j = chrome_trace(&r, 300, 400);
        let text = j.to_string();
        assert!(text.contains("\"name\":\"faults\""), "{text}");
        assert!(text.contains("\"ph\":\"i\""), "{text}");
        assert!(text.contains("\"name\":\"hbm_replay\""), "{text}");
        assert!(Json::parse(&text).is_ok());
        let c = csv(&r);
        assert!(c.contains("fault,3,hbm_replay,800,800,hbm_replay,17"), "{c}");
    }

    #[test]
    fn tune_trace_is_deterministic_and_tracks_running_best() {
        let spans = vec![
            TuneSpan {
                id: 0,
                genome: "b=8;f=512;s=0;h=false;ov=;c=".to_string(),
                outcome: "dominated".to_string(),
                throughput: 2400.0,
                latency_ms: 2.5,
                footprint: 7000,
            },
            TuneSpan {
                id: 1,
                genome: "b=16;f=512;s=0;h=false;ov=;c=".to_string(),
                outcome: "pareto".to_string(),
                throughput: 2600.0,
                latency_ms: 2.4,
                footprint: 6900,
            },
            TuneSpan {
                id: 2,
                genome: "b=8;f=128;s=0;h=false;ov=;c=".to_string(),
                outcome: "rejected".to_string(),
                throughput: 0.0,
                latency_ms: 0.0,
                footprint: 0,
            },
        ];
        let j = chrome_tune_trace(&spans);
        let text = j.to_string();
        assert_eq!(chrome_tune_trace(&spans).to_string(), text, "byte-stable");
        assert_eq!(Json::parse(&text).unwrap(), j, "strict parser round trip");
        let ev = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        let counters: Vec<f64> = ev
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
            .map(|e| e.get("args").unwrap().get("im_per_s").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(counters, vec![2400.0, 2600.0], "running max over scored candidates");
        // the rejected candidate renders as a span but not a counter
        let spans_out = ev
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .count();
        assert_eq!(spans_out, 3);
        assert!(text.contains("\"cname\":\"bad\""), "{text}");
    }

    #[test]
    fn serve_trace_parses() {
        let spans =
            [RequestSpan { start_us: 1.0, dur_us: 2.5, replica: 0 }];
        let j = chrome_serve_trace(&spans, 2);
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
