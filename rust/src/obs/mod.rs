//! Observability: cycle-domain flight recorder, trace export, and
//! serving metrics exposition.
//!
//! H2PIPE's design decisions rest on *profiles* — the authors measured
//! HBM latency/bandwidth against expected address patterns (§III-A,
//! Fig. 3) and sized FIFOs from worst-case behavior (§IV-A). This module
//! is the reproduction's instrument for producing the same kind of
//! time-resolved evidence:
//!
//! * [`probe`] — the `&mut dyn Probe` hook the simulators publish
//!   samples through. Disabled (`None`) it costs one branch per tick;
//!   the hooks stay wired in permanently.
//! * [`recorder`] — the windowed flight recorder: per-window engine
//!   stall breakdowns, per-PC bandwidth/row-hit windows, weight-FIFO
//!   occupancy, inter-device link occupancy, HBM burst events. Window
//!   deltas of cumulative counters, so window sums equal end-of-run
//!   aggregates exactly.
//! * [`trace`] — Chrome/Perfetto `trace_event` JSON + compact CSV
//!   rendering of a recording (`h2pipe simulate --trace out.json`).
//! * [`expo`] — Prometheus text exposition of serving metrics over a
//!   plain-`std` HTTP endpoint (`h2pipe serve --metrics-port P`), plus
//!   the autotuner's counter series (`h2pipe tune --metrics`).
//!
//! The autotuner publishes per-candidate scoring events on a dedicated
//! trace track ([`trace::chrome_tune_trace`]) with a candidate-index time
//! axis, so tuning runs are inspectable in the same Perfetto UI as cycle
//! traces and stay byte-stable for a given seed.

pub mod expo;
pub mod probe;
pub mod recorder;
pub mod trace;

pub use expo::{prometheus_text, tune_prometheus_text, MetricsServer};
pub use probe::{NullProbe, Probe};
pub use recorder::Recorder;
pub use trace::{chrome_tune_trace, RequestSpan, TuneSpan};
