//! FPGA + HBM device models.
//!
//! Defaults model the paper's testbed: a Gidel board with a Stratix 10
//! NX2100 (-2 speed grade) and two 4-Hi HBM2 stacks (§II-C, §VI). All
//! resource numbers that feed the Table I / Table III accounting are here
//! in one place.

/// DRAM timing parameters for one HBM2 pseudo-channel, expressed in
/// *controller clock cycles* (the 400 MHz user-interface clock, 2.5 ns per
/// cycle). Values follow the HBM2 JEDEC ballpark and are calibrated so the
/// §III-A traffic experiment reproduces the paper's Fig. 3a/3b curves.
#[derive(Debug, Clone, PartialEq)]
pub struct HbmTiming {
    /// ACTIVATE to internal READ/WRITE delay (tRCD).
    pub t_rcd: u32,
    /// PRECHARGE to ACTIVATE delay (tRP).
    pub t_rp: u32,
    /// ACTIVATE to PRECHARGE minimum (tRAS).
    pub t_ras: u32,
    /// Read CAS latency (CL): column command to first data beat.
    pub t_cl: u32,
    /// Write CAS latency (CWL).
    pub t_cwl: u32,
    /// Column-to-column delay between bursts to *different* bank groups.
    pub t_ccd_s: u32,
    /// Column-to-column delay within the *same* bank group.
    pub t_ccd_l: u32,
    /// ACTIVATE-to-ACTIVATE minimum between different banks (tRRD).
    pub t_rrd: u32,
    /// Four-activate window (tFAW): at most 4 ACTIVATEs per window.
    pub t_faw: u32,
    /// Write recovery: last write beat to PRECHARGE (tWR).
    pub t_wr: u32,
    /// Write-to-read bus turnaround (tWTR).
    pub t_wtr: u32,
    /// Read-to-write bus turnaround.
    pub t_rtw: u32,
    /// Refresh interval (tREFI): one REFRESH command due per interval.
    pub t_refi: u32,
    /// Refresh cycle time (tRFC): pseudo-channel blocked per REFRESH.
    pub t_rfc: u32,
    /// Minimum data-bus gap between distinct read bursts (DQS preamble +
    /// command pipeline re-steer in the hardened controller).
    pub t_rd_gap: u32,
    /// Minimum data-bus gap between distinct write bursts (write preamble
    /// is longer; this is the main source of the ~15 pp read/write
    /// efficiency spread in Fig. 3a).
    pub t_wr_gap: u32,
}

impl HbmTiming {
    /// HBM2 timing at 2.5 ns controller cycles (400 MHz), JEDEC-ballpark.
    pub fn hbm2_default() -> Self {
        Self {
            t_rcd: 6,   // ~14 ns
            t_rp: 6,    // ~14 ns
            t_ras: 14,  // ~33 ns
            t_cl: 6,    // ~14 ns
            t_cwl: 3,   // ~7 ns
            t_ccd_s: 1,
            t_ccd_l: 2,
            t_rrd: 2,   // ~4 ns
            t_faw: 8,   // ~20 ns (HBM2 pseudo-channel: small tFAW)
            t_wr: 7,    // ~16 ns
            t_wtr: 4,   // ~9 ns
            t_rtw: 3,
            t_refi: 1560, // 3.9 us
            t_rfc: 104,   // 260 ns
            t_rd_gap: 1,
            t_wr_gap: 4,
        }
    }

    /// Minimum row cycle time tRC = tRAS + tRP.
    pub fn t_rc(&self) -> u32 {
        self.t_ras + self.t_rp
    }
}

/// Geometry of the HBM subsystem attached to the FPGA.
#[derive(Debug, Clone, PartialEq)]
pub struct HbmGeometry {
    /// Number of HBM stacks on the package (Stratix 10 NX2100: 2).
    pub stacks: u32,
    /// Pseudo-channels per stack (4-Hi stack: 4 dies x 2 ch x 2 PC = 16).
    pub pcs_per_stack: u32,
    /// Banks addressable within one pseudo-channel.
    pub banks_per_pc: u32,
    /// Bank groups per pseudo-channel (tCCD_L applies within a group).
    pub bank_groups: u32,
    /// Row size in bytes (columns x device width): 1 KiB rows per PC.
    pub row_bytes: u32,
    /// User-interface data width in bits (hardened controller: 256).
    pub interface_bits: u32,
    /// Controller user-clock frequency in MHz (max 400 on S10 NX).
    pub controller_mhz: u32,
    /// Capacity per pseudo-channel in bytes (4 GB stack / 16 PCs).
    pub pc_capacity_bytes: u64,
}

impl HbmGeometry {
    /// Two 4-Hi HBM2 stacks as on the Gidel Stratix 10 NX2100 board.
    pub fn nx2100_default() -> Self {
        Self {
            stacks: 2,
            pcs_per_stack: 16,
            banks_per_pc: 16,
            bank_groups: 4,
            row_bytes: 1024,
            interface_bits: 256,
            controller_mhz: 400,
            pc_capacity_bytes: 256 << 20, // 256 MiB
        }
    }

    /// Total pseudo-channels across all stacks.
    pub fn total_pcs(&self) -> u32 {
        self.stacks * self.pcs_per_stack
    }

    /// Peak bandwidth of one pseudo-channel in bytes/s.
    pub fn pc_peak_bw(&self) -> f64 {
        self.interface_bits as f64 / 8.0 * self.controller_mhz as f64 * 1e6
    }

    /// Peak bandwidth of one stack in bytes/s (204.8 GB/s for HBM2 @ 2.5ns).
    pub fn stack_peak_bw(&self) -> f64 {
        self.pc_peak_bw() * self.pcs_per_stack as f64
    }

    /// Bytes per interface beat (one controller cycle of data).
    pub fn beat_bytes(&self) -> u32 {
        self.interface_bits / 8
    }
}

/// FPGA device + board model.
///
/// The resource numbers feed the compiler's Table I accounting and the
/// logic-utilization figures of Table II / Table III.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceConfig {
    /// Human-readable device name.
    pub name: String,
    /// M20K block RAMs available (NX2100: 6847 blocks = 140 Mb).
    pub m20k_blocks: u32,
    /// Bits per M20K block (20 Kbit = 20480).
    pub m20k_bits: u32,
    /// AI-optimized tensor blocks (NX2100: 3960).
    pub tensor_blocks: u32,
    /// Adaptive logic modules (NX2100: ~702k ALMs).
    pub alms: u32,
    /// Core (layer-engine) clock in MHz; H2PIPE closes timing at 300.
    pub core_mhz: u32,
    /// HBM subsystem geometry.
    pub hbm: HbmGeometry,
    /// HBM DRAM timing.
    pub hbm_timing: HbmTiming,
    /// Pseudo-channels excluded from use. The paper leaves out PC16
    /// (adjacent to the secure device manager) for timing-closure reasons.
    pub excluded_pcs: Vec<u32>,
}

impl DeviceConfig {
    /// The paper's testbed: Stratix 10 NX2100 on a Gidel board.
    pub fn stratix10_nx2100() -> Self {
        Self {
            name: "Stratix 10 NX2100".to_string(),
            m20k_blocks: 6847,
            m20k_bits: 20480,
            tensor_blocks: 3960,
            alms: 702_720,
            core_mhz: 300,
            hbm: HbmGeometry::nx2100_default(),
            hbm_timing: HbmTiming::hbm2_default(),
            excluded_pcs: vec![16],
        }
    }

    /// Hypothetical device with `n` extra HBM stacks and scaled compute,
    /// used for the Fig. 6 unlimited-bandwidth bound experiments.
    pub fn with_unlimited_hbm(mut self) -> Self {
        self.hbm.stacks = 64; // effectively unlimited for our CNNs
        self.excluded_pcs.clear();
        self.name = format!("{} (unlimited HBM)", self.name);
        self
    }

    /// Total on-chip BRAM capacity in bits (140 Mb for the NX2100).
    pub fn bram_bits(&self) -> u64 {
        self.m20k_blocks as u64 * self.m20k_bits as u64
    }

    /// Number of usable pseudo-channels after exclusions.
    pub fn usable_pcs(&self) -> u32 {
        self.hbm.total_pcs() - self.excluded_pcs.len() as u32
    }

    /// Effective HBM bandwidth available to tensor chains, in bytes/s.
    ///
    /// Matches the paper's §VI-B arithmetic: only 240 of the 256 interface
    /// bits feed 80-bit tensor-chain lanes (3 x 80 = 240), and data is
    /// consumed at the *core* clock, so the usable rate is
    /// `usable_pcs x 240 bit x core_mhz` = 279 GB/s for 31 PCs @ 300 MHz.
    pub fn effective_hbm_bw(&self) -> f64 {
        let chain_bits_per_pc = 3 * 80;
        self.usable_pcs() as f64 * chain_bits_per_pc as f64 / 8.0 * self.core_mhz as f64 * 1e6
    }

    /// Tensor-chain slots a pseudo-channel can feed (256-bit PC word /
    /// 80-bit chain requirement = 3, §III-B).
    pub fn chains_per_pc(&self) -> u32 {
        self.hbm.interface_bits / 80
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nx2100_bram_is_140_mbits() {
        let d = DeviceConfig::stratix10_nx2100();
        let mbits = d.bram_bits() as f64 / 1.0e6;
        // paper: "can only store 140 Mbits of data at a time in its BRAM"
        assert!((139.0..141.0).contains(&mbits), "{mbits}");
    }

    #[test]
    fn stack_bandwidth_is_204_8_gbps() {
        let g = HbmGeometry::nx2100_default();
        assert!((g.stack_peak_bw() - 204.8e9).abs() < 1e6);
        assert_eq!(g.total_pcs(), 32);
    }

    #[test]
    fn effective_bandwidth_matches_paper_279_gbps() {
        let d = DeviceConfig::stratix10_nx2100();
        assert_eq!(d.usable_pcs(), 31);
        // paper §VI-B: "maximum available HBM bandwidth of 279 GB/s"
        let gbps = d.effective_hbm_bw() / 1e9;
        assert!((278.0..280.0).contains(&gbps), "{gbps}");
    }

    #[test]
    fn three_chains_per_pc() {
        let d = DeviceConfig::stratix10_nx2100();
        assert_eq!(d.chains_per_pc(), 3);
    }

    #[test]
    fn trc_is_ras_plus_rp() {
        let t = HbmTiming::hbm2_default();
        assert_eq!(t.t_rc(), t.t_ras + t.t_rp);
    }

    #[test]
    fn unlimited_hbm_has_no_exclusions() {
        let d = DeviceConfig::stratix10_nx2100().with_unlimited_hbm();
        assert!(d.excluded_pcs.is_empty());
        assert!(d.usable_pcs() > 1000);
    }
}
