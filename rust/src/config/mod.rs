//! Device and compiler configuration.
//!
//! [`DeviceConfig`] describes the FPGA + HBM testbed (defaults model the
//! Gidel Stratix 10 NX2100 board used in the paper); [`HbmTiming`] carries
//! the DRAM timing parameters the cycle-level HBM substrate enforces;
//! [`CompilerOptions`] are the user-facing knobs of the H2PIPE compiler.

mod device;
mod options;

pub use device::{DeviceConfig, HbmGeometry, HbmTiming};
pub use options::{
    BurstLengthPolicy, CompilerOptions, EfficiencyTable, FlowControl, WeightPlacement,
};
