//! User-facing compiler options (the knobs §IV–§V expose).

pub use crate::fabric::FlowControl;

/// Where a layer's weights live.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightPlacement {
    /// Weights in on-chip M20K buffers (original HPIPE behaviour).
    OnChip,
    /// Weights streamed from an HBM pseudo-channel (§IV-A).
    Hbm,
}

/// How the compiler picks the HBM burst length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BurstLengthPolicy {
    /// Force one burst length for every offloaded layer.
    Fixed(u32),
    /// The paper's §VI-A conclusion: BL=8 when the pipeline's bottleneck
    /// layer keeps its weights on chip (saves logic), BL=32 when the
    /// bottleneck layer streams from HBM (buys ~2% throughput).
    Auto,
}

impl BurstLengthPolicy {
    /// Legal burst lengths on the hardened controller.
    pub const LEGAL: [u32; 6] = [1, 2, 4, 8, 16, 32];

    pub fn validate(&self) -> anyhow::Result<()> {
        if let BurstLengthPolicy::Fixed(bl) = self {
            anyhow::ensure!(
                Self::LEGAL.contains(bl),
                "burst length {bl} not in {:?}",
                Self::LEGAL
            );
        }
        Ok(())
    }
}

/// Measured HBM random-read efficiency by burst length.
///
/// The compiler's steady-state stall model multiplies each offloaded
/// layer's weight-stream bandwidth by the efficiency the §III-A traffic
/// experiment measured at the chosen burst length. The default table is
/// the Fig. 3a calibration; a recalibration run (`cargo bench --bench
/// fig3a_hbm_efficiency`) can override it without editing source —
/// the table travels inside [`CompilerOptions`] and is persisted with
/// every compiled plan artifact (`h2pipe::session::CompiledModel`).
#[derive(Debug, Clone, PartialEq)]
pub struct EfficiencyTable {
    /// `(burst_len, read_efficiency)` breakpoints, sorted by burst
    /// length. `lookup` uses the entry with the largest burst length not
    /// exceeding the query (the curve saturates upward).
    pub entries: Vec<(u32, f64)>,
}

impl Default for EfficiencyTable {
    fn default() -> Self {
        Self::calibrated()
    }
}

impl EfficiencyTable {
    /// The Fig. 3a calibration measured on the simulated HBM2 substrate.
    pub fn calibrated() -> Self {
        Self {
            entries: vec![
                (1, 0.22),
                (2, 0.44),
                (4, 0.74),
                (8, 0.826),
                (16, 0.875),
                (32, 0.902),
            ],
        }
    }

    /// Read efficiency at `burst_len`: the entry with the largest burst
    /// length `<= burst_len`, or the first entry for shorter bursts.
    pub fn lookup(&self, burst_len: u32) -> f64 {
        self.entries
            .iter()
            .rev()
            .find(|&&(bl, _)| bl <= burst_len)
            .or_else(|| self.entries.first())
            .map(|&(_, eff)| eff)
            .unwrap_or(1.0)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.entries.is_empty(), "efficiency table has no entries");
        for w in self.entries.windows(2) {
            anyhow::ensure!(
                w[0].0 < w[1].0,
                "efficiency table burst lengths must be strictly increasing ({} then {})",
                w[0].0,
                w[1].0
            );
        }
        for &(bl, eff) in &self.entries {
            anyhow::ensure!(
                eff > 0.0 && eff <= 1.0,
                "efficiency {eff} at burst {bl} out of range (0, 1]"
            );
        }
        Ok(())
    }
}

/// Options controlling H2PIPE compilation.
#[derive(Debug, Clone)]
pub struct CompilerOptions {
    /// Burst-length selection policy (§III-B / §VI-A).
    pub burst_length: BurstLengthPolicy,
    /// Force all weights to HBM (the paper's "all-HBM" configuration) or
    /// let Algorithm 1 build the hybrid memory system.
    pub all_hbm: bool,
    /// Width in bits of the boot-time HBM write path (§IV-C, default 30).
    pub write_path_bits: u32,
    /// Depth of the last-stage weight FIFOs in 80-bit words (§IV-A: 512
    /// words to cover the worst-case ~1214 ns HBM read latency).
    pub last_stage_fifo_depth: u32,
    /// Tensor chains grouped per duplicated last-stage FIFO (§IV-A: 6 was
    /// empirically the best Fmax / duplication trade-off).
    pub fifo_group_size: u32,
    /// Maximum fraction of device logic/DSP the compiler may allocate when
    /// scaling parallelism (the paper uses 85% for the unlimited-BW bound).
    pub max_utilization: f64,
    /// Weight precision in bits (the NX port of HPIPE is 8-bit).
    pub weight_bits: u32,
    /// Upper bound on total parallelism-doubling iterations, a safety
    /// valve for the allocation loop.
    pub max_parallelism_steps: u32,
    /// Maximum tensor chains (p_i * p_o) per layer engine. A light-touch
    /// cap (default 32) on weight-broadcast fanout: wider broadcast trees
    /// and deeper last-stage-FIFO duplication collapse Fmax on the real
    /// device (§IV-A found 6 AI-TBs per FIFO group was already the
    /// trade-off point). The paper's bottleneck-layer rates imply their
    /// engines ran fewer chains still; see EXPERIMENTS.md for the
    /// resulting calibration deltas.
    pub max_chains_per_layer: u32,
    /// HBM read-efficiency calibration used by the stall model. Defaults
    /// to the Fig. 3a measurement; recalibration overrides it here (and
    /// the table is persisted inside every saved plan artifact).
    pub efficiency: EfficiencyTable,
    /// Flow-control protocol of the weight distribution network (§V-A).
    /// `Credit` is the paper's fix for the Fig. 5 head-of-line deadlock
    /// and the only protocol `h2pipe check` can prove cycle-free;
    /// `ReadyValid` reproduces the broken baseline and is flagged by the
    /// static deadlock rule (H2P030) whenever layers share a
    /// pseudo-channel.
    pub flow_control: FlowControl,
    /// Assumed weight-sparsity fraction in `[0, 1)`. HPIPE (Hall & Betz)
    /// skips zero weights, shrinking the *on-chip* cost side of Eq. 1;
    /// this knob discounts the Eq. 1 score numerator by `1 - sparsity`
    /// so the offload ordering reflects a sparsity-aware build. Storage
    /// and HBM traffic accounting stay dense — the knob re-ranks
    /// decisions, it never lets a plan under-report its footprint.
    pub sparsity_fraction: f64,
    /// Per-layer placement overrides `(layer index, offload_to_hbm)`,
    /// applied after Algorithm 1 inside the memory-fit loop. The
    /// autotuner's mechanism for exploring offload flips; indices must be
    /// strictly increasing (one canonical form, so equal override sets
    /// always hash equal) and must name weight layers.
    pub offload_overrides: Vec<(usize, bool)>,
}

impl Default for CompilerOptions {
    fn default() -> Self {
        Self {
            burst_length: BurstLengthPolicy::Auto,
            all_hbm: false,
            write_path_bits: 30,
            last_stage_fifo_depth: 512,
            fifo_group_size: 6,
            max_utilization: 0.85,
            weight_bits: 8,
            max_parallelism_steps: 64,
            max_chains_per_layer: 32,
            efficiency: EfficiencyTable::calibrated(),
            flow_control: FlowControl::Credit,
            sparsity_fraction: 0.0,
            offload_overrides: Vec::new(),
        }
    }
}

impl CompilerOptions {
    pub fn validate(&self) -> anyhow::Result<()> {
        self.burst_length.validate()?;
        anyhow::ensure!(
            (1..=256).contains(&self.write_path_bits),
            "write path width {} out of range 1..=256",
            self.write_path_bits
        );
        anyhow::ensure!(self.last_stage_fifo_depth.is_power_of_two(), "FIFO depth must be 2^n");
        anyhow::ensure!(self.fifo_group_size >= 1, "fifo group size must be >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.max_utilization),
            "max_utilization must be in [0,1]"
        );
        anyhow::ensure!(self.weight_bits == 8 || self.weight_bits == 16, "8- or 16-bit weights");
        self.efficiency.validate()?;
        anyhow::ensure!(
            self.sparsity_fraction.is_finite() && (0.0..1.0).contains(&self.sparsity_fraction),
            "sparsity_fraction {} must be finite and in [0, 1)",
            self.sparsity_fraction
        );
        for w in self.offload_overrides.windows(2) {
            anyhow::ensure!(
                w[0].0 < w[1].0,
                "offload overrides must use strictly increasing layer indices ({} then {})",
                w[0].0,
                w[1].0
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_and_match_paper() {
        let o = CompilerOptions::default();
        o.validate().unwrap();
        assert_eq!(o.write_path_bits, 30);
        assert_eq!(o.last_stage_fifo_depth, 512);
        assert_eq!(o.fifo_group_size, 6);
        assert_eq!(o.weight_bits, 8);
        // the paper's production protocol is credit-based (§V-A)
        assert_eq!(o.flow_control, FlowControl::Credit);
    }

    #[test]
    fn illegal_burst_rejected() {
        let mut o = CompilerOptions::default();
        o.burst_length = BurstLengthPolicy::Fixed(3);
        assert!(o.validate().is_err());
        o.burst_length = BurstLengthPolicy::Fixed(8);
        assert!(o.validate().is_ok());
    }

    #[test]
    fn fifo_depth_must_be_power_of_two() {
        let mut o = CompilerOptions::default();
        o.last_stage_fifo_depth = 500;
        assert!(o.validate().is_err());
    }

    #[test]
    fn efficiency_table_matches_legacy_calibration() {
        let t = EfficiencyTable::calibrated();
        for (bl, want) in [(1, 0.22), (2, 0.44), (4, 0.74), (8, 0.826), (16, 0.875), (32, 0.902)] {
            assert_eq!(t.lookup(bl), want, "BL{bl}");
        }
        // below the first breakpoint: clamp to the first entry
        assert_eq!(t.lookup(0), 0.22);
    }

    #[test]
    fn efficiency_table_validation() {
        let mut t = EfficiencyTable::calibrated();
        t.validate().unwrap();
        t.entries[0].1 = 1.5;
        assert!(t.validate().is_err(), "efficiency above 1");
        let unordered = EfficiencyTable { entries: vec![(8, 0.8), (4, 0.7)] };
        assert!(unordered.validate().is_err(), "unsorted bursts");
        let empty = EfficiencyTable { entries: vec![] };
        assert!(empty.validate().is_err());
        // an invalid table makes the whole options invalid
        let mut o = CompilerOptions::default();
        o.efficiency = empty;
        assert!(o.validate().is_err());
    }

    #[test]
    fn sparsity_fraction_bounds() {
        let mut o = CompilerOptions::default();
        assert_eq!(o.sparsity_fraction, 0.0, "dense by default");
        o.sparsity_fraction = 0.75;
        assert!(o.validate().is_ok());
        o.sparsity_fraction = 1.0;
        assert!(o.validate().is_err(), "fully sparse weights are meaningless");
        o.sparsity_fraction = -0.1;
        assert!(o.validate().is_err());
        o.sparsity_fraction = f64::NAN;
        assert!(o.validate().is_err());
    }

    #[test]
    fn offload_overrides_must_be_canonical() {
        let mut o = CompilerOptions::default();
        assert!(o.offload_overrides.is_empty(), "no overrides by default");
        o.offload_overrides = vec![(2, true), (5, false)];
        assert!(o.validate().is_ok());
        o.offload_overrides = vec![(5, true), (2, false)];
        assert!(o.validate().is_err(), "unsorted override indices");
        o.offload_overrides = vec![(2, true), (2, false)];
        assert!(o.validate().is_err(), "duplicate override indices");
    }

    #[test]
    fn write_path_bounds() {
        let mut o = CompilerOptions::default();
        o.write_path_bits = 0;
        assert!(o.validate().is_err());
        o.write_path_bits = 257;
        assert!(o.validate().is_err());
        o.write_path_bits = 256;
        assert!(o.validate().is_ok());
    }
}
