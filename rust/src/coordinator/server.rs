//! Threaded inference server: request router + dynamic batcher.
//!
//! Clients submit images over a bounded channel (back-pressure on
//! overload); a worker drains up to `batch_size` requests at a time and
//! executes them through a [`crate::runtime`] backend. Both wall-clock
//! latency and *modelled FPGA timing* (from the compiled plan / cycle
//! sim) are reported, so the serving example can present the
//! paper-relevant numbers next to live measurements.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::compiler::AcceleratorPlan;
use crate::coordinator::metrics::Metrics;
use crate::faults::ServeFaultKind;
use crate::runtime::{reference, Executable, Runtime};
use crate::util::Json;

/// Typed serving failure — what a client can actually branch on (retry?
/// fail over? shed load?), replacing the stringly `anyhow` errors the
/// serving path used to surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded queue was full (back-pressure) or admission control
    /// shed the request. Retrying elsewhere / later is reasonable.
    Overloaded,
    /// No response within the request deadline. The work may still
    /// complete server-side; the response is discarded.
    Timeout,
    /// The worker thread is gone — crashed or shut down. Fail over and
    /// let the watchdog reboot it.
    ReplicaDown,
    /// The backend rejected this specific request (bad input, model
    /// error); retrying the same payload will fail again.
    Backend(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Overloaded => write!(f, "server overloaded (queue full or load shed)"),
            Self::Timeout => write!(f, "request deadline exceeded"),
            Self::ReplicaDown => write!(f, "replica worker is down"),
            Self::Backend(e) => write!(f, "backend error: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Artifact name to serve (e.g. "cifarnet").
    pub model: String,
    /// Artifact directory.
    pub artifact_dir: String,
    /// Input tensor dims of the artifact.
    pub input_dims: Vec<usize>,
    /// Maximum dynamic batch per dispatch.
    pub batch_size: usize,
    /// Bounded queue depth (requests beyond it are rejected).
    pub queue_depth: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_timeout: Duration,
    /// Modelled per-image FPGA service time in seconds. Populate it from
    /// a compiled plan with [`ServerConfig::with_modelled_plan`] (or the
    /// cycle sim's measured rate); left at 0.0 the report's
    /// `modelled_throughput` is 0 rather than wrong.
    pub modelled_image_s: f64,
    /// Per-request response deadline for [`InferenceServer::infer`]'s
    /// `recv_timeout` — the bound that turns a wedged worker into a
    /// typed [`ServeError::Timeout`] instead of an unbounded hang.
    pub request_deadline: Duration,
    /// Serving-side fault injection for this server instance (`--faults`
    /// runs only); `None` in production.
    pub fault: Option<ServeFaultKind>,
}

impl ServerConfig {
    /// Config for any built-in reference model (`runtime::reference`
    /// `BUILTIN_MODELS`); input dims come from the model graph itself, so
    /// they cannot drift from the backend.
    pub fn builtin(model: &str, artifact_dir: &str) -> Result<Self> {
        let input_dims = reference::builtin_input_dims(model).with_context(|| {
            format!(
                "model {model:?} is not a built-in reference model (available: {:?})",
                reference::BUILTIN_MODELS
            )
        })?;
        Ok(Self {
            model: model.into(),
            artifact_dir: artifact_dir.into(),
            input_dims,
            batch_size: 8,
            queue_depth: 256,
            batch_timeout: Duration::from_millis(2),
            modelled_image_s: 0.0,
            request_deadline: Duration::from_secs(2),
            fault: None,
        })
    }

    pub fn cifarnet(artifact_dir: &str) -> Self {
        Self::builtin("cifarnet", artifact_dir).expect("cifarnet is a built-in model")
    }

    /// Derive the modelled FPGA service time from a compiled plan's
    /// throughput estimate — the wiring every serve entry point needs, so
    /// callers no longer hand-compute `1.0 / est_throughput` (or forget
    /// and silently report a modelled rate of zero).
    pub fn with_modelled_plan(mut self, plan: &AcceleratorPlan) -> Self {
        self.modelled_image_s =
            if plan.est_throughput > 0.0 { 1.0 / plan.est_throughput } else { 0.0 };
        self
    }
}

/// One inference request.
struct Request {
    image: Vec<i32>,
    enqueued: Instant,
    resp: SyncSender<Result<Vec<i32>, String>>,
}

/// Serving summary.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub completed: u64,
    pub rejected: u64,
    pub wall_throughput: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch: f64,
    /// Fraction of offered requests rejected.
    pub drop_rate: f64,
    /// What the modelled FPGA would have sustained on this stream.
    pub modelled_throughput: f64,
}

impl ServerReport {
    /// Machine-scrapable form (emitted by the serve CLI and embedded in
    /// fleet reports).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("wall_throughput_rps", self.wall_throughput)
            .set("mean_latency_ms", self.mean_latency_ms)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("mean_batch", self.mean_batch)
            .set("drop_rate", self.drop_rate)
            .set("modelled_throughput_rps", self.modelled_throughput);
        o
    }
}

/// The inference server.
#[derive(Debug)]
pub struct InferenceServer {
    tx: Option<SyncSender<Request>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    cfg: ServerConfig,
}

impl InferenceServer {
    /// Boot: start the worker thread, which creates the runtime backend
    /// and loads the model locally (the PJRT backend's `xla` handles are
    /// not `Send`, so the executable must live on the thread using it).
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let mut m = Metrics::new();
        m.batch_capacity = cfg.batch_size;
        let metrics = Arc::new(Mutex::new(m));
        let m2 = metrics.clone();
        let wcfg = cfg.clone();
        let (boot_tx, boot_rx) = sync_channel::<Result<(), String>>(1);
        let worker = std::thread::spawn(move || {
            let exe = match Runtime::cpu(&wcfg.artifact_dir)
                .and_then(|rt| rt.load(&wcfg.model).context("loading model artifact"))
            {
                Ok(exe) => {
                    let _ = boot_tx.send(Ok(()));
                    exe
                }
                Err(e) => {
                    let _ = boot_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            worker_loop(rx, exe, wcfg, m2)
        });
        boot_rx
            .recv()
            .context("worker died during boot")?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(Self { tx: Some(tx), worker: Some(worker), metrics, cfg })
    }

    /// Submit one image; blocks until the result arrives or the
    /// configured `request_deadline` expires. Every failure mode is a
    /// typed [`ServeError`] — a full queue, a dead worker, and a blown
    /// deadline are different decisions for the caller.
    pub fn infer(&self, image: Vec<i32>) -> Result<Vec<i32>, ServeError> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request { image, enqueued: Instant::now(), resp: rtx };
        match self.tx.as_ref().expect("server running").try_send(req) {
            Ok(()) => {}
            Err(std::sync::mpsc::TrySendError::Full(_)) => {
                self.metrics.lock().unwrap().rejected += 1;
                return Err(ServeError::Overloaded);
            }
            Err(std::sync::mpsc::TrySendError::Disconnected(_)) => {
                return Err(ServeError::ReplicaDown);
            }
        }
        match rrx.recv_timeout(self.cfg.request_deadline) {
            Ok(Ok(out)) => Ok(out),
            Ok(Err(e)) => Err(ServeError::Backend(e)),
            Err(RecvTimeoutError::Timeout) => {
                self.metrics.lock().unwrap().timeouts += 1;
                Err(ServeError::Timeout)
            }
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::ReplicaDown),
        }
    }

    /// Is the worker thread still running? The watchdog polls this to
    /// detect crashed replicas without submitting probe traffic.
    pub fn is_healthy(&self) -> bool {
        self.worker.as_ref().map_or(false, |w| !w.is_finished())
    }

    /// Fire-and-collect convenience used by load generators: submit a
    /// whole stream at a fixed arrival rate from this thread.
    pub fn run_closed_loop(&self, images: Vec<Vec<i32>>) -> Result<usize> {
        let mut n = 0;
        for img in images {
            if self.infer(img).is_ok() {
                n += 1;
            }
        }
        Ok(n)
    }

    /// A point-in-time copy of the live metrics window (the Prometheus
    /// exposition path scrapes this without stopping the server).
    pub fn metrics_snapshot(&self) -> crate::coordinator::MetricsSnapshot {
        self.metrics.lock().unwrap().snapshot()
    }

    /// Stop the worker and produce the final report.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let m = self.metrics.lock().unwrap();
        let modelled = if self.cfg.modelled_image_s > 0.0 {
            1.0 / self.cfg.modelled_image_s
        } else {
            0.0
        };
        ServerReport {
            completed: m.completed,
            rejected: m.rejected,
            wall_throughput: m.throughput(),
            mean_latency_ms: m.mean_latency_ms(),
            p50_ms: m.latency_ms(50.0),
            p99_ms: m.latency_ms(99.0),
            mean_batch: m.mean_batch_size(),
            drop_rate: m.drop_rate(),
            modelled_throughput: modelled,
        }
    }
}

fn worker_loop(
    rx: Receiver<Request>,
    exe: Executable,
    cfg: ServerConfig,
    metrics: Arc<Mutex<Metrics>>,
) {
    let mut served: u64 = 0;
    loop {
        // block for the first request of a batch
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped: shutdown
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_timeout;
        while batch.len() < cfg.batch_size {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if let Some(ServeFaultKind::Slow { extra_ms }) = cfg.fault {
            std::thread::sleep(Duration::from_millis(extra_ms));
        }
        let n = batch.len();
        for req in batch {
            if let Some(ServeFaultKind::Crash { after_requests }) = cfg.fault {
                if served >= after_requests {
                    // Simulated worker crash: drop the queue and every
                    // pending response sender. Clients observe
                    // `ServeError::ReplicaDown`; the router's watchdog
                    // sees the finished thread and reboots from config.
                    return;
                }
            }
            let out = exe
                .run_i32(&req.image, &cfg.input_dims)
                .map_err(|e| format!("{e:#}"));
            served += 1;
            let lat = req.enqueued.elapsed().as_secs_f64();
            metrics.lock().unwrap().record(lat);
            let _ = req.resp.send(out);
        }
        metrics.lock().unwrap().record_batch(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The reference-interpreter backend needs no artifacts, so these run
    // unconditionally in the offline crate set.
    fn artifact_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn serves_and_reports() {
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.modelled_image_s = 1.0 / 4174.0;
        let srv = InferenceServer::start(cfg).unwrap();
        let img = vec![1i32; 32 * 32 * 3];
        for _ in 0..20 {
            let out = srv.infer(img.clone()).unwrap();
            assert_eq!(out.len(), 10);
        }
        let rep = srv.shutdown();
        assert_eq!(rep.completed, 20);
        assert!(rep.mean_latency_ms > 0.0);
        assert!((rep.modelled_throughput - 4174.0).abs() < 1.0);
    }

    #[test]
    fn modelled_rate_derives_from_plan() {
        let d = crate::config::DeviceConfig::stratix10_nx2100();
        let plan = crate::compiler::compile(
            &crate::nn::zoo::resnet18(),
            &d,
            &crate::config::CompilerOptions::default(),
        )
        .unwrap();
        let cfg = ServerConfig::cifarnet(&artifact_dir()).with_modelled_plan(&plan);
        assert!(cfg.modelled_image_s > 0.0);
        let srv = InferenceServer::start(cfg).unwrap();
        srv.infer(vec![1i32; 32 * 32 * 3]).unwrap();
        let rep = srv.shutdown();
        assert!(
            (rep.modelled_throughput - plan.est_throughput).abs() < 1.0,
            "modelled {:.0} vs plan {:.0}",
            rep.modelled_throughput,
            plan.est_throughput
        );
        let j = rep.to_json().to_string();
        assert!(j.contains("\"completed\":1"), "{j}");
    }

    #[test]
    fn serves_residual_free_builtin() {
        // mobilenet_edge: depthwise-separable, no skip path
        let cfg = ServerConfig::builtin("mobilenet_edge", &artifact_dir()).unwrap();
        assert_eq!(cfg.input_dims, vec![32, 32, 3]);
        let srv = InferenceServer::start(cfg).unwrap();
        let img = vec![9i32; 32 * 32 * 3];
        let a = srv.infer(img.clone()).unwrap();
        let b = srv.infer(img).unwrap();
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
        let rep = srv.shutdown();
        assert_eq!(rep.completed, 2);
    }

    #[test]
    fn builtin_rejects_unknown_model() {
        let err = ServerConfig::builtin("alexnet", &artifact_dir()).unwrap_err();
        assert!(format!("{err:#}").contains("alexnet"));
    }

    #[test]
    fn deterministic_outputs() {
        let srv = InferenceServer::start(ServerConfig::cifarnet(&artifact_dir())).unwrap();
        let img = vec![7i32; 32 * 32 * 3];
        let a = srv.infer(img.clone()).unwrap();
        let b = srv.infer(img).unwrap();
        assert_eq!(a, b);
        srv.shutdown();
    }

    #[test]
    fn deadline_turns_a_straggler_into_a_typed_timeout() {
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.fault = Some(ServeFaultKind::Slow { extra_ms: 500 });
        cfg.request_deadline = Duration::from_millis(40);
        let srv = InferenceServer::start(cfg).unwrap();
        let err = srv.infer(vec![1i32; 32 * 32 * 3]).unwrap_err();
        assert_eq!(err, ServeError::Timeout);
        assert_eq!(srv.metrics_snapshot().timeouts, 1);
        srv.shutdown();
    }

    #[test]
    fn crash_fault_surfaces_replica_down() {
        let mut cfg = ServerConfig::cifarnet(&artifact_dir());
        cfg.fault = Some(ServeFaultKind::Crash { after_requests: 2 });
        cfg.request_deadline = Duration::from_millis(500);
        let srv = InferenceServer::start(cfg).unwrap();
        let img = vec![3i32; 32 * 32 * 3];
        assert!(srv.infer(img.clone()).is_ok());
        assert!(srv.infer(img.clone()).is_ok());
        let err = srv.infer(img.clone()).unwrap_err();
        assert_eq!(err, ServeError::ReplicaDown);
        // the worker thread exits promptly after the crash fires
        let t0 = Instant::now();
        while srv.is_healthy() && t0.elapsed() < Duration::from_secs(2) {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!srv.is_healthy(), "crashed worker must read as unhealthy");
        let rep = srv.shutdown();
        assert_eq!(rep.completed, 2);
    }

    #[test]
    fn concurrent_clients() {
        let srv = std::sync::Arc::new(
            InferenceServer::start(ServerConfig::cifarnet(&artifact_dir())).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = srv.clone();
            handles.push(std::thread::spawn(move || {
                let img = vec![t as i32; 32 * 32 * 3];
                for _ in 0..5 {
                    s.infer(img.clone()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let rep = std::sync::Arc::into_inner(srv).unwrap().shutdown();
        assert_eq!(rep.completed, 20);
    }
}
