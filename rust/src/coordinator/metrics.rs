//! Serving metrics: request latency percentiles + throughput windows.

use std::time::Instant;

use crate::util::{Json, Percentiles};

/// Accumulated serving metrics.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// Wall-clock latency per request (seconds).
    latency: Percentiles,
    /// Requests completed.
    pub completed: u64,
    /// Requests rejected by back-pressure.
    pub rejected: u64,
    /// Batches dispatched.
    pub batches: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            latency: Percentiles::new(),
            completed: 0,
            rejected: 0,
            batches: 0,
        }
    }

    pub fn record(&mut self, latency_s: f64) {
        self.latency.push(latency_s);
        self.completed += 1;
    }

    pub fn record_batch(&mut self, n: usize) {
        self.batches += 1;
        let _ = n;
    }

    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn latency_ms(&mut self, pct: f64) -> f64 {
        self.latency.percentile(pct) * 1e3
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency.mean() * 1e3
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }

    /// Machine-scrapable snapshot (`util::json` — NaN percentiles of an
    /// empty window serialize as `null`). Server and fleet reports embed
    /// this so serving metrics can be diffed and plotted like the bench
    /// outputs.
    pub fn to_json(&mut self) -> Json {
        let mut o = Json::obj();
        o.set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("batches", self.batches)
            .set("throughput_rps", self.throughput())
            .set("mean_latency_ms", self.mean_latency_ms())
            .set("p50_ms", self.latency_ms(50.0))
            .set("p99_ms", self.latency_ms(99.0));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(i as f64 / 1000.0);
        }
        m.record_batch(100);
        assert_eq!(m.completed, 100);
        assert!((m.mean_latency_ms() - 50.5).abs() < 1e-9);
        assert!((m.latency_ms(50.0) - 50.5).abs() < 1e-9);
        assert_eq!(m.mean_batch_size(), 100.0);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn json_snapshot_is_scrapable() {
        let mut m = Metrics::new();
        m.record(0.010);
        m.record(0.030);
        m.record_batch(2);
        let j = m.to_json().to_string();
        assert!(j.contains("\"completed\":2"), "{j}");
        assert!(j.contains("\"p50_ms\":20"), "{j}");
        // an empty window must serialize NaN percentiles as null
        let j = Metrics::new().to_json().to_string();
        assert!(j.contains("\"mean_latency_ms\":null"), "{j}");
    }
}
