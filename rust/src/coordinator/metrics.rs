//! Serving metrics: request latency percentiles + throughput windows.

use std::time::Instant;

use crate::util::{Json, Percentiles};

/// Accumulated serving metrics.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    /// Wall-clock latency per request (seconds).
    latency: Percentiles,
    /// Requests offered (router entry count). Zero on metrics that only
    /// see completions (per-replica servers); when tracked, the
    /// conservation invariant `offered == completed + rejected` is what
    /// the fault ledger's `lost` is computed from.
    pub offered: u64,
    /// Requests completed.
    pub completed: u64,
    /// Requests rejected by back-pressure.
    pub rejected: u64,
    /// Retry attempts beyond a request's first try (router-level).
    pub retries: u64,
    /// Requests that completed on a later attempt than their first.
    pub failovers: u64,
    /// Requests that hit the per-request deadline.
    pub timeouts: u64,
    /// Requests shed by admission control (also counted in `rejected`).
    pub shed: u64,
    /// Watchdog reboots of crashed replicas.
    pub reboots: u64,
    /// Summed detection-to-recovered time across reboots (ms).
    pub mttr_sum_ms: f64,
    /// Batches dispatched.
    pub batches: u64,
    /// Requests carried by those batches (batch-fill numerator).
    pub batched_requests: u64,
    /// Configured batch capacity (batch-fill denominator); 0 = unknown.
    pub batch_capacity: usize,
}

/// One point-in-time copy of a [`Metrics`] window — the exchange type
/// between the serving stack and [`crate::obs::expo`]'s Prometheus
/// rendering. Plain data so it can cross the router/replica boundary
/// without holding any lock.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub retries: u64,
    pub failovers: u64,
    pub timeouts: u64,
    pub shed: u64,
    pub reboots: u64,
    /// Mean time to recovery across reboots (ms); 0 with no reboots.
    pub mttr_ms: f64,
    pub batches: u64,
    pub batched_requests: u64,
    /// Seconds since the metrics window opened.
    pub uptime_s: f64,
    pub throughput_rps: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// rejected / (completed + rejected); 0 with no traffic.
    pub drop_rate: f64,
    /// mean batch size / configured capacity; 0 when capacity is unknown.
    pub batch_fill: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            latency: Percentiles::new(),
            offered: 0,
            completed: 0,
            rejected: 0,
            retries: 0,
            failovers: 0,
            timeouts: 0,
            shed: 0,
            reboots: 0,
            mttr_sum_ms: 0.0,
            batches: 0,
            batched_requests: 0,
            batch_capacity: 0,
        }
    }

    /// Mean time to recovery across watchdog reboots (ms); 0 when
    /// nothing was ever rebooted.
    pub fn mttr_ms(&self) -> f64 {
        if self.reboots == 0 {
            0.0
        } else {
            self.mttr_sum_ms / self.reboots as f64
        }
    }

    pub fn record(&mut self, latency_s: f64) {
        self.latency.push(latency_s);
        self.completed += 1;
    }

    pub fn record_batch(&mut self, n: usize) {
        self.batches += 1;
        self.batched_requests += n as u64;
    }

    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn latency_ms(&self, pct: f64) -> f64 {
        self.latency.percentile(pct) * 1e3
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency.mean() * 1e3
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    /// Fraction of offered requests rejected (0 with no traffic).
    pub fn drop_rate(&self) -> f64 {
        let offered = self.completed + self.rejected;
        if offered == 0 {
            0.0
        } else {
            self.rejected as f64 / offered as f64
        }
    }

    /// Mean batch size over the configured capacity (0 when the capacity
    /// was never set — e.g. router-level metrics, which don't batch).
    pub fn batch_fill(&self) -> f64 {
        if self.batch_capacity == 0 {
            0.0
        } else {
            self.mean_batch_size() / self.batch_capacity as f64
        }
    }

    /// Lock-free-transportable copy of the current window (see
    /// [`MetricsSnapshot`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            completed: self.completed,
            rejected: self.rejected,
            retries: self.retries,
            failovers: self.failovers,
            timeouts: self.timeouts,
            shed: self.shed,
            reboots: self.reboots,
            mttr_ms: self.mttr_ms(),
            batches: self.batches,
            batched_requests: self.batched_requests,
            uptime_s: self.uptime_s(),
            throughput_rps: self.throughput(),
            mean_latency_ms: self.mean_latency_ms(),
            p50_ms: self.latency_ms(50.0),
            p99_ms: self.latency_ms(99.0),
            drop_rate: self.drop_rate(),
            batch_fill: self.batch_fill(),
        }
    }

    /// Machine-scrapable snapshot (`util::json` — NaN percentiles of an
    /// empty window serialize as `null`). Server and fleet reports embed
    /// this so serving metrics can be diffed and plotted like the bench
    /// outputs.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("retries", self.retries)
            .set("failovers", self.failovers)
            .set("timeouts", self.timeouts)
            .set("shed", self.shed)
            .set("reboots", self.reboots)
            .set("mttr_ms", self.mttr_ms())
            .set("batches", self.batches)
            .set("throughput_rps", self.throughput())
            .set("mean_latency_ms", self.mean_latency_ms())
            .set("p50_ms", self.latency_ms(50.0))
            .set("p99_ms", self.latency_ms(99.0))
            .set("drop_rate", self.drop_rate())
            .set("uptime_s", self.uptime_s())
            .set("batch_fill", self.batch_fill());
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(i as f64 / 1000.0);
        }
        m.record_batch(100);
        assert_eq!(m.completed, 100);
        assert!((m.mean_latency_ms() - 50.5).abs() < 1e-9, "mean is tracked exactly");
        // percentiles come from the log-bucketed histogram: ~1% rel error
        assert!((m.latency_ms(50.0) - 50.5).abs() / 50.5 < 0.02, "{}", m.latency_ms(50.0));
        assert_eq!(m.mean_batch_size(), 100.0);
        assert!(m.throughput() > 0.0);
        assert_eq!(m.drop_rate(), 0.0);
    }

    #[test]
    fn drop_rate_and_batch_fill() {
        let mut m = Metrics::new();
        m.batch_capacity = 8;
        for _ in 0..6 {
            m.record(0.001);
        }
        m.rejected = 2;
        m.record_batch(4);
        m.record_batch(2);
        assert!((m.drop_rate() - 0.25).abs() < 1e-12);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        assert!((m.batch_fill() - 3.0 / 8.0).abs() < 1e-12);
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert_eq!(s.batched_requests, 6);
        assert!((s.batch_fill - 3.0 / 8.0).abs() < 1e-12);
        assert!(s.uptime_s >= 0.0);
    }

    #[test]
    fn json_snapshot_is_scrapable() {
        let mut m = Metrics::new();
        m.record(0.010);
        m.record(0.030);
        m.record_batch(2);
        let j = m.to_json();
        let p50 = j.get("p50_ms").and_then(crate::util::Json::as_f64).unwrap();
        assert!((9.0..=31.0).contains(&p50), "histogram p50 within sample range: {p50}");
        let s = j.to_string();
        assert!(s.contains("\"completed\":2"), "{s}");
        assert!(s.contains("\"drop_rate\":0"), "{s}");
        assert!(s.contains("\"batch_fill\":0"), "{s}");
        // an empty window must serialize NaN percentiles as null
        let s = Metrics::new().to_json().to_string();
        assert!(s.contains("\"mean_latency_ms\":null"), "{s}");
    }
}
