//! Boot-time weight download (§IV-C).
//!
//! At power-up the host sends every HBM-resident weight over PCIe into
//! the accelerator, which forwards it through a deliberately *narrow*
//! write path (default 30 bits) that is deserialized to 256 bits only at
//! the AXI controllers — saving >3000 registers versus a full-width bus
//! at the cost of a longer (one-time) boot. This module models that
//! trade-off and actually pushes the write traffic through the simulated
//! HBM controllers so the write-efficiency curve of Fig. 3a applies.

use crate::compiler::AcceleratorPlan;
use crate::compiler::resources::REG_PER_WRITE_PATH_BIT;
use crate::hbm::controller::{Dir, PcTuning, Request};
use crate::hbm::HbmStack;

/// Outcome of the weight download.
#[derive(Debug, Clone)]
pub struct BootReport {
    /// Total bytes written to HBM.
    pub bytes: u64,
    /// Write-path width used (bits).
    pub write_path_bits: u32,
    /// Registers spent on the write path (the §IV-C resource cost).
    pub write_path_registers: u64,
    /// Boot time in seconds (limited by the narrow path or by HBM write
    /// bandwidth, whichever is slower).
    pub seconds: f64,
    /// HBM write efficiency observed while downloading.
    pub hbm_write_efficiency: f64,
}

impl BootReport {
    /// Machine-scrapable form (embedded in session `RunReport`s).
    pub fn to_json(&self) -> crate::util::Json {
        let mut o = crate::util::Json::obj();
        o.set("bytes", self.bytes)
            .set("write_path_bits", self.write_path_bits)
            .set("write_path_registers", self.write_path_registers)
            .set("seconds", self.seconds)
            .set("hbm_write_efficiency", self.hbm_write_efficiency);
        o
    }
}

/// Simulate the one-time weight download for a compiled plan.
///
/// The narrow path delivers `write_path_bits` per core cycle; bursts are
/// accumulated and issued to each PC's controller in layer order (the
/// §V-B clockwise assignment). Returns the measured boot report.
///
/// **Deprecated** for application code: prefer
/// [`crate::session::CompiledModel::boot`], which ties the download to
/// the artifact's provenance; this free function remains the engine.
pub fn boot_weights(plan: &AcceleratorPlan) -> BootReport {
    let geom = &plan.device.hbm;
    let timing = &plan.device.hbm_timing;
    let bytes = plan.hbm_weight_bytes();
    let width = plan.options.write_path_bits;

    // Rate of the narrow path in bytes/s (core clock domain).
    let path_bps = width as f64 / 8.0 * plan.device.core_mhz as f64 * 1e6;

    // Push the same volume through one simulated PC to measure the write
    // efficiency the controllers achieve on this (mostly sequential)
    // pattern. The download is sequential per layer region.
    let mut stack = HbmStack::new(geom, timing, PcTuning::default());
    let pc = stack.pc(0);
    let burst = plan.burst_len.max(8);
    let burst_bytes = burst as u64 * geom.beat_bytes() as u64;
    let sample_bytes = bytes.clamp(1 << 20, 8 << 20); // sample up to 8 MiB
    let mut issued = 0u64;
    let mut addr = 0u64;
    let mut id = 0u64;
    let mut completed = 0u64;
    let total_reqs = sample_bytes / burst_bytes;
    while completed < total_reqs {
        if issued < total_reqs && pc.can_accept(burst) {
            pc.push(Request { id, dir: Dir::Write, addr, burst });
            addr += burst_bytes;
            issued += 1;
            id += 1;
        }
        let mut bus = crate::hbm::CmdBus::new();
        pc.tick(&mut bus);
        completed += pc.drain_completions().len() as u64;
    }
    let write_eff = pc.stats.busy_efficiency();

    // The effective HBM write rate across all used PCs.
    let used_pcs = plan
        .hbm_layers()
        .flat_map(|l| l.pcs.iter().map(|&(pc, _)| pc))
        .collect::<std::collections::HashSet<_>>();
    let hbm_bps = used_pcs.len().max(1) as f64 * geom.pc_peak_bw() * write_eff;

    let seconds = bytes as f64 / path_bps.min(hbm_bps).max(1.0);
    BootReport {
        bytes,
        write_path_bits: width,
        write_path_registers: width as u64 * REG_PER_WRITE_PATH_BIT,
        seconds,
        hbm_write_efficiency: write_eff,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::config::{CompilerOptions, DeviceConfig};
    use crate::nn::zoo;

    fn plan_with_width(width: u32) -> AcceleratorPlan {
        let d = DeviceConfig::stratix10_nx2100();
        let mut o = CompilerOptions::default();
        o.write_path_bits = width;
        compile(&zoo::resnet50(), &d, &o).unwrap()
    }

    #[test]
    fn narrow_path_saves_registers_costs_time() {
        let narrow = boot_weights(&plan_with_width(30));
        let wide = boot_weights(&plan_with_width(256));
        assert!(narrow.write_path_registers < wide.write_path_registers);
        // §IV-C: ">3000 registers saved" going 256 -> 30 bits
        assert!(
            wide.write_path_registers - narrow.write_path_registers > 2500,
            "saved {}",
            wide.write_path_registers - narrow.write_path_registers
        );
        assert!(narrow.seconds > wide.seconds, "narrow must boot slower");
    }

    #[test]
    fn boot_time_is_acceptable_at_default_width() {
        // 30-bit path at 300 MHz = 1.125 GB/s; ResNet-50's HBM weights are
        // tens of MB -> well under a second.
        let r = boot_weights(&plan_with_width(30));
        assert!(r.bytes > 1 << 20, "R50 offloads >1 MiB of weights");
        assert!(r.seconds < 1.0, "boot {:.3}s", r.seconds);
    }

    #[test]
    fn write_efficiency_measured_on_sequential_pattern() {
        let r = boot_weights(&plan_with_width(30));
        // sequential writes do much better than the random-pattern floor
        assert!(r.hbm_write_efficiency > 0.5, "write eff {:.3}", r.hbm_write_efficiency);
    }
}
