//! L3 serving coordinator.
//!
//! The paper's accelerator is driven from a host: weights are downloaded
//! once over PCIe through the §IV-C write path, then images stream
//! through the layer pipeline. This module is that host-side runtime:
//!
//! * [`boot`] — the one-time weight download through the narrow write
//!   path (width/boot-time/register trade-off of §IV-C);
//! * [`server`] — a threaded request router + batcher that executes
//!   functional inference through a [`crate::runtime`] backend (the
//!   reference interpreter by default, PJRT artifacts with `--features
//!   pjrt`) and reports both wall-clock and modelled-FPGA timing;
//! * [`metrics`] — latency/throughput accounting.
//!
//! Python never appears here: the binary is self-contained in either
//! backend configuration.

pub mod boot;
pub mod metrics;
pub mod server;

pub use boot::{boot_weights, BootReport};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{InferenceServer, ServeError, ServerConfig, ServerReport};
