//! H2PIPE command-line launcher.
//!
//! Every subcommand is routed through the typed [`h2pipe::session`]
//! pipeline (`Session::builder() -> CompiledModel -> Deployment ->
//! RunReport`); `compile --out` persists the plan artifact and
//! `simulate`/`serve`/`boot` accept `--plan` to consume it, reproducing
//! the in-memory path bit-for-bit.
//!
//! Arg parsing is hand-rolled against per-subcommand specs (`clap` is not
//! in the offline crate set): options that take a value consume the next
//! token verbatim — even one starting with `--` — and a missing value or
//! unknown option fails with that subcommand's usage instead of being
//! silently reclassified as a flag. `h2pipe help <cmd>` prints the spec.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};
use h2pipe::analysis;
use h2pipe::compiler::memory_breakdown;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::hbm::{AddressPattern, TrafficConfig, TrafficGen};
use h2pipe::nn::zoo;
use h2pipe::session::{
    CompiledModel, DeploymentTarget, ServeOptions, Session, SessionBuilder, TraceOptions,
};
use h2pipe::sim::pipeline::SimConfig;
use h2pipe::util::fmt_mbits;
use h2pipe::verify::{check_partition, Severity};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Static description of one subcommand: which `--key value` options and
/// which bare `--flag`s it accepts, plus its usage text.
struct CmdSpec {
    name: &'static str,
    about: &'static str,
    usage: &'static str,
    /// Options that consume the next token as their value.
    keys: &'static [&'static str],
    /// Bare flags.
    flags: &'static [&'static str],
}

const MODEL_LIST: &str =
    "resnet18|resnet50|vgg16|mobilenetv1|mobilenetv2|mobilenetv3|mobilenet_edge";

const SPECS: &[CmdSpec] = &[
    CmdSpec {
        name: "compile",
        about: "compile a model into an accelerator plan (optionally persist it)",
        usage: "h2pipe compile [--model NAME] [--all-hbm] [--burst N] \
                [--write-path-bits N] [--out FILE.json]",
        keys: &["model", "burst", "write-path-bits", "out"],
        flags: &["all-hbm"],
    },
    CmdSpec {
        name: "check",
        about: "statically verify a plan (H2P0xx diagnostics, no simulation)",
        usage: "h2pipe check [--model NAME | --plan FILE.json] [--all-hbm] [--burst N] \
                [--write-path-bits N] [--shards M] [--deny warn] [--json]",
        keys: &["model", "plan", "burst", "write-path-bits", "shards", "deny"],
        flags: &["all-hbm", "json"],
    },
    CmdSpec {
        name: "simulate",
        about: "cycle-simulate a plan (freshly compiled or loaded from --plan)",
        usage: "h2pipe simulate [--model NAME | --plan FILE.json] [--all-hbm] [--burst N] \
                [--write-path-bits N] [--images N] [--warmup N] [--faults FILE.json] \
                [--trace OUT.json] [--trace-csv OUT.csv] [--trace-window N]",
        keys: &[
            "model",
            "plan",
            "burst",
            "write-path-bits",
            "images",
            "warmup",
            "faults",
            "trace",
            "trace-csv",
            "trace-window",
        ],
        flags: &["all-hbm"],
    },
    CmdSpec {
        name: "characterize",
        about: "run the §III-A HBM traffic characterization",
        usage: "h2pipe characterize [--bursts 1,2,4,8,16,32] \
                [--pattern random|sequential|interleaved3]",
        keys: &["bursts", "pattern"],
        flags: &[],
    },
    CmdSpec {
        name: "table1",
        about: "Table I memory accounting for the model zoo",
        usage: "h2pipe table1",
        keys: &[],
        flags: &[],
    },
    CmdSpec {
        name: "bounds",
        about: "Eq. 2 traffic + Fig. 6 throughput bounds",
        usage: "h2pipe bounds",
        keys: &[],
        flags: &[],
    },
    CmdSpec {
        name: "table3",
        about: "analytic Table III rows (benches run the full simulator)",
        usage: "h2pipe table3",
        keys: &[],
        flags: &[],
    },
    CmdSpec {
        name: "boot",
        about: "simulate the §IV-C boot-time weight download",
        usage: "h2pipe boot [--model NAME | --plan FILE.json] [--all-hbm] [--burst N] \
                [--write-path-bits N]",
        keys: &["model", "plan", "burst", "write-path-bits"],
        flags: &["all-hbm"],
    },
    CmdSpec {
        name: "serve",
        about: "serve inference requests through the fleet router",
        usage: "h2pipe serve [--model NAME | --plan FILE.json] [--requests N] [--batch N] \
                [--replicas N] [--shards M] [--clients N] [--seed N] \
                [--serve-model cifarnet|resnet_block|mobilenet_edge] \
                [--faults FILE.json] [--trace OUT.json] [--metrics-port P]",
        keys: &[
            "model",
            "plan",
            "requests",
            "batch",
            "replicas",
            "shards",
            "clients",
            "seed",
            "serve-model",
            "faults",
            "trace",
            "metrics-port",
        ],
        flags: &[],
    },
    CmdSpec {
        name: "faults",
        about: "write a seeded h2pipe.faults/v1 fault-plan artifact",
        usage: "h2pipe faults [--preset chaos] [--seed N] [--out FILE.json]",
        keys: &["preset", "seed", "out"],
        flags: &[],
    },
    CmdSpec {
        name: "infer",
        about: "single inference through the runtime backend",
        usage: "h2pipe infer",
        keys: &[],
        flags: &[],
    },
    CmdSpec {
        name: "tune",
        about: "autotune plan decisions (Pareto search over burst/FIFO/cut/offload)",
        usage: "h2pipe tune [--model NAME|all] [--budget N] [--seed N] [--images N] \
                [--shards M] [--workers N] [--out DIR] [--trace OUT.json] \
                [--metrics OUT.prom]",
        keys: &[
            "model", "budget", "seed", "images", "shards", "workers", "out", "trace", "metrics",
        ],
        flags: &[],
    },
];

fn spec(cmd: &str) -> Option<&'static CmdSpec> {
    SPECS.iter().find(|s| s.name == cmd)
}

fn general_help() -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "h2pipe — H2PIPE (FPL 2024) reproduction");
    let _ = writeln!(s, "usage: h2pipe <command> [options]   (h2pipe help <command> for details)");
    let _ = writeln!(s);
    for sp in SPECS {
        let _ = writeln!(s, "  {:<13} {}", sp.name, sp.about);
    }
    let _ = writeln!(s, "  {:<13} {}", "help", "show this list, or one command's options");
    let _ = writeln!(s);
    let _ = writeln!(s, "models: {MODEL_LIST}");
    s
}

fn cmd_help(sp: &CmdSpec) -> String {
    format!("{}\n\nusage: {}", sp.about, sp.usage)
}

/// Parsed `--key value` / `--flag` arguments for one subcommand.
struct Args {
    cmd: String,
    /// Positional arguments (only `help` takes one).
    positional: Vec<String>,
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

fn parse_args(argv: Vec<String>) -> Result<Args> {
    let mut it = argv.into_iter();
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let rest: Vec<String> = it.collect();
    if cmd == "help" || cmd == "--help" || cmd == "-h" {
        return Ok(Args {
            cmd: "help".to_string(),
            positional: rest,
            kv: HashMap::new(),
            flags: Vec::new(),
        });
    }
    let sp = spec(&cmd)
        .ok_or_else(|| anyhow!("unknown command {cmd:?}\n\n{}", general_help()))?;
    let mut kv = HashMap::new();
    let mut flags = Vec::new();
    let mut i = 0;
    while i < rest.len() {
        let a = &rest[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected argument {a:?}\n\nusage: {}", sp.usage);
        };
        if sp.flags.iter().any(|f| *f == key) {
            flags.push(key.to_string());
            i += 1;
        } else if sp.keys.iter().any(|k| *k == key) {
            // the value is taken verbatim, even when it starts with "--"
            match rest.get(i + 1) {
                Some(v) => {
                    kv.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => bail!("--{key} requires a value\n\nusage: {}", sp.usage),
            }
        } else {
            bail!("unknown option --{key} for {cmd}\n\nusage: {}", sp.usage);
        }
    }
    Ok(Args { cmd, positional: Vec::new(), kv, flags })
}

impl Args {
    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.kv.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v:?}: {e}")),
        }
    }

    /// Session builder carrying this command's compile-stage knobs.
    fn builder(&self) -> Result<SessionBuilder> {
        let mut b = Session::builder()
            .model(self.kv.get("model").map(String::as_str).unwrap_or("resnet18"))
            .device(DeviceConfig::stratix10_nx2100());
        if self.flag("all-hbm") {
            b = b.all_hbm(true);
        }
        if let Some(burst) = self.kv.get("burst") {
            b = b.fixed_burst(burst.parse().map_err(|e| anyhow!("--burst {burst:?}: {e}"))?);
        }
        if let Some(w) = self.kv.get("write-path-bits") {
            b = b.write_path_bits(w.parse().map_err(|e| anyhow!("--write-path-bits {w:?}: {e}"))?);
        }
        Ok(b)
    }

    /// Flight-recorder options from `--trace`/`--trace-csv`/
    /// `--trace-window`; `None` when tracing was not requested.
    fn trace_options(&self) -> Result<Option<TraceOptions>> {
        let json_path = self.kv.get("trace").cloned();
        let csv_path = self.kv.get("trace-csv").cloned();
        if json_path.is_none() && csv_path.is_none() {
            anyhow::ensure!(
                !self.kv.contains_key("trace-window"),
                "--trace-window requires --trace or --trace-csv"
            );
            return Ok(None);
        }
        let defaults = TraceOptions::default();
        Ok(Some(TraceOptions {
            json_path,
            csv_path,
            window: self.get("trace-window", defaults.window)?,
        }))
    }

    /// The armed fault plan from `--faults`, if any.
    fn fault_plan(&self) -> Result<Option<h2pipe::faults::FaultPlan>> {
        match self.kv.get("faults") {
            None => Ok(None),
            Some(path) => Ok(Some(h2pipe::faults::FaultPlan::load(path)?)),
        }
    }

    /// The artifact stage: load `--plan` or compile from the knobs.
    fn compiled(&self) -> Result<CompiledModel> {
        match self.kv.get("plan") {
            Some(path) => {
                for k in ["model", "burst", "write-path-bits"] {
                    anyhow::ensure!(
                        !self.kv.contains_key(k),
                        "--{k} conflicts with --plan (the artifact pins compile options)"
                    );
                }
                anyhow::ensure!(!self.flag("all-hbm"), "--all-hbm conflicts with --plan");
                CompiledModel::load(path)
            }
            None => self.builder()?.compile(),
        }
    }
}

fn run() -> Result<()> {
    let args = parse_args(std::env::args().skip(1).collect())?;
    let device = DeviceConfig::stratix10_nx2100();
    match args.cmd.as_str() {
        "help" => match args.positional.first() {
            None => print!("{}", general_help()),
            Some(cmd) => match spec(cmd) {
                Some(sp) => println!("{}", cmd_help(sp)),
                None => bail!("unknown command {cmd:?}\n\n{}", general_help()),
            },
        },
        "compile" => {
            let cm = args.builder()?.compile()?;
            print!("{}", cm.plan().report());
            if let Some(path) = args.kv.get("out") {
                cm.save(path)?;
                println!("plan artifact written to {path}");
            }
        }
        "check" => {
            // Broken artifacts must load for diagnosis, so `--plan` takes
            // the unchecked path; the verifier reports what `load` would
            // have refused.
            let cm = match args.kv.get("plan") {
                Some(path) => {
                    for k in ["model", "burst", "write-path-bits"] {
                        anyhow::ensure!(
                            !args.kv.contains_key(k),
                            "--{k} conflicts with --plan (the artifact pins compile options)"
                        );
                    }
                    anyhow::ensure!(!args.flag("all-hbm"), "--all-hbm conflicts with --plan");
                    CompiledModel::load_unchecked(path)?
                }
                None => args.builder()?.compile()?,
            };
            let mut report = cm.verify();
            let shards = args.get("shards", 1usize)?;
            if shards > 1 {
                let plan = cm.plan();
                let pp = h2pipe::cluster::partition(
                    cm.network(),
                    &plan.device,
                    &plan.options,
                    &h2pipe::cluster::PartitionOptions {
                        shards: Some(shards),
                        max_shards: shards,
                    },
                )
                .context("partitioning for fleet check")?;
                report
                    .diagnostics
                    .extend(check_partition(cm.network(), &pp).diagnostics);
            }
            let deny = match args.kv.get("deny").map(String::as_str) {
                None => Severity::Error,
                Some("warn") => Severity::Warn,
                Some("note") => Severity::Note,
                Some(other) => {
                    bail!("--deny {other:?}: expected \"warn\" or \"note\" (errors always deny)")
                }
            };
            if args.flag("json") {
                println!("{}", report.to_json());
            } else {
                print!("{}", report.render());
            }
            if report.denies(deny) {
                bail!(
                    "{}: {} finding(s) at or above the deny threshold",
                    cm.network().name,
                    report.diagnostics.iter().filter(|d| d.severity >= deny).count()
                );
            }
        }
        "simulate" => {
            let cm = args.compiled()?;
            let cfg = SimConfig {
                images: args.get("images", 5u64)?,
                warmup_images: args.get("warmup", 2u64)?,
                ..SimConfig::default()
            };
            let mut dep = cm.deploy(DeploymentTarget::SingleDevice(cfg));
            if let Some(t) = args.trace_options()? {
                dep = dep.with_trace(t);
            }
            if let Some(fp) = args.fault_plan()? {
                dep = dep.with_faults(fp);
            }
            let rep = dep.run()?;
            println!("{}", rep.summary());
            println!("{}", rep.to_json());
        }
        "faults" => {
            let preset = args.kv.get("preset").map(String::as_str).unwrap_or("chaos");
            anyhow::ensure!(preset == "chaos", "unknown preset {preset:?} (expected \"chaos\")");
            let fp = h2pipe::faults::FaultPlan::chaos_preset(args.get("seed", 42u64)?);
            match args.kv.get("out") {
                Some(path) => {
                    fp.save(path)?;
                    println!("fault plan written to {path}");
                }
                None => println!("{}", fp.to_json()),
            }
        }
        "characterize" => {
            let bursts: Vec<u32> = args
                .kv
                .get("bursts")
                .map(String::as_str)
                .unwrap_or("1,2,4,8,16,32")
                .split(',')
                .map(|s| s.parse().context("burst list"))
                .collect::<Result<_>>()?;
            let pattern = match args.kv.get("pattern").map(String::as_str).unwrap_or("random") {
                "random" => AddressPattern::Random,
                "sequential" => AddressPattern::Sequential,
                "interleaved3" => AddressPattern::Interleaved(3),
                p => bail!("unknown pattern {p:?}"),
            };
            let gen = TrafficGen::new(&device);
            println!("pattern {pattern:?}");
            println!(
                "{:>5} {:>9} {:>9} {:>10} {:>10} {:>10}",
                "BL", "read_eff", "write_eff", "lat_min", "lat_avg", "lat_max"
            );
            for bl in bursts {
                let r = gen.run(&TrafficConfig::new(pattern, bl));
                println!(
                    "{bl:>5} {:>9.3} {:>9.3} {:>8.0}ns {:>8.0}ns {:>8.0}ns",
                    r.read_efficiency,
                    r.write_efficiency,
                    r.read_lat_min_ns,
                    r.read_lat_avg_ns,
                    r.read_lat_max_ns
                );
            }
        }
        "table1" => {
            let o = CompilerOptions::default();
            println!(
                "{:<14} {:>12} {:>10} {:>8}  {}",
                "Model", "Weight Mem", "Act Mem", "Act %", "fits NX2100?"
            );
            for net in zoo::table1_models() {
                let b = memory_breakdown(&net, &o);
                println!(
                    "{:<14} {:>12} {:>10} {:>7.1}%  {}",
                    b.model,
                    fmt_mbits(b.weight_bits),
                    fmt_mbits(b.act_bits),
                    100.0 * b.act_fraction(),
                    if b.exceeds(&device) { "NO (shaded)" } else { "yes" }
                );
            }
        }
        "bounds" => {
            let o = CompilerOptions::default();
            for net in zoo::eval_models() {
                let b = analysis::bounds::bounds_report(&net, &device, &o)?;
                println!(
                    "{:<10} Eq2 traffic {:>7.1} MB/img   all-HBM bound {:>6.0} im/s   unlimited-BW bound {:>6.0} im/s",
                    b.model,
                    b.traffic_bytes as f64 / 1e6,
                    b.all_hbm_bound,
                    b.unlimited_bw_bound
                );
            }
        }
        "table3" => {
            // quick analytic H2PIPE rows (benches use the full simulator)
            let mut ours = Vec::new();
            let mut macs = Vec::new();
            for net in zoo::eval_models() {
                let cm = Session::builder().network(net).device(device.clone()).compile()?;
                let plan = cm.plan();
                macs.push((plan.network.clone(), cm.network().total_macs()));
                ours.push(analysis::H2pipeResult {
                    network: plan.network.clone(),
                    all_hbm_throughput: 0.0,
                    hybrid_throughput: plan.est_throughput,
                    latency_ms: plan.est_latency * 1e3,
                    logic_util: plan.usage.alm_frac(&device),
                    bram_util: plan.usage.m20k_frac(&device),
                    dsp_util: plan.usage.tb_frac(&device),
                    freq_mhz: device.core_mhz,
                });
            }
            print!("{}", analysis::table3_text(&ours, &macs));
        }
        "boot" => {
            let cm = args.compiled()?;
            let r = cm.boot();
            println!(
                "{}: {} MiB to HBM over a {}-bit write path: {:.1} ms boot, {} write-path regs, write eff {:.2}",
                cm.network().name,
                r.bytes >> 20,
                r.write_path_bits,
                r.seconds * 1e3,
                r.write_path_registers,
                r.hbm_write_efficiency
            );
            println!("{}", r.to_json());
        }
        "serve" => {
            let cm = args.compiled()?;
            let opts = ServeOptions {
                serve_model: args
                    .kv
                    .get("serve-model")
                    .cloned()
                    .unwrap_or_else(|| "cifarnet".to_string()),
                requests: args.get("requests", 64usize)?,
                batch: args.get("batch", 8usize)?,
                replicas: args.get("replicas", 1usize)?,
                shards: args.get("shards", 1usize)?,
                clients: args.get("clients", 1usize)?,
                seed: args.get("seed", 7u64)?,
                metrics_port: match args.kv.get("metrics-port") {
                    None => None,
                    Some(p) => {
                        Some(p.parse().map_err(|e| anyhow!("--metrics-port {p:?}: {e}"))?)
                    }
                },
                ..ServeOptions::default()
            };
            let mut dep = cm.deploy(DeploymentTarget::Serve(opts));
            if let Some(t) = args.trace_options()? {
                dep = dep.with_trace(t);
            }
            if let Some(fp) = args.fault_plan()? {
                dep = dep.with_faults(fp);
            }
            let rep = dep.run()?;
            println!("{}", rep.summary());
            println!("{}", rep.to_json());
        }
        "infer" => {
            let rt = h2pipe::runtime::Runtime::cpu("artifacts")?;
            let exe = rt.load("cifarnet")?;
            let img = vec![1i32; 32 * 32 * 3];
            let out = exe.run_i32(&img, &[32, 32, 3])?;
            println!("cifarnet logits: {out:?}");
        }
        "tune" => {
            let topts = h2pipe::tune::TuneOptions {
                budget: args.get("budget", 12u32)?,
                seed: args.get("seed", 7u64)?,
                sim_images: args.get("images", 4u64)?,
                workers: args.get("workers", 0usize)?,
                shards: args.get("shards", 1usize)?,
            };
            let models: Vec<&str> = match args.kv.get("model").map(String::as_str) {
                None | Some("all") => h2pipe::tune::DEFAULT_SWEEP.to_vec(),
                Some(m) => vec![m],
            };
            let single = models.len() == 1;
            anyhow::ensure!(
                single || !(args.kv.contains_key("trace") || args.kv.contains_key("metrics")),
                "--trace/--metrics need a single --model (got a {}-model sweep)",
                models.len()
            );
            if let Some(dir) = args.kv.get("out") {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating output directory {dir}"))?;
            }
            for model in models {
                let out = h2pipe::tune::tune_model(model, &device, &topts)?;
                print!("{}", out.report.render());
                if let Some(dir) = args.kv.get("out") {
                    let rpath = format!("{dir}/{model}.tune.json");
                    out.report.save(&rpath)?;
                    println!("tune report written to {rpath}");
                    if let Some(cm) = &out.winner {
                        let ppath = format!("{dir}/{model}.plan.json");
                        cm.save(&ppath)?;
                        println!("winning plan written to {ppath}");
                    }
                }
                if let Some(path) = args.kv.get("trace") {
                    let trace = h2pipe::obs::chrome_tune_trace(&out.report.trace_spans());
                    std::fs::write(path, trace.to_string())
                        .with_context(|| format!("writing tune trace {path}"))?;
                    println!("tune trace written to {path}");
                }
                if let Some(path) = args.kv.get("metrics") {
                    let text = h2pipe::obs::tune_prometheus_text(model, &out.report.counters);
                    std::fs::write(path, text)
                        .with_context(|| format!("writing tune metrics {path}"))?;
                    println!("tune metrics written to {path}");
                }
            }
        }
        _ => unreachable!("parse_args only returns known commands"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn value_starting_with_dashes_is_taken_verbatim() {
        let a = parse_args(argv(&["compile", "--out", "--weird-name.json"])).unwrap();
        assert_eq!(a.kv.get("out").unwrap(), "--weird-name.json");
    }

    #[test]
    fn missing_value_fails_with_usage() {
        let e = parse_args(argv(&["compile", "--model"])).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("--model requires a value"), "{msg}");
        assert!(msg.contains("usage: h2pipe compile"), "{msg}");
    }

    #[test]
    fn unknown_option_fails_with_usage() {
        let e = parse_args(argv(&["simulate", "--modle", "resnet18"])).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown option --modle"), "{msg}");
        assert!(msg.contains("usage: h2pipe simulate"), "{msg}");
    }

    #[test]
    fn unknown_command_lists_commands() {
        let e = parse_args(argv(&["frobnicate"])).unwrap_err();
        assert!(format!("{e:#}").contains("unknown command"), "{e:#}");
    }

    #[test]
    fn flags_and_values_parse_together() {
        let a = parse_args(argv(&[
            "simulate", "--all-hbm", "--model", "vgg16", "--images", "3",
        ]))
        .unwrap();
        assert!(a.flag("all-hbm"));
        assert_eq!(a.kv.get("model").unwrap(), "vgg16");
        assert_eq!(a.get("images", 5u64).unwrap(), 3);
    }

    #[test]
    fn help_takes_a_positional_command() {
        let a = parse_args(argv(&["help", "serve"])).unwrap();
        assert_eq!(a.cmd, "help");
        assert_eq!(a.positional, vec!["serve".to_string()]);
        assert!(cmd_help(spec("serve").unwrap()).contains("--replicas"));
    }

    #[test]
    fn no_args_means_help() {
        let a = parse_args(Vec::new()).unwrap();
        assert_eq!(a.cmd, "help");
        assert!(general_help().contains("compile"));
    }

    #[test]
    fn tune_spec_parses_sweep_and_budget() {
        let a = parse_args(argv(&[
            "tune", "--model", "all", "--budget", "6", "--seed", "42", "--out", "/tmp/t",
        ]))
        .unwrap();
        assert_eq!(a.kv.get("model").unwrap(), "all");
        assert_eq!(a.get("budget", 12u32).unwrap(), 6);
        assert_eq!(a.get("seed", 7u64).unwrap(), 42);
        assert!(cmd_help(spec("tune").unwrap()).contains("--budget"));
    }

    #[test]
    fn plan_conflicts_with_compile_knobs() {
        let a = parse_args(argv(&["simulate", "--plan", "p.json", "--model", "vgg16"])).unwrap();
        let e = a.compiled().unwrap_err();
        assert!(format!("{e:#}").contains("conflicts with --plan"), "{e:#}");
    }
}
