//! H2PIPE command-line launcher.
//!
//! Subcommands (arg parsing is hand-rolled — `clap` is not in the offline
//! crate set):
//!
//! ```text
//! h2pipe compile      --model resnet50 [--all-hbm] [--burst N] [--write-path-bits N]
//! h2pipe simulate     --model resnet50 [--all-hbm] [--burst N] [--images N]
//! h2pipe characterize [--bursts 1,2,4,8,16,32] [--pattern random|sequential|interleaved3]
//! h2pipe table1
//! h2pipe bounds
//! h2pipe table3
//! h2pipe boot         --model vgg16 [--write-path-bits N]
//! h2pipe serve        [--requests N] [--batch N] [--replicas N] [--shards M]
//! h2pipe infer
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};
use h2pipe::analysis;
use h2pipe::cluster::{partition, FleetRouter, PartitionOptions};
use h2pipe::compiler::{compile, memory_breakdown};
use h2pipe::config::{BurstLengthPolicy, CompilerOptions, DeviceConfig};
use h2pipe::coordinator::{boot_weights, ServerConfig};
use h2pipe::hbm::{AddressPattern, TrafficConfig, TrafficGen};
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};
use h2pipe::util::{fmt_mbits, XorShift64};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parsed `--key value` / `--flag` arguments.
struct Args {
    cmd: String,
    kv: HashMap<String, String>,
    flags: Vec<String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut kv = HashMap::new();
    let mut flags = Vec::new();
    let rest: Vec<String> = it.collect();
    let mut i = 0;
    while i < rest.len() {
        let a = &rest[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(key.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        } else {
            bail!("unexpected argument {a:?}");
        }
    }
    Ok(Args { cmd, kv, flags })
}

impl Args {
    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.kv.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    fn model(&self) -> Result<h2pipe::nn::Network> {
        let name = self.kv.get("model").map(String::as_str).unwrap_or("resnet18");
        zoo::by_name(name).with_context(|| format!("unknown model {name:?}"))
    }

    fn compiler_options(&self) -> Result<CompilerOptions> {
        let mut o = CompilerOptions::default();
        if self.flag("all-hbm") {
            o.all_hbm = true;
        }
        if let Some(b) = self.kv.get("burst") {
            o.burst_length = BurstLengthPolicy::Fixed(b.parse()?);
        }
        o.write_path_bits = self.get("write-path-bits", o.write_path_bits)?;
        o.validate()?;
        Ok(o)
    }
}

fn run() -> Result<()> {
    let args = parse_args()?;
    let device = DeviceConfig::stratix10_nx2100();
    match args.cmd.as_str() {
        "compile" => {
            let net = args.model()?;
            let plan = compile(&net, &device, &args.compiler_options()?)?;
            print!("{}", plan.report());
        }
        "simulate" => {
            let net = args.model()?;
            let plan = compile(&net, &device, &args.compiler_options()?)?;
            let cfg = SimConfig {
                images: args.get("images", 5u64)?,
                warmup_images: args.get("warmup", 2u64)?,
                ..SimConfig::default()
            };
            let rep = simulate(&net, &plan, &cfg)?;
            println!(
                "{}: {:.0} im/s   latency {:.2} ms   freeze {:.3}   bottleneck {} ({})   hbm eff {:.3}",
                rep.network,
                rep.throughput,
                rep.latency * 1e3,
                rep.freeze_fraction,
                rep.bottleneck,
                if rep.bottleneck_on_hbm { "HBM" } else { "on-chip" },
                rep.hbm_efficiency,
            );
        }
        "characterize" => {
            let bursts: Vec<u32> = args
                .kv
                .get("bursts")
                .map(String::as_str)
                .unwrap_or("1,2,4,8,16,32")
                .split(',')
                .map(|s| s.parse().context("burst list"))
                .collect::<Result<_>>()?;
            let pattern = match args.kv.get("pattern").map(String::as_str).unwrap_or("random") {
                "random" => AddressPattern::Random,
                "sequential" => AddressPattern::Sequential,
                "interleaved3" => AddressPattern::Interleaved(3),
                p => bail!("unknown pattern {p:?}"),
            };
            let gen = TrafficGen::new(&device);
            println!("pattern {pattern:?}");
            println!(
                "{:>5} {:>9} {:>9} {:>10} {:>10} {:>10}",
                "BL", "read_eff", "write_eff", "lat_min", "lat_avg", "lat_max"
            );
            for bl in bursts {
                let r = gen.run(&TrafficConfig::new(pattern, bl));
                println!(
                    "{bl:>5} {:>9.3} {:>9.3} {:>8.0}ns {:>8.0}ns {:>8.0}ns",
                    r.read_efficiency,
                    r.write_efficiency,
                    r.read_lat_min_ns,
                    r.read_lat_avg_ns,
                    r.read_lat_max_ns
                );
            }
        }
        "table1" => {
            let o = CompilerOptions::default();
            println!(
                "{:<14} {:>12} {:>10} {:>8}  {}",
                "Model", "Weight Mem", "Act Mem", "Act %", "fits NX2100?"
            );
            for net in zoo::table1_models() {
                let b = memory_breakdown(&net, &o);
                println!(
                    "{:<14} {:>12} {:>10} {:>7.1}%  {}",
                    b.model,
                    fmt_mbits(b.weight_bits),
                    fmt_mbits(b.act_bits),
                    100.0 * b.act_fraction(),
                    if b.exceeds(&device) { "NO (shaded)" } else { "yes" }
                );
            }
        }
        "bounds" => {
            let o = CompilerOptions::default();
            for net in zoo::eval_models() {
                let b = analysis::bounds::bounds_report(&net, &device, &o)?;
                println!(
                    "{:<10} Eq2 traffic {:>7.1} MB/img   all-HBM bound {:>6.0} im/s   unlimited-BW bound {:>6.0} im/s",
                    b.model,
                    b.traffic_bytes as f64 / 1e6,
                    b.all_hbm_bound,
                    b.unlimited_bw_bound
                );
            }
        }
        "table3" => {
            // quick analytic H2PIPE rows (benches use the full simulator)
            let o = CompilerOptions::default();
            let mut ours = Vec::new();
            let mut macs = Vec::new();
            for net in zoo::eval_models() {
                let plan = compile(&net, &device, &o)?;
                macs.push((net.name.clone(), net.total_macs()));
                ours.push(analysis::H2pipeResult {
                    network: net.name.clone(),
                    all_hbm_throughput: 0.0,
                    hybrid_throughput: plan.est_throughput,
                    latency_ms: plan.est_latency * 1e3,
                    logic_util: plan.usage.alm_frac(&device),
                    bram_util: plan.usage.m20k_frac(&device),
                    dsp_util: plan.usage.tb_frac(&device),
                    freq_mhz: device.core_mhz,
                });
            }
            print!("{}", analysis::table3_text(&ours, &macs));
        }
        "boot" => {
            let net = args.model()?;
            let plan = compile(&net, &device, &args.compiler_options()?)?;
            let r = boot_weights(&plan);
            println!(
                "{}: {} MiB to HBM over a {}-bit write path: {:.1} ms boot, {} write-path regs, write eff {:.2}",
                net.name,
                r.bytes >> 20,
                r.write_path_bits,
                r.seconds * 1e3,
                r.write_path_registers,
                r.hbm_write_efficiency
            );
        }
        "serve" => {
            let n_req: usize = args.get("requests", 64usize)?;
            let replicas: usize = args.get("replicas", 1usize)?;
            let shards: usize = args.get("shards", 1usize)?;
            let model = args.kv.get("serve-model").map(String::as_str).unwrap_or("cifarnet");
            let mut cfg = ServerConfig::builtin(model, "artifacts")?;
            cfg.batch_size = args.get("batch", 8usize)?;
            // modelled FPGA rate: ResNet-18 hybrid plan, optionally cut
            // into pipeline-parallel shards
            let net = zoo::resnet18();
            let modelled = if shards > 1 {
                let pp = partition(
                    &net,
                    &device,
                    &CompilerOptions::default(),
                    &PartitionOptions { shards: Some(shards), max_shards: shards },
                )?;
                print!("{}", pp.report());
                cfg.modelled_image_s = 1.0 / pp.est_throughput();
                format!("{shards}-shard ResNet-18 plan")
            } else {
                let plan = compile(&net, &device, &CompilerOptions::default())?;
                cfg = cfg.with_modelled_plan(&plan);
                "ResNet-18 hybrid plan".to_string()
            };
            let router = FleetRouter::start(cfg.clone(), replicas)?;
            let pixels: usize = cfg.input_dims.iter().product();
            let mut rng = XorShift64::new(7);
            let mut ok = 0usize;
            for _ in 0..n_req {
                let img: Vec<i32> =
                    (0..pixels).map(|_| rng.next_range(0, 255) as i32 - 128).collect();
                if router.infer(img).is_ok() {
                    ok += 1;
                }
            }
            let rep = router.shutdown();
            println!(
                "served {ok} requests over {replicas} replica(s): wall {:.0} im/s, mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms",
                rep.wall_throughput, rep.mean_latency_ms, rep.p50_ms, rep.p99_ms
            );
            println!(
                "modelled FPGA rate ({modelled} x {replicas} replica(s)): {:.0} im/s",
                rep.modelled_throughput
            );
            println!("{}", rep.to_json().to_string());
        }
        "infer" => {
            let rt = h2pipe::runtime::Runtime::cpu("artifacts")?;
            let exe = rt.load("cifarnet")?;
            let img = vec![1i32; 32 * 32 * 3];
            let out = exe.run_i32(&img, &[32, 32, 3])?;
            println!("cifarnet logits: {out:?}");
        }
        _ => {
            println!(
                "h2pipe — H2PIPE (FPL 2024) reproduction\n\
                 commands: compile | simulate | characterize | table1 | bounds | table3 | boot | serve | infer\n\
                 common:   --model resnet18|resnet50|vgg16|mobilenetv1|mobilenetv2|mobilenetv3\n\
                 compile:  --all-hbm --burst 8|16|32 --write-path-bits N\n\
                 simulate: --images N --warmup N\n\
                 serve:    --requests N --batch N --replicas N --shards M \
                 --serve-model cifarnet|resnet_block|mobilenet_edge"
            );
        }
    }
    Ok(())
}
