//! The §IV-A weight distribution network, wired to the HBM substrate.
//!
//! Every HBM-fed layer owns one *stream* per pseudo-channel it was
//! assigned to (1..=3 chain slots per PC). A stream's prefetcher runs in
//! the HBM clock domain, issues burst reads whenever its credit counter
//! holds a full burst of space (the §V-A credit protocol — reads are
//! never issued that could not drain), and lands data in the layer's
//! last-stage FIFO word pool. Engines consume `chains` 80-bit words per
//! compute cycle and return the credits (the `dequeue` of Fig. 4a).
//!
//! Addresses replay the layer's kernel region cyclically — HPIPE reloads
//! weights once per output line (Eq. 2) — so each stream is sequential
//! within its region, and 2-3 streams interleave per PC: the access
//! pattern of §III-B.

use std::collections::HashMap;

use crate::compiler::AcceleratorPlan;
use crate::fabric::CreditCounter;
use crate::faults::{site_seed, FaultTotals, HbmFaultSpec, ThrottleWindow};
use crate::hbm::controller::{Dir, PcStats, PcTuning, Request};
use crate::hbm::HbmStack;
use crate::obs::Probe;

/// Words of 80 bits delivered per 256-bit beat (240 of 256 bits used).
pub const WORDS_PER_BEAT: u64 = 3;

/// One (layer, pseudo-channel) weight stream.
#[derive(Debug, Clone)]
struct Stream {
    layer_idx: usize,
    /// Global PC id.
    pc: u32,
    /// Chain slots this stream feeds (words consumed per engine cycle).
    chains: u32,
    /// Words currently sitting in the last-stage FIFO pool.
    fifo_words: u64,
    /// Credits over the FIFO capacity (words).
    credits: CreditCounter,
    /// Byte region [base, base + region) replayed cyclically.
    base: u64,
    region: u64,
    next_off: u64,
    /// High-water mark of FIFO occupancy (sizing studies).
    max_words: u64,
}

/// One pseudo-channel's prefetcher state (§Perf: precomputed so the hot
/// loop never touches a hash map or allocates).
#[derive(Debug, Clone)]
struct PcGroup {
    stack_idx: usize,
    local_pc: usize,
    streams: Vec<usize>,
    rr: usize,
}

/// The whole weight subsystem: HBM stacks + streams + per-PC prefetchers.
#[derive(Debug)]
pub struct WeightSubsystem {
    stacks: Vec<HbmStack>,
    streams: Vec<Stream>,
    /// layer idx -> stream indices (indexed by layer id; empty = on-chip).
    by_layer: Vec<Vec<usize>>,
    /// Per-PC prefetch groups (round-robin arbitration state inline).
    pc_groups: Vec<PcGroup>,
    /// (stack, channel) pairs that carry weight streams — idle channels
    /// are never ticked (§Perf).
    active_channels: Vec<(usize, usize)>,
    /// request id -> (stream idx, words).
    pending: HashMap<u64, (usize, u64)>,
    next_id: u64,
    burst: u32,
    words_per_burst: u64,
    /// PCs per stack (global pseudo-channel id derivation for probes).
    pcs_per_stack: u32,
    /// Total weight-read beats completed (bandwidth accounting).
    pub beats_read: u64,
}

impl WeightSubsystem {
    /// Build from a compiled plan.
    pub fn new(plan: &AcceleratorPlan) -> Self {
        let geom = &plan.device.hbm;
        let timing = &plan.device.hbm_timing;
        let n_stacks = geom.stacks as usize;
        let stacks =
            (0..n_stacks).map(|_| HbmStack::new(geom, timing, PcTuning::default())).collect();

        let mut streams: Vec<Stream> = Vec::new();
        let mut by_layer: Vec<Vec<usize>> = vec![Vec::new(); plan.layers.len()];
        let mut by_pc: HashMap<u32, Vec<usize>> = HashMap::new();
        // Region allocator: next free byte per PC.
        let mut pc_cursor: HashMap<u32, u64> = HashMap::new();

        for (li, lp) in plan.layers.iter().enumerate() {
            if lp.pcs.is_empty() || !lp.stats.has_weights {
                continue;
            }
            let total_chains = lp.par.chains();
            let weight_bytes = (lp.stats.weight_bits / 8).max(32);
            for &(pc, chains) in &lp.pcs {
                // share of the kernel bytes proportional to chain share,
                // burst-aligned, at least one burst
                let burst_bytes = plan.burst_len as u64 * geom.beat_bytes() as u64;
                let share = (weight_bytes * chains as u64 / total_chains as u64)
                    .max(burst_bytes)
                    .div_ceil(burst_bytes)
                    * burst_bytes;
                let base = *pc_cursor.entry(pc).or_insert(0);
                pc_cursor.insert(pc, base + share);
                // last-stage FIFO: 512 words per chain; plus burst-matching
                // slack of 4 bursts
                let cap = plan.options.last_stage_fifo_depth as u64 * chains as u64
                    + 4 * plan.burst_len as u64 * WORDS_PER_BEAT;
                let si = streams.len();
                streams.push(Stream {
                    layer_idx: li,
                    pc,
                    chains,
                    fifo_words: 0,
                    credits: CreditCounter::new(cap as u32),
                    base,
                    region: share,
                    next_off: 0,
                    max_words: 0,
                });
                by_layer[li].push(si);
                by_pc.entry(pc).or_default().push(si);
            }
        }
        let mut pc_groups: Vec<PcGroup> = by_pc
            .into_iter()
            .map(|(pc, streams)| PcGroup {
                stack_idx: (pc / geom.pcs_per_stack) as usize,
                local_pc: (pc % geom.pcs_per_stack) as usize,
                streams,
                rr: 0,
            })
            .collect();
        pc_groups.sort_by_key(|g| (g.stack_idx, g.local_pc));
        let mut active_channels: Vec<(usize, usize)> =
            pc_groups.iter().map(|g| (g.stack_idx, g.local_pc / 2)).collect();
        active_channels.sort_unstable();
        active_channels.dedup();
        Self {
            active_channels,
            stacks,
            streams,
            by_layer,
            pc_groups,
            pending: HashMap::new(),
            next_id: 0,
            burst: plan.burst_len,
            words_per_burst: plan.burst_len as u64 * WORDS_PER_BEAT,
            pcs_per_stack: geom.pcs_per_stack,
            beats_read: 0,
        }
    }

    /// Number of streams (for tests).
    pub fn num_streams(&self) -> usize {
        self.streams.len()
    }

    /// Arm the plan's HBM fault sections on every weight-carrying PC.
    /// Each PC gets its own RNG stream ([`site_seed`] over the global PC
    /// id) and only the throttle windows addressed to it, so injection is
    /// deterministic and independent per site.
    pub fn apply_faults(
        &mut self,
        hbm: Option<&HbmFaultSpec>,
        throttle: &[ThrottleWindow],
        seed: u64,
    ) {
        for gi in 0..self.pc_groups.len() {
            let (stack_idx, local_pc) =
                (self.pc_groups[gi].stack_idx, self.pc_groups[gi].local_pc);
            let pc = stack_idx as u32 * self.pcs_per_stack + local_pc as u32;
            let windows: Vec<ThrottleWindow> =
                throttle.iter().filter(|t| t.pc == pc as usize).cloned().collect();
            self.stacks[stack_idx].pc(local_pc).inject_faults(
                hbm.cloned(),
                windows,
                site_seed(seed, u64::from(pc)),
            );
        }
    }

    /// The conservation ledger summed over every weight-carrying PC:
    /// HBM read faults land as `injected`/`retried`(replays)/`dropped`,
    /// throttle denial as `throttled_cycles`.
    pub fn fault_totals(&self) -> FaultTotals {
        let mut t = FaultTotals::default();
        self.for_each_pc_stats(|_, s| {
            t.injected += s.faults_injected;
            t.retried += s.fault_replays;
            t.dropped += s.faults_dropped;
            t.throttled_cycles += s.throttled_cycles;
        });
        t
    }

    /// Advance the HBM clock domain one controller cycle: issue prefetch
    /// reads (credit-gated) and collect completions.
    pub fn hbm_tick(&mut self) {
        self.hbm_tick_probed(None);
    }

    /// [`Self::hbm_tick`] with an optional probe receiving one
    /// [`Probe::hbm_burst`] event per completed weight burst. `None`
    /// costs one branch in the completion drain.
    pub fn hbm_tick_probed(&mut self, mut probe: Option<&mut dyn Probe>) {
        let words_per_burst = self.words_per_burst;
        // one issue attempt per PC per cycle, round-robin over its streams
        for g in &mut self.pc_groups {
            let n = g.streams.len();
            for k in 0..n {
                let si = g.streams[(g.rr + k) % n];
                let s = &mut self.streams[si];
                if !s.credits.can_acquire(words_per_burst as u32) {
                    continue;
                }
                let ctrl = self.stacks[g.stack_idx].pc(g.local_pc);
                if !ctrl.can_accept(self.burst) {
                    break; // controller back-pressure: stop for this PC
                }
                let id = self.next_id;
                self.next_id += 1;
                let addr = s.base + s.next_off;
                s.next_off += self.burst as u64 * 32;
                if s.next_off + self.burst as u64 * 32 > s.region {
                    s.next_off = 0; // kernel replay (per-line reload)
                }
                s.credits.acquire(words_per_burst as u32);
                ctrl.push(Request { id, dir: Dir::Read, addr, burst: self.burst });
                self.pending.insert(id, (si, words_per_burst));
                g.rr = (g.rr + k + 1) % n;
                break;
            }
        }
        // advance the DRAM and collect completions (active channels only)
        for &(st, ch) in &self.active_channels {
            let channel = &mut self.stacks[st].channels[ch];
            channel.tick();
            for (k, pcc) in channel.pcs.iter_mut().enumerate() {
                for c in pcc.drain_completions() {
                    if let Some((si, words)) = self.pending.remove(&c.id) {
                        let s = &mut self.streams[si];
                        s.fifo_words += words;
                        s.max_words = s.max_words.max(s.fifo_words);
                        self.beats_read += self.burst as u64;
                        if let Some(p) = probe.as_deref_mut() {
                            let pc = st as u32 * self.pcs_per_stack + (ch * 2 + k) as u32;
                            p.hbm_burst(pc, c.accept_cycle, c.done_cycle, self.burst);
                        }
                    }
                }
                // Fault events must drain unconditionally (bounded
                // memory); they reach the recorder's faults track only
                // when a probe is attached.
                for e in pcc.drain_fault_events() {
                    if let Some(p) = probe.as_deref_mut() {
                        let pc = st as u32 * self.pcs_per_stack + (ch * 2 + k) as u32;
                        let kind = if e.replayed { "hbm_replay" } else { "hbm_drop" };
                        p.fault_event(pc, e.cycle, kind, e.id);
                    }
                }
            }
        }
    }

    /// Can `layer` consume one compute cycle's weight words right now?
    pub fn layer_ready(&self, layer_idx: usize) -> bool {
        // on-chip weights (no streams) are always ready
        self.by_layer[layer_idx].iter().all(|&si| {
            let s = &self.streams[si];
            s.fifo_words >= s.chains as u64
        })
    }

    /// Consume one compute cycle's words for `layer` (caller must have
    /// checked [`Self::layer_ready`]); returns credits via `dequeue`.
    pub fn consume(&mut self, layer_idx: usize) {
        for &si in &self.by_layer[layer_idx] {
            let s = &mut self.streams[si];
            debug_assert!(s.fifo_words >= s.chains as u64, "consume without ready");
            s.fifo_words -= s.chains as u64;
            s.credits.release(s.chains);
        }
    }

    /// Aggregate FIFO occupancy for a layer (diagnostics).
    pub fn fifo_words(&self, layer_idx: usize) -> u64 {
        self.by_layer[layer_idx].iter().map(|&si| self.streams[si].fifo_words).sum()
    }

    /// Aggregate compiled FIFO capacity for a layer in words (the credit
    /// window each stream advertises, summed over the layer's streams).
    pub fn fifo_capacity(&self, layer_idx: usize) -> u64 {
        self.by_layer[layer_idx].iter().map(|&si| self.streams[si].credits.max() as u64).sum()
    }

    /// High-water mark of a layer's FIFO occupancy (sum of per-stream
    /// peaks — an upper bound on the simultaneous peak, which is the
    /// conservative direction for checking the compiled depth).
    pub fn fifo_peak(&self, layer_idx: usize) -> u64 {
        self.by_layer[layer_idx].iter().map(|&si| self.streams[si].max_words).sum()
    }

    /// True when the layer streams weights from HBM (has streams).
    pub fn layer_has_streams(&self, layer_idx: usize) -> bool {
        !self.by_layer[layer_idx].is_empty()
    }

    /// Visit the cumulative controller stats of every weight-carrying
    /// pseudo-channel as `(global_pc, stats)`, in PC order.
    pub fn for_each_pc_stats(&self, mut f: impl FnMut(u32, &PcStats)) {
        for g in &self.pc_groups {
            let pc = g.stack_idx as u32 * self.pcs_per_stack + g.local_pc as u32;
            let stats =
                &self.stacks[g.stack_idx].channels[g.local_pc / 2].pcs[g.local_pc % 2].stats;
            f(pc, stats);
        }
    }

    // --- event-driven fast path (crate-internal) ------------------------
    //
    // The skip-ahead scheduler in `sim::events` drives the subsystem
    // through these hooks instead of `hbm_tick_probed`. Semantics are
    // tick-exact: `try_issue_group` is the slow path's phase-1 body for
    // one group, `channel_event` is its phase-2 body for one channel, and
    // consume/catch-up closed forms replace only cycles proven inert.

    /// Number of prefetch groups (one per weight-carrying PC).
    pub(crate) fn num_groups(&self) -> usize {
        self.pc_groups.len()
    }

    /// Number of weight-carrying channels.
    pub(crate) fn num_active_channels(&self) -> usize {
        self.active_channels.len()
    }

    /// `(stack, local_pc)` a group issues to.
    pub(crate) fn group_target(&self, gi: usize) -> (usize, usize) {
        (self.pc_groups[gi].stack_idx, self.pc_groups[gi].local_pc)
    }

    /// Stream indices arbitrated by group `gi`.
    pub(crate) fn group_streams(&self, gi: usize) -> &[usize] {
        &self.pc_groups[gi].streams
    }

    /// Index into the active-channel list for a group's PC.
    pub(crate) fn channel_index_for_group(&self, gi: usize) -> usize {
        let key = (self.pc_groups[gi].stack_idx, self.pc_groups[gi].local_pc / 2);
        self.active_channels.iter().position(|&c| c == key).expect("group channel active")
    }

    /// Streams feeding `layer` (empty for on-chip layers).
    pub(crate) fn layer_streams(&self, layer_idx: usize) -> &[usize] {
        &self.by_layer[layer_idx]
    }

    /// Words consumed from stream `si` per engine compute cycle.
    pub(crate) fn stream_chains(&self, si: usize) -> u32 {
        self.streams[si].chains
    }

    /// Whole compute cycles stream `si` can currently fuel.
    pub(crate) fn stream_budget_cycles(&self, si: usize) -> u64 {
        let s = &self.streams[si];
        s.fifo_words / s.chains as u64
    }

    /// Credit words still missing before stream `si` could accept another
    /// burst issue (0 = `can_acquire` already holds).
    pub(crate) fn stream_acquire_deficit(&self, si: usize) -> u64 {
        (self.words_per_burst as u32).saturating_sub(self.streams[si].credits.available()) as u64
    }

    /// Apply `n` engine compute cycles of consumption to stream `si` in
    /// closed form — the exact aggregate of `n` per-cycle [`Self::consume`]
    /// effects on this stream (FIFO drain plus credit return).
    pub(crate) fn stream_apply_consumes(&mut self, si: usize, n: u64) {
        if n == 0 {
            return;
        }
        let s = &mut self.streams[si];
        let words = n * s.chains as u64;
        debug_assert!(s.fifo_words >= words, "consume schedule overran the FIFO");
        s.fifo_words -= words;
        s.credits.release(words as u32);
    }

    /// Catch both PCs of active channel `ci` up to controller cycle `to`
    /// (closed-form counter accrual over a command-inert span).
    pub(crate) fn channel_catch_up(&mut self, ci: usize, to: u64) {
        let (st, ch) = self.active_channels[ci];
        let channel = &mut self.stacks[st].channels[ch];
        channel.pcs[0].catch_up(to);
        channel.pcs[1].catch_up(to);
    }

    /// Conservative next-command bound over both PCs of channel `ci`.
    pub(crate) fn channel_next_wake(&self, ci: usize, now: u64) -> u64 {
        let (st, ch) = self.active_channels[ci];
        let channel = &self.stacks[st].channels[ch];
        channel.pcs[0].next_wake(now).min(channel.pcs[1].next_wake(now))
    }

    /// Catch one PC up to controller cycle `to` (issue-side bookkeeping:
    /// a request accepted at cycle `h` must see `pc.now() == h`).
    pub(crate) fn pc_catch_up(&mut self, stack: usize, local_pc: usize, to: u64) {
        self.stacks[stack].pc(local_pc).catch_up(to);
    }

    /// One issue attempt for group `gi` — exactly the slow path's phase-1
    /// body. The caller must have materialized the group's stream consume
    /// schedules through the core cycles visible at the current controller
    /// cycle and caught the target PC up to it. Returns true on issue.
    pub(crate) fn try_issue_group(&mut self, gi: usize) -> bool {
        let words_per_burst = self.words_per_burst;
        let g = &mut self.pc_groups[gi];
        let n = g.streams.len();
        for k in 0..n {
            let si = g.streams[(g.rr + k) % n];
            let s = &mut self.streams[si];
            if !s.credits.can_acquire(words_per_burst as u32) {
                continue;
            }
            let ctrl = self.stacks[g.stack_idx].pc(g.local_pc);
            if !ctrl.can_accept(self.burst) {
                break; // controller back-pressure: stop for this PC
            }
            let id = self.next_id;
            self.next_id += 1;
            let addr = s.base + s.next_off;
            s.next_off += self.burst as u64 * 32;
            if s.next_off + self.burst as u64 * 32 > s.region {
                s.next_off = 0; // kernel replay (per-line reload)
            }
            s.credits.acquire(words_per_burst as u32);
            ctrl.push(Request { id, dir: Dir::Read, addr, burst: self.burst });
            self.pending.insert(id, (si, words_per_burst));
            g.rr = (g.rr + k + 1) % n;
            return true;
        }
        false
    }

    /// The fast path's channel event at controller cycle `h`: catch both
    /// PCs up, run the real channel tick with priority `h % 2`, and drain
    /// completions / fault events exactly as the slow path does within
    /// the same controller cycle. The caller must have materialized the
    /// consume schedules of every stream on this channel through the core
    /// cycles visible at `h` (FIFO peaks are sampled at refill time).
    ///
    /// `refilled_layers` collects the layer of each refilled stream (for
    /// engine wake-up); `cas_issued[k]` is set when PC `k` completed a
    /// burst this cycle (its queue drained, so issue may resume).
    pub(crate) fn channel_event(
        &mut self,
        ci: usize,
        h: u64,
        mut probe: Option<&mut dyn Probe>,
        refilled_layers: &mut Vec<usize>,
        cas_issued: &mut [bool; 2],
    ) {
        let (st, ch) = self.active_channels[ci];
        let channel = &mut self.stacks[st].channels[ch];
        channel.pcs[0].catch_up(h);
        channel.pcs[1].catch_up(h);
        channel.tick_with_priority((h % 2) as usize);
        for (k, pcc) in channel.pcs.iter_mut().enumerate() {
            for c in pcc.drain_completions() {
                cas_issued[k] = true;
                if let Some((si, words)) = self.pending.remove(&c.id) {
                    let s = &mut self.streams[si];
                    s.fifo_words += words;
                    s.max_words = s.max_words.max(s.fifo_words);
                    self.beats_read += self.burst as u64;
                    refilled_layers.push(s.layer_idx);
                    if let Some(p) = probe.as_deref_mut() {
                        let pc = st as u32 * self.pcs_per_stack + (ch * 2 + k) as u32;
                        p.hbm_burst(pc, c.accept_cycle, c.done_cycle, self.burst);
                    }
                }
            }
            for e in pcc.drain_fault_events() {
                if let Some(p) = probe.as_deref_mut() {
                    let pc = st as u32 * self.pcs_per_stack + (ch * 2 + k) as u32;
                    let kind = if e.replayed { "hbm_replay" } else { "hbm_drop" };
                    p.fault_event(pc, e.cycle, kind, e.id);
                }
            }
        }
    }

    /// Mean HBM read efficiency across active PCs (busy-cycle basis).
    pub fn mean_read_efficiency(&mut self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u32;
        for st in &mut self.stacks {
            for ch in &mut st.channels {
                for pcc in ch.pcs.iter_mut() {
                    if pcc.stats.reads > 0 {
                        sum += pcc.stats.busy_efficiency();
                        n += 1;
                    }
                }
            }
        }
        if n == 0 { 0.0 } else { sum / n as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::config::{CompilerOptions, DeviceConfig};
    use crate::nn::zoo;

    fn plan_r50() -> AcceleratorPlan {
        let d = DeviceConfig::stratix10_nx2100();
        compile(&zoo::resnet50(), &d, &CompilerOptions::default()).unwrap()
    }

    #[test]
    fn streams_built_for_every_hbm_layer() {
        let plan = plan_r50();
        let ws = WeightSubsystem::new(&plan);
        let hbm_layers = plan.hbm_layers().count();
        assert!(hbm_layers > 0);
        assert!(ws.num_streams() >= hbm_layers, "at least one stream per HBM layer");
        for (i, l) in plan.layers.iter().enumerate() {
            if !l.pcs.is_empty() {
                assert!(!ws.by_layer[i].is_empty(), "{} missing streams", l.stats.name);
            }
        }
    }

    #[test]
    fn onchip_layers_always_ready() {
        let plan = plan_r50();
        let ws = WeightSubsystem::new(&plan);
        for (i, l) in plan.layers.iter().enumerate() {
            if l.pcs.is_empty() {
                assert!(ws.layer_ready(i));
            }
        }
    }

    #[test]
    fn prefetch_fills_fifos() {
        let plan = plan_r50();
        let mut ws = WeightSubsystem::new(&plan);
        let (first_hbm, _) = plan
            .layers
            .iter()
            .enumerate()
            .find(|(_, l)| !l.pcs.is_empty())
            .map(|(i, l)| (i, l))
            .unwrap();
        assert!(!ws.layer_ready(first_hbm), "FIFOs start empty");
        for _ in 0..2_000 {
            ws.hbm_tick();
        }
        assert!(ws.layer_ready(first_hbm), "prefetch must fill the FIFO");
        assert!(ws.beats_read > 0);
    }

    #[test]
    fn consume_returns_credits_and_supply_sustains() {
        let plan = plan_r50();
        let mut ws = WeightSubsystem::new(&plan);
        let li = plan
            .layers
            .iter()
            .enumerate()
            .find(|(_, l)| !l.pcs.is_empty())
            .map(|(i, _)| i)
            .unwrap();
        // warm up
        for _ in 0..3_000 {
            ws.hbm_tick();
        }
        // base tick 1200 MHz: core consumes every 4th tick (300 MHz),
        // HBM advances every 3rd tick (400 MHz)
        let mut consumed = 0u64;
        let mut frozen = 0u64;
        for t in 0..120_000u64 {
            if t % 4 == 0 {
                if ws.layer_ready(li) {
                    ws.consume(li);
                    consumed += 1;
                } else {
                    frozen += 1;
                }
            }
            if t % 3 == 0 {
                ws.hbm_tick();
            }
        }
        assert!(consumed > 0);
        let freeze_frac = frozen as f64 / (consumed + frozen) as f64;
        assert!(freeze_frac < 0.35, "freeze fraction {freeze_frac:.3} too high");
    }

    #[test]
    fn faulted_prefetch_conserves_and_still_supplies() {
        let plan = plan_r50();
        let mut ws = WeightSubsystem::new(&plan);
        ws.apply_faults(
            Some(&HbmFaultSpec { start: 0, end: 50_000, prob: 0.05, max_replays: 3 }),
            &[ThrottleWindow { pc: 0, start: 0, end: 20_000, deny: 2, period: 8 }],
            42,
        );
        let li = plan
            .layers
            .iter()
            .enumerate()
            .find(|(_, l)| !l.pcs.is_empty())
            .map(|(i, _)| i)
            .unwrap();
        for _ in 0..50_000 {
            ws.hbm_tick();
        }
        let t = ws.fault_totals();
        assert!(t.injected > 0, "window must fire on a busy subsystem");
        assert_eq!(t.lost(), 0, "conservation: {t:?}");
        assert_eq!(t.injected, t.retried + t.dropped, "{t:?}");
        assert!(t.throttled_cycles > 0, "PC 0 carries weights on r50");
        assert!(ws.layer_ready(li), "bounded replay must not starve the FIFO");

        // Same seed, same workload → identical ledger.
        let mut ws2 = WeightSubsystem::new(&plan);
        ws2.apply_faults(
            Some(&HbmFaultSpec { start: 0, end: 50_000, prob: 0.05, max_replays: 3 }),
            &[ThrottleWindow { pc: 0, start: 0, end: 20_000, deny: 2, period: 8 }],
            42,
        );
        for _ in 0..50_000 {
            ws2.hbm_tick();
        }
        assert_eq!(ws2.fault_totals(), t, "seeded injection must be deterministic");
    }

    #[test]
    fn fifo_never_exceeds_credit_capacity() {
        let plan = plan_r50();
        let mut ws = WeightSubsystem::new(&plan);
        for _ in 0..20_000 {
            ws.hbm_tick();
        }
        for s in &ws.streams {
            assert!(
                s.max_words <= s.credits.max() as u64,
                "stream for layer {} overfilled: {} > {}",
                s.layer_idx,
                s.max_words,
                s.credits.max()
            );
        }
    }
}
