//! Event-driven (skip-ahead) scheduler for the pipeline simulation.
//!
//! The slow path advances one 1200 MHz base tick at a time and touches
//! every engine, prefetch group and HBM channel on every domain cycle.
//! Steady state is overwhelmingly *inert*, though: an engine deep inside
//! a line neither stalls nor completes, an empty HBM channel does nothing
//! until its next refresh, a credit-starved prefetch group cannot issue
//! until a scheduled FIFO consume returns words. This module exploits
//! that by keeping, per component, the earliest cycle at which its state
//! can possibly change, and jumping the clock between those cycles.
//!
//! Exactness contract (see DESIGN.md §14): every *observable* action —
//! an engine's per-cycle `tick`, a group's issue attempt, a channel's
//! command cycle, a probe sample — runs the **same code at the same
//! cycle** as the slow path. Skipped spans are closed over only when the
//! outcome of every skipped cycle is provably inert and its counter
//! effect has a closed form:
//!
//! * a *running* engine mid-line accrues `active` cycles and FIFO
//!   consumes (`stream_apply_consumes`) — the batch never includes the
//!   line-completion cycle, so every gate re-check happens for real;
//! * a *stalled* engine accrues exactly one stall class — each gate
//!   input (producer lines, consumer progress, FIFO refills, external
//!   limits) generates a wake at its visibility cycle, so the earliest
//!   wake bounds the span;
//! * an idle or command-blocked pseudo-channel accrues busy/total
//!   counters via [`PseudoChannel::catch_up`] and wakes at the
//!   conservative [`PseudoChannel::next_wake`] bound (never late, may be
//!   early — early wakes re-evaluate and reschedule, which is harmless).
//!
//! Clock mapping: core cycle `c` executes at base tick `4*(c-1)`, HBM
//! controller cycle `h` at base tick `3*h`. Within one base tick the HBM
//! phase runs before the core phase and the probe boundary after the
//! core phase, exactly like `step_base_tick_probed`.
//!
//! [`PseudoChannel::catch_up`]: crate::hbm::controller::PseudoChannel::catch_up
//! [`PseudoChannel::next_wake`]: crate::hbm::controller::PseudoChannel::next_wake

// Index loops below deliberately re-index through `sim` / `self` inside
// the body (the iterator form would hold a shared borrow across the
// `&mut` calls the body makes), which trips this purely syntactic lint.
#![allow(clippy::needless_range_loop)]

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use crate::obs::Probe;
use crate::sim::engine::EngineState;
use crate::sim::pipeline::{PipelineSim, SimConfig};

/// Same-tick phase order (must match `step_base_tick_probed`).
const ORD_HBM: u8 = 0;
const ORD_CORE: u8 = 1;
const ORD_PROBE: u8 = 2;

/// Scheduler-side view of one engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EngMode {
    /// Must be re-evaluated at `eng_next` (no committed span).
    Pending,
    /// Accrues one stall class until an external wake; `eng_next` holds
    /// the earliest wake (or `u64::MAX`).
    Stalled(EngineState),
    /// Provably active through `until` (exclusive of the line-completion
    /// cycle); re-evaluated at `until + 1`.
    Running { until: u64 },
    /// Finished all images — never evaluated again.
    Done,
}

/// Core-cycle at which a consume executed at core cycle `c` becomes
/// visible to the HBM domain, and vice versa. A consume at core `c`
/// (base tick `4*(c-1)`) is visible to HBM cycle `h` iff `4*(c-1) < 3h`;
/// a refill at HBM `h` (base tick `3h`) is visible to core `c` iff
/// `3h <= 4*(c-1)` (the HBM phase runs first within a tick).
#[inline]
fn hbm_visible_core(h: u64) -> u64 {
    // last core cycle whose consume is visible at HBM cycle h
    (3 * h + 3) / 4
}

#[inline]
fn core_wake_for_hbm(h: u64) -> u64 {
    // first core cycle that sees a refill performed at HBM cycle h
    hbm_visible_core(h) + 1
}

#[inline]
fn hbm_wake_for_core(c: u64) -> u64 {
    // first HBM cycle that sees a consume executed at core cycle c
    (4 * (c - 1)) / 3 + 1
}

/// The event-wheel state for one [`PipelineSim`].
///
/// Owns no simulator state itself — everything observable lives in the
/// `PipelineSim`; this struct holds only scheduling metadata (next-event
/// bounds, committed consume schedules, the event heap). The fleet
/// driver holds one `FastCore` per shard and advances all of them on a
/// shared local clock via [`FastCore::next_tick`] /
/// [`FastCore::process_tick`].
#[derive(Debug)]
pub(crate) struct FastCore {
    images: u64,
    /// Next core cycle each engine must be evaluated at (`u64::MAX` for
    /// stalled engines awaiting a wake and finished engines).
    eng_next: Vec<u64>,
    mode: Vec<EngMode>,
    /// Last core cycle with stats applied, per engine.
    synced: Vec<u64>,
    /// Committed consume schedule per stream: consumes for core cycles
    /// `(applied, until]` have happened logically but are not yet
    /// applied to the FIFO counters.
    sched_applied: Vec<u64>,
    sched_until: Vec<u64>,
    /// Prefetch group feeding each stream (for credit-wake re-arming).
    stream_group: Vec<usize>,
    /// Next HBM cycle each prefetch group attempts an issue at.
    group_next: Vec<u64>,
    /// Next HBM cycle each weight channel must run a command cycle at.
    chan_next: Vec<u64>,
    /// Active-channel index serving each group.
    group_channel: Vec<usize>,
    /// Groups on each channel, by pseudo-channel parity.
    chan_groups: Vec<[Option<usize>; 2]>,
    /// Every stream whose FIFO lives on each channel.
    chan_streams: Vec<Vec<usize>>,
    /// Every stream (for probe / finalize materialization).
    all_streams: Vec<usize>,
    /// Next probe-boundary core cycle (unused when `window == 0`).
    probe_next: u64,
    window: u64,
    /// Event heap over `(base_tick, phase)`; lazy — stale duplicates pop
    /// as no-ops because every due-check consults the `*_next` arrays.
    heap: BinaryHeap<Reverse<(u64, u8)>>,
    /// Scratch buffer for refilled layers (avoids per-event allocation).
    refill_buf: Vec<usize>,
    done_count: usize,
    finished: bool,
    finished_cycle: u64,
}

impl FastCore {
    pub(crate) fn new(sim: &PipelineSim, images: u64, probe_window: u64) -> Self {
        let n = sim.engines.len();
        let ng = sim.weights.num_groups();
        let nc = sim.weights.num_active_channels();
        let mut stream_group = Vec::new();
        let mut group_channel = vec![0usize; ng];
        let mut chan_groups = vec![[None, None]; nc];
        let mut chan_streams: Vec<Vec<usize>> = vec![Vec::new(); nc];
        let mut all_streams = Vec::new();
        for gi in 0..ng {
            let ci = sim.weights.channel_index_for_group(gi);
            let (_, local_pc) = sim.weights.group_target(gi);
            group_channel[gi] = ci;
            chan_groups[ci][local_pc % 2] = Some(gi);
            for &si in sim.weights.group_streams(gi) {
                if stream_group.len() <= si {
                    stream_group.resize(si + 1, usize::MAX);
                }
                stream_group[si] = gi;
                chan_streams[ci].push(si);
                all_streams.push(si);
            }
        }
        let ns = stream_group.len();
        let mut heap = BinaryHeap::new();
        // Every engine evaluates at core cycle 1 (base tick 0); groups
        // attempt their first issue and channels run their first command
        // cycle at HBM cycle 0 (also base tick 0).
        heap.push(Reverse((0, ORD_CORE)));
        if ng > 0 || nc > 0 {
            heap.push(Reverse((0, ORD_HBM)));
        }
        let window = probe_window;
        if window > 0 {
            heap.push(Reverse((4 * (window - 1), ORD_PROBE)));
        }
        Self {
            images,
            eng_next: vec![1; n],
            mode: vec![EngMode::Pending; n],
            synced: vec![0; n],
            sched_applied: vec![0; ns],
            sched_until: vec![0; ns],
            stream_group,
            group_next: vec![0; ng],
            chan_next: vec![0; nc],
            group_channel,
            chan_groups,
            chan_streams,
            all_streams,
            probe_next: window,
            window,
            heap,
            refill_buf: Vec::new(),
            done_count: 0,
            finished: false,
            finished_cycle: 0,
        }
    }

    pub(crate) fn finished(&self) -> bool {
        self.finished
    }

    pub(crate) fn finished_cycle(&self) -> u64 {
        self.finished_cycle
    }

    /// Base tick of the next scheduled event, if any.
    pub(crate) fn next_tick(&self) -> Option<u64> {
        self.heap.peek().map(|Reverse((t, _))| *t)
    }

    #[inline]
    fn push_core(&mut self, cycle: u64) {
        self.heap.push(Reverse((4 * (cycle - 1), ORD_CORE)));
    }

    #[inline]
    fn push_hbm(&mut self, h: u64) {
        self.heap.push(Reverse((3 * h, ORD_HBM)));
    }

    /// Process every event scheduled at base tick `tau` (HBM phase, then
    /// core phase, then probe boundary — the slow path's in-tick order).
    pub(crate) fn process_tick(
        &mut self,
        sim: &mut PipelineSim,
        tau: u64,
        mut probe: Option<&mut dyn Probe>,
    ) {
        while let Some(&Reverse((t, ord))) = self.heap.peek() {
            if t != tau {
                debug_assert!(t > tau, "event at {t} missed (now {tau})");
                break;
            }
            self.heap.pop();
            match ord {
                ORD_HBM => self.hbm_phase(sim, tau / 3, probe.as_deref_mut()),
                ORD_CORE => self.core_phase(sim, tau / 4 + 1),
                _ => self.probe_phase(sim, tau / 4 + 1, probe.as_deref_mut()),
            }
        }
    }

    /// Apply the committed consume schedule of stream `si` through core
    /// cycle `cyc` (inclusive) in closed form.
    fn apply_stream_to(&mut self, sim: &mut PipelineSim, si: usize, cyc: u64) {
        let target = cyc.min(self.sched_until[si]);
        let applied = self.sched_applied[si];
        if target > applied {
            sim.weights.stream_apply_consumes(si, target - applied);
            self.sched_applied[si] = target;
        }
    }

    /// Close the stats gap of engine `i` through core cycle `to`
    /// (inclusive): a committed span accrues its single known outcome.
    pub(crate) fn materialize_engine_stats(&mut self, sim: &mut PipelineSim, i: usize, to: u64) {
        let from = self.synced[i];
        if to <= from {
            return;
        }
        let span = to - from;
        match self.mode[i] {
            EngMode::Running { until } => {
                debug_assert!(to <= until, "running span overran its commitment");
                let e = &mut sim.engines[i];
                e.stats.active += span;
                e.line_cycle += span;
                debug_assert!(e.line_cycle < e.cycles_per_line, "batch crossed a line boundary");
            }
            EngMode::Stalled(class) => {
                let e = &mut sim.engines[i];
                match class {
                    EngineState::InputStarved => e.stats.input_starved += span,
                    EngineState::OutputBlocked => e.stats.output_blocked += span,
                    EngineState::WeightFrozen => e.stats.weight_frozen += span,
                    _ => unreachable!("not a stall class"),
                }
            }
            EngMode::Done => {}
            EngMode::Pending => {
                debug_assert!(false, "pending engine left a stats gap of {span}");
            }
        }
        self.synced[i] = to;
    }

    /// HBM controller cycle `h`: prefetch issue for every due group
    /// (slow-path phase 1), then a real command cycle with completion
    /// and fault drains for every due channel (slow-path phase 2).
    fn hbm_phase(&mut self, sim: &mut PipelineSim, h: u64, mut probe: Option<&mut dyn Probe>) {
        let vis = hbm_visible_core(h);
        for gi in 0..self.group_next.len() {
            if self.group_next[gi] > h {
                continue;
            }
            // Credits must reflect every consume visible at h before the
            // acquire check, and the PC must sit at cycle h to accept.
            let n_streams = sim.weights.group_streams(gi).len();
            let mut any_acquirable = false;
            for k in 0..n_streams {
                let si = sim.weights.group_streams(gi)[k];
                self.apply_stream_to(sim, si, vis);
                any_acquirable |= sim.weights.stream_acquire_deficit(si) == 0;
            }
            let (st, pc) = sim.weights.group_target(gi);
            sim.weights.pc_catch_up(st, pc, h);
            if sim.weights.try_issue_group(gi) {
                // Data now queues on the channel; it must run command
                // cycles from h on, and the group may issue again at h+1.
                let ci = self.group_channel[gi];
                self.chan_next[ci] = self.chan_next[ci].min(h);
                self.group_next[gi] = h + 1;
                self.push_hbm(h + 1);
            } else if any_acquirable {
                // Controller back-pressure: capacity frees exactly when a
                // burst completes, which the channel event reports.
                self.group_next[gi] = u64::MAX;
            } else {
                // Credit-starved: the earliest committed consume that
                // returns a full burst of credit words bounds the wake.
                let mut wake = u64::MAX;
                for k in 0..n_streams {
                    let si = sim.weights.group_streams(gi)[k];
                    let deficit = sim.weights.stream_acquire_deficit(si);
                    let chains = sim.weights.stream_chains(si) as u64;
                    let cstar = self.sched_applied[si] + deficit.div_ceil(chains);
                    if cstar <= self.sched_until[si] {
                        wake = wake.min(hbm_wake_for_core(cstar));
                    }
                }
                self.group_next[gi] = wake;
                if wake != u64::MAX {
                    self.push_hbm(wake);
                }
                // wake == MAX: re-armed when a consumer engine commits a
                // new batch (see eval_engine).
            }
        }
        for ci in 0..self.chan_next.len() {
            if self.chan_next[ci] > h {
                continue;
            }
            // FIFO levels must be current before refills so occupancy
            // peaks are sampled exactly as the slow path would.
            for k in 0..self.chan_streams[ci].len() {
                let si = self.chan_streams[ci][k];
                self.apply_stream_to(sim, si, vis);
            }
            let mut refills = std::mem::take(&mut self.refill_buf);
            refills.clear();
            let mut cas_issued = [false; 2];
            sim.weights.channel_event(ci, h, probe.as_deref_mut(), &mut refills, &mut cas_issued);
            for &layer in &refills {
                if self.mode[layer] == EngMode::Stalled(EngineState::WeightFrozen) {
                    let w = core_wake_for_hbm(h);
                    if w < self.eng_next[layer] {
                        self.eng_next[layer] = w;
                        self.push_core(w);
                    }
                }
            }
            self.refill_buf = refills;
            for (k, &fired) in cas_issued.iter().enumerate() {
                if !fired {
                    continue;
                }
                if let Some(gi) = self.chan_groups[ci][k] {
                    if self.group_next[gi] > h + 1 {
                        self.group_next[gi] = h + 1;
                        self.push_hbm(h + 1);
                    }
                }
            }
            let nw = sim.weights.channel_next_wake(ci, h + 1);
            self.chan_next[ci] = nw;
            self.push_hbm(nw);
        }
    }

    /// Core cycle `c`: evaluate every due engine in index order (the
    /// slow path's `step_core` loop order, which line-event wakes rely
    /// on: consumers sit at higher indices and are swept later in the
    /// same cycle; producers see relaxed back-pressure at `c + 1`).
    fn core_phase(&mut self, sim: &mut PipelineSim, c: u64) {
        sim.core_cycles = c;
        for i in 0..self.eng_next.len() {
            if self.eng_next[i] <= c {
                self.eval_engine(sim, i, c);
            }
        }
        if self.done_count == self.eng_next.len() && !self.finished {
            self.finished = true;
            self.finished_cycle = c;
        }
    }

    /// Run the real per-cycle step for engine `i` at core cycle `c`,
    /// then commit the longest provably-inert span that follows.
    fn eval_engine(&mut self, sim: &mut PipelineSim, i: usize, c: u64) {
        let images = self.images;
        // 1. catch this engine's weight streams up to the cycle before
        //    the real tick (layer_ready must see exact FIFO levels)
        if sim.engines[i].hbm_fed {
            for k in 0..sim.weights.layer_streams(i).len() {
                let si = sim.weights.layer_streams(i)[k];
                self.apply_stream_to(sim, si, c - 1);
            }
        }
        // 2. close the committed stats span
        self.materialize_engine_stats(sim, i, c - 1);
        self.synced[i] = c; // the real tick below accounts cycle c
        if sim.engines[i].done(images) {
            if self.mode[i] != EngMode::Done {
                self.mode[i] = EngMode::Done;
                self.done_count += 1;
            }
            self.eng_next[i] = u64::MAX;
            return;
        }
        // 3. the real tick — gate computation identical to step_core
        let sink = sim.engines.len() - 1;
        let input_ok = if i == 0 {
            sim.engines[0].lines_produced < sim.input_limit
        } else {
            sim.producers_meta[i]
                .iter()
                .zip(sim.need_cache[i].iter())
                .all(|(&(p, _), &need)| sim.engines[p].lines_produced >= need)
        };
        let lines = sim.engines[i].lines_produced;
        let mut output_ok = sim.consumers_meta[i]
            .iter()
            .zip(sim.limit_cache[i].iter())
            .all(|(&(cj, _), &limit)| lines < limit || sim.engines[cj].done(images));
        if i == sink {
            output_ok = output_ok && lines < sim.sink_limit;
        }
        let wa = if !sim.engines[i].hbm_fed || sim.weights.layer_ready(i) {
            u64::MAX
        } else {
            0
        };
        let st = sim.engines[i].tick(c, images, input_ok, output_ok, wa);
        // 4. commit the follow-on span and schedule the next evaluation
        match st {
            EngineState::Active => {
                if sim.engines[i].hbm_fed {
                    sim.weights.consume(i);
                }
                let line_event = sim.engines[i].lines_produced != lines;
                if line_event {
                    sim.refresh_caches(i);
                    for k in 0..sim.consumers_meta[i].len() {
                        let cj = sim.consumers_meta[i][k].0;
                        debug_assert!(cj > i, "consumers sit later in the sweep");
                        self.wake_stalled(cj, c, false);
                    }
                    for k in 0..sim.producers_meta[i].len() {
                        let p = sim.producers_meta[i][k].0;
                        self.wake_stalled(p, c + 1, true);
                    }
                }
                if sim.engines[i].done(images) {
                    self.mode[i] = EngMode::Done;
                    self.done_count += 1;
                    self.eng_next[i] = u64::MAX;
                    // producers may now run unbounded past this engine
                    for k in 0..sim.producers_meta[i].len() {
                        let p = sim.producers_meta[i][k].0;
                        self.wake_stalled(p, c + 1, true);
                    }
                    return;
                }
                if line_event {
                    // gates change at line boundaries: re-check for real
                    self.mode[i] = EngMode::Pending;
                    self.eng_next[i] = c + 1;
                    self.push_core(c + 1);
                    return;
                }
                // mid-line: active through the cycle before completion,
                // bounded by the FIFO words already on chip
                let e = &sim.engines[i];
                let mut batch = e.cycles_per_line - e.line_cycle - 1;
                if sim.engines[i].hbm_fed {
                    for k in 0..sim.weights.layer_streams(i).len() {
                        let si = sim.weights.layer_streams(i)[k];
                        batch = batch.min(sim.weights.stream_budget_cycles(si));
                    }
                }
                if batch == 0 {
                    self.mode[i] = EngMode::Pending;
                    self.eng_next[i] = c + 1;
                    self.push_core(c + 1);
                    return;
                }
                let until = c + batch;
                self.mode[i] = EngMode::Running { until };
                self.eng_next[i] = until + 1;
                self.push_core(until + 1);
                if sim.engines[i].hbm_fed {
                    for k in 0..sim.weights.layer_streams(i).len() {
                        let si = sim.weights.layer_streams(i)[k];
                        debug_assert_eq!(
                            self.sched_applied[si], self.sched_until[si],
                            "new schedule over an unapplied one"
                        );
                        self.sched_applied[si] = c;
                        self.sched_until[si] = until;
                        // the committed consumes may refund the credits a
                        // starved prefetch group is waiting for
                        let gi = self.stream_group[si];
                        if self.group_next[gi] == u64::MAX {
                            let hw = hbm_wake_for_core(c + 1);
                            self.group_next[gi] = hw;
                            self.push_hbm(hw);
                        }
                    }
                }
            }
            EngineState::InputStarved | EngineState::OutputBlocked | EngineState::WeightFrozen => {
                self.mode[i] = EngMode::Stalled(st);
                self.eng_next[i] = u64::MAX;
            }
            EngineState::Done => unreachable!("done handled before the tick"),
        }
    }

    /// Wake a stalled engine at core cycle `at` (spurious wakes are
    /// harmless: evaluation is exact at any cycle and re-stalls cleanly).
    fn wake_stalled(&mut self, i: usize, at: u64, push: bool) {
        if !matches!(self.mode[i], EngMode::Stalled(_)) {
            return;
        }
        if at < self.eng_next[i] {
            self.eng_next[i] = at;
            if push {
                self.push_core(at);
            }
        }
    }

    /// External head-limit raise (fleet exchange), visible at `at`.
    pub(crate) fn note_input_limit_raised(&mut self, at: u64) {
        if self.mode[0] == EngMode::Stalled(EngineState::InputStarved) {
            self.wake_stalled(0, at, true);
        }
    }

    /// External sink-limit change (fleet exchange), visible at `at`. A
    /// decrease can invalidate a committed active span (the slow path
    /// would stall the sink mid-line once the bound bites), so the batch
    /// is truncated to end just before visibility; an increase can only
    /// unblock, so a stalled sink is re-evaluated.
    pub(crate) fn note_sink_limit_changed(
        &mut self,
        sim: &mut PipelineSim,
        at: u64,
        decreased: bool,
    ) {
        let sink = self.mode.len() - 1;
        match self.mode[sink] {
            EngMode::Stalled(_) => self.wake_stalled(sink, at, true),
            EngMode::Running { until } if decreased && until >= at => {
                self.materialize_engine_stats(sim, sink, at - 1);
                self.mode[sink] = EngMode::Running { until: at - 1 };
                self.eng_next[sink] = at;
                self.push_core(at);
                if sim.engines[sink].hbm_fed {
                    for k in 0..sim.weights.layer_streams(sink).len() {
                        let si = sim.weights.layer_streams(sink)[k];
                        debug_assert!(self.sched_applied[si] < at);
                        self.sched_until[si] = self.sched_until[si].min(at - 1);
                    }
                }
            }
            _ => {}
        }
    }

    /// Probe boundary at core cycle `b`: bring every observable counter
    /// current (engines, FIFOs, PC stats) and publish one cumulative
    /// sample — byte-identical to the slow path's in-tick sample.
    fn probe_phase(&mut self, sim: &mut PipelineSim, b: u64, probe: Option<&mut dyn Probe>) {
        if self.window == 0 || b != self.probe_next {
            return; // stale duplicate entry
        }
        if let Some(p) = probe {
            for i in 0..self.eng_next.len() {
                self.materialize_engine_stats(sim, i, b);
            }
            for k in 0..self.all_streams.len() {
                let si = self.all_streams[k];
                self.apply_stream_to(sim, si, b);
            }
            let hh = hbm_wake_for_core(b);
            for ci in 0..self.chan_next.len() {
                sim.weights.channel_catch_up(ci, hh);
            }
            sim.core_cycles = b;
            sim.sample_probe(p);
        }
        self.probe_next += self.window;
        self.heap.push(Reverse((4 * (self.probe_next - 1), ORD_PROBE)));
    }

    /// Land the simulator on the exact slow-path end state: all stream
    /// schedules applied, every PC caught up to the last executed HBM
    /// cycle, and the base-tick/core-cycle clocks set as if the run had
    /// stepped tick by tick and broken out after core cycle `c_done`.
    pub(crate) fn finalize(&mut self, sim: &mut PipelineSim, c_done: u64) {
        for i in 0..self.eng_next.len() {
            self.materialize_engine_stats(sim, i, c_done);
        }
        for k in 0..self.all_streams.len() {
            let si = self.all_streams[k];
            self.apply_stream_to(sim, si, c_done);
        }
        let hh = hbm_wake_for_core(c_done);
        for ci in 0..self.chan_next.len() {
            sim.weights.channel_catch_up(ci, hh);
        }
        sim.core_cycles = c_done;
        sim.t = 4 * (c_done - 1) + 1;
    }

    /// Bring counters current at a wedge bail so the embedded stall
    /// breakdown matches what the slow path would report at tick `max`.
    pub(crate) fn settle_for_wedge(&mut self, sim: &mut PipelineSim, max_base_ticks: u64) {
        let c_bail = (max_base_ticks.saturating_sub(1)) / 4 + 1;
        for i in 0..self.eng_next.len() {
            let to = match self.mode[i] {
                EngMode::Running { until } => c_bail.min(until),
                _ => c_bail,
            };
            self.materialize_engine_stats(sim, i, to);
        }
        for k in 0..self.all_streams.len() {
            let si = self.all_streams[k];
            self.apply_stream_to(sim, si, c_bail);
        }
        sim.core_cycles = c_bail;
        sim.t = max_base_ticks;
    }
}

/// Event-driven replacement for the slow path's run loop. Returns the
/// core cycle at which the warmup-image threshold was crossed, exactly
/// as `run_inner`'s per-tick check would have recorded it.
pub(crate) fn run_fast(
    sim: &mut PipelineSim,
    cfg: &SimConfig,
    images: u64,
    mut probe: Option<&mut dyn Probe>,
) -> Result<Option<u64>> {
    let window = probe.as_deref().map_or(0, |p| p.window().max(1));
    let mut fc = FastCore::new(sim, images, window);
    let mut warmup_done_at: Option<u64> = None;
    loop {
        let tau = fc.next_tick().unwrap_or(u64::MAX);
        if tau >= cfg.max_base_ticks {
            fc.settle_for_wedge(sim, cfg.max_base_ticks);
            bail!(
                "simulation exceeded max_base_ticks — pipeline wedged?\n{}",
                sim.wedge_breakdown()
            );
        }
        fc.process_tick(sim, tau, probe.as_deref_mut());
        // sink image completions only happen inside core phases, where
        // core_cycles is kept current — same value the slow path records
        if warmup_done_at.is_none() && sim.sink_images_done() >= cfg.warmup_images {
            warmup_done_at = Some(sim.core_cycles);
        }
        if fc.finished() {
            break;
        }
    }
    let c_done = fc.finished_cycle();
    fc.finalize(sim, c_done);
    Ok(warmup_done_at)
}
