//! The whole layer-pipelined accelerator, cycle by cycle.
//!
//! [`PipelineSim`] instantiates one [`LayerEngineSim`] per IR layer
//! (weightless layers — pools, adds, global pools — become width-parallel
//! pass-through engines at one cycle per line), wires inter-layer line
//! dependencies and back-pressure from the IR DAG, attaches the
//! [`WeightSubsystem`] for HBM-fed layers, and advances core (300 MHz)
//! and HBM (400 MHz) domains from a common 1200 MHz base tick.

use anyhow::{bail, Result};

use crate::compiler::AcceleratorPlan;
use crate::nn::{Network, OpKind};
use crate::obs::Probe;
use crate::sim::engine::{EngineState, LayerEngineSim};
use crate::sim::weights::WeightSubsystem;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Images to push through the pipeline.
    pub images: u64,
    /// Leading images excluded from the throughput measurement.
    pub warmup_images: u64,
    /// Safety valve on base ticks.
    pub max_base_ticks: u64,
    /// Step every base tick through every component (the reference
    /// interpreter) instead of the event-driven scheduler in
    /// [`crate::sim::events`]. Both paths produce identical reports,
    /// artifacts and probe streams — this switch exists for
    /// cross-checking and debugging. Defaults to `false`, or to the
    /// `H2PIPE_SLOW_SIM=1` environment variable.
    pub exact_stepping: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            images: 6,
            warmup_images: 2,
            max_base_ticks: 40_000_000_000,
            exact_stepping: slow_sim_from_env(),
        }
    }
}

/// `H2PIPE_SLOW_SIM=1` forces the exact-stepping reference path
/// everywhere a `SimConfig`/`FleetConfig` is built from defaults — the
/// CI equivalence job runs every suite once per value.
pub(crate) fn slow_sim_from_env() -> bool {
    std::env::var("H2PIPE_SLOW_SIM").map_or(false, |v| v == "1")
}

/// One engine's end-of-run stall accounting, by name.
///
/// Replaces the positional `(String, u64, u64, u64, u64)` tuple the
/// report used to carry — the JSON form was already keyed, so the
/// serialized artifact/report schema is unchanged.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineStat {
    pub name: String,
    pub active: u64,
    pub input_starved: u64,
    pub output_blocked: u64,
    pub weight_frozen: u64,
}

/// Simulation results.
///
/// [`SimReport::to_json`] is the machine-scrapable form embedded in the
/// session layer's unified [`crate::session::RunReport`].
#[derive(Debug, Clone)]
pub struct SimReport {
    pub network: String,
    /// Measured steady-state throughput (images/s).
    pub throughput: f64,
    /// First-image latency (s).
    pub latency: f64,
    /// Fraction of bottleneck-engine cycles lost to the weight freeze.
    pub freeze_fraction: f64,
    /// Name of the engine with the most active cycles.
    pub bottleneck: String,
    /// Whether the bottleneck engine streams weights from HBM.
    pub bottleneck_on_hbm: bool,
    /// Mean busy-cycle HBM read efficiency observed.
    pub hbm_efficiency: f64,
    /// Total core cycles simulated.
    pub core_cycles: u64,
    /// Per-engine stall accounting.
    pub engine_stats: Vec<EngineStat>,
    /// Fault-injection ledger — `Some` only when a fault plan was armed
    /// via [`PipelineSim::apply_faults`], so healthy-run reports stay
    /// byte-identical to pre-fault builds.
    pub faults: Option<crate::faults::FaultTotals>,
}

impl SimReport {
    /// Machine-scrapable form (embedded in session `RunReport`s and the
    /// `h2pipe simulate` JSON output).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let mut engines = Json::Arr(Vec::new());
        for s in &self.engine_stats {
            let mut e = Json::obj();
            e.set("name", s.name.as_str())
                .set("active", s.active)
                .set("input_starved", s.input_starved)
                .set("output_blocked", s.output_blocked)
                .set("weight_frozen", s.weight_frozen);
            engines.push(e);
        }
        let mut o = Json::obj();
        o.set("network", self.network.as_str())
            .set("throughput", self.throughput)
            .set("latency_s", self.latency)
            .set("freeze_fraction", self.freeze_fraction)
            .set("bottleneck", self.bottleneck.as_str())
            .set("bottleneck_on_hbm", self.bottleneck_on_hbm)
            .set("hbm_efficiency", self.hbm_efficiency)
            .set("core_cycles", self.core_cycles)
            .set("engines", engines);
        if let Some(f) = &self.faults {
            o.set("faults", f.to_json());
        }
        o
    }
}

/// One full-accelerator simulation instance.
///
/// Hot-loop layout note (§Perf): producer/consumer adjacency is stored as
/// flat per-engine vectors carrying the values the inner loop needs
/// (producer out_h, edge capacity), so the per-cycle dependency checks are
/// pure indexed reads — no hash lookups on the hot path.
#[derive(Debug)]
pub struct PipelineSim {
    plan: AcceleratorPlan,
    /// Crate visibility on the stepping state below: the event-driven
    /// scheduler ([`crate::sim::events`]) runs the same per-cycle code
    /// against these fields, just at sparse cycles.
    pub(crate) engines: Vec<LayerEngineSim>,
    /// producers_meta[i] = (producer idx, producer out_h).
    pub(crate) producers_meta: Vec<Vec<(usize, u32)>>,
    /// consumers_meta[i] = (consumer idx, edge capacity in producer lines).
    pub(crate) consumers_meta: Vec<Vec<(usize, u64)>>,
    /// §Perf caches: dependency thresholds only change when an engine
    /// crosses a line boundary, so they are recomputed on line events
    /// instead of every cycle.
    /// need_cache[i][k] = cumulative producer-k lines engine i waits for.
    pub(crate) need_cache: Vec<Vec<u64>>,
    /// limit_cache[i][j] = line bound imposed on producer i by consumer j
    /// (consumer's oldest needed line + edge capacity).
    pub(crate) limit_cache: Vec<Vec<u64>>,
    pub(crate) weights: WeightSubsystem,
    /// Base-tick (1200 MHz) counter the clock domains derive from.
    pub(crate) t: u64,
    /// Core cycles elapsed (one per 4 base ticks).
    pub(crate) core_cycles: u64,
    /// Cumulative line budget granted to the head (Input) engine by an
    /// external feeder — the lines that have arrived over an inter-device
    /// link. `u64::MAX` (default) models a free-running source.
    pub(crate) input_limit: u64,
    /// Cumulative line budget granted to the sink engine by a downstream
    /// consumer — the credit bound of an inter-device link's receive
    /// FIFO. `u64::MAX` (default) models an always-ready consumer.
    pub(crate) sink_limit: u64,
    /// Set by [`Self::apply_faults`]; gates the report's `faults` block.
    faults_armed: bool,
}

impl PipelineSim {
    /// Build a simulator from a compiled plan and its source network.
    pub fn new(net: &Network, plan: &AcceleratorPlan) -> Result<Self> {
        anyhow::ensure!(net.len() == plan.layers.len(), "plan does not match network");
        let mut engines = Vec::with_capacity(net.len());
        for (i, l) in net.layers().iter().enumerate() {
            let (stride, pad, full) = match &l.op {
                OpKind::Conv { stride, pad, .. } => (*stride, *pad, false),
                OpKind::MaxPool { stride, pad, .. } => (*stride, *pad, false),
                OpKind::GlobalAvgPool | OpKind::Fc { .. } | OpKind::SqueezeExcite { .. } => {
                    (1, 0, true)
                }
                OpKind::Input { .. } | OpKind::Add => (1, 0, false),
            };
            let mut e = LayerEngineSim::from_plan(i, &plan.layers[i], stride, pad, full);
            // weightless layers: width-parallel pass-through, 1 cycle/line
            if !plan.layers[i].stats.has_weights {
                e.cycles_per_line = 1;
                e.out_h = l.out.h.max(1);
                e.kh = match &l.op {
                    OpKind::MaxPool { k, .. } => *k,
                    _ => 1,
                };
            }
            engines.push(e);
        }
        // Edge capacities: sliding-window consumers hold kh+3 producer
        // lines; full-input consumers and residual adds hold the whole
        // tensor (+2 lines of slack for the next image's head).
        let edge_cap = |l: &crate::nn::Layer, p: usize| -> u64 {
            match &l.op {
                OpKind::Add | OpKind::Fc { .. } | OpKind::GlobalAvgPool
                | OpKind::SqueezeExcite { .. } => net.layer(p).out.h as u64 + 2,
                OpKind::Conv { kh, .. } => *kh as u64 + 3,
                OpKind::MaxPool { k, .. } => *k as u64 + 3,
                OpKind::Input { .. } => unreachable!("input has no producers"),
            }
        };
        let mut producers_meta: Vec<Vec<(usize, u32)>> = vec![Vec::new(); net.len()];
        let mut consumers_meta: Vec<Vec<(usize, u64)>> = vec![Vec::new(); net.len()];
        for l in net.layers() {
            for &p in &l.inputs {
                producers_meta[l.id].push((p, engines[p].out_h));
                consumers_meta[p].push((l.id, edge_cap(l, p)));
            }
        }
        let mut sim = Self {
            plan: plan.clone(),
            need_cache: producers_meta.iter().map(|v| vec![0; v.len()]).collect(),
            limit_cache: consumers_meta.iter().map(|v| vec![0; v.len()]).collect(),
            engines,
            producers_meta,
            consumers_meta,
            weights: WeightSubsystem::new(plan),
            t: 0,
            core_cycles: 0,
            input_limit: u64::MAX,
            sink_limit: u64::MAX,
            faults_armed: false,
        };
        for i in 0..sim.engines.len() {
            sim.refresh_caches(i);
        }
        Ok(sim)
    }

    /// Recompute the dependency thresholds that depend on engine `i`'s
    /// position: what it waits for (need_cache[i]) and the back-pressure
    /// bound it imposes on each of its producers (limit_cache[p][..]).
    pub(crate) fn refresh_caches(&mut self, i: usize) {
        for (k, &(p, p_out_h)) in self.producers_meta[i].iter().enumerate() {
            self.need_cache[i][k] = self.engines[i].cum_input_needed(p_out_h);
            let oldest = self.engines[i].oldest_input_needed(p_out_h);
            // locate edge (p -> i) in p's consumer list
            for (j, &(c, cap)) in self.consumers_meta[p].iter().enumerate() {
                if c == i {
                    self.limit_cache[p][j] = oldest + cap;
                }
            }
        }
    }

    /// Grant the head (Input) engine a cumulative line budget: the lines
    /// delivered so far over an inter-device link. The head engine stalls
    /// input-starved once it has forwarded every granted line.
    pub fn set_input_limit(&mut self, lines: u64) {
        self.input_limit = lines;
    }

    /// Bound the sink engine's cumulative output lines: the credit bound
    /// imposed by a downstream device's receive FIFO. At the bound the
    /// sink blocks, back-pressuring the whole shard (no data is dropped).
    pub fn set_sink_limit(&mut self, lines: u64) {
        self.sink_limit = lines;
    }

    /// Lines the head (Input) engine has forwarded — what an upstream
    /// link may retire (credit return).
    pub fn head_lines_consumed(&self) -> u64 {
        self.engines[0].lines_produced
    }

    /// Lines the sink engine has produced — what a downstream link has
    /// been offered.
    pub fn sink_lines_produced(&self) -> u64 {
        self.engines[self.engines.len() - 1].lines_produced
    }

    /// Images fully emitted by the sink engine.
    pub fn sink_images_done(&self) -> u64 {
        self.engines[self.engines.len() - 1].image
    }

    /// Core cycles the sink engine spent output-blocked (for a sharded
    /// sink, that is exactly the inter-device credit stall).
    pub fn sink_output_blocked(&self) -> u64 {
        self.engines[self.engines.len() - 1].stats.output_blocked
    }

    /// Core-cycle timestamp of the first completed image, if any.
    pub fn first_image_done_cycle(&self) -> Option<u64> {
        self.engines[self.engines.len() - 1].image_done_cycles.first().copied()
    }

    /// (name, active cycles) of the busiest weight engine — the shard's
    /// bottleneck candidate.
    pub fn busiest_engine(&self) -> (String, u64) {
        self.engines
            .iter()
            .enumerate()
            .filter(|(i, _)| self.plan.layers[*i].stats.has_weights)
            .map(|(i, e)| (self.plan.layers[i].stats.name.clone(), e.stats.active))
            .max_by_key(|&(_, a)| a)
            .unwrap_or_else(|| ("<none>".to_string(), 0))
    }

    /// Base ticks (1200 MHz) elapsed.
    pub fn base_ticks(&self) -> u64 {
        self.t
    }

    /// Core cycles (300 MHz) elapsed.
    pub fn core_cycles(&self) -> u64 {
        self.core_cycles
    }

    /// True once every engine has finished `images`.
    pub fn all_done(&self, images: u64) -> bool {
        self.engines.iter().all(|e| e.done(images))
    }

    /// Advance one 1200 MHz base tick: the HBM domain (400 MHz) fires
    /// every 3rd tick, the core domain (300 MHz) every 4th. This is the
    /// composition point for multi-device simulation — a fleet steps all
    /// of its shards' sims in lockstep and exchanges line/credit state
    /// between ticks.
    pub fn step_base_tick(&mut self, images: u64) {
        self.step_base_tick_probed(images, None);
    }

    /// [`Self::step_base_tick`] with an optional observability probe.
    ///
    /// With `None` this is the exact plain tick (the `Option` check is the
    /// only added work, which the disabled-overhead bench bounds). With a
    /// probe, the HBM domain reports burst completions as they drain and
    /// the core domain publishes a cumulative sample of every engine / PC /
    /// FIFO every `probe.window()` core cycles.
    pub fn step_base_tick_probed(&mut self, images: u64, mut probe: Option<&mut dyn Probe>) {
        if self.t % 3 == 0 {
            self.weights.hbm_tick_probed(probe.as_deref_mut());
        }
        if self.t % 4 == 0 {
            self.core_cycles += 1;
            self.step_core(images);
            if let Some(p) = probe {
                if self.core_cycles % p.window().max(1) == 0 {
                    self.sample_probe(p);
                }
            }
        }
        self.t += 1;
    }

    /// Publish one cumulative sample of every observable counter to `p`.
    /// Samples are cumulative; the recorder turns consecutive samples into
    /// window deltas, so window sums equal end-of-run aggregates exactly.
    pub fn sample_probe(&mut self, p: &mut dyn Probe) {
        let now = self.core_cycles;
        for (i, e) in self.engines.iter().enumerate() {
            p.engine_sample(now, i, &self.plan.layers[e.layer_idx].stats.name, &e.stats);
        }
        for i in 0..self.engines.len() {
            if self.weights.layer_has_streams(i) {
                p.fifo_sample(
                    now,
                    i,
                    &self.plan.layers[i].stats.name,
                    self.weights.fifo_words(i),
                    self.weights.fifo_capacity(i),
                    self.weights.fifo_peak(i),
                );
            }
        }
        self.weights.for_each_pc_stats(|pc, stats| p.pc_sample(now, pc, stats));
    }

    /// The attached weight subsystem (read-only; for observability tests).
    pub fn weight_subsystem(&self) -> &WeightSubsystem {
        &self.weights
    }

    /// Arm a fault plan's HBM sections (read errors + throttle windows)
    /// on this sim's weight subsystem. The resulting [`SimReport`] then
    /// carries the conservation ledger under `faults`.
    pub fn apply_faults(&mut self, fp: &crate::faults::FaultPlan) {
        self.weights.apply_faults(fp.hbm.as_ref(), &fp.throttle, fp.seed);
        self.faults_armed = true;
    }

    /// The current fault ledger (all-zero when nothing was armed).
    pub fn fault_totals(&self) -> crate::faults::FaultTotals {
        self.weights.fault_totals()
    }

    /// One core-domain cycle across all engines.
    fn step_core(&mut self, images: u64) {
        let n = self.engines.len();
        let sink = n - 1;
        for i in 0..n {
            if self.engines[i].done(images) {
                continue;
            }
            // input dependency (cached thresholds); the head engine is
            // additionally gated by the external line budget
            let input_ok = if i == 0 {
                self.engines[0].lines_produced < self.input_limit
            } else {
                self.producers_meta[i]
                    .iter()
                    .zip(self.need_cache[i].iter())
                    .all(|(&(p, _), &need)| self.engines[p].lines_produced >= need)
            };
            // output back-pressure (cached bounds); the sink engine is
            // additionally gated by the downstream credit bound
            let lines = self.engines[i].lines_produced;
            let mut output_ok = self.consumers_meta[i]
                .iter()
                .zip(self.limit_cache[i].iter())
                .all(|(&(c, _), &limit)| lines < limit || self.engines[c].done(images));
            if i == sink {
                output_ok = output_ok && lines < self.sink_limit;
            }
            // weight readiness: only HBM-fed engines consult the
            // distribution network
            let wa = if !self.engines[i].hbm_fed || self.weights.layer_ready(i) {
                u64::MAX
            } else {
                0
            };
            let before_lines = self.engines[i].lines_produced;
            let st = self.engines[i].tick(self.core_cycles, images, input_ok, output_ok, wa);
            if st == EngineState::Active {
                if self.engines[i].hbm_fed {
                    self.weights.consume(i);
                }
                if self.engines[i].lines_produced != before_lines {
                    self.refresh_caches(i);
                }
            }
        }
    }

    /// Run the simulation.
    pub fn run(&mut self, cfg: &SimConfig) -> Result<SimReport> {
        self.run_inner(cfg, None)
    }

    /// [`Self::run`] with a flight-recorder probe attached.
    ///
    /// A trailing flush sample is published after the loop so the final
    /// (partial) window is recorded and window sums stay conservative.
    pub fn run_probed(&mut self, cfg: &SimConfig, probe: &mut dyn Probe) -> Result<SimReport> {
        self.run_inner(cfg, Some(probe))
    }

    /// Stall diagnosis embedded in the `max_base_ticks` bail: per-class
    /// totals plus the engines deepest into a stall, with their image /
    /// line position — enough to see *which* dependency wedged without
    /// re-running under a probe.
    pub(crate) fn wedge_breakdown(&self) -> String {
        use std::fmt::Write as _;
        let (mut active, mut starved, mut blocked, mut frozen) = (0u64, 0u64, 0u64, 0u64);
        for e in &self.engines {
            active += e.stats.active;
            starved += e.stats.input_starved;
            blocked += e.stats.output_blocked;
            frozen += e.stats.weight_frozen;
        }
        let mut s = String::new();
        let _ = writeln!(
            s,
            "stall breakdown at core cycle {} (base tick {}):",
            self.core_cycles, self.t
        );
        let _ = writeln!(
            s,
            "  totals: active={active} input_starved={starved} output_blocked={blocked} \
             weight_frozen={frozen}"
        );
        let mut worst: Vec<usize> = (0..self.engines.len()).collect();
        worst.sort_by_key(|&i| {
            let st = &self.engines[i].stats;
            std::cmp::Reverse(st.input_starved + st.output_blocked + st.weight_frozen)
        });
        for &i in worst.iter().take(4) {
            let e = &self.engines[i];
            let _ = writeln!(
                s,
                "  [{i}] {}: image {} line-cycle {}/{} ({} lines out), starved={} blocked={} \
                 frozen={}",
                self.plan.layers[e.layer_idx].stats.name,
                e.image,
                e.line_cycle,
                e.cycles_per_line,
                e.lines_produced,
                e.stats.input_starved,
                e.stats.output_blocked,
                e.stats.weight_frozen,
            );
        }
        s.trim_end().to_string()
    }

    /// The reference run loop: one base tick at a time, every component
    /// touched every domain cycle. The event-driven path in
    /// [`crate::sim::events`] must match this tick for tick.
    fn run_exact(
        &mut self,
        cfg: &SimConfig,
        images: u64,
        mut probe: Option<&mut dyn Probe>,
    ) -> Result<Option<u64>> {
        let mut warmup_done_at: Option<u64> = None;
        loop {
            if self.t >= cfg.max_base_ticks {
                bail!(
                    "simulation exceeded max_base_ticks — pipeline wedged?\n{}",
                    self.wedge_breakdown()
                );
            }
            self.step_base_tick_probed(images, probe.as_deref_mut());
            if warmup_done_at.is_none() && self.sink_images_done() >= cfg.warmup_images {
                warmup_done_at = Some(self.core_cycles);
            }
            if self.all_done(images) {
                break;
            }
        }
        Ok(warmup_done_at)
    }

    fn run_inner(
        &mut self,
        cfg: &SimConfig,
        mut probe: Option<&mut dyn Probe>,
    ) -> Result<SimReport> {
        let images = cfg.images.max(cfg.warmup_images + 1);
        let warmup_done_at = if cfg.exact_stepping {
            self.run_exact(cfg, images, probe.as_deref_mut())?
        } else {
            crate::sim::events::run_fast(self, cfg, images, probe.as_deref_mut())?
        };
        if let Some(p) = probe {
            self.sample_probe(p);
        }

        let hz = self.plan.device.core_mhz as f64 * 1e6;
        let measured_images = images - cfg.warmup_images;
        let span = self.core_cycles - warmup_done_at.unwrap_or(0);
        let throughput = measured_images as f64 * hz / span.max(1) as f64;
        let latency = self.first_image_done_cycle().map(|c| c as f64 / hz).unwrap_or(f64::NAN);

        // bottleneck: weight engine with the most active cycles
        let (bi, _) = self
            .engines
            .iter()
            .enumerate()
            .filter(|(i, _)| self.plan.layers[*i].stats.has_weights)
            .max_by_key(|(_, e)| e.stats.active)
            .expect("some weight engine");
        let be = &self.engines[bi];
        let freeze_fraction = be.stats.weight_frozen as f64
            / (be.stats.active + be.stats.weight_frozen).max(1) as f64;

        let engine_stats = self
            .engines
            .iter()
            .map(|e| {
                let s = &e.stats;
                EngineStat {
                    name: self.plan.layers[e.layer_idx].stats.name.clone(),
                    active: s.active,
                    input_starved: s.input_starved,
                    output_blocked: s.output_blocked,
                    weight_frozen: s.weight_frozen,
                }
            })
            .collect();

        Ok(SimReport {
            network: self.plan.network.clone(),
            throughput,
            latency,
            freeze_fraction,
            bottleneck: self.plan.layers[bi].stats.name.clone(),
            bottleneck_on_hbm: self.engines[bi].hbm_fed,
            hbm_efficiency: self.weights.mean_read_efficiency(),
            core_cycles: self.core_cycles,
            engine_stats,
            faults: self.faults_armed.then(|| self.weights.fault_totals()),
        })
    }
}

/// Simulate a compiled plan in one call (the main entry used by benches).
///
/// **Deprecated:** prefer the staged [`crate::session`] API —
/// `CompiledModel::simulate` (typed report) or
/// `deploy(DeploymentTarget::SingleDevice)` (unified `RunReport`) — which
/// guarantees the plan and network belong together. This free function
/// remains for benches and low-level callers.
pub fn simulate(
    net: &Network,
    plan: &AcceleratorPlan,
    cfg: &SimConfig,
) -> Result<SimReport> {
    PipelineSim::new(net, plan)?.run(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::config::{CompilerOptions, DeviceConfig};
    use crate::nn::zoo;

    fn quick_cfg() -> SimConfig {
        SimConfig {
            images: 3,
            warmup_images: 1,
            max_base_ticks: 20_000_000_000,
            ..Default::default()
        }
    }

    #[test]
    fn resnet18_hybrid_simulates() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let rep = simulate(&net, &plan, &quick_cfg()).unwrap();
        assert!(rep.throughput > 500.0, "throughput {:.0}", rep.throughput);
        assert!(rep.latency > 0.0 && rep.latency < 0.1, "latency {}", rep.latency);
    }

    #[test]
    fn mobilenet_v2_no_hbm_no_freeze() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::mobilenet_v2();
        let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let rep = simulate(&net, &plan, &quick_cfg()).unwrap();
        assert_eq!(rep.freeze_fraction, 0.0, "on-chip weights never freeze");
        assert!(rep.throughput > 100.0);
    }

    #[test]
    fn throughput_close_to_analytic_estimate() {
        // The cycle sim should land within ~40% of the compiler's analytic
        // estimate for an on-chip-bottleneck network.
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let rep = simulate(&net, &plan, &quick_cfg()).unwrap();
        let ratio = rep.throughput / plan.est_throughput;
        assert!((0.4..1.3).contains(&ratio), "sim/est ratio {ratio:.2}");
    }

    #[test]
    fn all_hbm_slower_than_hybrid_in_sim() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let hybrid = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let mut o = CompilerOptions::default();
        o.all_hbm = true;
        let all = compile(&net, &d, &o).unwrap();
        let rh = simulate(&net, &hybrid, &quick_cfg()).unwrap();
        let ra = simulate(&net, &all, &quick_cfg()).unwrap();
        assert!(
            rh.throughput > ra.throughput,
            "hybrid {:.0} vs all-HBM {:.0}",
            rh.throughput,
            ra.throughput
        );
    }

    #[test]
    fn input_limit_gates_the_head_engine() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let mut sim = PipelineSim::new(&net, &plan).unwrap();
        sim.set_input_limit(0);
        for _ in 0..40_000 {
            sim.step_base_tick(3);
        }
        assert_eq!(sim.head_lines_consumed(), 0, "head must not run ahead of delivery");
        assert_eq!(sim.sink_lines_produced(), 0);
        // granting lines lets the head forward exactly that many
        sim.set_input_limit(5);
        for _ in 0..40_000 {
            sim.step_base_tick(3);
        }
        assert_eq!(sim.head_lines_consumed(), 5);
    }

    #[test]
    fn sink_limit_blocks_instead_of_dropping() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let mut sim = PipelineSim::new(&net, &plan).unwrap();
        sim.set_sink_limit(1);
        for _ in 0..4_000_000 {
            sim.step_base_tick(3);
            if sim.sink_output_blocked() > 0 {
                break;
            }
        }
        assert!(sim.sink_lines_produced() <= 1, "sink overran its credit bound");
        assert!(sim.sink_output_blocked() > 0, "sink must register the credit stall");
    }

    #[test]
    fn faulted_simulation_completes_conserves_and_is_deterministic() {
        use crate::faults::{FaultPlan, HbmFaultSpec};
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let mut fp = FaultPlan::new(11);
        fp.hbm = Some(HbmFaultSpec { start: 0, end: 500_000, prob: 0.02, max_replays: 3 });
        let run = |fp: &FaultPlan| {
            let mut sim = PipelineSim::new(&net, &plan).unwrap();
            sim.apply_faults(fp);
            sim.run(&quick_cfg()).unwrap()
        };
        let rep = run(&fp);
        let t = rep.faults.expect("armed run must carry the ledger");
        assert!(t.injected > 0, "error window must fire: {t:?}");
        assert_eq!(t.lost(), 0, "conservation: {t:?}");
        let j = rep.to_json().to_string();
        assert!(j.contains("\"lost\":0"), "{j}");
        assert!(j.contains("\"recovered\":"), "{j}");
        // Same seed ⇒ byte-identical report (the CI determinism check).
        let rep2 = run(&fp);
        assert_eq!(rep.to_json().to_string(), rep2.to_json().to_string());
        // A healthy run stays byte-identical to pre-fault builds.
        let healthy = simulate(&net, &plan, &quick_cfg()).unwrap();
        assert!(healthy.faults.is_none());
        assert!(!healthy.to_json().to_string().contains("\"faults\""));
        // Faults cost throughput, not correctness.
        assert!(rep.throughput <= healthy.throughput * 1.001);
    }

    #[test]
    fn conservation_every_engine_finishes_every_image() {
        let d = DeviceConfig::stratix10_nx2100();
        let net = zoo::resnet18();
        let plan = compile(&net, &d, &CompilerOptions::default()).unwrap();
        let mut sim = PipelineSim::new(&net, &plan).unwrap();
        let cfg = quick_cfg();
        sim.run(&cfg).unwrap();
        for e in &sim.engines {
            assert!(e.done(cfg.images), "engine {} incomplete", e.layer_idx);
            assert_eq!(e.lines_produced, cfg.images * e.out_h as u64);
        }
    }
}
