//! Cycle-level simulation of the H2PIPE dataflow pipeline.
//!
//! This is the testbed substitute for the Stratix 10 NX board: layer
//! engines with AI-TB timing semantics ([`engine`]), the §IV-A weight
//! distribution network wired to the [`crate::hbm`] substrate
//! ([`weights`]), and the whole layer-pipelined accelerator
//! ([`pipeline`]) with the freeze-signal stall mechanism of §IV-B.
//!
//! Two clock domains are modelled exactly as on the board: layer engines
//! tick at the 300 MHz core clock, HBM controllers at 400 MHz; the
//! simulator advances both from a 1200 MHz base tick (core = every 4th,
//! HBM = every 3rd base tick) and the [`crate::fabric::DcFifo`] crossing
//! sits between them.

pub mod engine;
pub(crate) mod events;
pub mod pipeline;
pub mod weights;

pub use engine::{EngineState, LayerEngineSim};
pub use pipeline::{EngineStat, PipelineSim, SimConfig, SimReport};
pub use weights::WeightSubsystem;
