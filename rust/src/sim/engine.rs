//! One layer engine in the simulated pipeline.
//!
//! An engine walks its output tensor line by line. Each output line costs
//! `kh * kw * ceil(ci/10/p_i) * ceil(co/p_o)` core cycles (the AI-TB chain
//! timing of §III-B) and consumes `p_i * p_o` 80-bit weight words per
//! cycle. The engine advances only when:
//!   * its producers have delivered the input lines the current output
//!     line's receptive field needs,
//!   * downstream line buffers have space (back-pressure),
//!   * its weight source is ready — on-chip weights always are; HBM
//!     weights require the last-stage FIFO to hold one cycle's words, and
//!     an empty FIFO asserts the §IV-B `freeze`.

use crate::compiler::LayerPlan;
use crate::config::WeightPlacement;

/// Why an engine did not advance this cycle (stall accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineState {
    /// Advanced one compute cycle.
    Active,
    /// Waiting for producer lines.
    InputStarved,
    /// Waiting for downstream buffer space.
    OutputBlocked,
    /// Frozen: weight FIFO (HBM path) cannot supply this cycle's words.
    WeightFrozen,
    /// Finished all images.
    Done,
}

/// Per-engine stall counters.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub active: u64,
    pub input_starved: u64,
    pub output_blocked: u64,
    pub weight_frozen: u64,
}

impl EngineStats {
    /// Total accounted cycles (active + every stall class) — the
    /// denominator for stall-fraction and flight-recorder window checks.
    pub fn total(&self) -> u64 {
        self.active + self.input_starved + self.output_blocked + self.weight_frozen
    }
}

/// Cycle-level state of one layer engine.
#[derive(Debug, Clone)]
pub struct LayerEngineSim {
    /// Index into the plan's layer vec.
    pub layer_idx: usize,
    /// Cycles to produce one output line.
    pub cycles_per_line: u64,
    /// 80-bit weight words consumed per compute cycle (p_i * p_o).
    pub words_per_cycle: u32,
    /// Output lines per image.
    pub out_h: u32,
    /// Geometry for input-dependency computation.
    pub kh: u32,
    pub stride: u32,
    pub pad: u32,
    /// Needs every producer line before starting (FC / GAP / SE heads).
    pub needs_full_input: bool,
    /// Weights stream from HBM (freeze semantics apply).
    pub hbm_fed: bool,

    /// Progress: current image index and output line within it.
    pub image: u64,
    pub line: u32,
    /// Cycle within the current line.
    pub line_cycle: u64,
    /// Cumulative output lines produced (across images).
    pub lines_produced: u64,
    /// Completion cycle of each finished image (first N kept).
    pub image_done_cycles: Vec<u64>,
    pub stats: EngineStats,
}

impl LayerEngineSim {
    /// Build from a compiled layer plan. `stride`/`pad` come from the IR.
    pub fn from_plan(idx: usize, lp: &LayerPlan, stride: u32, pad: u32, full_input: bool) -> Self {
        let s = &lp.stats;
        let cycles_per_line =
            (s.cycles_per_image(lp.par.p_i, lp.par.p_o) / s.out_h.max(1) as u64).max(1);
        Self {
            layer_idx: idx,
            cycles_per_line,
            words_per_cycle: lp.par.chains(),
            out_h: s.out_h.max(1),
            kh: s.kh.max(1),
            stride: stride.max(1),
            pad,
            needs_full_input: full_input,
            hbm_fed: lp.placement == WeightPlacement::Hbm && s.has_weights,
            image: 0,
            line: 0,
            line_cycle: 0,
            lines_produced: 0,
            image_done_cycles: Vec::new(),
            stats: EngineStats::default(),
        }
    }

    /// Producer lines (within the current image) required before output
    /// line `y` can compute: the bottom row of its receptive field.
    pub fn input_lines_needed(&self, y: u32, in_h: u32) -> u32 {
        if self.needs_full_input {
            return in_h;
        }
        let last = y as i64 * self.stride as i64 + self.kh as i64 - 1 - self.pad as i64;
        (last + 1).clamp(1, in_h as i64) as u32
    }

    /// Cumulative producer lines needed for the engine's *current*
    /// position.
    pub fn cum_input_needed(&self, in_h: u32) -> u64 {
        self.image * in_h as u64 + self.input_lines_needed(self.line, in_h) as u64
    }

    /// First input line still referenced by the current output line — the
    /// producer may not run further than `buffer_lines` past it.
    pub fn oldest_input_needed(&self, in_h: u32) -> u64 {
        if self.needs_full_input {
            return self.image * in_h as u64;
        }
        let first = (self.line as i64 * self.stride as i64 - self.pad as i64).max(0) as u64;
        self.image * in_h as u64 + first.min(in_h as u64)
    }

    /// True once all `images` are complete.
    pub fn done(&self, images: u64) -> bool {
        self.image >= images
    }

    /// Attempt to advance one core cycle.
    ///
    /// `input_ok` / `output_ok`: dependency checks computed by the
    /// pipeline; `weight_words_available`: last-stage FIFO level for
    /// HBM-fed engines (ignored otherwise). Returns what happened; on an
    /// `Active` cycle the caller must deduct `words_per_cycle` from the
    /// FIFO when HBM-fed.
    pub fn tick(
        &mut self,
        now: u64,
        images: u64,
        input_ok: bool,
        output_ok: bool,
        weight_words_available: u64,
    ) -> EngineState {
        if self.done(images) {
            return EngineState::Done;
        }
        if !input_ok {
            self.stats.input_starved += 1;
            return EngineState::InputStarved;
        }
        if !output_ok {
            self.stats.output_blocked += 1;
            return EngineState::OutputBlocked;
        }
        if self.hbm_fed && weight_words_available < self.words_per_cycle as u64 {
            self.stats.weight_frozen += 1;
            return EngineState::WeightFrozen;
        }
        self.stats.active += 1;
        self.line_cycle += 1;
        if self.line_cycle >= self.cycles_per_line {
            self.line_cycle = 0;
            self.line += 1;
            self.lines_produced += 1;
            if self.line >= self.out_h {
                self.line = 0;
                self.image += 1;
                if self.image_done_cycles.len() < 64 {
                    self.image_done_cycles.push(now);
                }
            }
        }
        EngineState::Active
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(out_h: u32, cpl: u64) -> LayerEngineSim {
        LayerEngineSim {
            layer_idx: 0,
            cycles_per_line: cpl,
            words_per_cycle: 2,
            out_h,
            kh: 3,
            stride: 1,
            pad: 1,
            needs_full_input: false,
            hbm_fed: false,
            image: 0,
            line: 0,
            line_cycle: 0,
            lines_produced: 0,
            image_done_cycles: Vec::new(),
            stats: EngineStats::default(),
        }
    }

    #[test]
    fn produces_lines_at_expected_rate() {
        let mut e = engine(4, 10);
        for t in 0..40 {
            assert_eq!(e.tick(t, 10, true, true, 0), EngineState::Active);
        }
        assert_eq!(e.lines_produced, 4);
        assert_eq!(e.image, 1, "one image after out_h * cycles_per_line");
    }

    #[test]
    fn receptive_field_dependency() {
        let e = engine(8, 1);
        // 3x3 stride 1 pad 1: line 0 needs input lines 0..=1 -> 2 lines
        assert_eq!(e.input_lines_needed(0, 8), 2);
        assert_eq!(e.input_lines_needed(1, 8), 3);
        // clamped at the bottom edge
        assert_eq!(e.input_lines_needed(7, 8), 8);
    }

    #[test]
    fn strided_dependency() {
        let mut e = engine(4, 1);
        e.stride = 2;
        e.kh = 3;
        e.pad = 1;
        // y=1: rows 1..=3 -> 4 lines
        assert_eq!(e.input_lines_needed(1, 8), 4);
    }

    #[test]
    fn full_input_layers_wait_for_whole_image() {
        let mut e = engine(1, 5);
        e.needs_full_input = true;
        assert_eq!(e.input_lines_needed(0, 7), 7);
    }

    #[test]
    fn hbm_freeze_blocks_without_words() {
        let mut e = engine(4, 10);
        e.hbm_fed = true;
        assert_eq!(e.tick(0, 1, true, true, 1), EngineState::WeightFrozen);
        assert_eq!(e.stats.weight_frozen, 1);
        assert_eq!(e.tick(1, 1, true, true, 2), EngineState::Active);
    }

    #[test]
    fn stall_accounting() {
        let mut e = engine(4, 10);
        e.tick(0, 1, false, true, 0);
        e.tick(1, 1, true, false, 0);
        e.tick(2, 1, true, true, 0);
        assert_eq!(e.stats.input_starved, 1);
        assert_eq!(e.stats.output_blocked, 1);
        assert_eq!(e.stats.active, 1);
    }
}
