//! Streaming and batch statistics used by the HBM characterization,
//! the simulator's stall accounting, and the bench harness.

/// Welford online mean/variance plus min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch percentile computation over collected samples.
///
/// The Fig. 3b latency experiment reports min/avg/max; the serving example
/// additionally reports p50/p90/p99, so we keep the raw samples.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Percentile in `[0, 100]` by nearest-rank interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let rank = p / 100.0 * (self.samples.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn min(&mut self) -> f64 {
        self.percentile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_stats_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = OnlineStats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles_basic() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert_eq!(p.min(), 1.0);
        assert_eq!(p.max(), 100.0);
        assert!((p.median() - 50.5).abs() < 1e-9);
        assert!((p.percentile(90.0) - 90.1).abs() < 1e-9);
        assert!((p.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_percentiles_are_nan() {
        let mut p = Percentiles::new();
        assert!(p.median().is_nan());
        assert!(p.mean().is_nan());
    }
}
