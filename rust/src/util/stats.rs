//! Streaming and batch statistics used by the HBM characterization,
//! the simulator's stall accounting, and the bench harness.

/// Welford online mean/variance plus min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch percentile estimation over a log-bucketed histogram.
///
/// The Fig. 3b latency experiment reports min/avg/max; the serving stack
/// reports p50/p99 on every scrape. Keeping raw samples made each
/// percentile query O(n log n) and memory O(n) for the lifetime of a
/// server; instead this stores HdrHistogram-style buckets — one power-of-2
/// octave split into [`Percentiles::SUBBUCKETS`] linear sub-buckets —
/// covering `[1e-9, 1e12]`. Bucket midpoints bound the relative error by
/// `1 / (2 * SUBBUCKETS)` (< 1%); min, max, and mean are tracked exactly,
/// so p0/p100/mean keep their old exact values and an empty histogram
/// still reports NaN everywhere.
#[derive(Debug, Clone)]
pub struct Percentiles {
    /// Bucket counts, grown on demand up to `OCTAVES * SUBBUCKETS`.
    buckets: Vec<u64>,
    /// Samples below `MIN_TRACKED` (or non-finite) — reported as `min`.
    underflow: u64,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Percentiles {
    fn default() -> Self {
        Self::new()
    }
}

impl Percentiles {
    /// Linear sub-buckets per power-of-2 octave (relative error <= 0.4%).
    pub const SUBBUCKETS: usize = 128;
    /// Smallest trackable magnitude (1 ns when samples are seconds).
    const MIN_TRACKED: f64 = 1e-9;
    /// Largest trackable magnitude; beyond it samples clamp to the top
    /// bucket (min/max stay exact regardless).
    const MAX_TRACKED: f64 = 1e12;

    pub fn new() -> Self {
        Self {
            buckets: Vec::new(),
            underflow: 0,
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        match Self::bucket_index(x) {
            None => self.underflow += 1,
            Some(i) => {
                if i >= self.buckets.len() {
                    self.buckets.resize(i + 1, 0);
                }
                self.buckets[i] += 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Histogram slot for `x`: octave `floor(log2(x / MIN_TRACKED))`,
    /// linear sub-bucket within the octave. `None` = underflow.
    fn bucket_index(x: f64) -> Option<usize> {
        if !(x >= Self::MIN_TRACKED) {
            return None; // below range, zero, negative, or NaN
        }
        let r = x.min(Self::MAX_TRACKED) / Self::MIN_TRACKED;
        let octave = r.log2().floor() as usize;
        let sub = (((r / (octave as f64).exp2()) - 1.0) * Self::SUBBUCKETS as f64).floor()
            as usize;
        Some(octave * Self::SUBBUCKETS + sub.min(Self::SUBBUCKETS - 1))
    }

    /// `[lo, hi)` value bounds of bucket `i` (inverse of `bucket_index`).
    fn bucket_bounds(i: usize) -> (f64, f64) {
        let octave = (i / Self::SUBBUCKETS) as f64;
        let sub = (i % Self::SUBBUCKETS) as f64;
        let base = Self::MIN_TRACKED * octave.exp2();
        let width = base / Self::SUBBUCKETS as f64;
        (base + sub * width, base + (sub + 1.0) * width)
    }

    /// Percentile in `[0, 100]`; midpoint of the covering bucket, clamped
    /// to the exact observed `[min, max]`.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.n == 0 {
            return f64::NAN;
        }
        // The endpoints are tracked exactly; only interior quantiles go
        // through the histogram.
        if p == 0.0 {
            return self.min;
        }
        if p == 100.0 {
            return self.max;
        }
        let rank = p / 100.0 * (self.n - 1) as f64;
        let mut cum = self.underflow as f64;
        if cum > rank {
            return self.min;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c as f64;
            if cum > rank {
                let (lo, hi) = Self::bucket_bounds(i);
                return ((lo + hi) * 0.5).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.max }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.sum / self.n as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_stats_merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = OnlineStats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles_basic() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        // min / max / mean are tracked exactly; quantiles are histogram
        // estimates within the documented ~1% relative error.
        assert_eq!(p.min(), 1.0);
        assert_eq!(p.max(), 100.0);
        assert!((p.mean() - 50.5).abs() < 1e-9);
        assert!((p.median() - 50.5).abs() / 50.5 < 0.02, "median {}", p.median());
        assert!((p.percentile(90.0) - 90.1).abs() / 90.1 < 0.02, "{}", p.percentile(90.0));
        assert_eq!(p.percentile(0.0), 1.0);
        assert_eq!(p.percentile(100.0), 100.0);
    }

    #[test]
    fn empty_percentiles_are_nan() {
        let p = Percentiles::new();
        assert!(p.median().is_nan());
        assert!(p.mean().is_nan());
        assert!(p.min().is_nan());
    }

    #[test]
    fn histogram_tracks_exact_quantiles_on_100k_samples() {
        // Log-uniform samples over 6 decades — the shape of serving
        // latencies — checked against exact sorted-sample quantiles.
        let mut rng = crate::util::XorShift64::new(0x0b5ef);
        let mut p = Percentiles::new();
        let mut exact: Vec<f64> = Vec::with_capacity(100_000);
        for _ in 0..100_000 {
            let x = 10f64.powf(rng.next_f64() * 6.0 - 4.0); // 1e-4 .. 1e2
            p.push(x);
            exact.push(x);
        }
        exact.sort_by(|a, b| a.total_cmp(b));
        for q in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9] {
            let rank = q / 100.0 * (exact.len() - 1) as f64;
            let lo = exact[rank.floor() as usize];
            let hi = exact[rank.ceil() as usize];
            let truth = lo + (hi - lo) * (rank - rank.floor());
            let est = p.percentile(q);
            let rel = (est - truth).abs() / truth;
            assert!(rel < 0.01, "p{q}: est {est} vs exact {truth} (rel {rel:.4})");
        }
        assert_eq!(p.min(), exact[0]);
        assert_eq!(p.max(), *exact.last().unwrap());
        assert_eq!(p.len(), 100_000);
    }

    #[test]
    fn histogram_handles_out_of_range_samples() {
        let mut p = Percentiles::new();
        p.push(-3.0); // below range -> underflow, still exact min
        p.push(0.0);
        p.push(5.0);
        assert_eq!(p.min(), -3.0);
        assert_eq!(p.max(), 5.0);
        assert_eq!(p.percentile(0.0), -3.0);
        assert_eq!(p.percentile(100.0), 5.0);
        assert_eq!(p.len(), 3);
    }
}
