//! Small shared utilities: deterministic RNG, statistics, and lightweight
//! JSON/CSV emission (the offline crate set has no `rand`/`serde`).

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::XorShift64;
pub use stats::{OnlineStats, Percentiles};

/// Integer ceiling division: `ceil(a / b)` for non-negative integers.
///
/// Used throughout the resource model — e.g. the Eq. 1 M20K count is
/// `ceil(bits / 20480)`.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// Round `a` up to the nearest multiple of `m`.
#[inline]
pub fn round_up(a: u64, m: u64) -> u64 {
    ceil_div(a, m) * m
}

/// Format a bit count as human-readable megabits with one decimal,
/// matching the units in the paper's Table I.
pub fn fmt_mbits(bits: u64) -> String {
    format!("{:.1} Mb", bits as f64 / 1.0e6)
}

/// Format bytes/s as GB/s with one decimal (paper convention: 1 GB = 1e9 B).
pub fn fmt_gbps(bytes_per_s: f64) -> String {
    format!("{:.1} GB/s", bytes_per_s / 1.0e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_inexact() {
        assert_eq!(ceil_div(10, 5), 2);
        assert_eq!(ceil_div(11, 5), 3);
        assert_eq!(ceil_div(0, 5), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_mbits(102_000_000), "102.0 Mb");
        assert_eq!(fmt_gbps(204.8e9), "204.8 GB/s");
    }
}
