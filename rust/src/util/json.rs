//! Minimal JSON value + emitter + parser.
//!
//! Bench and report outputs are machine-readable JSON so that experiment
//! results can be diffed / plotted; `serde` is not in the offline crate
//! set, so we carry a tiny value model with a correct string escaper and,
//! since plan artifacts became persistable (`h2pipe::session`), a strict
//! recursive-descent parser. The emitter writes f64s in Rust's shortest
//! round-trip form, so `parse(v.to_string()) == v` for every value this
//! module can emit (NaN/Inf excepted — they serialize as `null`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object value; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Push into an array value; panics if `self` is not an array.
    pub fn push(&mut self, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(v) => v.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Serialize with two-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close_pad = "  ".repeat(depth);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    x.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close_pad}]");
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close_pad}}}");
            }
            _ => self.write(out),
        }
    }

    /// Parse a JSON document. Strict: exactly one value, nothing but
    /// whitespace after it, no trailing commas, no comments.
    pub fn parse(text: &str) -> Result<Json> {
        let chars: Vec<char> = text.chars().collect();
        let mut p = Parser { chars, pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            bail!("trailing characters at offset {} of JSON document", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup; `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as u64 if it is a non-negative integer exactly
    /// representable in f64 (all counts this crate serializes are).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9.007_199_254_740_992e15 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_u64().and_then(|n| u32::try_from(n).ok())
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|n| usize::try_from(n).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Compact serialization; `json.to_string()` comes with it for free.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Nesting bound for the parser — far above any plan artifact, but keeps
/// adversarial input from overflowing the stack.
const MAX_DEPTH: usize = 128;

struct Parser {
    chars: Vec<char>,
    pos: usize,
}

impl Parser {
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn expect(&mut self, c: char) -> Result<()> {
        match self.peek() {
            Some(x) if x == c => {
                self.pos += 1;
                Ok(())
            }
            Some(x) => bail!("expected {c:?} at offset {}, found {x:?}", self.pos),
            None => bail!("expected {c:?} at offset {}, found end of input", self.pos),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        for c in word.chars() {
            self.expect(c)?;
        }
        Ok(value)
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("JSON nesting deeper than {MAX_DEPTH}");
        }
        match self.peek() {
            Some('{') => self.object(depth),
            Some('[') => self.array(depth),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('t') => self.literal("true", Json::Bool(true)),
            Some('f') => self.literal("false", Json::Bool(false)),
            Some('n') => self.literal("null", Json::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected {c:?} at offset {}", self.pos),
            None => bail!("unexpected end of input at offset {}", self.pos),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect('{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(',') => self.pos += 1,
                Some('}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect('[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(',') => self.pos += 1,
                Some(']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect('"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at offset {}", self.pos),
                Some('"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some('\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| anyhow::anyhow!("dangling escape at offset {}", self.pos))?;
                    self.pos += 1;
                    match esc {
                        '"' => s.push('"'),
                        '\\' => s.push('\\'),
                        '/' => s.push('/'),
                        'b' => s.push('\u{8}'),
                        'f' => s.push('\u{c}'),
                        'n' => s.push('\n'),
                        'r' => s.push('\r'),
                        't' => s.push('\t'),
                        'u' => {
                            let hi = self.hex4()?;
                            // surrogate pair handling for completeness
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                self.expect('\\')?;
                                self.expect('u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate at offset {}", self.pos);
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            match char::from_u32(code) {
                                Some(c) => s.push(c),
                                None => bail!("invalid \\u escape at offset {}", self.pos),
                            }
                        }
                        c => bail!("unknown escape \\{c} at offset {}", self.pos),
                    }
                }
                Some(c) if (c as u32) < 0x20 => {
                    bail!("unescaped control character at offset {}", self.pos)
                }
                Some(c) => {
                    s.push(c);
                    self.pos += 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self
                .peek()
                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape at offset {}", self.pos))?;
            let d = c
                .to_digit(16)
                .ok_or_else(|| anyhow::anyhow!("bad hex digit {c:?} at offset {}", self.pos))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some('-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some('.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some('e' | 'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some('+' | '-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let tok: String = self.chars[start..self.pos].iter().collect();
        match tok.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => bail!("invalid number {tok:?} at offset {start}"),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3.5).to_string(), "3.5");
        assert_eq!(Json::from(42u64).to_string(), "42");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").to_string(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nested_object_stable_order() {
        let mut o = Json::obj();
        o.set("z", 1u64).set("a", "x");
        let mut inner = Json::Arr(vec![]);
        inner.push(1u64).push(2u64);
        o.set("arr", inner);
        assert_eq!(o.to_string(), "{\"a\":\"x\",\"arr\":[1,2],\"z\":1}");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string(), "null");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_parses_shape() {
        let mut o = Json::obj();
        o.set("k", 1u64);
        let p = o.to_pretty();
        assert!(p.contains("\"k\": 1"));
        assert!(p.ends_with("}\n"));
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::from(true));
        assert_eq!(Json::parse("false").unwrap(), Json::from(false));
        assert_eq!(Json::parse("42").unwrap(), Json::from(42u64));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::from(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::from("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_string_escapes() {
        assert_eq!(Json::parse(r#""a\"b\\c\nd""#).unwrap().as_str(), Some("a\"b\\c\nd"));
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
        // surrogate pair: U+1F600
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str(), Some("\u{1F600}"));
        // raw non-ASCII passes through (the emitter writes it raw)
        assert_eq!(Json::parse("\"héllo\"").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1 2", "[1] x", "\"unterminated",
            "{\"a\":1,}", "nan", "--1",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn emit_parse_round_trip() {
        let mut o = Json::obj();
        o.set("z", 1u64).set("a", "x\ny").set("f", 0.1 + 0.2).set("neg", -7i64);
        let mut inner = Json::Arr(vec![]);
        inner.push(Json::Null).push(true).push(3.25);
        o.set("arr", inner);
        for text in [o.to_string(), o.to_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), o, "{text}");
        }
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 5, "s": "t", "b": false, "x": 1.5}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(5));
        assert_eq!(j.get("n").unwrap().as_u32(), Some(5));
        assert_eq!(j.get("x").unwrap().as_u64(), None, "non-integer");
        assert_eq!(j.get("x").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("s").unwrap().as_str(), Some("t"));
        assert_eq!(j.get("missing"), None);
        assert_eq!(Json::Null.get("n"), None);
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None, "negative");
    }
}
