//! Minimal JSON value + emitter.
//!
//! Bench and report outputs are machine-readable JSON so that experiment
//! results can be diffed / plotted; `serde` is not in the offline crate
//! set, so we carry a tiny value model with a correct string escaper.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object value; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Push into an array value; panics if `self` is not an array.
    pub fn push(&mut self, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(v) => v.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close_pad = "  ".repeat(depth);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    x.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close_pad}]");
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{close_pad}}}");
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3.5).to_string(), "3.5");
        assert_eq!(Json::from(42u64).to_string(), "42");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").to_string(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nested_object_stable_order() {
        let mut o = Json::obj();
        o.set("z", 1u64).set("a", "x");
        let mut inner = Json::Arr(vec![]);
        inner.push(1u64).push(2u64);
        o.set("arr", inner);
        assert_eq!(o.to_string(), "{\"a\":\"x\",\"arr\":[1,2],\"z\":1}");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string(), "null");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_parses_shape() {
        let mut o = Json::obj();
        o.set("k", 1u64);
        let p = o.to_pretty();
        assert!(p.contains("\"k\": 1"));
        assert!(p.ends_with("}\n"));
    }
}
