//! Deterministic xorshift64* RNG.
//!
//! The HBM characterization experiments (§III-A) issue *random-address*
//! traffic; reproducibility of every figure requires a seeded, portable
//! generator, so we use xorshift64* rather than an OS RNG. The `rand`
//! crate is not in the offline crate set.

/// A seeded xorshift64* pseudo-random generator.
///
/// Passes BigCrush's basic batteries and is more than adequate for address
/// and workload generation. Never use for cryptography.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a non-zero seed. A zero seed is remapped to
    /// a fixed odd constant (xorshift has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        let state = if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed };
        Self { state }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform value in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift range reduction (Lemire); slight modulo bias is
        // irrelevant at our bounds (<2^40) but this avoids division too.
        ((u128::from(self.next_u64()) * u128::from(bound)) >> 64) as u64
    }

    /// Uniform value in `[lo, hi]` inclusive.
    #[inline]
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.next_below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn bounds_respected() {
        let mut r = XorShift64::new(7);
        for _ in 0..10_000 {
            let v = r.next_below(13);
            assert!(v < 13);
            let w = r.next_range(5, 9);
            assert!((5..=9).contains(&w));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = XorShift64::new(99);
        let mut buckets = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            buckets[r.next_below(10) as usize] += 1;
        }
        for &b in &buckets {
            // expect ~10k per bucket; allow ±10%
            assert!((9_000..11_000).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
