//! Dual-clock FIFO (DCFIFO): the HBM-to-fabric clock crossing of §IV-A.
//!
//! The weight prefetch path runs in the 400 MHz HBM controller domain
//! while layer engines run at the 300 MHz core clock. A DCFIFO's read
//! side observes writes only after the gray-coded write pointer has been
//! synchronized — modelled here as a fixed number of *read-domain* ticks
//! of visibility latency.
//!
//! The simulator drives both domains from a common base tick (1200 MHz =
//! lcm(400, 300)): the write side ticks every 3 base ticks, the read side
//! every 4.

use std::collections::VecDeque;

/// Dual-clock FIFO with synchronizer latency.
#[derive(Debug, Clone)]
pub struct DcFifo<T> {
    q: VecDeque<(T, u64)>, // (item, read-domain tick when it becomes visible)
    capacity: usize,
    sync_ticks: u64,
    read_tick: u64,
    max_occupancy: usize,
}

impl<T> DcFifo<T> {
    /// `sync_ticks` read-domain cycles of pointer-synchronizer latency
    /// (2 flops is typical).
    pub fn new(capacity: usize, sync_ticks: u64) -> Self {
        assert!(capacity > 0, "zero-capacity DCFIFO");
        Self { q: VecDeque::with_capacity(capacity), capacity, sync_ticks, read_tick: 0, max_occupancy: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total words held (write-side view; includes not-yet-visible words).
    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() == self.capacity
    }

    pub fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }

    /// Advance the read-domain clock one tick.
    pub fn tick_read(&mut self) {
        self.read_tick += 1;
    }

    /// Write-side push (HBM domain). Fails when full.
    pub fn push(&mut self, v: T) -> bool {
        if self.is_full() {
            return false;
        }
        self.q.push_back((v, self.read_tick + self.sync_ticks));
        self.max_occupancy = self.max_occupancy.max(self.q.len());
        true
    }

    /// True if the read side currently sees a word.
    pub fn readable(&self) -> bool {
        matches!(self.q.front(), Some((_, vis)) if *vis <= self.read_tick)
    }

    /// Read-side pop; `None` until the head word's synchronizer delay has
    /// elapsed.
    pub fn pop(&mut self) -> Option<T> {
        if self.readable() {
            self.q.pop_front().map(|(v, _)| v)
        } else {
            None
        }
    }

    /// Read-side peek.
    pub fn peek(&self) -> Option<&T> {
        match self.q.front() {
            Some((v, vis)) if *vis <= self.read_tick => Some(v),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visibility_delayed_by_sync() {
        let mut f = DcFifo::new(8, 2);
        f.push(42u32);
        assert!(!f.readable(), "word must not be visible immediately");
        f.tick_read();
        assert!(!f.readable());
        f.tick_read();
        assert!(f.readable());
        assert_eq!(f.pop(), Some(42));
    }

    #[test]
    fn zero_sync_is_immediate() {
        let mut f = DcFifo::new(4, 0);
        f.push(1u8);
        assert_eq!(f.pop(), Some(1));
    }

    #[test]
    fn order_preserved_across_domains() {
        let mut f = DcFifo::new(16, 2);
        for i in 0..10u32 {
            f.push(i);
        }
        let mut out = Vec::new();
        for _ in 0..20 {
            f.tick_read();
            while let Some(v) = f.pop() {
                out.push(v);
            }
        }
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn full_rejects_push() {
        let mut f = DcFifo::new(2, 1);
        assert!(f.push(1u8));
        assert!(f.push(2));
        assert!(!f.push(3));
        assert_eq!(f.len(), 2);
    }
}
