//! On-chip flow-control fabric.
//!
//! The paper's §IV-A weight distribution network is built from: a weight
//! prefetcher in the HBM clock domain, a dual-clock FIFO per pseudo-
//! channel, per-layer burst-matching single-clock FIFOs, an 80-bit
//! serializer, and daisy-chained 512-deep last-stage FIFOs feeding groups
//! of AI tensor blocks. §V-A shows the ready/valid version of this network
//! deadlocks under head-of-line blocking (Fig. 5) and replaces it with a
//! credit-based latency-insensitive protocol.
//!
//! This module provides those primitives ([`ScFifo`], [`DcFifo`],
//! [`CreditCounter`], [`ReadyValid`]) plus an executable reproduction of
//! the Fig. 5 deadlock ([`deadlock`]).

pub mod credit;
pub mod dcfifo;
pub mod deadlock;
pub mod fifo;
pub mod ready_valid;

pub use credit::CreditCounter;
pub use dcfifo::DcFifo;
pub use deadlock::{run_shared_pc_pipeline, FlowControl, PipelineOutcome};
pub use fifo::ScFifo;
pub use ready_valid::ReadyValid;
