//! Executable reproduction of the Fig. 5 deadlock.
//!
//! Three consecutive layer engines share one HBM pseudo-channel: their
//! weight words arrive interleaved through a single HBM-to-fabric DCFIFO
//! and are distributed to per-layer burst-matching FIFOs. Activations flow
//! layer 1 -> 2 -> 3 through shallow queues.
//!
//! Under **ready/valid** flow control the prefetcher issues reads greedily
//! whenever the DCFIFO has space. If a burst-matching FIFO fills while its
//! layer is starved of activations, the DCFIFO head blocks (head-of-line),
//! upstream layers lose their weight supply, activations stop, and the
//! whole pipeline wedges — exactly the scenario of Fig. 5.
//!
//! Under **credit** flow control the prefetcher holds a credit counter per
//! burst-matching FIFO and never issues a read that could not drain, so
//! the DCFIFO never blocks and the pipeline always completes.

use crate::fabric::credit::CreditCounter;
use crate::fabric::dcfifo::DcFifo;
use crate::fabric::fifo::ScFifo;

/// Flow-control protocol for the weight distribution network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowControl {
    /// Greedy prefetch + backpressure (the original HPIPE style).
    ReadyValid,
    /// Credit-based reservation (the H2PIPE fix).
    Credit,
}

/// Result of a pipeline run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PipelineOutcome {
    /// All layers processed `items` work items within `cycles`.
    Completed { cycles: u64 },
    /// No progress for the watchdog window; `head_layer` is the layer the
    /// stuck DCFIFO head word belongs to.
    Deadlocked { cycle: u64, head_layer: usize, starved_layer: usize },
}

/// Scenario parameters.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Weight words each layer consumes per work item. The Fig. 5-style
    /// asymmetry (layer 1 much hungrier than its round-robin share) is
    /// what exposes the deadlock.
    pub weights_per_item: [u32; 3],
    /// Capacity of each burst-matching FIFO, in weight words.
    pub burst_fifo_capacity: usize,
    /// Capacity of the shared HBM-to-fabric DCFIFO.
    pub dcfifo_capacity: usize,
    /// Capacity of the inter-layer activation queues.
    pub act_queue_capacity: usize,
    /// Work items each layer must complete.
    pub items: u64,
    /// Simulated HBM read latency (cycles from issue to DCFIFO arrival).
    pub hbm_latency: u64,
    /// Cycles without progress before declaring deadlock.
    pub watchdog: u64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        Self {
            weights_per_item: [4, 1, 1],
            burst_fifo_capacity: 4,
            dcfifo_capacity: 16,
            act_queue_capacity: 2,
            items: 200,
            hbm_latency: 12,
            watchdog: 10_000,
        }
    }
}

/// Run the three-layer shared-PC scenario under the given protocol.
pub fn run_shared_pc_pipeline(flow: FlowControl, cfg: &ScenarioConfig) -> PipelineOutcome {
    // In-flight HBM reads: (arrival_cycle, layer).
    let mut in_flight: std::collections::VecDeque<(u64, usize)> = Default::default();
    let mut dcfifo: DcFifo<usize> = DcFifo::new(cfg.dcfifo_capacity, 1);
    let mut burst: Vec<ScFifo<usize>> =
        (0..3).map(|_| ScFifo::with_capacity(cfg.burst_fifo_capacity)).collect();
    let mut credits: Vec<CreditCounter> =
        (0..3).map(|_| CreditCounter::new(cfg.burst_fifo_capacity as u32)).collect();
    // Activation queues in front of layers 1 and 2 (layer 0 reads the
    // image input, which is always available).
    let mut acts: Vec<ScFifo<u64>> =
        (0..2).map(|_| ScFifo::with_capacity(cfg.act_queue_capacity)).collect();
    // Per-layer progress: weights consumed toward the current item, items
    // done.
    let mut consumed = [0u32; 3];
    let mut done = [0u64; 3];
    let mut issued_weights = [0u64; 3];
    let total_weights: Vec<u64> =
        cfg.weights_per_item.iter().map(|&w| w as u64 * cfg.items).collect();

    let mut cycle: u64 = 0;
    let mut last_progress_cycle: u64 = 0;
    let mut rr = 0usize; // round-robin pointer for prefetch issue

    loop {
        let mut progressed = false;

        // --- prefetcher (HBM domain): issue up to one read per cycle ---
        // DCFIFO space must exist for every outstanding word in either
        // protocol (that is the physical buffer); the protocols differ in
        // whether the *destination* FIFO space is reserved.
        if dcfifo.len() + in_flight.len() < dcfifo.capacity() {
            for k in 0..3 {
                let l = (rr + k) % 3;
                if issued_weights[l] >= total_weights[l] {
                    continue;
                }
                let can_issue = match flow {
                    FlowControl::ReadyValid => true,
                    FlowControl::Credit => credits[l].can_acquire(1),
                };
                if can_issue {
                    if flow == FlowControl::Credit {
                        credits[l].acquire(1);
                    }
                    in_flight.push_back((cycle + cfg.hbm_latency, l));
                    issued_weights[l] += 1;
                    rr = (l + 1) % 3;
                    break;
                }
            }
        }

        // --- HBM returns data into the DCFIFO -------------------------
        while let Some(&(arr, l)) = in_flight.front() {
            if arr <= cycle && !dcfifo.is_full() {
                dcfifo.push(l);
                in_flight.pop_front();
                progressed = true;
            } else {
                break;
            }
        }

        // --- distributor: DCFIFO head -> its layer's burst FIFO -------
        dcfifo.tick_read();
        if let Some(&l) = dcfifo.peek() {
            if !burst[l].is_full() {
                let l = dcfifo.pop().expect("peeked");
                burst[l].push(l);
                progressed = true;
            }
            // else: head-of-line blocking — the Fig. 5 hazard.
        }

        // --- layer engines (core domain) -------------------------------
        for l in 0..3 {
            if done[l] >= cfg.items {
                continue;
            }
            // activation available? layer 0 streams the input image.
            let act_ready = if l == 0 { true } else { !acts[l - 1].is_empty() };
            // output space available? layer 2 drains off-chip.
            let out_ready = if l == 2 { true } else { !acts[l].is_full() };
            if !act_ready || !out_ready || burst[l].is_empty() {
                continue;
            }
            burst[l].pop();
            if FlowControl::Credit == flow {
                credits[l].release(1); // the Fig. 4a 'dequeue' signal
            }
            consumed[l] += 1;
            progressed = true;
            if consumed[l] == cfg.weights_per_item[l] {
                consumed[l] = 0;
                done[l] += 1;
                if l > 0 {
                    acts[l - 1].pop();
                }
                if l < 2 {
                    acts[l].push(done[l]);
                }
            }
        }

        if progressed {
            last_progress_cycle = cycle;
        }
        if done.iter().all(|&d| d >= cfg.items) {
            return PipelineOutcome::Completed { cycles: cycle };
        }
        if cycle - last_progress_cycle > cfg.watchdog {
            let head_layer = dcfifo.peek().copied().unwrap_or(3);
            let starved_layer = (0..3)
                .find(|&l| done[l] < cfg.items && burst[l].is_empty())
                .unwrap_or(3);
            return PipelineOutcome::Deadlocked { cycle, head_layer, starved_layer };
        }
        cycle += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ready_valid_deadlocks_in_fig5_scenario() {
        let out = run_shared_pc_pipeline(FlowControl::ReadyValid, &ScenarioConfig::default());
        match out {
            PipelineOutcome::Deadlocked { head_layer, starved_layer, .. } => {
                // the stuck head word belongs to a downstream layer while
                // an upstream layer starves — the exact Fig. 5 picture
                assert!(head_layer > starved_layer, "head {head_layer} starved {starved_layer}");
            }
            PipelineOutcome::Completed { .. } => panic!("expected deadlock under ready/valid"),
        }
    }

    #[test]
    fn credit_completes_same_scenario() {
        let out = run_shared_pc_pipeline(FlowControl::Credit, &ScenarioConfig::default());
        assert!(
            matches!(out, PipelineOutcome::Completed { .. }),
            "credit protocol must not deadlock: {out:?}"
        );
    }

    #[test]
    fn credit_completes_across_many_shapes() {
        // Property-style sweep: the credit protocol never deadlocks for
        // any weight-ratio / capacity combination.
        let mut rng = crate::util::XorShift64::new(2024);
        for _ in 0..30 {
            let cfg = ScenarioConfig {
                weights_per_item: [
                    rng.next_range(1, 6) as u32,
                    rng.next_range(1, 6) as u32,
                    rng.next_range(1, 6) as u32,
                ],
                burst_fifo_capacity: rng.next_range(2, 8) as usize,
                dcfifo_capacity: rng.next_range(8, 24) as usize,
                act_queue_capacity: rng.next_range(1, 4) as usize,
                items: 50,
                hbm_latency: rng.next_range(1, 30),
                watchdog: 10_000,
            };
            let out = run_shared_pc_pipeline(FlowControl::Credit, &cfg);
            assert!(
                matches!(out, PipelineOutcome::Completed { .. }),
                "credit deadlocked for {cfg:?}: {out:?}"
            );
        }
    }

    #[test]
    fn ready_valid_ok_when_fifos_are_deep_enough() {
        // With generous buffering the ready/valid design also completes —
        // the deadlock is a function of shared-PC buffer pressure, which
        // is why it escaped the original HPIPE.
        let cfg = ScenarioConfig {
            burst_fifo_capacity: 4096,
            dcfifo_capacity: 16,
            ..ScenarioConfig::default()
        };
        let out = run_shared_pc_pipeline(FlowControl::ReadyValid, &cfg);
        assert!(matches!(out, PipelineOutcome::Completed { .. }), "{out:?}");
    }

    #[test]
    fn credit_no_slower_when_no_hazard() {
        // Symmetric demand: both protocols complete; credits must not cost
        // meaningful throughput.
        let cfg = ScenarioConfig {
            weights_per_item: [1, 1, 1],
            ..ScenarioConfig::default()
        };
        let rv = run_shared_pc_pipeline(FlowControl::ReadyValid, &cfg);
        let cr = run_shared_pc_pipeline(FlowControl::Credit, &cfg);
        let (PipelineOutcome::Completed { cycles: c_rv }, PipelineOutcome::Completed { cycles: c_cr }) =
            (rv, cr)
        else {
            panic!("both should complete");
        };
        assert!(
            (c_cr as f64) < 1.2 * c_rv as f64,
            "credit {c_cr} should be within 20% of ready/valid {c_rv}"
        );
    }
}
