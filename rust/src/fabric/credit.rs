//! Credit-based flow control (§V-A).
//!
//! The weight prefetching logic holds one credit counter per downstream
//! burst-matching FIFO, initialized to that FIFO's free capacity. An HBM
//! read for a layer is only issued when the layer's counter holds enough
//! credits for the whole burst, which guarantees the shared DCFIFO can
//! always drain — the head word's destination FIFO has reserved space, so
//! head-of-line blocking (and the Fig. 5 deadlock) is impossible.

/// A hardware-style credit counter.
#[derive(Debug, Clone)]
pub struct CreditCounter {
    credits: u32,
    max: u32,
}

impl CreditCounter {
    /// Counter initialized to (and capped at) `max` credits.
    pub fn new(max: u32) -> Self {
        Self { credits: max, max }
    }

    pub fn available(&self) -> u32 {
        self.credits
    }

    pub fn max(&self) -> u32 {
        self.max
    }

    /// Outstanding (consumed, not yet returned) credits.
    pub fn outstanding(&self) -> u32 {
        self.max - self.credits
    }

    /// Fraction of capacity currently outstanding, in [0, 1] — the
    /// downstream FIFO's fill level as the credit protocol sees it.
    pub fn occupancy_frac(&self) -> f64 {
        if self.max == 0 {
            return 0.0;
        }
        self.outstanding() as f64 / self.max as f64
    }

    /// Can `n` credits be acquired?
    pub fn can_acquire(&self, n: u32) -> bool {
        self.credits >= n
    }

    /// Acquire `n` credits (decrement when an HBM read request is issued).
    /// Returns false and does nothing if insufficient.
    pub fn acquire(&mut self, n: u32) -> bool {
        if self.credits < n {
            return false;
        }
        self.credits -= n;
        true
    }

    /// Return `n` credits (the layer engine's `dequeue` signal in
    /// Fig. 4a). Panics on over-return — that is a protocol bug, never a
    /// recoverable runtime condition.
    pub fn release(&mut self, n: u32) {
        assert!(
            self.credits + n <= self.max,
            "credit over-return: {} + {n} > {}",
            self.credits,
            self.max
        );
        self.credits += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_roundtrip() {
        let mut c = CreditCounter::new(8);
        assert_eq!(c.available(), 8);
        assert!(c.acquire(5));
        assert_eq!(c.available(), 3);
        assert_eq!(c.outstanding(), 5);
        c.release(5);
        assert_eq!(c.available(), 8);
    }

    #[test]
    fn acquire_fails_without_credits() {
        let mut c = CreditCounter::new(4);
        assert!(c.acquire(4));
        assert!(!c.acquire(1));
        assert_eq!(c.available(), 0);
    }

    #[test]
    #[should_panic(expected = "credit over-return")]
    fn over_release_panics() {
        let mut c = CreditCounter::new(4);
        c.release(1);
    }

    #[test]
    fn never_negative_never_above_max_under_random_ops() {
        let mut rng = crate::util::XorShift64::new(77);
        let mut c = CreditCounter::new(16);
        let mut outstanding = 0u32;
        for _ in 0..100_000 {
            if rng.next_bool(0.5) {
                let n = rng.next_range(1, 4) as u32;
                if c.acquire(n) {
                    outstanding += n;
                }
            } else if outstanding > 0 {
                let n = (rng.next_range(1, 4) as u32).min(outstanding);
                c.release(n);
                outstanding -= n;
            }
            assert!(c.available() <= 16);
            assert_eq!(c.available() + outstanding, 16, "credit conservation");
        }
    }
}
