//! Single-clock FIFO (SCFIFO) with almost-full / almost-empty thresholds.
//!
//! Burst-matching FIFOs (§IV-A, sized proportionally to the burst length)
//! and the 512-word last-stage weight FIFOs are both instances of this.
//! The `almost_empty` threshold is what drives the §IV-B `freeze` signal;
//! `almost_full` drove the original ready/valid design that §V-A replaces
//! with credits.

use std::collections::VecDeque;

/// Bounded FIFO with HW-style occupancy flags.
#[derive(Debug, Clone)]
pub struct ScFifo<T> {
    q: VecDeque<T>,
    capacity: usize,
    almost_full_slack: usize,
    almost_empty_level: usize,
    /// High-water mark for sizing studies.
    max_occupancy: usize,
}

impl<T> ScFifo<T> {
    /// A FIFO of `capacity` words. `almost_full` asserts when fewer than
    /// `almost_full_slack` slots remain; `almost_empty` when at most
    /// `almost_empty_level` words remain.
    pub fn new(capacity: usize, almost_full_slack: usize, almost_empty_level: usize) -> Self {
        assert!(capacity > 0, "zero-capacity FIFO");
        Self {
            q: VecDeque::with_capacity(capacity),
            capacity,
            almost_full_slack,
            almost_empty_level,
            max_occupancy: 0,
        }
    }

    /// Convenience: thresholds at 1/8 capacity either side.
    pub fn with_capacity(capacity: usize) -> Self {
        let t = (capacity / 8).max(1);
        Self::new(capacity, t, t)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() == self.capacity
    }

    pub fn free(&self) -> usize {
        self.capacity - self.q.len()
    }

    /// HW `almost_full` flag.
    pub fn almost_full(&self) -> bool {
        self.free() < self.almost_full_slack
    }

    /// HW `almost_empty` flag (the §IV-B freeze trigger).
    pub fn almost_empty(&self) -> bool {
        self.q.len() <= self.almost_empty_level
    }

    /// Highest occupancy ever observed (FIFO sizing studies).
    pub fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }

    /// Push; returns false (dropping nothing) when full.
    pub fn push(&mut self, v: T) -> bool {
        if self.is_full() {
            return false;
        }
        self.q.push_back(v);
        self.max_occupancy = self.max_occupancy.max(self.q.len());
        true
    }

    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let mut f = ScFifo::with_capacity(3);
        assert!(f.push(1));
        assert!(f.push(2));
        assert!(f.push(3));
        assert!(f.is_full());
        assert!(!f.push(4), "push to full FIFO must fail");
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn flags() {
        let mut f: ScFifo<u32> = ScFifo::new(8, 2, 2);
        assert!(f.almost_empty());
        assert!(!f.almost_full());
        for i in 0..7 {
            f.push(i);
        }
        assert!(f.almost_full(), "7/8 with slack 2");
        assert!(!f.almost_empty());
        while f.len() > 2 {
            f.pop();
        }
        assert!(f.almost_empty());
    }

    #[test]
    fn high_water_mark() {
        let mut f = ScFifo::with_capacity(16);
        for i in 0..10 {
            f.push(i);
        }
        for _ in 0..5 {
            f.pop();
        }
        for i in 0..3 {
            f.push(i);
        }
        assert_eq!(f.max_occupancy(), 10);
    }

    #[test]
    #[should_panic(expected = "zero-capacity")]
    fn zero_capacity_panics() {
        let _ = ScFifo::<u8>::new(0, 1, 1);
    }
}
