//! Ready/valid (AXI-stream style) link — the §V-A baseline protocol.
//!
//! A single-register latency-insensitive link: the producer may load a
//! word when the register is empty (`ready`), the consumer may take it
//! when `valid`. H2PIPE's original HPIPE fabric used this style; the
//! paper shows it deadlocks when a shared DCFIFO fans out to multiple
//! burst-matching FIFOs (Fig. 5), motivating [`super::credit`].

/// One-deep ready/valid pipeline register.
#[derive(Debug, Clone)]
pub struct ReadyValid<T> {
    slot: Option<T>,
}

impl<T> Default for ReadyValid<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ReadyValid<T> {
    pub fn new() -> Self {
        Self { slot: None }
    }

    /// Producer-side `ready`: can a word be loaded this cycle?
    pub fn ready(&self) -> bool {
        self.slot.is_none()
    }

    /// Consumer-side `valid`: is a word present?
    pub fn valid(&self) -> bool {
        self.slot.is_some()
    }

    /// Producer handshake: load when ready.
    pub fn send(&mut self, v: T) -> bool {
        if self.slot.is_some() {
            return false;
        }
        self.slot = Some(v);
        true
    }

    /// Consumer handshake: take when valid.
    pub fn recv(&mut self) -> Option<T> {
        self.slot.take()
    }

    /// Consumer peek without dequeue.
    pub fn peek(&self) -> Option<&T> {
        self.slot.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handshake() {
        let mut l = ReadyValid::new();
        assert!(l.ready() && !l.valid());
        assert!(l.send(7u32));
        assert!(!l.ready() && l.valid());
        assert!(!l.send(8), "backpressure while occupied");
        assert_eq!(l.recv(), Some(7));
        assert!(l.ready());
        assert_eq!(l.recv(), None);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut l = ReadyValid::new();
        l.send("w");
        assert_eq!(l.peek(), Some(&"w"));
        assert!(l.valid());
        assert_eq!(l.recv(), Some("w"));
    }
}
