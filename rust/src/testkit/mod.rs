//! Minimal in-repo property-testing kit.
//!
//! The offline crate set has no `proptest`, so this provides the subset
//! the suite needs: seeded generators, a property runner that reports the
//! failing *case seed* for one-line reproduction, and size-bounded value
//! generation. No shrinking — failing seeds regenerate the exact case,
//! which has proven sufficient for the invariants tested here.
//!
//! It also carries [`golden`], a tiny snapshot-test helper (no `insta`
//! offline) used to pin the compiler's offload decisions per model.

use std::path::Path;

use crate::util::XorShift64;

/// Compare `content` against the golden file at `path`.
///
/// * Missing golden file: it is created (bootstrap) and the check passes
///   with a note on stderr — commit the generated file to pin the
///   behaviour.
/// * Existing file: exact string comparison; set `H2PIPE_BLESS=1` to
///   rewrite goldens after an intentional behaviour change.
///
/// Returns `Err` with a readable first-difference report on mismatch.
pub fn golden(path: &Path, content: &str) -> Result<(), String> {
    if std::env::var_os("H2PIPE_BLESS").is_some() || !path.exists() {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("creating {}: {e}", dir.display()))?;
        }
        std::fs::write(path, content).map_err(|e| format!("writing {}: {e}", path.display()))?;
        eprintln!("golden: wrote {}", path.display());
        return Ok(());
    }
    let want =
        std::fs::read_to_string(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
    if want == content {
        return Ok(());
    }
    let diff_line = want
        .lines()
        .zip(content.lines())
        .position(|(a, b)| a != b)
        .unwrap_or_else(|| want.lines().count().min(content.lines().count()));
    Err(format!(
        "golden mismatch vs {} at line {}:\n  golden: {:?}\n  actual: {:?}\n\
         (re-bless with H2PIPE_BLESS=1 if the change is intentional)",
        path.display(),
        diff_line + 1,
        want.lines().nth(diff_line).unwrap_or("<eof>"),
        content.lines().nth(diff_line).unwrap_or("<eof>"),
    ))
}

/// Random-value source handed to properties.
#[derive(Debug)]
pub struct Gen {
    rng: XorShift64,
    /// Seed that reproduces this case exactly.
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: XorShift64::new(seed), case_seed: seed }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.next_range(lo, hi)
    }

    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.rng.next_range(lo as u64, hi as u64) as u32
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.next_range(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_bool(p)
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    pub fn vec_u64(&mut self, len_lo: usize, len_hi: usize, lo: u64, hi: u64) -> Vec<u64> {
        let n = self.usize(len_lo, len_hi);
        (0..n).map(|_| self.u64(lo, hi)).collect()
    }
}

/// Run `prop` for `cases` seeded cases; panic with the reproducing seed on
/// the first failure. Properties return `Err(message)` to fail.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    // master seed fixed for determinism; per-case seeds derived
    let master = 0x5eed_0000_c0de_0000u64 ^ fxhash(name);
    for i in 0..cases {
        let case_seed = master.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed on case {i} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed (debugging helper).
pub fn recheck(seed: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("case (seed {seed:#x}) still fails: {msg}");
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always-ok", 50, |g| {
            n += 1;
            let v = g.u64(0, 100);
            if v <= 100 { Ok(()) } else { Err("impossible".into()) }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn failing_property_panics_with_seed() {
        check("must-fail", 10, |g| {
            let v = g.u64(0, 9);
            if v < 10 { Err(format!("v={v}")) } else { Ok(()) }
        });
    }

    #[test]
    fn case_seeds_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("collect", 5, |g| {
            first.push(g.u64(0, u64::MAX - 1));
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("collect", 5, |g| {
            second.push(g.u64(0, u64::MAX - 1));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn golden_bootstraps_then_compares() {
        let dir = std::env::temp_dir().join(format!("h2pipe-golden-{}", std::process::id()));
        let path = dir.join("snap.txt");
        let _ = std::fs::remove_file(&path);
        // first call bootstraps the file
        golden(&path, "a\nb\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a\nb\n");
        // same content passes, different content reports the first diff line
        golden(&path, "a\nb\n").unwrap();
        let err = golden(&path, "a\nc\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recheck_reproduces() {
        let mut g = Gen::new(42);
        let v1 = g.u64(0, 1000);
        recheck(42, |g| {
            let v2 = g.u64(0, 1000);
            if v1 == v2 { Ok(()) } else { Err("not reproducible".into()) }
        });
    }
}
