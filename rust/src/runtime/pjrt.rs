//! PJRT backend: load and execute the AOT artifacts on the hot path.
//!
//! Enabled with `--features pjrt`, which requires the `xla` crate (PJRT
//! C API bindings) — uncomment its line in `rust/Cargo.toml`; it is not
//! part of the offline crate set. `python/compile/aot.py` lowers the L2
//! JAX graphs (which call the L1 Pallas kernels with `interpret=True`)
//! to **HLO text** under `artifacts/`; this backend compiles those
//! artifacts once at boot and executes them per request.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::runtime::{Backend, Model};

/// PJRT CPU client backend.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend").field("platform", &self.client.platform_name()).finish()
    }
}

impl PjrtBackend {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<name>.hlo.txt` from the artifact directory and compile it.
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serialized protos
    /// carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see DESIGN.md §10 / aot.py docstring).
    fn load_model(&self, artifact_dir: &Path, name: &str) -> Result<Box<dyn Model>> {
        let path = artifact_dir.join(format!("{name}.hlo.txt"));
        ensure!(
            path.exists(),
            "artifact {} missing — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {name}"))?;
        Ok(Box::new(PjrtModel { name: name.to_string(), exe }))
    }
}

/// A compiled artifact: one PJRT executable per model variant.
struct PjrtModel {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Model for PjrtModel {
    fn name(&self) -> &str {
        &self.name
    }

    /// Execute with a single int32 tensor input; the artifact returns a
    /// 1-tuple (aot.py lowers with `return_tuple=True`).
    fn run_i32(&self, input: &[i32], dims: &[usize]) -> Result<Vec<i32>> {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims_i64).context("reshaping input")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        out.to_vec::<i32>().context("converting result to i32 vec")
    }
}
