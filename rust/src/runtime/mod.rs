//! Runtime backends: functional CNN execution on the serving hot path.
//!
//! The serving coordinator needs a *functional* executor next to the
//! timing model. A [`Backend`] loads [`Model`]s by name and executes
//! them; two implementations are provided:
//!
//! * [`reference`] (default) — a pure-Rust int8 reference interpreter
//!   over the [`crate::nn`] IR with deterministic weights. It needs no
//!   external crates and no prebuilt artifacts, so `h2pipe serve` /
//!   `h2pipe infer`, the coordinator, and every test work in the
//!   offline crate set.
//! * [`pjrt`] (`--features pjrt`) — the PJRT CPU client that compiles
//!   and runs the `artifacts/*.hlo.txt` lowered by
//!   `python/compile/aot.py` (L2 JAX graphs calling the L1 Pallas
//!   kernels with `interpret=True`). Requires the `xla` crate; see
//!   DESIGN.md §10 for the HLO-text interchange rationale. Python is
//!   never on the request path in either backend.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use reference::ReferenceBackend;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

/// A loaded, executable model (one artifact or built-in graph).
///
/// Implementations are created on (and stay on) the thread that uses
/// them — the PJRT handles are not `Send`, so the trait imposes no
/// threading bound and the server worker loads its model in-thread.
pub trait Model {
    fn name(&self) -> &str;

    /// Execute with a single int32 tensor input of the given dims. The
    /// boundary is int32 (the `xla` crate's literal API has no i8); the
    /// graph clips to the int8 datapath internally.
    fn run_i32(&self, input: &[i32], dims: &[usize]) -> Result<Vec<i32>>;
}

/// An execution backend that can load models by name from an artifact
/// directory.
pub trait Backend {
    /// Short backend identifier: "reference" or "pjrt".
    fn name(&self) -> &'static str;

    /// Platform string (PJRT naming), e.g. "cpu".
    fn platform_name(&self) -> String;

    /// Load the named model. Backends must fail with a clear,
    /// actionable error when the model is unknown or its artifact is
    /// missing.
    fn load_model(&self, artifact_dir: &Path, name: &str) -> Result<Box<dyn Model>>;
}

/// A backend plus the artifact directory models are loaded from.
pub struct Runtime {
    backend: Box<dyn Backend>,
    artifact_dir: PathBuf,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("backend", &self.backend.name())
            .field("artifact_dir", &self.artifact_dir)
            .finish()
    }
}

impl Runtime {
    /// CPU runtime rooted at an artifact directory: the PJRT client when
    /// the `pjrt` feature is enabled, the reference interpreter
    /// otherwise — callers never need to know which.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        #[cfg(feature = "pjrt")]
        {
            Ok(Self::with_backend(Box::new(pjrt::PjrtBackend::cpu()?), artifact_dir))
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(Self::with_backend(Box::new(ReferenceBackend::new()), artifact_dir))
        }
    }

    /// Explicitly use the pure-Rust reference interpreter.
    pub fn reference(artifact_dir: impl AsRef<Path>) -> Self {
        Self::with_backend(Box::new(ReferenceBackend::new()), artifact_dir)
    }

    /// Use a caller-provided backend.
    pub fn with_backend(backend: Box<dyn Backend>, artifact_dir: impl AsRef<Path>) -> Self {
        Self { backend, artifact_dir: artifact_dir.as_ref().to_path_buf() }
    }

    /// Platform string of the underlying backend (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.backend.platform_name()
    }

    /// Which backend is in use: "reference" or "pjrt".
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Load the named model through the backend.
    pub fn load(&self, name: &str) -> Result<Executable> {
        Ok(Executable { model: self.backend.load_model(&self.artifact_dir, name)? })
    }
}

/// A loaded model, ready to execute requests.
pub struct Executable {
    model: Box<dyn Model>,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("model", &self.model.name()).finish()
    }
}

impl Executable {
    pub fn name(&self) -> &str {
        self.model.name()
    }

    /// Execute with a single int32 tensor input of the given dims.
    pub fn run_i32(&self, input: &[i32], dims: &[usize]) -> Result<Vec<i32>> {
        let n: usize = dims.iter().product();
        ensure!(n == input.len(), "input length {} != dims product {}", input.len(), n);
        self.model.run_i32(input, dims)
    }

    /// Convenience for int8-ranged data (the datapath dtype).
    pub fn run_int8(&self, input: &[i8], dims: &[usize]) -> Result<Vec<i8>> {
        let wide: Vec<i32> = input.iter().map(|&v| v as i32).collect();
        let out = self.run_i32(&wide, dims)?;
        Ok(out.into_iter().map(|v| v.clamp(-128, 127) as i8).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn cpu_client_comes_up() {
        // Works with no `xla` crate and no artifacts present: without the
        // `pjrt` feature this is the reference interpreter.
        let rt = Runtime::cpu(artifacts()).unwrap();
        assert_eq!(rt.platform().to_lowercase(), "cpu");
    }

    #[test]
    fn default_backend_is_reference_without_pjrt_feature() {
        let rt = Runtime::cpu(artifacts()).unwrap();
        #[cfg(not(feature = "pjrt"))]
        assert_eq!(rt.backend_name(), "reference");
        #[cfg(feature = "pjrt")]
        assert_eq!(rt.backend_name(), "pjrt");
    }

    #[test]
    fn load_and_run_cifarnet() {
        let rt = Runtime::reference(artifacts());
        let exe = rt.load("cifarnet").unwrap();
        assert_eq!(exe.name(), "cifarnet");
        let img = vec![1i8; 32 * 32 * 3];
        let out = exe.run_int8(&img, &[32, 32, 3]).unwrap();
        assert_eq!(out.len(), 10);
        // deterministic graph + deterministic input => deterministic output
        let out2 = exe.run_int8(&img, &[32, 32, 3]).unwrap();
        assert_eq!(out, out2);
    }

    #[test]
    fn run_rejects_bad_dims() {
        let rt = Runtime::reference(artifacts());
        let exe = rt.load("cifarnet").unwrap();
        let img = vec![0i8; 7];
        assert!(exe.run_int8(&img, &[32, 32, 3]).is_err());
        // right element count, wrong tensor shape
        let img = vec![0i8; 32 * 32 * 3];
        assert!(exe.run_int8(&img, &[3, 32, 32]).is_err());
    }

    #[test]
    fn missing_artifact_is_clear_error() {
        // Must pass with no `xla` crate and no artifacts: both backends
        // point the user at `make artifacts` for unknown models.
        let rt = Runtime::cpu(artifacts()).unwrap();
        let err = match rt.load("nonexistent_model") {
            Ok(_) => panic!("expected load failure"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
        assert!(msg.contains("nonexistent_model"), "error must name the model: {msg}");
    }
}
