//! PJRT runtime: load and execute the AOT artifacts on the hot path.
//!
//! `python/compile/aot.py` lowers the L2 JAX graphs (which call the L1
//! Pallas kernels with `interpret=True`) to **HLO text** under
//! `artifacts/`. This module wraps the `xla` crate (PJRT C API) to compile
//! those artifacts once at boot and execute them per request — Python is
//! never on the request path.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

/// A compiled artifact: one PJRT executable per model variant.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT client plus the artifact directory executables are loaded from.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Platform string of the underlying PJRT client (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<name>.hlo.txt` from the artifact directory and compile it.
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serialized protos
    /// carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see DESIGN.md §9 / aot.py docstring).
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        ensure!(
            path.exists(),
            "artifact {} missing — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {name}"))?;
        Ok(Executable { name: name.to_string(), exe })
    }
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with a single int32 tensor input of the given dims; the
    /// artifact returns a 1-tuple (aot.py lowers with `return_tuple=True`).
    ///
    /// The artifact boundary is int32 because the `xla` crate's literal
    /// API has no i8; the graph casts to the int8 datapath internally.
    pub fn run_i32(&self, input: &[i32], dims: &[usize]) -> Result<Vec<i32>> {
        let n: usize = dims.iter().product();
        ensure!(n == input.len(), "input length {} != dims product {}", input.len(), n);
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims_i64).context("reshaping input")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        out.to_vec::<i32>().context("converting result to i32 vec")
    }

    /// Convenience for int8-ranged data (the datapath dtype).
    pub fn run_int8(&self, input: &[i8], dims: &[usize]) -> Result<Vec<i8>> {
        let wide: Vec<i32> = input.iter().map(|&v| v as i32).collect();
        let out = self.run_i32(&wide, dims)?;
        Ok(out.into_iter().map(|v| v.clamp(-128, 127) as i8).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts().join("cifarnet.hlo.txt").exists()
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu(artifacts()).unwrap();
        assert_eq!(rt.platform().to_lowercase(), "cpu");
    }

    #[test]
    fn load_and_run_cifarnet() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu(artifacts()).unwrap();
        let exe = rt.load("cifarnet").unwrap();
        let img = vec![1i8; 32 * 32 * 3];
        let out = exe.run_int8(&img, &[32, 32, 3]).unwrap();
        assert_eq!(out.len(), 10);
        // deterministic graph + deterministic input => deterministic output
        let out2 = exe.run_int8(&img, &[32, 32, 3]).unwrap();
        assert_eq!(out, out2);
    }

    #[test]
    fn run_rejects_bad_dims() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu(artifacts()).unwrap();
        let exe = rt.load("cifarnet").unwrap();
        let img = vec![0i8; 7];
        assert!(exe.run_int8(&img, &[32, 32, 3]).is_err());
    }

    #[test]
    fn missing_artifact_is_clear_error() {
        let rt = Runtime::cpu(artifacts()).unwrap();
        let err = match rt.load("nonexistent_model") {
            Ok(_) => panic!("expected load failure"),
            Err(e) => e,
        };
        assert!(format!("{err}").contains("make artifacts"));
    }
}
