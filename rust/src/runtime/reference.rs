//! Pure-Rust int8 reference interpreter.
//!
//! Executes small CNN graphs expressed in the [`crate::nn`] IR with the
//! same int8-datapath semantics the AOT artifacts implement: inputs are
//! clipped to the int8 range at the boundary, convolutions accumulate in
//! wide integers and requantize by an arithmetic right shift, and every
//! activation is clamped back into `[-128, 127]` (post-ReLU layers into
//! `[0, 127]`). Weights are deterministic pseudo-random int8 values
//! derived from the model and layer names, so outputs are bit-exact
//! across runs and platforms — the property the serving tests rely on.
//!
//! Three built-in graphs cover the serving paths the offline crate set
//! exercises (the first two mirror the AOT artifacts
//! `python/compile/aot.py` produces):
//!
//! * `cifarnet` — 32x32x3 -> conv/pool/conv/pool/GAP/FC -> 10 logits;
//! * `resnet_block` — 56x56x64 residual block, post-ReLU output;
//! * `mobilenet_edge` — compact depthwise-separable stack from
//!   `nn::zoo`, 32x32x3 -> 10 logits, *no* residual path.

use std::path::Path;

use anyhow::{bail, ensure, Result};

use crate::nn::{ConvKind, Layer, Network, OpKind, Shape};
use crate::runtime::{Backend, Model};
use crate::util::XorShift64;

/// Models the reference backend can serve with no artifacts present.
pub const BUILTIN_MODELS: [&str; 3] = ["cifarnet", "resnet_block", "mobilenet_edge"];

/// Input tensor dims (h, w, c) of a built-in model, derived from the
/// model graph itself so server configs cannot drift from the backend.
pub fn builtin_input_dims(name: &str) -> Option<Vec<usize>> {
    builtin_model(name).map(|m| m.input_dims().to_vec())
}

/// Construct a built-in model by name.
fn builtin_model(name: &str) -> Option<ReferenceModel> {
    match name {
        "cifarnet" => Some(ReferenceModel::cifarnet()),
        "resnet_block" => Some(ReferenceModel::resnet_block()),
        "mobilenet_edge" => Some(ReferenceModel::mobilenet_edge()),
        _ => None,
    }
}

/// The pure-Rust fallback backend (the default without `--features pjrt`).
#[derive(Debug, Default)]
pub struct ReferenceBackend;

impl ReferenceBackend {
    pub fn new() -> Self {
        Self
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    fn load_model(&self, _artifact_dir: &Path, name: &str) -> Result<Box<dyn Model>> {
        match builtin_model(name) {
            Some(m) => Ok(Box::new(m)),
            None => bail!(
                "model {name:?} is not a built-in reference model (available: \
                 {BUILTIN_MODELS:?}); for AOT artifacts run `make artifacts` and \
                 build with `--features pjrt`"
            ),
        }
    }
}

/// Per-layer execution parameters alongside the IR layer.
struct LayerExec {
    /// Deterministic int8 weights. Layout: `[co][kh][kw][ci]` for
    /// standard/pointwise convs, `[co][kh][kw]` for depthwise,
    /// `[out][in]` for FC; empty for weightless ops.
    weights: Vec<i8>,
    /// Arithmetic right shift requantizing the wide accumulator.
    shift: u32,
    /// Apply ReLU (clamp to `[0, 127]` instead of `[-128, 127]`).
    relu: bool,
}

/// An IR network plus deterministic weights — one built-in model.
pub struct ReferenceModel {
    net: Network,
    execs: Vec<LayerExec>,
    input_dims: Vec<usize>,
}

impl std::fmt::Debug for ReferenceModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReferenceModel")
            .field("net", &self.net.name)
            .field("layers", &self.execs.len())
            .field("input_dims", &self.input_dims)
            .finish()
    }
}

impl ReferenceModel {
    /// The cifarnet artifact's stand-in: 32x32x3 image -> 10 logits.
    pub fn cifarnet() -> Self {
        let mut n = Network::new("cifarnet", Shape::new(32, 32, 3));
        let c1 = n
            .add(
                "conv1",
                OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 8 },
                &[0],
            )
            .expect("cifarnet conv1");
        let p1 = n.add("pool1", OpKind::MaxPool { k: 2, stride: 2, pad: 0 }, &[c1]).expect("pool1");
        let c2 = n
            .add(
                "conv2",
                OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 16 },
                &[p1],
            )
            .expect("cifarnet conv2");
        let p2 = n.add("pool2", OpKind::MaxPool { k: 2, stride: 2, pad: 0 }, &[c2]).expect("pool2");
        let g = n.add("gap", OpKind::GlobalAvgPool, &[p2]).expect("gap");
        n.add("fc", OpKind::Fc { out_features: 10 }, &[g]).expect("fc");
        n.validate().expect("cifarnet validates");
        Self::from_network(n, &[])
    }

    /// The resnet_block artifact's stand-in: 56x56x64 residual block with
    /// a post-ReLU output (conv-conv-add-relu).
    pub fn resnet_block() -> Self {
        let mut n = Network::new("resnet_block", Shape::new(56, 56, 64));
        let c1 = n
            .add(
                "conv1",
                OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 64 },
                &[0],
            )
            .expect("block conv1");
        let c2 = n
            .add(
                "conv2",
                OpKind::Conv { kind: ConvKind::Standard, kh: 3, kw: 3, stride: 1, pad: 1, out_c: 64 },
                &[c1],
            )
            .expect("block conv2");
        n.add("add", OpKind::Add, &[c2, 0]).expect("block add");
        n.validate().expect("resnet_block validates");
        // residual semantics: pre-add conv output is linear, the add is
        // followed by the block's ReLU
        Self::from_network(n, &[("conv2", 5, false), ("add", 0, true)])
    }

    /// The mobilenet_edge serving model: the depthwise-separable stack
    /// from [`crate::nn::zoo::mobilenet_edge`] — no residual path, so the
    /// serving tests cover the skip-free execution scenario.
    pub fn mobilenet_edge() -> Self {
        Self::from_network(crate::nn::zoo::mobilenet_edge(), &[])
    }

    /// Build execution state for a network. `overrides` replaces the
    /// default (shift, relu) for the named layers.
    fn from_network(net: Network, overrides: &[(&str, u32, bool)]) -> Self {
        let execs = net
            .layers()
            .iter()
            .map(|l| {
                let (mut shift, mut relu) = match &l.op {
                    // conv accumulators grow with sqrt(k*k*ci); wider
                    // fan-in gets a larger default shift
                    OpKind::Conv { .. } if l.in_c() >= 32 => (5, true),
                    OpKind::Conv { .. } => (3, true),
                    OpKind::Fc { .. } => (5, false),
                    _ => (0, false),
                };
                if let Some(&(_, s, r)) = overrides.iter().find(|(n, _, _)| *n == l.name) {
                    shift = s;
                    relu = r;
                }
                LayerExec { weights: gen_weights(&net.name, l), shift, relu }
            })
            .collect();
        let s = net.input_shape();
        let input_dims = vec![s.h as usize, s.w as usize, s.c as usize];
        Self { net, execs, input_dims }
    }

    /// Expected input tensor dims (h, w, c).
    pub fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }
}

/// Deterministic int8 weights for one layer, seeded from model + layer
/// names (stable across runs, platforms and layer reordering).
fn gen_weights(model: &str, l: &Layer) -> Vec<i8> {
    let count = match &l.op {
        OpKind::Conv { .. } | OpKind::Fc { .. } => l.weight_params(),
        _ => 0,
    };
    let mut seed = 0xcbf2_9ce4_8422_2325u64;
    for b in model.bytes().chain([b'/']).chain(l.name.bytes()) {
        seed ^= b as u64;
        seed = seed.wrapping_mul(0x100_0000_01b3);
    }
    let mut rng = XorShift64::new(seed);
    (0..count).map(|_| rng.next_range(0, 14) as i8 - 7).collect()
}

/// Requantize a wide accumulator onto the int8 datapath.
#[inline]
fn requant(acc: i64, shift: u32, relu: bool) -> i32 {
    let v = (acc >> shift) as i32;
    let lo = if relu { 0 } else { -128 };
    v.clamp(lo, 127)
}

impl Model for ReferenceModel {
    fn name(&self) -> &str {
        &self.net.name
    }

    fn run_i32(&self, input: &[i32], dims: &[usize]) -> Result<Vec<i32>> {
        ensure!(
            dims == self.input_dims.as_slice(),
            "model {} expects input dims {:?}, got {:?}",
            self.net.name,
            self.input_dims,
            dims
        );
        let mut acts: Vec<Vec<i32>> = Vec::with_capacity(self.net.len());
        // int8 datapath: clip at the artifact boundary like the AOT graph
        acts.push(input.iter().map(|&v| v.clamp(-128, 127)).collect());
        for l in &self.net.layers()[1..] {
            let e = &self.execs[l.id];
            let x = &acts[l.inputs[0]];
            let out = match &l.op {
                OpKind::Conv { kind, kh, kw, stride, pad, .. } => {
                    conv2d(x, l.in_shape(), l.out, *kind, *kh, *kw, *stride, *pad, e)
                }
                OpKind::MaxPool { k, stride, pad } => {
                    maxpool(x, l.in_shape(), l.out, *k, *stride, *pad)
                }
                OpKind::GlobalAvgPool => global_avg_pool(x, l.in_shape()),
                OpKind::Fc { out_features } => fc(x, *out_features, e),
                OpKind::Add => {
                    let y = &acts[l.inputs[1]];
                    let lo = if e.relu { 0 } else { -128 };
                    x.iter().zip(y.iter()).map(|(&a, &b)| (a + b).clamp(lo, 127)).collect()
                }
                OpKind::Input { .. } | OpKind::SqueezeExcite { .. } => {
                    bail!("reference interpreter does not support {:?} at layer {}", l.op, l.name)
                }
            };
            acts.push(out);
        }
        Ok(acts.pop().expect("network is non-empty"))
    }
}

/// NHWC index helper.
#[inline]
fn at(w: usize, c: usize, y: usize, x: usize, ch: usize) -> usize {
    (y * w + x) * c + ch
}

#[allow(clippy::too_many_arguments)]
fn conv2d(
    inp: &[i32],
    in_s: Shape,
    out_s: Shape,
    kind: ConvKind,
    kh: u32,
    kw: u32,
    stride: u32,
    pad: u32,
    e: &LayerExec,
) -> Vec<i32> {
    let (ih, iw, ic) = (in_s.h as i64, in_s.w as i64, in_s.c as usize);
    let (oh, ow, oc) = (out_s.h as usize, out_s.w as usize, out_s.c as usize);
    let (kh, kw) = (kh as usize, kw as usize);
    let mut out = vec![0i32; oh * ow * oc];
    for oy in 0..oh {
        for ox in 0..ow {
            for co in 0..oc {
                let mut acc = 0i64;
                for ky in 0..kh {
                    for kx in 0..kw {
                        let y = (oy * stride as usize + ky) as i64 - pad as i64;
                        let x = (ox * stride as usize + kx) as i64 - pad as i64;
                        if y < 0 || y >= ih || x < 0 || x >= iw {
                            continue;
                        }
                        let (y, x) = (y as usize, x as usize);
                        if kind == ConvKind::Depthwise {
                            // one filter per channel, layout [co][kh][kw]
                            let wv = e.weights[(co * kh + ky) * kw + kx] as i64;
                            acc += inp[at(iw as usize, ic, y, x, co)] as i64 * wv;
                        } else {
                            let wbase = ((co * kh + ky) * kw + kx) * ic;
                            let xbase = at(iw as usize, ic, y, x, 0);
                            for ci in 0..ic {
                                acc += inp[xbase + ci] as i64 * e.weights[wbase + ci] as i64;
                            }
                        }
                    }
                }
                out[at(ow, oc, oy, ox, co)] = requant(acc, e.shift, e.relu);
            }
        }
    }
    out
}

fn maxpool(inp: &[i32], in_s: Shape, out_s: Shape, k: u32, stride: u32, pad: u32) -> Vec<i32> {
    let (ih, iw, c) = (in_s.h as i64, in_s.w as i64, in_s.c as usize);
    let (oh, ow) = (out_s.h as usize, out_s.w as usize);
    let mut out = vec![0i32; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut best: Option<i32> = None;
                for ky in 0..k as usize {
                    for kx in 0..k as usize {
                        let y = (oy * stride as usize + ky) as i64 - pad as i64;
                        let x = (ox * stride as usize + kx) as i64 - pad as i64;
                        if y < 0 || y >= ih || x < 0 || x >= iw {
                            continue;
                        }
                        let v = inp[at(iw as usize, c, y as usize, x as usize, ch)];
                        best = Some(best.map_or(v, |b: i32| b.max(v)));
                    }
                }
                out[at(ow, c, oy, ox, ch)] = best.unwrap_or(0);
            }
        }
    }
    out
}

fn global_avg_pool(inp: &[i32], in_s: Shape) -> Vec<i32> {
    let (h, w, c) = (in_s.h as usize, in_s.w as usize, in_s.c as usize);
    let n = (h * w) as i64;
    (0..c)
        .map(|ch| {
            let sum: i64 = (0..h * w).map(|i| inp[i * c + ch] as i64).sum();
            (sum / n.max(1)) as i32
        })
        .collect()
}

fn fc(inp: &[i32], out_features: u32, e: &LayerExec) -> Vec<i32> {
    let n = inp.len();
    (0..out_features as usize)
        .map(|o| {
            let acc: i64 =
                inp.iter().zip(&e.weights[o * n..(o + 1) * n]).map(|(&x, &w)| x as i64 * w as i64).sum();
            requant(acc, e.shift, e.relu)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cifarnet_shape_and_determinism() {
        let m = ReferenceModel::cifarnet();
        assert_eq!(m.input_dims(), &[32, 32, 3]);
        let img: Vec<i32> = (0..32 * 32 * 3).map(|i| (i % 251) as i32 - 125).collect();
        let a = m.run_i32(&img, &[32, 32, 3]).unwrap();
        let b = m.run_i32(&img, &[32, 32, 3]).unwrap();
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| (-128..=127).contains(&v)), "int8-ranged logits: {a:?}");
    }

    #[test]
    fn cifarnet_distinguishes_inputs() {
        let m = ReferenceModel::cifarnet();
        let a = m.run_i32(&vec![1; 32 * 32 * 3], &[32, 32, 3]).unwrap();
        let b = m.run_i32(&vec![-7; 32 * 32 * 3], &[32, 32, 3]).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn input_clipping_matches_int8_boundary() {
        let m = ReferenceModel::cifarnet();
        let wide = m.run_i32(&vec![500; 32 * 32 * 3], &[32, 32, 3]).unwrap();
        let clipped = m.run_i32(&vec![127; 32 * 32 * 3], &[32, 32, 3]).unwrap();
        assert_eq!(wide, clipped);
    }

    #[test]
    fn resnet_block_output_is_post_relu() {
        let m = ReferenceModel::resnet_block();
        let x: Vec<i32> = (0..56 * 56 * 64).map(|i| (i % 9) as i32 - 4).collect();
        let y = m.run_i32(&x, &[56, 56, 64]).unwrap();
        assert_eq!(y.len(), 56 * 56 * 64);
        assert!(y.iter().all(|&v| (0..=127).contains(&v)), "post-ReLU range violated");
        assert!(y.iter().any(|&v| v > 0), "all-zero block output is suspicious");
    }

    #[test]
    fn mobilenet_edge_executes_deterministically() {
        let m = ReferenceModel::mobilenet_edge();
        assert_eq!(m.input_dims(), &[32, 32, 3]);
        let img: Vec<i32> = (0..32 * 32 * 3).map(|i| (i % 197) as i32 - 98).collect();
        let a = m.run_i32(&img, &[32, 32, 3]).unwrap();
        let b = m.run_i32(&img, &[32, 32, 3]).unwrap();
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| (-128..=127).contains(&v)), "int8-ranged logits: {a:?}");
        // the depthwise path must carry signal, not collapse to a constant
        let c = m.run_i32(&vec![33; 32 * 32 * 3], &[32, 32, 3]).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn every_builtin_has_input_dims_and_loads() {
        let b = ReferenceBackend::new();
        for name in BUILTIN_MODELS {
            let dims = builtin_input_dims(name).unwrap_or_else(|| panic!("{name} dims"));
            let m = b.load_model(Path::new("artifacts"), name).unwrap();
            let n: usize = dims.iter().product();
            let out = m.run_i32(&vec![1i32; n], &dims).unwrap();
            assert!(!out.is_empty(), "{name}");
        }
        assert!(builtin_input_dims("alexnet").is_none());
    }

    #[test]
    fn unknown_model_error_is_actionable() {
        let b = ReferenceBackend::new();
        let err = b.load_model(Path::new("artifacts"), "alexnet").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("alexnet") && msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn weights_are_deterministic_and_in_range() {
        // NOTE: this checks determinism within one build, not stability of
        // the generator across code changes — editing gen_weights (seeds,
        // RNG mapping) still silently shifts every serving test's
        // numerics. If downstream ever depends on exact outputs, pin
        // literal weight/logit values here.
        let m = ReferenceModel::cifarnet();
        let w = &m.execs[1].weights;
        assert_eq!(w.len(), 3 * 3 * 3 * 8);
        let again = ReferenceModel::cifarnet();
        assert_eq!(w, &again.execs[1].weights);
        assert!(w.iter().all(|&v| (-7..=7).contains(&v)));
        // weights must not be degenerate (all equal -> layers collapse)
        assert!(w.iter().any(|&v| v != w[0]));
    }
}
