//! # H2PIPE — layer-pipelined CNN inference with High-Bandwidth Memory
//!
//! Reproduction of *H2PIPE: High Throughput CNN Inference on FPGAs with
//! High-Bandwidth Memory* (Doumet, Stan, Hall, Betz — FPL 2024).
//!
//! The crate is organized as the Layer-3 (rust) part of a three-layer
//! rust + JAX + Pallas stack:
//!
//! * [`nn`] — CNN graph IR and the model zoo used in the paper
//!   (ResNet-18/50, VGG-16, MobileNetV1/2/3).
//! * [`hbm`] — a cycle-level HBM2 substrate: DRAM banks, pseudo-channel
//!   controllers, channel command-bus sharing, 4-Hi stacks, and the AXI
//!   traffic generator used for the paper's §III-A characterization.
//! * [`fabric`] — on-chip flow-control fabric: SCFIFOs, dual-clock FIFOs,
//!   ready/valid links (to reproduce the Fig. 5 deadlock) and the
//!   credit-based weight-distribution network that fixes it.
//! * [`compiler`] — the H2PIPE compiler: per-layer parallelism selection,
//!   the Eq. 1 offload score, Algorithm 1 layer selection, pseudo-channel
//!   assignment, burst-length policy and full resource accounting against
//!   the Stratix 10 NX2100 device model.
//! * [`sim`] — the cycle-level layer-pipelined dataflow simulator that
//!   stands in for the FPGA: layer engines with AI-TB semantics, activation
//!   line buffers, freeze-signal stalling, and end-to-end throughput /
//!   latency measurement.
//! * [`coordinator`] — the serving runtime: boot-time weight download
//!   through the §IV-C write path, request batching, and dispatch to both
//!   the timing model and the PJRT-executed AOT artifacts.
//! * [`cluster`] — multi-FPGA scale-out: the partition planner that cuts
//!   a network into pipeline-parallel shards, the fleet simulator that
//!   composes one pipeline sim per device through credit-based
//!   inter-device links, and the replica router for fleet serving.
//! * [`session`] — the typed end-to-end pipeline API:
//!   `Session::builder() -> CompiledModel -> Deployment -> RunReport`,
//!   with `CompiledModel` persistable as a JSON plan artifact
//!   (compile once, simulate/serve many).
//! * [`runtime`] — pluggable execution backends behind one [`runtime::Backend`]
//!   trait: a pure-Rust int8 reference interpreter (default, works in the
//!   offline crate set with no artifacts) and, behind the non-default
//!   `pjrt` feature, a PJRT CPU client that loads `artifacts/*.hlo.txt`
//!   produced by `python/compile/aot.py`.
//! * [`analysis`] — Eq. 2 memory-traffic bounds, the Fig. 6 theoretical
//!   upper bounds, the Table III prior-work dataset and report generation.
//! * [`obs`] — observability: the `Probe` hook wired through the cycle
//!   simulators, the windowed flight recorder, Chrome/Perfetto trace
//!   export (`simulate --trace`), and Prometheus metrics exposition for
//!   serving (`serve --metrics-port`).
//! * [`bench_harness`], [`testkit`], [`util`] — in-repo replacements for
//!   criterion / proptest / serde, which are unavailable in the offline
//!   crate set this build runs against.
//!
//! * [`faults`] — deterministic fault injection and recovery: the seeded
//!   `h2pipe.faults/v1` scenario artifact (`FaultPlan`), HBM read-error
//!   replay, thermal-throttle and link-stall windows, replica outages,
//!   and the conservation ledger (`FaultTotals`) proving nothing is
//!   silently lost (`simulate --faults` / `serve --faults`).
//! * [`tune`] — `h2pipe tune`: the parallel plan-space autotuner. A
//!   seeded evolutionary search over burst, FIFO-depth, sparsity,
//!   offload-override and fleet-cut decisions; every candidate compiles
//!   through the real session pipeline, must pass the verifier, and is
//!   scored by short cycle simulations on a deterministic worker pool.
//!   Emits the `h2pipe.tune/v1` Pareto report plus the winning plan as a
//!   replayable artifact.
//! * [`verify`] — `h2pipe check`: the static plan verifier. Re-derives
//!   every invariant the compiler assumes (resource budgets, per-PC HBM
//!   bandwidth, Fig. 5 deadlock freedom, Fig. 6 FIFO depth bounds,
//!   estimate/provenance consistency, fleet cut legality) over any plan
//!   artifact and reports structured `H2P0xx` diagnostics.
//!
//! See `DESIGN.md` for the experiment index mapping every paper table and
//! figure to a bench target, and `EXPERIMENTS.md` for measured results.

#![forbid(unsafe_code)]
#![warn(rust_2018_idioms, missing_debug_implementations)]

pub mod analysis;
pub mod bench_harness;
pub mod cluster;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod fabric;
pub mod faults;
pub mod hbm;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod testkit;
pub mod tune;
pub mod util;
pub mod verify;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
