//! Design-space exploration across the model zoo: burst length x memory
//! policy x write-path width — the §VI-A / §IV-C trade-off studies plus
//! the future-work NAS-style sweep suggested in §VII.
//!
//! Run with:  cargo run --release --example design_space

use h2pipe::compiler::compile;
use h2pipe::config::{BurstLengthPolicy, CompilerOptions, DeviceConfig};
use h2pipe::coordinator::boot_weights;
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};

fn main() -> anyhow::Result<()> {
    let device = DeviceConfig::stratix10_nx2100();
    let cfg = SimConfig { images: 4, warmup_images: 1, ..Default::default() };

    println!("=== burst length x memory policy (cycle-simulated) ===");
    println!(
        "{:<12} {:>7} {:>8} {:>9} {:>9} {:>8} {:>8}",
        "model", "policy", "burst", "im/s", "lat(ms)", "M20K%", "freeze"
    );
    for name in ["resnet18", "resnet50", "vgg16"] {
        let net = zoo::by_name(name).unwrap();
        for all_hbm in [false, true] {
            for bl in [8u32, 32] {
                let mut o = CompilerOptions::default();
                o.all_hbm = all_hbm;
                o.burst_length = BurstLengthPolicy::Fixed(bl);
                let plan = compile(&net, &device, &o)?;
                let rep = simulate(&net, &plan, &cfg)?;
                println!(
                    "{:<12} {:>7} {:>8} {:>9.0} {:>9.2} {:>7.0}% {:>8.4}",
                    name,
                    if all_hbm { "allHBM" } else { "hybrid" },
                    bl,
                    rep.throughput,
                    rep.latency * 1e3,
                    100.0 * plan.usage.m20k_frac(&device),
                    rep.freeze_fraction,
                );
            }
        }
    }

    println!("\n=== write-path width (boot time vs registers, VGG-16) ===");
    let net = zoo::vgg16();
    println!("{:>9} {:>10} {:>9}", "width(b)", "boot(ms)", "regs");
    for width in [16u32, 30, 64, 128, 256] {
        let mut o = CompilerOptions::default();
        o.write_path_bits = width;
        let plan = compile(&net, &device, &o)?;
        let r = boot_weights(&plan);
        println!("{width:>9} {:>10.1} {:>9}", r.seconds * 1e3, r.write_path_registers);
    }

    println!("\n=== §VII NAS-style sweep: per-layer chain cap (ResNet-50) ===");
    println!("{:>6} {:>9} {:>9} {:>7}", "cap", "im/s", "HBM lyrs", "M20K%");
    for cap in [4u32, 8, 16, 32, 64] {
        let mut o = CompilerOptions::default();
        o.max_chains_per_layer = cap;
        let net = zoo::resnet50();
        let plan = compile(&net, &device, &o)?;
        let rep = simulate(&net, &plan, &cfg)?;
        println!(
            "{cap:>6} {:>9.0} {:>9} {:>6.0}%",
            rep.throughput,
            plan.hbm_layers().count(),
            100.0 * plan.usage.m20k_frac(&device)
        );
    }
    Ok(())
}
