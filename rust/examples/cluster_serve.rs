//! Fleet serving driver (the cluster subsystem's E2E validation run).
//!
//! 1. Partitions ResNet-18 into two pipeline-parallel shards, each
//!    compiled as a standalone accelerator (offload decisions re-run per
//!    shard).
//! 2. Co-simulates the shards cycle-accurately — one pipeline sim per
//!    device, inter-device links as credit-based FIFOs — and reports the
//!    2-replica (shared-nothing) aggregate next to the per-replica rate.
//! 3. Serves real inference requests through the fleet router: two
//!    replica servers of the residual-free `mobilenet_edge` built-in,
//!    least-outstanding-requests routing, merged metrics emitted as JSON.
//!
//! Run with:  cargo run --release --example cluster_serve [-- <num_requests>]

use std::sync::Arc;

use h2pipe::cluster::{partition, FleetConfig, FleetRouter, FleetSim, PartitionOptions};
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::coordinator::ServerConfig;
use h2pipe::nn::zoo;
use h2pipe::util::XorShift64;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let device = DeviceConfig::stratix10_nx2100();
    let opts = CompilerOptions::default();

    // --- partition: two devices, offload re-planned per shard -----------
    let net = zoo::resnet18();
    let pp = partition(
        &net,
        &device,
        &opts,
        &PartitionOptions { shards: Some(2), max_shards: 2 },
    )?;
    print!("{}", pp.report());

    // --- fleet sim: credit-linked shards, 2 shared-nothing replicas ------
    let fleet = FleetSim::new(&pp)?;
    let two = fleet
        .run(&FleetConfig { images: 4, warmup_images: 1, replicas: 2, ..Default::default() })?;
    println!(
        "fleet sim: per replica {:.0} im/s, 2-replica aggregate {:.0} im/s (bottleneck shard {} / {})",
        two.per_replica_throughput,
        two.aggregate_throughput,
        two.bottleneck_shard,
        two.bottleneck_engine
    );
    assert!(
        two.aggregate_throughput >= 1.8 * two.per_replica_throughput,
        "replication must scale: {:.0} vs {:.0}",
        two.aggregate_throughput,
        two.per_replica_throughput
    );
    println!("{}", two.to_json().to_string());

    // --- fleet serving: 2 replicas behind the router ---------------------
    let mut cfg = ServerConfig::builtin("mobilenet_edge", "artifacts")?;
    cfg.batch_size = 8;
    cfg.modelled_image_s = 1.0 / pp.est_throughput();
    let router = Arc::new(FleetRouter::start(cfg, 2)?);
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let r = router.clone();
        let per_client = n_requests / 4;
        handles.push(std::thread::spawn(move || {
            let mut rng = XorShift64::new(500 + t);
            let mut ok = 0usize;
            for _ in 0..per_client {
                let img: Vec<i32> =
                    (0..32 * 32 * 3).map(|_| rng.next_range(0, 255) as i32 - 128).collect();
                if r.infer(img).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let mut total = 0usize;
    for h in handles {
        total += h.join().expect("client thread");
    }
    let rep = Arc::into_inner(router).expect("all clients done").shutdown();
    println!(
        "served {total} requests over {} replicas: wall {:.0} im/s, p99 {:.2} ms",
        rep.replicas, rep.wall_throughput, rep.p99_ms
    );
    println!("{}", rep.to_json().to_string());
    assert_eq!(rep.completed as usize, total);
    assert!(rep.per_replica.iter().all(|r| r.completed > 0), "both replicas must serve");
    println!("cluster serve OK");
    Ok(())
}
