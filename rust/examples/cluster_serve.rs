//! Fleet serving driver (the cluster subsystem's E2E validation run),
//! routed end-to-end through `h2pipe::session`.
//!
//! 1. Compiles ResNet-18 into a session artifact, then deploys it to the
//!    fleet target: two pipeline-parallel shards, each recompiled as a
//!    standalone accelerator, co-simulated cycle-accurately with
//!    credit-based inter-device links, 2 shared-nothing replicas.
//! 2. Deploys the same artifact to the serve target: two replica servers
//!    of the residual-free `mobilenet_edge` built-in behind the
//!    least-outstanding-requests router, with the modelled FPGA rate
//!    taken from the 2-shard partition, merged metrics emitted as JSON.
//!
//! Run with:  cargo run --release --example cluster_serve [-- <num_requests>]

use h2pipe::cluster::{FleetConfig, PartitionOptions};
use h2pipe::session::{DeploymentTarget, ServeOptions, Session};

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);

    // --- compile once ----------------------------------------------------
    let compiled = Session::builder().model("resnet18").compile()?;

    // --- fleet sim: credit-linked shards, 2 shared-nothing replicas ------
    let fleet = compiled
        .deploy(DeploymentTarget::Fleet {
            partition: PartitionOptions { shards: Some(2), max_shards: 2 },
            fleet: FleetConfig { images: 4, warmup_images: 1, replicas: 2, ..Default::default() },
        })
        .run()?;
    let per_replica = fleet
        .detail
        .get("per_replica_throughput")
        .and_then(|v| v.as_f64())
        .expect("fleet detail carries the per-replica rate");
    println!(
        "fleet sim: per replica {:.0} im/s, 2-replica aggregate {:.0} im/s (bottleneck shard {} / {})",
        per_replica,
        fleet.throughput,
        fleet.detail.get("bottleneck_shard").and_then(|v| v.as_u64()).unwrap_or(0),
        fleet.detail.get("bottleneck_engine").and_then(|v| v.as_str()).unwrap_or("?"),
    );
    assert!(
        fleet.throughput >= 1.8 * per_replica,
        "replication must scale: {:.0} vs {:.0}",
        fleet.throughput,
        per_replica
    );
    println!("{}", fleet.to_json().to_string());

    // --- fleet serving: 2 replicas behind the router ----------------------
    let rep = compiled
        .deploy(DeploymentTarget::Serve(ServeOptions {
            serve_model: "mobilenet_edge".to_string(),
            requests: n_requests,
            batch: 8,
            replicas: 2,
            shards: 2, // modelled FPGA rate from the 2-shard partition
            clients: 4,
            seed: 500,
            ..ServeOptions::default()
        }))
        .run()?;
    let detail = &rep.detail;
    let ok = detail.get("ok").and_then(|v| v.as_u64()).unwrap_or(0);
    let replicas = detail.get("replicas").and_then(|v| v.as_u64()).unwrap_or(0);
    println!(
        "served {ok} requests over {replicas} replicas: wall {:.0} im/s, mean {:.2} ms",
        rep.throughput, rep.latency_ms
    );
    println!("{}", rep.to_json().to_string());
    let completed = detail
        .get("metrics")
        .and_then(|m| m.get("completed"))
        .and_then(|v| v.as_u64())
        .unwrap_or(0);
    assert_eq!(completed, ok, "every accepted request accounted for");
    let per_replica_served = detail.get("per_replica").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(per_replica_served.len(), 2);
    assert!(
        per_replica_served
            .iter()
            .all(|r| r.get("completed").and_then(|v| v.as_u64()).unwrap_or(0) > 0),
        "both replicas must serve"
    );
    println!("cluster serve OK");
    Ok(())
}
