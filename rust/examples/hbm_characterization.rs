//! The §III-A characterization instrument as a standalone tool.
//!
//! Sweeps address patterns and burst lengths against the simulated HBM2
//! pseudo-channel and prints the Fig. 3a/3b data, plus the §III-B
//! three-chain interleaving check that justifies sharing one PC between
//! three tensor chains.
//!
//! Run with:  cargo run --release --example hbm_characterization

use h2pipe::config::DeviceConfig;
use h2pipe::hbm::{AddressPattern, TrafficConfig, TrafficGen};

fn main() {
    let device = DeviceConfig::stratix10_nx2100();
    let gen = TrafficGen::new(&device);
    println!(
        "HBM2 pseudo-channel: {}-bit @ {} MHz, peak {:.1} GB/s",
        device.hbm.interface_bits,
        device.hbm.controller_mhz,
        device.hbm.pc_peak_bw() / 1e9
    );

    for pattern in [AddressPattern::Random, AddressPattern::Sequential, AddressPattern::Interleaved(3)]
    {
        println!("\n--- pattern {pattern:?} ---");
        println!(
            "{:>4} {:>9} {:>9} {:>10} {:>10} {:>10} {:>10}",
            "BL", "read_eff", "write_eff", "lat_min", "lat_avg", "lat_max", "read GB/s"
        );
        for bl in [1u32, 2, 4, 8, 16, 32] {
            let r = gen.run(&TrafficConfig::new(pattern, bl));
            println!(
                "{bl:>4} {:>9.3} {:>9.3} {:>8.0}ns {:>8.0}ns {:>8.0}ns {:>10.2}",
                r.read_efficiency,
                r.write_efficiency,
                r.read_lat_min_ns,
                r.read_lat_avg_ns,
                r.read_lat_max_ns,
                r.read_efficiency * device.hbm.pc_peak_bw() / 1e9,
            );
        }
    }

    // §III-B: can one PC sustain 3 tensor chains?
    println!("\n--- §III-B provisioning: 3 chains x 80 bit @ 300 MHz = 9.0 GB/s demand per PC ---");
    for bl in [8u32, 16, 32] {
        let bw = gen.interleaved_read_bw(3, bl);
        let demand = 3.0 * 80.0 / 8.0 * device.core_mhz as f64 * 1e6;
        println!(
            "BL{bl:<2}: interleaved-3 sustained {:.2} GB/s vs demand {:.2} GB/s -> {}",
            bw / 1e9,
            demand / 1e9,
            if bw >= demand { "OK" } else { "INSUFFICIENT" }
        );
    }
}
