//! Quickstart: compile ResNet-18 for the NX2100, inspect the hybrid
//! memory plan, run the cycle simulator, then execute a real AOT-compiled
//! CNN artifact through the PJRT runtime — the full L1→L3 path.
//!
//! Run with:  cargo run --release --example quickstart

use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig, WeightPlacement};
use h2pipe::nn::zoo;
use h2pipe::runtime::Runtime;
use h2pipe::sim::pipeline::{simulate, SimConfig};

fn main() -> anyhow::Result<()> {
    // --- 1. Compile a network for the paper's device -------------------
    // ResNet-50: 219 Mb of weights vs 140 Mb of BRAM — the compiler MUST
    // build a hybrid memory system (Table I shading).
    let device = DeviceConfig::stratix10_nx2100();
    let net = zoo::resnet50();
    let opts = CompilerOptions::default();
    let plan = compile(&net, &device, &opts)?;

    println!("device: {} ({} M20K, {} AI-TBs)", device.name, device.m20k_blocks, device.tensor_blocks);
    println!(
        "{}: {} weight layers, {} offloaded to HBM, burst length {}",
        net.name,
        plan.layers.iter().filter(|l| l.stats.has_weights).count(),
        plan.hbm_layers().count(),
        plan.burst_len
    );
    println!(
        "resources: M20K {:.0}%  AI-TB {:.0}%  ALM {:.0}%",
        100.0 * plan.usage.m20k_frac(&device),
        100.0 * plan.usage.tb_frac(&device),
        100.0 * plan.usage.alm_frac(&device)
    );
    // top-3 offload decisions by Eq. 1 score
    let mut scored: Vec<_> = plan.hbm_layers().collect();
    scored.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    for l in scored.iter().take(3) {
        println!(
            "  offloaded {:20} score {:8.1}  PCs {:?}",
            l.stats.name, l.score, l.pcs
        );
    }
    assert!(plan.layers.iter().any(|l| l.placement == WeightPlacement::Hbm));

    // --- 2. Simulate the accelerator -----------------------------------
    let rep = simulate(&net, &plan, &SimConfig { images: 4, warmup_images: 1, ..Default::default() })?;
    println!(
        "simulated: {:.0} im/s, latency {:.2} ms (paper hybrid hw: 1004 im/s, 9.48 ms)",
        rep.throughput,
        rep.latency * 1e3
    );

    // --- 3. Execute a functional CNN through the runtime backend -------
    // Default build: the pure-Rust int8 reference interpreter (works with
    // no artifacts). With `--features pjrt` + `make artifacts`: the real
    // JAX/Pallas-authored AOT artifact through the PJRT CPU client.
    let rt = Runtime::cpu("artifacts")?;
    println!("runtime backend: {} ({})", rt.backend_name(), rt.platform());
    let exe = rt.load("cifarnet")?;
    let img: Vec<i32> = (0..32 * 32 * 3).map(|i| (i % 256) as i32 - 128).collect();
    let logits = exe.run_i32(&img, &[32, 32, 3])?;
    let best = logits.iter().enumerate().max_by_key(|(_, &v)| v).unwrap();
    println!("cifarnet logits: {logits:?} -> class {}", best.0);

    println!("quickstart OK");
    Ok(())
}
