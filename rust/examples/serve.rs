//! End-to-end serving driver (the repository's E2E validation run).
//!
//! Exercises the whole `h2pipe::session` pipeline: builder → compiled
//! artifact (with a JSON round-trip through a temp file, proving the
//! persisted plan drives the same deployment) → boot → single-device
//! cycle sim → live serving through the coordinator. Numerics come from
//! the reference backend (or the AOT-compiled PJRT artifact with
//! `--features pjrt`); timing comes from both wall clock and the modelled
//! FPGA pipeline. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run with:  cargo run --release --example serve [-- <num_requests>]

use h2pipe::session::{CompiledModel, DeploymentTarget, ServeOptions, Session};
use h2pipe::sim::pipeline::SimConfig;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);

    // --- compile stage: model -> persistable artifact --------------------
    let compiled = Session::builder().model("resnet18").compile()?;
    let plan_path = std::env::temp_dir().join(format!("h2pipe-serve-{}.json", std::process::id()));
    compiled.save(&plan_path)?;
    let compiled = CompiledModel::load(&plan_path)?; // the artifact drives everything below
    println!(
        "compiled {} for {} (options {:016x}), artifact at {}",
        compiled.provenance().model,
        compiled.provenance().device,
        compiled.provenance().options_hash,
        plan_path.display()
    );

    // --- boot: weight download through the §IV-C write path --------------
    let boot = compiled.boot();
    println!(
        "boot: {} MiB of weights -> HBM over the {}-bit write path in {:.1} ms (write eff {:.2})",
        boot.bytes >> 20,
        boot.write_path_bits,
        boot.seconds * 1e3,
        boot.hbm_write_efficiency
    );

    // --- modelled FPGA timing from the cycle simulator -------------------
    let sim = compiled
        .deploy(DeploymentTarget::SingleDevice(SimConfig {
            images: 4,
            warmup_images: 1,
            ..Default::default()
        }))
        .run()?;
    println!(
        "modelled FPGA pipeline ({}): {:.0} im/s, {:.2} ms latency",
        sim.model, sim.throughput, sim.latency_ms
    );

    // --- serve real inference requests -----------------------------------
    // modelled service time: prefer the cycle sim's measured rate over the
    // plan's analytic estimate
    let rep = compiled
        .deploy(DeploymentTarget::Serve(ServeOptions {
            serve_model: "cifarnet".to_string(),
            requests: n_requests,
            batch: 16,
            clients: 4,
            seed: 100,
            modelled_image_s: Some(1.0 / sim.throughput),
            ..ServeOptions::default()
        }))
        .run()?;

    let detail = &rep.detail;
    let ok = detail.get("ok").and_then(|v| v.as_u64()).unwrap_or(0);
    let submitted = detail.get("submitted").and_then(|v| v.as_u64()).unwrap_or(0);
    println!("served {ok}/{submitted} requests from 4 concurrent clients");
    println!("{}", rep.summary());
    println!("{}", rep.to_json().to_string());
    let completed = detail
        .get("metrics")
        .and_then(|m| m.get("completed"))
        .and_then(|v| v.as_u64())
        .unwrap_or(0);
    assert_eq!(completed, ok, "router metrics must match client-side count");
    let modelled = detail
        .get("modelled_throughput_rps")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    assert!(
        (modelled - sim.throughput).abs() < 1.0,
        "modelled rate {modelled:.0} must come from the cycle sim ({:.0})",
        sim.throughput
    );
    let _ = std::fs::remove_file(&plan_path);
    println!("serve OK");
    Ok(())
}
