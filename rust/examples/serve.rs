//! End-to-end serving driver (the repository's E2E validation run).
//!
//! Boots the accelerator — weight download through the §IV-C write path —
//! then serves a stream of batched inference requests through the L3
//! coordinator: numerics come from the AOT-compiled PJRT artifact
//! (JAX + Pallas int8 CNN, Python not involved at runtime), timing comes
//! from both wall clock and the modelled FPGA pipeline. Results are
//! recorded in EXPERIMENTS.md §E2E.
//!
//! Run with:  cargo run --release --example serve [-- <num_requests>]

use std::sync::Arc;

use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::coordinator::{boot_weights, InferenceServer, ServerConfig};
use h2pipe::nn::zoo;
use h2pipe::sim::pipeline::{simulate, SimConfig};
use h2pipe::util::XorShift64;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let device = DeviceConfig::stratix10_nx2100();

    // --- boot: compile the plan + download weights ----------------------
    let net = zoo::resnet18();
    let plan = compile(&net, &device, &CompilerOptions::default())?;
    let boot = boot_weights(&plan);
    println!(
        "boot: {} MiB of weights -> HBM over the {}-bit write path in {:.1} ms (write eff {:.2})",
        boot.bytes >> 20,
        boot.write_path_bits,
        boot.seconds * 1e3,
        boot.hbm_write_efficiency
    );

    // --- modelled FPGA timing from the cycle simulator ------------------
    let sim = simulate(&net, &plan, &SimConfig { images: 4, warmup_images: 1, ..Default::default() })?;
    println!(
        "modelled FPGA pipeline ({}): {:.0} im/s, {:.2} ms latency",
        net.name,
        sim.throughput,
        sim.latency * 1e3
    );

    // --- serve real inference requests ----------------------------------
    let mut cfg = ServerConfig::cifarnet("artifacts");
    cfg.batch_size = 16;
    // modelled service time: prefer the cycle sim's measured rate over
    // the plan estimate (`with_modelled_plan` is the analytic shortcut)
    cfg.modelled_image_s = 1.0 / sim.throughput;
    let srv = Arc::new(InferenceServer::start(cfg)?);

    // 4 closed-loop clients
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let s = srv.clone();
        let per_client = n_requests / 4;
        handles.push(std::thread::spawn(move || {
            let mut rng = XorShift64::new(100 + t);
            let mut ok = 0usize;
            for _ in 0..per_client {
                let img: Vec<i32> =
                    (0..32 * 32 * 3).map(|_| rng.next_range(0, 255) as i32 - 128).collect();
                if s.infer(img).is_ok() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let mut total = 0usize;
    for h in handles {
        total += h.join().expect("client thread");
    }
    let rep = Arc::into_inner(srv).expect("all clients done").shutdown();

    println!("served {total} requests from 4 concurrent clients");
    println!(
        "wall:     {:.0} im/s   mean {:.2} ms   p50 {:.2} ms   p99 {:.2} ms   mean batch {:.1}",
        rep.wall_throughput, rep.mean_latency_ms, rep.p50_ms, rep.p99_ms, rep.mean_batch
    );
    println!(
        "modelled: {:.0} im/s on the simulated Stratix 10 NX + HBM2 pipeline",
        rep.modelled_throughput
    );
    assert_eq!(rep.completed as usize, total);
    println!("serve OK");
    Ok(())
}
