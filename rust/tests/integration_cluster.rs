//! Integration: the multi-FPGA cluster subsystem (ISSUE 2 acceptance).
//!
//! (a) a ResNet-50-class plan that exceeds one device's M20K budget
//!     partitions into >= 2 shards that each fit;
//! (b) fleet-sim aggregate throughput with 2 replicas is >= 1.8x a
//!     single replica on the same workload;
//! (c) shard-to-shard credit back-pressure stalls the upstream shard
//!     instead of dropping data.

use h2pipe::cluster::{
    partition, partition_at, FleetConfig, FleetRouter, FleetSim, PartitionOptions,
};
use h2pipe::compiler::compile;
use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::coordinator::ServerConfig;
use h2pipe::nn::zoo;

fn quick_fleet() -> FleetConfig {
    FleetConfig { images: 3, warmup_images: 1, ..Default::default() }
}

#[test]
fn oversized_resnet50_partitions_into_fitting_shards() {
    // (a): shrink the device's M20K budget until even maximal HBM offload
    // cannot fit ResNet-50 on one chip, then partition.
    let o = CompilerOptions::default();
    let net = zoo::resnet50();
    let mut constrained = None;
    for m20k in [3400u32, 3200, 3000, 2800, 2600, 2400, 2200, 2000] {
        let mut d = DeviceConfig::stratix10_nx2100();
        d.m20k_blocks = m20k;
        d.name = format!("NX2100/{m20k}-M20K");
        if compile(&net, &d, &o).is_err() {
            constrained = Some(d);
            break;
        }
    }
    let d = constrained.expect("ResNet-50 must overflow a sufficiently small M20K budget");

    let pp = partition(&net, &d, &o, &PartitionOptions::default()).unwrap();
    assert!(pp.num_shards() >= 2, "one device cannot hold the plan: {}", pp.num_shards());
    // every shard fits the constrained device on its own
    for sh in &pp.shards {
        assert!(
            sh.plan.usage.m20k <= d.m20k_blocks as u64,
            "shard {}..{}: {} M20K > budget {}",
            sh.first_layer,
            sh.last_layer,
            sh.plan.usage.m20k,
            d.m20k_blocks
        );
    }
    // coverage: contiguous and complete over the original network
    assert_eq!(pp.shards[0].first_layer, 1);
    assert_eq!(pp.shards.last().unwrap().last_layer, net.len() - 1);
    for w in pp.shards.windows(2) {
        assert_eq!(w[1].first_layer, w[0].last_layer + 1);
        assert_eq!(w[1].net.input_shape(), w[0].net.layers().last().unwrap().out);
    }
}

#[test]
fn two_replicas_scale_aggregate_throughput() {
    // (b): replicas share no simulated hardware, so the fleet model
    // scales one cycle-accurate replica run exactly N-fold — 2 replicas
    // must report >= 1.8x one replica on the same sharded workload.
    let d = DeviceConfig::stratix10_nx2100();
    let o = CompilerOptions::default();
    let pp = partition(
        &zoo::resnet18(),
        &d,
        &o,
        &PartitionOptions { shards: Some(2), max_shards: 2 },
    )
    .unwrap();
    let fleet = FleetSim::new(&pp).unwrap();
    let base = quick_fleet();
    let one = fleet.run(&base).unwrap();
    let two = fleet.run(&FleetConfig { replicas: 2, ..base }).unwrap();
    assert!(one.aggregate_throughput > 0.0);
    assert!(
        two.aggregate_throughput >= 1.8 * one.aggregate_throughput,
        "2 replicas {:.0} im/s vs 1 replica {:.0} im/s",
        two.aggregate_throughput,
        one.aggregate_throughput
    );
    assert_eq!(two.replicas, 2);
    assert_eq!(two.shards, 2);
}

#[test]
fn credit_backpressure_stalls_upstream_without_loss() {
    // (c): a deliberately unbalanced cut — a tiny fast front shard (stem
    // only) feeding the heavy rest of the network over a 2-line credit
    // window. The upstream sink must block on credit, and every boundary
    // line must still arrive downstream.
    let d = DeviceConfig::stratix10_nx2100();
    let o = CompilerOptions::default();
    let net = zoo::resnet18();
    // layers: 0 input, 1 conv1, 2 maxpool | 3.. residual stages
    let pp = partition_at(&net, &d, &o, &[3]).unwrap();
    assert_eq!(pp.num_shards(), 2);
    let fleet = FleetSim::new(&pp).unwrap();
    let cfg = FleetConfig { link_capacity_lines: 2, ..quick_fleet() };
    let rep = fleet.run(&cfg).unwrap();

    let link = &rep.links[0];
    assert!(
        link.upstream_blocked > 0,
        "fast upstream shard must stall on the 2-line credit window"
    );
    assert!(
        link.peak_occupancy <= cfg.link_capacity_lines as u64,
        "link occupancy {} exceeded the credit window",
        link.peak_occupancy
    );
    // conservation: every boundary line of every image crossed the link
    let boundary_h = pp.shards[0].net.layers().last().unwrap().out.h as u64;
    assert_eq!(link.lines, cfg.images * boundary_h, "lines dropped or duplicated");
    assert!(rep.aggregate_throughput > 0.0, "pipeline must still complete");
}

#[test]
fn fleet_router_serves_sharded_model_replicas() {
    // End-to-end serving over the cluster path: the modelled rate comes
    // from a sharded partition plan, requests flow through 2 replicas of
    // the residual-free built-in model.
    let d = DeviceConfig::stratix10_nx2100();
    let o = CompilerOptions::default();
    let pp = partition(
        &zoo::resnet18(),
        &d,
        &o,
        &PartitionOptions { shards: Some(2), max_shards: 2 },
    )
    .unwrap();
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let mut cfg = ServerConfig::builtin("mobilenet_edge", &dir).unwrap();
    cfg.modelled_image_s = 1.0 / pp.est_throughput();
    let router = FleetRouter::start(cfg, 2).unwrap();
    let img = vec![5i32; 32 * 32 * 3];
    for _ in 0..8 {
        let out = router.infer(img.clone()).unwrap();
        assert_eq!(out.len(), 10);
    }
    let rep = router.shutdown();
    assert_eq!(rep.completed, 8);
    assert_eq!(rep.rejected, 0);
    assert!(rep.per_replica.iter().all(|r| r.completed > 0), "both replicas must serve");
    assert!(rep.modelled_throughput > 0.0, "sharded modelled rate must be wired through");
    let json = rep.to_json().to_string();
    assert!(json.contains("\"replicas\":2"), "{json}");
}
