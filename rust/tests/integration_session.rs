//! Integration: the `h2pipe::session` pipeline and its persistable plan
//! artifacts.
//!
//! The central claim: a `CompiledModel` saved to JSON and loaded back is
//! indistinguishable from the in-memory one — same serialized bytes, same
//! offload decisions, and an *identical* `RunReport` from the cycle
//! simulator — for all three zoo models the issue names. That is what
//! makes `h2pipe compile --out plan.json && h2pipe simulate --plan
//! plan.json` a faithful replay of `h2pipe simulate --model ...`.

use std::path::PathBuf;

use h2pipe::session::{CompiledModel, DeploymentTarget, ServeOptions, Session};
use h2pipe::sim::pipeline::SimConfig;
use h2pipe::testkit;

const ROUND_TRIP_MODELS: [&str; 3] = ["resnet50", "vgg16", "mobilenet_edge"];

fn quick() -> SimConfig {
    SimConfig { images: 3, warmup_images: 1, ..SimConfig::default() }
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("h2pipe-{tag}-{}.json", std::process::id()))
}

#[test]
fn artifact_round_trip_produces_identical_run_report() {
    for model in ROUND_TRIP_MODELS {
        let cm = Session::builder().model(model).compile().unwrap();
        let path = tmp_path(&format!("rt-{model}"));
        cm.save(&path).unwrap();
        let loaded = CompiledModel::load(&path).unwrap();

        // the artifact decodes to the same plan, bit for bit
        assert_eq!(
            loaded.to_json().to_string(),
            cm.to_json().to_string(),
            "{model}: save/load/save must be byte-stable"
        );
        assert_eq!(loaded.offload_fingerprint(), cm.offload_fingerprint(), "{model}");
        assert_eq!(loaded.provenance(), cm.provenance(), "{model}");

        // ...and the loaded plan drives an identical simulation report
        let direct =
            cm.deploy(DeploymentTarget::SingleDevice(quick())).run().unwrap();
        let replayed =
            loaded.deploy(DeploymentTarget::SingleDevice(quick())).run().unwrap();
        assert_eq!(
            replayed.to_json().to_string(),
            direct.to_json().to_string(),
            "{model}: plan-file replay must reproduce the in-memory report exactly"
        );
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn artifact_file_is_byte_stable_across_saves() {
    let cm = Session::builder().model("resnet50").compile().unwrap();
    let a = tmp_path("stable-a");
    let b = tmp_path("stable-b");
    cm.save(&a).unwrap();
    CompiledModel::load(&a).unwrap().save(&b).unwrap();
    assert_eq!(
        std::fs::read_to_string(&a).unwrap(),
        std::fs::read_to_string(&b).unwrap(),
        "artifacts are diffable: identical plans serialize identically"
    );
    std::fs::remove_file(&a).unwrap();
    std::fs::remove_file(&b).unwrap();
}

#[test]
fn golden_offload_decisions_per_model() {
    // Pin Algorithm 1's per-layer placement for the three artifact models.
    // The golden files live under tests/golden/; a behaviour change shows
    // up as a readable diff (re-bless with H2PIPE_BLESS=1 when intended).
    for model in ROUND_TRIP_MODELS {
        let cm = Session::builder().model(model).compile().unwrap();
        let path = PathBuf::from(format!(
            "{}/tests/golden/offload_{model}.txt",
            env!("CARGO_MANIFEST_DIR")
        ));
        testkit::golden(&path, &cm.offload_fingerprint())
            .unwrap_or_else(|e| panic!("{model}: {e}"));
    }
}

#[test]
fn offload_shape_matches_table1_expectations() {
    // Independent of the golden files: R50 and VGG-16 exceed on-chip BRAM
    // and must offload; mobilenet_edge fits and must not.
    let hbm_count = |model: &str| {
        Session::builder().model(model).compile().unwrap().plan().hbm_layers().count()
    };
    assert!(hbm_count("resnet50") > 0, "ResNet-50 must offload");
    assert!(hbm_count("vgg16") > 0, "VGG-16 must offload");
    assert_eq!(hbm_count("mobilenet_edge"), 0, "mobilenet_edge fits on chip");
}

#[test]
fn loaded_plan_drives_serving() {
    // The artifact also feeds the serve target: modelled rate comes from
    // the persisted plan, requests flow through the replica router.
    let cm = Session::builder().model("resnet50").compile().unwrap();
    let path = tmp_path("serve");
    cm.save(&path).unwrap();
    let loaded = CompiledModel::load(&path).unwrap();
    let rep = loaded
        .deploy(DeploymentTarget::Serve(ServeOptions {
            serve_model: "mobilenet_edge".to_string(),
            requests: 8,
            batch: 4,
            ..ServeOptions::default()
        }))
        .run()
        .unwrap();
    assert_eq!(rep.target, "serve");
    let ok = rep.detail.get("ok").and_then(|v| v.as_u64()).unwrap();
    assert_eq!(ok, 8, "all requests must complete");
    let modelled = rep
        .detail
        .get("modelled_throughput_rps")
        .and_then(|v| v.as_f64())
        .unwrap();
    assert!(
        (modelled - cm.plan().est_throughput).abs() < 1.0,
        "modelled rate {modelled:.0} must come from the persisted plan ({:.0})",
        cm.plan().est_throughput
    );
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn corrupt_artifacts_are_rejected_not_misread() {
    let cm = Session::builder().model("mobilenet_edge").compile().unwrap();
    let path = tmp_path("corrupt");
    cm.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();

    // truncated file
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert!(CompiledModel::load(&path).is_err(), "truncated artifact must not load");

    // plausible-looking edit that breaks integrity (resource usage)
    let tampered = text.replacen("\"m20k\":", "\"m20k_x\":", 1);
    assert_ne!(tampered, text, "fixture must actually change the document");
    std::fs::write(&path, tampered).unwrap();
    assert!(CompiledModel::load(&path).is_err(), "tampered artifact must not load");

    std::fs::remove_file(&path).unwrap();
}

#[test]
fn fleet_deployment_from_artifact() {
    // Shard the persisted ResNet-18 plan across two devices and co-sim.
    let cm = Session::builder().model("resnet18").compile().unwrap();
    let path = tmp_path("fleet");
    cm.save(&path).unwrap();
    let loaded = CompiledModel::load(&path).unwrap();
    let rep = loaded
        .deploy(DeploymentTarget::Fleet {
            partition: h2pipe::cluster::PartitionOptions { shards: Some(2), max_shards: 2 },
            fleet: h2pipe::cluster::FleetConfig {
                images: 3,
                warmup_images: 1,
                ..Default::default()
            },
        })
        .run()
        .unwrap();
    assert_eq!(rep.target, "fleet");
    assert!(rep.throughput > 0.0);
    assert_eq!(rep.detail.get("shards").and_then(|v| v.as_u64()), Some(2));
    std::fs::remove_file(&path).unwrap();
}
