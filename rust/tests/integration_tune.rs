//! Integration: the `h2pipe::tune` autotuner (ISSUE 9 acceptance).
//!
//! (a) same-seed runs produce byte-identical tune reports (Pareto front
//!     included) at any worker count;
//! (b) every Pareto-front genome recompiles into a plan that passes the
//!     static verifier at `--deny warn` — the legality gate really was
//!     hard;
//! (c) the winner's simulated throughput is at least the default plan's
//!     on a zoo model, verified by an independent simulation;
//! (d) the `h2pipe.tune/v1` artifact round-trips byte-stably through
//!     disk and rejects foreign format tags;
//! (e) the default sweep includes resnet18.

use h2pipe::config::{CompilerOptions, DeviceConfig};
use h2pipe::nn::zoo;
use h2pipe::session::Session;
use h2pipe::sim::pipeline::SimConfig;
use h2pipe::tune::{tune_model, TuneOptions, TuneReport, DEFAULT_SWEEP};
use h2pipe::util::Json;
use h2pipe::verify::Severity;

fn device() -> DeviceConfig {
    DeviceConfig::stratix10_nx2100()
}

fn quick(budget: u32, seed: u64, workers: usize) -> TuneOptions {
    TuneOptions { budget, seed, sim_images: 3, workers, shards: 1 }
}

#[test]
fn same_seed_same_report_at_any_worker_count() {
    let a = tune_model("resnet18", &device(), &quick(6, 42, 1)).unwrap();
    let b = tune_model("resnet18", &device(), &quick(6, 42, 3)).unwrap();
    assert_eq!(
        a.report.to_json().to_pretty(),
        b.report.to_json().to_pretty(),
        "same seed must be byte-identical regardless of worker count"
    );
    // and the winning artifacts agree
    let pa = a.winner.unwrap();
    let pb = b.winner.unwrap();
    assert_eq!(pa.to_json().to_pretty(), pb.to_json().to_pretty());

    // a different seed may search differently — the report must at least
    // record the seed it used
    let c = tune_model("resnet18", &device(), &quick(6, 43, 1)).unwrap();
    assert_eq!(c.report.seed, 43);
}

#[test]
fn every_pareto_genome_passes_the_verifier() {
    let out = tune_model("resnet18", &device(), &quick(8, 7, 2)).unwrap();
    let base = CompilerOptions::default();
    assert!(!out.report.pareto.is_empty());
    for &id in &out.report.pareto {
        let cand = &out.report.candidates[id as usize];
        assert_eq!(cand.outcome, "pareto");
        let cm = Session::builder()
            .network(zoo::resnet18())
            .device(device())
            .options(cand.genome.apply(&base))
            .compile()
            .unwrap_or_else(|e| panic!("front candidate {id} must recompile: {e:#}"));
        let report = cm.verify();
        assert!(
            !report.denies(Severity::Warn),
            "front candidate {id} fails `check --deny warn`:\n{}",
            report.render()
        );
    }
    // rejected candidates carry their verifier codes for the record
    for cand in &out.report.candidates {
        if cand.outcome == "rejected" {
            assert!(!cand.detail.is_empty(), "rejected candidate {} lost its codes", cand.id);
        }
    }
}

#[test]
fn winner_beats_or_matches_the_default_plan() {
    let out = tune_model("resnet18", &device(), &quick(8, 7, 2)).unwrap();
    let winner_id = out.report.winner.expect("a feasible baseline guarantees a winner");
    let winner = &out.report.candidates[winner_id as usize];

    // independent simulation of the default plan with the same config
    let cfg = SimConfig { images: 3, warmup_images: 1, ..SimConfig::default() };
    let default_cm = Session::builder().model("resnet18").device(device()).compile().unwrap();
    let default_sim = default_cm.simulate(&cfg).unwrap();
    assert!(
        winner.throughput >= default_sim.throughput,
        "winner {} im/s must not lose to the default {} im/s",
        winner.throughput,
        default_sim.throughput
    );

    // the emitted artifact replays to exactly the reported score
    let cm = out.winner.expect("single-device run emits the winning plan");
    let replay = cm.simulate(&cfg).unwrap();
    assert_eq!(
        replay.throughput.to_bits(),
        winner.throughput.to_bits(),
        "saved artifact must reproduce the reported winner score"
    );
}

#[test]
fn tune_report_round_trips_byte_stably() {
    let out = tune_model("resnet18", &device(), &quick(5, 11, 2)).unwrap();
    let path = std::env::temp_dir().join(format!("h2pipe-tune-rt-{}.json", std::process::id()));
    out.report.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let back = TuneReport::load(&path).unwrap();
    assert_eq!(back.to_json().to_pretty(), text, "disk round trip must be byte-identical");
    assert_eq!(back.winner, out.report.winner);
    assert_eq!(back.counters, out.report.counters);

    // foreign format tags are refused
    let mut j = Json::parse(&text).unwrap();
    j.set("format", "h2pipe.tune/v2");
    let err = TuneReport::from_json(&j).unwrap_err();
    assert!(format!("{err:#}").contains("unsupported tune format"), "{err:#}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn default_sweep_includes_resnet18() {
    assert!(DEFAULT_SWEEP.contains(&"resnet18"));
}
